//! Table 1 — RHT vs RFFT incoherence processing, 2-bit QuIP# (no FT).
//! Reproduced shape: Fourier ≈ Hadamard, slightly worse.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::experiments::{Runner, WINDOW_NATIVE};
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;
    let sizes: Vec<&str> = if args.has_flag("small") {
        vec!["s"]
    } else {
        vec!["s", "m", "l"]
    };

    println!("== Table 1: RHT vs RFFT, 2-bit QuIP# (no FT), w2 ppl ==\n");
    let mut header = vec!["incoherence".to_string()];
    header.extend(sizes.iter().map(|s| s.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);

    let mut rht = vec!["hadamard".to_string()];
    let mut rfft = vec!["fourier".to_string()];
    for s in &sizes {
        rht.push(format!(
            "{:.3}",
            runner.ppl(s, &Method::QuipSharp { bits: 2, ft: false }, "w2", WINDOW_NATIVE)?
        ));
        rfft.push(format!(
            "{:.3}",
            runner.ppl(s, &Method::QuipSharpRfft { bits: 2 }, "w2", WINDOW_NATIVE)?
        ));
    }
    t.row(&rht);
    t.row(&rfft);
    t.print();
    t.write_csv("table1_rht_vs_rfft")?;

    // Both must be in the same quality class (paper: RFFT "performs
    // slightly worse than the RHT but still achieves strong results").
    // At our model scale a single random sign/phase draw moves 2-bit ppl
    // by tens of percent, so the check is a class check: within 2× on
    // every size and geometric-mean ratio within [0.6, 1.5].
    let mut log_ratio = 0.0;
    for s in &sizes {
        let a = runner.ppl(s, &Method::QuipSharp { bits: 2, ft: false }, "w2", WINDOW_NATIVE)?;
        let b = runner.ppl(s, &Method::QuipSharpRfft { bits: 2 }, "w2", WINDOW_NATIVE)?;
        assert!(
            b / a < 2.0 && a / b < 2.0,
            "{s}: RHT {a} vs RFFT {b} not in the same quality class"
        );
        log_ratio += (b / a).ln();
    }
    let geo = (log_ratio / sizes.len() as f64).exp();
    println!("\ngeomean RFFT/RHT ppl ratio: {geo:.3} (paper: slightly above 1.0)");
    assert!((0.6..1.5).contains(&geo), "geomean ratio {geo} out of class");
    println!("assertion holds: RFFT in the same quality class as RHT (Table 1 shape)");
    Ok(())
}
