//! Table 7 — codebook comparison at ~2 bits on the largest model:
//! E8P vs E8-lattice-2.37-bit vs D4 (2 / 2.21) vs 8-D k-means.
//! Reproduced shape: E8P best among the 2-bit entries; the 2.37-bit E8
//! ball wins overall (more bits); D4 and k-means trail E8P.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::experiments::{Runner, WINDOW_NATIVE};
use quipsharp::quant::pipeline::{Method, SwapCodebook};
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;
    let size = args.get_or("size", if args.has_flag("small") { "s" } else { "l" }).to_string();

    println!("== Table 7: codebook swaps on '{size}' (no FT) ==\n");
    let rows: Vec<(&str, Method)> = vec![
        ("fp16", Method::Fp16),
        ("e8p (2 bit)", Method::QuipSharp { bits: 2, ft: false }),
        ("e8 lattice (2.37 bit)", Method::CodebookSwap { cb: SwapCodebook::E8TwoThirtySeven }),
        ("d4 (2 bit)", Method::CodebookSwap { cb: SwapCodebook::D4Two }),
        ("d4 (2.21 bit)", Method::CodebookSwap { cb: SwapCodebook::D4TwoTwentyOne }),
        ("kmeans 8d (2 bit)", Method::CodebookSwap { cb: SwapCodebook::KMeansTwo }),
    ];

    let mut t = Table::new(&["codebook", "code bits", "w2 ppl", "c4 ppl", "proxy rel"]);
    for (label, m) in &rows {
        let bits = runner.bits(&size, m)?;
        let w2 = runner.ppl(&size, m, "w2", WINDOW_NATIVE)?;
        let c4 = runner.ppl(&size, m, "c4", WINDOW_NATIVE)?;
        let proxy = if matches!(m, Method::Fp16) {
            0.0
        } else {
            runner.proxy_rel(&size, m)?
        };
        t.row(&[
            label.to_string(),
            format!("{bits:.2}"),
            format!("{w2:.3}"),
            format!("{c4:.3}"),
            format!("{proxy:.4}"),
        ]);
    }
    t.print();
    t.write_csv("table7_codebooks")?;

    let e8p = runner.ppl(&size, &Method::QuipSharp { bits: 2, ft: false }, "w2", WINDOW_NATIVE)?;
    let d4 = runner.ppl(&size, &Method::CodebookSwap { cb: SwapCodebook::D4Two }, "w2", WINDOW_NATIVE)?;
    let e8ball = runner.ppl(
        &size,
        &Method::CodebookSwap { cb: SwapCodebook::E8TwoThirtySeven },
        "w2",
        WINDOW_NATIVE,
    )?;
    println!("\ne8p {e8p:.3} vs d4 {d4:.3} vs e8-2.37 {e8ball:.3}");
    assert!(e8p <= d4 * 1.02, "E8P must match-or-beat D4 at 2 bits");
    assert!(e8ball <= e8p, "more bits (2.37) must not be worse");
    println!("assertion holds: Table 7 ordering reproduced");
    Ok(())
}
