//! Figure 3 — minimum achievable elementwise MSE of quantizing a unit
//! Gaussian with each codebook family, as a function of bitrate.
//! Exact reproduction (no model needed): E8-based codebooks must beat D4
//! and the half-integer product grids, with higher dimension better.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::quant::codebook::d4::D4Ball;
use quipsharp::quant::codebook::e8::{E8Ball, E8OneBit};
use quipsharp::quant::codebook::e8p::E8P;
use quipsharp::quant::codebook::kmeans::KMeansCodebook;
use quipsharp::quant::codebook::scalar::{HalfIntCube, HalfIntGrid};
use quipsharp::quant::codebook::VectorQuantizer;
use quipsharp::quant::scales::optimal_rho;

fn row(t: &mut Table, family: &str, q: &dyn VectorQuantizer) {
    let (rho, mse) = optimal_rho(q, 60_000, 3);
    t.row(&[
        family.to_string(),
        q.name(),
        format!("{}", q.dim()),
        format!("{:.3}", q.bits_per_weight()),
        format!("{rho:.3}"),
        format!("{mse:.5}"),
    ]);
}

fn main() -> Result<()> {
    println!("== Figure 3: Gaussian quantization MSE by codebook ==\n");
    let mut t = Table::new(&["family", "codebook", "dim", "bits/weight", "rho*", "mse"]);

    // Half-integer grids (1-D scalar + product cubes in 2/4/8 dims).
    for bits in [1u32, 2, 3, 4] {
        row(&mut t, "half-int d=1", &HalfIntGrid::new(bits));
    }
    for d in [2usize, 4, 8] {
        row(&mut t, &format!("half-int d={d}"), &HalfIntCube::new(2, d));
    }

    // D4 lattice ∩ ball at 2 / 2.21 / 3 bits.
    row(&mut t, "d4", &D4Ball::with_size(256));
    row(&mut t, "d4", &D4Ball::with_size(460));
    row(&mut t, "d4", &D4Ball::with_size(4096));

    // E8-based: E8P (the paper's), 1-bit E8, E8 ∩ ball at 2.37 bits.
    row(&mut t, "e8", &E8OneBit::new());
    row(&mut t, "e8 (E8P)", &E8P::new());
    row(&mut t, "e8", &E8Ball::with_size(1 << 19));

    // K-means (Table 7 / §C.3): same rate as E8P but learned.
    let km = KMeansCodebook::train_gaussian(8, 1 << 13, 1 << 15, 6, 99);
    row(&mut t, "kmeans (8d, 1.625b)", &km);

    t.print();
    t.write_csv("fig3_codebook_mse")?;

    // The paper's headline orderings, asserted:
    let mse_of = |q: &dyn VectorQuantizer| optimal_rho(q, 60_000, 3).1;
    let e8p = mse_of(&E8P::new());
    let d4 = mse_of(&D4Ball::with_size(256));
    let grid2 = mse_of(&HalfIntGrid::new(2));
    let cube8 = mse_of(&HalfIntCube::new(2, 8));
    assert!(e8p < d4, "E8P must beat D4 at 2 bits ({e8p} vs {d4})");
    assert!(e8p < grid2, "E8P must beat the scalar grid ({e8p} vs {grid2})");
    assert!(e8p < cube8, "lattice shaping must beat the plain 8-cube");
    println!("\nassertions hold: E8P < D4 < scalar grid at 2 bits (paper Fig. 3 ordering)");
    Ok(())
}
