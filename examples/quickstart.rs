//! Quickstart: load a trained model, quantize it with QuIP# at 2 bits,
//! compare perplexity and footprint, and generate some text.
//!
//!   cargo run --release --example quickstart [-- --size m]
//!
//! Requires `make artifacts` (corpus + trained models).

use anyhow::Result;
use quipsharp::eval::perplexity;
use quipsharp::generation::Generator;
use quipsharp::hessian::collect_hessians;
use quipsharp::model::Model;
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;
use quipsharp::data::load_corpus;

fn main() -> Result<()> {
    let args = Args::from_env();
    let art = args.get_or("art", "artifacts");
    let size = args.get_or("size", "s");

    println!("== QuIP# quickstart ==");
    let model = Model::load(art, size)?;
    println!(
        "model '{size}': {} params ({} layers, d={})",
        model.num_params(),
        model.cfg.n_layers,
        model.cfg.d_model
    );

    // 1. Calibration Hessians (paper §F.2).
    let calib = load_corpus(art, "corpus_calib")?;
    let hessians = collect_hessians(&model, &calib, 16, model.cfg.ctx);
    println!("collected {} layer Hessians", hessians.len());

    // 2. Quantize: RHT incoherence + BlockLDLQ + E8P (Algorithm 1).
    let method = Method::QuipSharp { bits: 2, ft: false };
    let qm = quantize_model(&model, &hessians, &method, 7140)?;
    println!(
        "quantized to {:.3} effective bits/weight (codes 2.0 + overheads — §F.1)",
        qm.avg_bits()
    );
    for (name, ql) in qm.layers.iter().take(2) {
        println!(
            "  {name}: mu_W {:.2} → {:.2} after RHT, proxy err {:.2}% of tr(WHWᵀ)",
            ql.stats.mu_before,
            ql.stats.mu_after,
            ql.stats.proxy_rel * 100.0
        );
    }

    // 3. Quality: perplexity before/after.
    let test = load_corpus(art, "corpus_test_w2")?;
    let ppl_fp = perplexity(&model, &test, 256, 4096);
    let ppl_q = perplexity(&qm.model, &test, 256, 4096);
    println!("perplexity: fp32 {ppl_fp:.3} → 2-bit QuIP# {ppl_q:.3}");

    // 4. Generate with the fused E8P decode hot path (Algorithm 2).
    let gen = Generator::quantized(&qm.model, &qm);
    let prompt = b"the ";
    let out = gen.generate(prompt, 48);
    let text: String = out.iter().map(|&b| b as char).collect();
    println!("generation (2-bit, fused decode): {:?}...", text);
    println!(
        "weight bytes/token: fp32 {} → quantized {}",
        Generator::dense(&model).weight_bytes_per_token(),
        gen.weight_bytes_per_token()
    );
    Ok(())
}
