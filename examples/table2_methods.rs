//! Table 2 — Wikitext2/C4-analog perplexity at context 128 ("ctx-2048
//! protocol") for AWQ-like, OmniQuant-like, QuIP# without FT & without E8
//! lattice, and full QuIP#, at 2/3/4 bits across the model family.
//!
//! Reproduced shape: QuIP# ≫ grid methods at 2 bits; grid methods usable
//! at 4 bits; the no-FT/no-E8 ablation sits in between.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::experiments::{Runner, WINDOW_SHORT};
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;
    let sizes: Vec<&str> = if args.has_flag("small") {
        vec!["s"]
    } else {
        vec!["s", "m", "l"]
    };

    println!("== Table 2: methods × bits, ppl @ ctx {WINDOW_SHORT} ==\n");
    let mut header = vec!["method".to_string(), "bits".to_string()];
    for s in &sizes {
        header.push(format!("{s}-w2"));
        header.push(format!("{s}-c4"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);

    let mut add_row = |runner: &mut Runner, method: &Method| -> Result<()> {
        let mut cells = vec![method.label(), format!("{:.2}", runner.bits(sizes[0], method)?)];
        for s in &sizes {
            cells.push(format!("{:.3}", runner.ppl(s, method, "w2", WINDOW_SHORT)?));
            cells.push(format!("{:.3}", runner.ppl(s, method, "c4", WINDOW_SHORT)?));
        }
        t.row(&cells);
        Ok(())
    };

    add_row(&mut runner, &Method::Fp16)?;
    for bits in [4u8, 3, 2] {
        add_row(&mut runner, &Method::AwqLike { bits })?;
        add_row(&mut runner, &Method::OmniquantLike { bits, group: None })?;
        add_row(&mut runner, &Method::QuipSharpNoE8 { bits })?;
        add_row(&mut runner, &Method::QuipSharp { bits, ft: true })?;
    }
    t.print();
    t.write_csv("table2_methods")?;

    // Headline ordering at 2 bits on the largest evaluated size.
    let big = *sizes.last().unwrap();
    let q2 = runner.ppl(big, &Method::QuipSharp { bits: 2, ft: true }, "w2", WINDOW_SHORT)?;
    let om2 = runner.ppl(big, &Method::OmniquantLike { bits: 2, group: None }, "w2", WINDOW_SHORT)?;
    let aw2 = runner.ppl(big, &Method::AwqLike { bits: 2 }, "w2", WINDOW_SHORT)?;
    println!("\n2-bit {big}: quip# {q2:.3} vs omniq {om2:.3} vs awq {aw2:.3}");
    assert!(q2 < om2 && q2 < aw2, "QuIP# must dominate grid methods at 2 bits");
    println!("assertion holds: QuIP# < OmniQuant-like, AWQ-like at 2 bits (Table 2 shape)");
    Ok(())
}
