//! Figures 1 / 4 / 5 — bit-scaling curves: perplexity vs total model bits
//! for QuIP# at 2/3/4 bits across the model family, the fp16 frontier
//! ("theoretically lossless 4-bit" = fp16 quality at 4 bits/weight), and
//! the AQLM-like VQ comparison (--vs-aqlm).
//!
//! Reproduced shape: at matched total bits the 3-bit curve sits at or
//! below the 4-bit curve, and 2-bit scales in parallel — the paper's
//! headline "3-bit beats 4-bit" scaling behaviour.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::experiments::{Runner, WINDOW_NATIVE};
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;
    let sizes: Vec<&str> = if args.has_flag("small") {
        vec!["s", "m"]
    } else {
        vec!["s", "m", "l"]
    };
    let vs_aqlm = args.has_flag("vs-aqlm");

    println!("== Figures 1/4/5: bit scaling (ppl vs total Gbits) ==\n");
    let mut t = Table::new(&["series", "model", "params", "total_gbits", "w2_ppl", "c4_ppl"]);

    let mut series: Vec<(String, Method)> = vec![
        ("fp16".into(), Method::Fp16),
        ("quip#-4bit".into(), Method::QuipSharp { bits: 4, ft: true }),
        ("quip#-3bit".into(), Method::QuipSharp { bits: 3, ft: true }),
        ("quip#-2bit".into(), Method::QuipSharp { bits: 2, ft: true }),
    ];
    if vs_aqlm {
        series.push(("aqlm-2bit".into(), Method::AqlmLike { bits: 2 }));
    }

    let mut curves: std::collections::BTreeMap<String, Vec<(f64, f64)>> = Default::default();
    for (name, m) in &series {
        for s in &sizes {
            let params = runner.num_params(s)? as f64;
            let bits = runner.bits(s, m)?;
            let gbits = params * bits / 1e9;
            let w2 = runner.ppl(s, m, "w2", WINDOW_NATIVE)?;
            let c4 = runner.ppl(s, m, "c4", WINDOW_NATIVE)?;
            t.row(&[
                name.clone(),
                s.to_string(),
                format!("{params:.0}"),
                format!("{gbits:.6}"),
                format!("{w2:.3}"),
                format!("{c4:.3}"),
            ]);
            curves.entry(name.clone()).or_default().push((gbits, w2));
        }
    }
    t.print();
    t.write_csv("fig_scaling")?;

    // Scaling claim: at the same *total bits*, lower-bit quantization of a
    // bigger model should beat higher-bit of a smaller one. Compare the
    // 2/3-bit big model against the 4-bit mid model (whose total bits are
    // comparable or larger).
    if sizes.len() >= 3 {
        let big = sizes[sizes.len() - 1];
        let mid = sizes[sizes.len() - 2];
        let p3_big = runner.ppl(big, &Method::QuipSharp { bits: 3, ft: true }, "w2", WINDOW_NATIVE)?;
        let p4_mid = runner.ppl(mid, &Method::QuipSharp { bits: 4, ft: true }, "w2", WINDOW_NATIVE)?;
        let gb3 = runner.num_params(big)? as f64 * runner.bits(big, &Method::QuipSharp { bits: 3, ft: true })?;
        let gb4 = runner.num_params(mid)? as f64 * runner.bits(mid, &Method::QuipSharp { bits: 4, ft: true })?;
        println!(
            "\n3-bit {big} ({:.2} Mbit): ppl {p3_big:.3}  vs  4-bit {mid} ({:.2} Mbit): ppl {p4_mid:.3}",
            gb3 / 1e6,
            gb4 / 1e6
        );
        assert!(
            p3_big < p4_mid,
            "3-bit-big must beat 4-bit-mid at ≥ total bits (Figure 1 claim)"
        );
        println!("assertion holds: lower-bit bigger model wins at matched storage (Fig. 1 shape)");
    }
    Ok(())
}
