//! Table 8 — QuIP# 2-bit vs OmniQuant-like W2A16 with and without g64
//! grouping (grouping costs +0.25 bits/weight for fp16 group scales).
//! Reproduced shape: QuIP# at 2.0 bits beats OmniQuant-like at 2.25.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::experiments::{Runner, WINDOW_SHORT};
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;
    let size = args.get_or("size", if args.has_flag("small") { "s" } else { "l" }).to_string();

    println!("== Table 8: grouping comparison on '{size}' ==\n");
    let rows: Vec<(&str, Method)> = vec![
        ("fp16", Method::Fp16),
        ("quip# 2bit", Method::QuipSharp { bits: 2, ft: true }),
        ("omniq w2a16", Method::OmniquantLike { bits: 2, group: None }),
        ("omniq w2a16 g64", Method::OmniquantLike { bits: 2, group: Some(64) }),
        ("omniq w3a16", Method::OmniquantLike { bits: 3, group: None }),
    ];
    let mut t = Table::new(&["method", "effective bits", "w2 ppl", "c4 ppl"]);
    for (label, m) in &rows {
        t.row(&[
            label.to_string(),
            format!("{:.2}", runner.bits(&size, m)?),
            format!("{:.3}", runner.ppl(&size, m, "w2", WINDOW_SHORT)?),
            format!("{:.3}", runner.ppl(&size, m, "c4", WINDOW_SHORT)?),
        ]);
    }
    t.print();
    t.write_csv("table8_grouping")?;

    let q = runner.ppl(&size, &Method::QuipSharp { bits: 2, ft: true }, "w2", WINDOW_SHORT)?;
    let og = runner.ppl(&size, &Method::OmniquantLike { bits: 2, group: Some(64) }, "w2", WINDOW_SHORT)?;
    let bits_q = runner.bits(&size, &Method::QuipSharp { bits: 2, ft: true })?;
    let bits_og = runner.bits(&size, &Method::OmniquantLike { bits: 2, group: Some(64) })?;
    println!("\nquip# {q:.3} @ {bits_q:.2}b vs omniq-g64 {og:.3} @ {bits_og:.2}b");
    assert!(bits_og > bits_q, "grouping must cost extra bits");
    assert!(q < og, "QuIP# must beat grouped OmniQuant-like despite fewer bits");
    println!("assertion holds: Table 8 shape reproduced");
    Ok(())
}
