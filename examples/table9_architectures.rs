//! Table 9 — QuIP# 2-bit (no FT) on architecturally different models:
//! a mixture-of-experts variant (Mixtral analog) and a non-Llama stack
//! (LayerNorm + GELU + learned positions; Falcon analog).
//! Reproduced shape: the pipeline runs unchanged; 2-bit ppl degrades
//! modestly relative to fp16.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::data::ZEROSHOT_TASKS;
use quipsharp::experiments::{Runner, WINDOW_NATIVE};
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;

    println!("== Table 9: other architectures, 2-bit QuIP# (no FT) ==\n");
    let mut header = vec![
        "model".to_string(),
        "bits".to_string(),
        "w2".to_string(),
        "c4".to_string(),
    ];
    header.extend(ZEROSHOT_TASKS.iter().map(|t| t.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);

    for size in ["moe", "nonllama"] {
        for m in [Method::Fp16, Method::QuipSharp { bits: 2, ft: false }] {
            let mut cells = vec![
                format!("{size} ({})", m.label()),
                format!("{:.2}", runner.bits(size, &m)?),
                format!("{:.3}", runner.ppl(size, &m, "w2", WINDOW_NATIVE)?),
                format!("{:.3}", runner.ppl(size, &m, "c4", WINDOW_NATIVE)?),
            ];
            for task in ZEROSHOT_TASKS {
                cells.push(format!("{:.1}", runner.zeroshot(size, &m, task)? * 100.0));
            }
            t.row(&cells);
        }
    }
    t.print();
    t.write_csv("table9_architectures")?;

    for size in ["moe", "nonllama"] {
        let fp = runner.ppl(size, &Method::Fp16, "w2", WINDOW_NATIVE)?;
        let q = runner.ppl(size, &Method::QuipSharp { bits: 2, ft: false }, "w2", WINDOW_NATIVE)?;
        println!("\n{size}: fp {fp:.3} → 2-bit {q:.3} ({:.1}× ratio)", q / fp);
        assert!(q.is_finite() && q < fp * 5.0, "{size}: 2-bit model must stay usable");
    }
    println!("assertion holds: QuIP# transfers across architectures (Table 9 shape)");
    Ok(())
}
