//! End-to-end serving demo (the repo's E2E validation run):
//! 1. load the trained model, quantize to 2-bit QuIP#,
//! 2. start the batching engine + TCP server,
//! 3. fire concurrent client requests, report latency/throughput,
//! 4. (if artifacts exist) run the same prompts through the PJRT
//!    three-layer path ({size}_decode_fp / _e8p) and cross-check.

use std::sync::Arc;

use anyhow::Result;
use quipsharp::experiments::Runner;
use quipsharp::model::Model;
use quipsharp::quant::pipeline::Method;
use quipsharp::serve::{serve_blocking, Client, Engine, NativeEngine, ServerConfig};
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let art = args.get_or("art", "artifacts").to_string();
    let size = args.get_or("size", "s").to_string();
    let n_requests = args.get_usize("requests", 16);
    let max_new = args.get_usize("max-new", 32);

    println!("== serve demo: '{size}' 2-bit QuIP# ==");
    let mut runner = Runner::new(&art)?;
    let qm = runner.qmodel(&size, &Method::QuipSharp { bits: 2, ft: false })?;
    let model = Arc::new(Model::new(qm.model.cfg.clone(), qm.model.params.clone()));
    let engine = Arc::new(NativeEngine::start(model.clone(), Some(qm.clone()), 8));
    let eng_dyn: Arc<dyn Engine> = engine.clone();
    let handle = serve_blocking(eng_dyn, ServerConfig::default())?;
    println!("server on {}", handle.local_addr);

    // Concurrent clients.
    let t0 = std::time::Instant::now();
    let addr = handle.local_addr;
    let mut joins = Vec::new();
    for i in 0..n_requests {
        joins.push(std::thread::spawn(move || -> Result<(usize, f64)> {
            let mut c = Client::connect(addr)?;
            let prompt: Vec<u8> = format!("the w{} ", i % 7).into_bytes();
            let (tokens, ms) = c.request(&prompt, max_new)?;
            Ok((tokens.len(), ms))
        }));
    }
    let mut total_tokens = 0usize;
    let mut lats = Vec::new();
    for j in joins {
        let (n, ms) = j.join().unwrap()?;
        total_tokens += n;
        lats.push(ms);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "{n_requests} requests, {total_tokens} tokens in {wall:.2}s → {:.1} tok/s; \
         latency p50 {:.0} ms, p99 {:.0} ms",
        total_tokens as f64 / wall,
        lats[lats.len() / 2],
        lats[lats.len() - 1],
    );
    let mut c = Client::connect(addr)?;
    println!("server stats: {}", c.stats()?.emit());
    c.shutdown()?;
    handle.stop();
    engine.stop();

    // --- PJRT three-layer path (optional, needs AOT artifacts) -------------
    match quipsharp::runtime::Runtime::new(&art) {
        Ok(rt) => {
            let artifact = format!("{size}_decode_fp");
            if rt.manifest.artifacts.contains_key(&artifact) {
                println!("\n== PJRT path ({artifact}) ==");
                let eng = quipsharp::serve::pjrt_engine::PjrtBatchEngine::new_fp(
                    &rt, &model, &artifact,
                )?;
                let prompts: Vec<Vec<u8>> =
                    (0..4).map(|i| format!("the w{i} ").into_bytes()).collect();
                let t0 = std::time::Instant::now();
                let outs = eng.generate_batch(&prompts, 16)?;
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "PJRT lockstep batch of {}: {} tokens in {dt:.2}s ({:.1} tok/s)",
                    prompts.len(),
                    outs.iter().map(|o| o.len()).sum::<usize>(),
                    outs.iter().map(|o| o.len()).sum::<usize>() as f64 / dt
                );
            } else {
                println!("\n(no decode artifact '{artifact}' — run `make artifacts`)");
            }
        }
        Err(e) => println!("\n(PJRT path skipped: {e})"),
    }
    Ok(())
}
