//! Table 3 (and Table 10 with --ablation) — zeroshot accuracy on the four
//! synthetic likelihood-comparison tasks (ArcE/ArcC/PiQA/Wino analogs).
//! Reproduced shape: QuIP# ≈ AQLM-like > grid methods at 2 bits; everyone
//! near fp16 at 4 bits; FT recovers most of the 2-bit gap (Table 10).

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::data::ZEROSHOT_TASKS;
use quipsharp::experiments::Runner;
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;
    let size = args.get_or("size", "m").to_string();
    let ablation = args.has_flag("ablation");

    let methods: Vec<Method> = if ablation {
        println!("== Table 10: zeroshot ablation on '{size}' ==\n");
        vec![
            Method::Fp16,
            Method::QuipSharpNoE8 { bits: 2 },
            Method::QuipSharp { bits: 2, ft: false },
            Method::QuipSharp { bits: 2, ft: true },
            Method::QuipSharpNoE8 { bits: 4 },
            Method::QuipSharp { bits: 4, ft: false },
            Method::QuipSharp { bits: 4, ft: true },
        ]
    } else {
        println!("== Table 3: zeroshot accuracy on '{size}' ==\n");
        vec![
            Method::Fp16,
            Method::OmniquantLike { bits: 4, group: None },
            Method::AqlmLike { bits: 4 },
            Method::QuipSharp { bits: 4, ft: true },
            Method::OmniquantLike { bits: 2, group: None },
            Method::AqlmLike { bits: 2 },
            Method::QuipSharp { bits: 2, ft: true },
        ]
    };

    let mut header = vec!["method".to_string(), "bits".to_string()];
    header.extend(ZEROSHOT_TASKS.iter().map(|t| t.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for m in &methods {
        let mut cells = vec![m.label(), format!("{:.2}", runner.bits(&size, m)?)];
        for task in ZEROSHOT_TASKS {
            cells.push(format!("{:.1}", runner.zeroshot(&size, m, task)? * 100.0));
        }
        t.row(&cells);
    }
    t.print();
    t.write_csv(if ablation { "table10_zeroshot_ablation" } else { "table3_zeroshot" })?;

    // 2-bit: QuIP# must beat the 2-bit grid baseline on average.
    let avg = |runner: &mut Runner, m: &Method| -> Result<f64> {
        let mut s = 0.0;
        for task in ZEROSHOT_TASKS {
            s += runner.zeroshot(&size, m, task)?;
        }
        Ok(s / ZEROSHOT_TASKS.len() as f64)
    };
    let q2 = avg(&mut runner, &Method::QuipSharp { bits: 2, ft: true })?;
    if !ablation {
        let om2 = avg(&mut runner, &Method::OmniquantLike { bits: 2, group: None })?;
        println!("\n2-bit mean acc: quip# {:.1}% vs omniq {:.1}%", q2 * 100.0, om2 * 100.0);
        assert!(q2 >= om2, "QuIP# must beat the grid baseline at 2 bits");
        println!("assertion holds (Table 3 shape)");
    } else {
        let noe8 = avg(&mut runner, &Method::QuipSharpNoE8 { bits: 2 })?;
        println!("\n2-bit mean acc: quip#+ft {:.1}% vs no-e8 {:.1}%", q2 * 100.0, noe8 * 100.0);
        assert!(q2 >= noe8, "full QuIP# must beat the no-E8 ablation at 2 bits");
        println!("assertion holds (Table 10 shape)");
    }
    Ok(())
}
