//! Table 4 — native-context perplexity with the full ablation ladder:
//! QuIP# / no-FT / no-E8, QuIP (Kronecker) baseline, AQLM-like VQ.
//! Reproduced shape: each QuIP# component adds quality; gaps widen at
//! 2 bits; QuIP (Kron + scalar) trails the RHT ablation.

use anyhow::Result;
use quipsharp::bench::Table;
use quipsharp::experiments::{Runner, WINDOW_NATIVE};
use quipsharp::quant::pipeline::Method;
use quipsharp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut runner = Runner::new(args.get_or("art", "artifacts"))?;
    let sizes: Vec<&str> = if args.has_flag("small") {
        vec!["s"]
    } else {
        vec!["s", "m", "l"]
    };

    println!("== Table 4: ablations, ppl @ native ctx {WINDOW_NATIVE} ==\n");
    let mut header = vec!["method".to_string(), "bits".to_string()];
    for s in &sizes {
        header.push(format!("{s}-w2"));
        header.push(format!("{s}-c4"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);

    let mut add = |runner: &mut Runner, m: &Method| -> Result<()> {
        let mut cells = vec![m.label(), format!("{:.2}", runner.bits(sizes[0], m)?)];
        for s in &sizes {
            cells.push(format!("{:.3}", runner.ppl(s, m, "w2", WINDOW_NATIVE)?));
            cells.push(format!("{:.3}", runner.ppl(s, m, "c4", WINDOW_NATIVE)?));
        }
        t.row(&cells);
        Ok(())
    };

    add(&mut runner, &Method::Fp16)?;
    for bits in [4u8, 3, 2] {
        add(&mut runner, &Method::QuipSharp { bits, ft: true })?;
        add(&mut runner, &Method::QuipSharp { bits, ft: false })?;
        add(&mut runner, &Method::QuipSharpNoE8 { bits })?;
    }
    add(&mut runner, &Method::QuipKron { bits: 2 })?;
    add(&mut runner, &Method::AqlmLike { bits: 2 })?;
    t.print();
    t.write_csv("table4_ablations")?;

    // Component ladder at 2 bits (mid size): FT ≤ noFT ≤ noE8, RHT ≤ Kron.
    let size = sizes[sizes.len() / 2];
    let ft = runner.ppl(size, &Method::QuipSharp { bits: 2, ft: true }, "w2", WINDOW_NATIVE)?;
    let noft = runner.ppl(size, &Method::QuipSharp { bits: 2, ft: false }, "w2", WINDOW_NATIVE)?;
    let noe8 = runner.ppl(size, &Method::QuipSharpNoE8 { bits: 2 }, "w2", WINDOW_NATIVE)?;
    let kron = runner.ppl(size, &Method::QuipKron { bits: 2 }, "w2", WINDOW_NATIVE)?;
    println!("\n2-bit {size}: ft {ft:.3} ≤ noft {noft:.3} ≤ noe8 {noe8:.3}; kron {kron:.3}");
    assert!(ft <= noft * 1.02, "FT should not hurt ({ft} vs {noft})");
    assert!(noft < noe8, "E8P lattice must beat the scalar grid");
    assert!(noe8 <= kron * 1.05, "RHT should match-or-beat Kronecker");
    println!("assertion holds: component ladder reproduces Table 4 ordering");
    Ok(())
}
