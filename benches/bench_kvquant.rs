//! KV-cache compression A/B: the serving engine under KV pool pressure
//! with an fp32 pool vs the 2-bit E8P cold tier (`kv_bits: 2`), at
//! *equal pool bytes*.
//!
//! The workload is built to make the tier's two effects measurable:
//! multi-page prompts (so sequences outgrow the pool and pressure is
//! certain) and more requests than the fp32 pool can hold concurrently.
//! With compression on, full pages behind the hot tail re-encode to
//! ~1/16 of their fp32 size (2-bit codes + per-slab scales), so the
//! same pool sustains strictly more concurrent sequences — reported as
//! `mean_batch`, the time-averaged admitted concurrency. (`peak_batch`
//! is the wrong lens here: every sequence starts one page small, so
//! both modes briefly admit `min(pool, max_batch)` lanes at t = 0.)
//! Preemptions also stop costing work: the fp32 engine requeues and
//! *re-prefills* its victims, while the quantized engine spills their
//! (mostly compressed) pages to the host arena and restores them, so
//! `prefill_tokens` stays exactly at the ideal (each prompt token
//! decoded once).
//!
//! Assertions (both modes, structural rather than timing-based):
//!   * quantized `mean_batch` strictly above fp32 at equal pool pages;
//!   * quantized `prefill_tokens` == ideal, fp32 above it (re-prefills);
//!   * the quantized run actually quantized/spilled/restored pages, and
//!     the fp32 run touched none of the machinery (the off path stays
//!     bit-exact with the pre-tier engine);
//!   * every request completes with exactly `max_new` tokens.
//!
//! `--smoke` (wired as `make bench-kvquant-smoke`, run in CI) shrinks
//! request count and decode length; the assertions are identical.
//! Results land in `BENCH_kvquant.json`.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use quipsharp::bench::Table;
use quipsharp::generation::paged::PAGE_ROWS;
use quipsharp::model::{Model, ModelConfig};
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::serve::{Engine, EngineOptions, EngineRequest, NativeEngine};
use quipsharp::util::json::Json;

struct Shape {
    n_requests: usize,
    max_new: usize,
}

/// Long decode: sequences reach 68 + 120 = 188 rows = 6 pages, the
/// whole pool — the fp32 engine ends up running requests nearly
/// single-file while the compressed tier keeps a batch going.
const FULL: Shape = Shape {
    n_requests: 12,
    max_new: 120,
};
/// CI shape: same structure, seconds-scale.
const SMOKE: Shape = Shape {
    n_requests: 6,
    max_new: 40,
};

struct RunStats {
    peak_admitted: u64,
    mean_batch: f64,
    preemptions: u64,
    prefill_tokens: u64,
    kv_pages_quantized: u64,
    kv_spills: u64,
    kv_restores: u64,
    codewords_decoded: u64,
    tok_per_sec: f64,
}

fn run(
    model: &Arc<Model>,
    qm: &Arc<quipsharp::qmodel::QuantizedModel>,
    pool_pages: usize,
    max_batch: usize,
    prompt_len: usize,
    shape: &Shape,
    kv_bits: usize,
) -> RunStats {
    let eng = NativeEngine::start_with_opts(
        model.clone(),
        Some(qm.clone()),
        EngineOptions {
            max_batch,
            pool_pages: Some(pool_pages),
            kv_bits,
            kv_hot_pages: 0,
            ..EngineOptions::default()
        },
    );
    let cw0 = quipsharp::model::qlinear::codewords_decoded();
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..shape.n_requests {
        let prompt: Vec<u8> = (0..prompt_len).map(|j| ((i * 11 + j * 7 + 3) % 50) as u8).collect();
        rxs.push(eng.submit(EngineRequest {
            id: i as u64,
            prompt,
            max_new: shape.max_new,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        }));
    }
    let mut tokens = 0usize;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), shape.max_new, "request truncated");
        tokens += resp.tokens.len();
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = eng.metrics();
    eng.stop();
    eng.join();
    RunStats {
        peak_admitted: m.peak_batch.load(Ordering::Relaxed),
        mean_batch: m.mean_batch(),
        preemptions: m.preemptions.load(Ordering::Relaxed),
        prefill_tokens: m.prefill_tokens.load(Ordering::Relaxed),
        kv_pages_quantized: m.kv_pages_quantized.load(Ordering::Relaxed),
        kv_spills: m.kv_spills.load(Ordering::Relaxed),
        kv_restores: m.kv_restores.load(Ordering::Relaxed),
        // The metrics gauge mirrors a process-wide counter; diff against
        // the run's start so back-to-back runs don't bleed into each
        // other.
        codewords_decoded: quipsharp::model::qlinear::codewords_decoded() - cw0,
        tok_per_sec: tokens as f64 / dt,
    }
}

fn stats_json(pool_pages: usize, kv_bits: usize, s: &RunStats) -> Json {
    Json::obj(vec![
        ("pool_pages", Json::num(pool_pages as f64)),
        ("kv_bits", Json::num(kv_bits as f64)),
        ("peak_admitted", Json::num(s.peak_admitted as f64)),
        ("mean_batch", Json::num(s.mean_batch)),
        ("preemptions", Json::num(s.preemptions as f64)),
        ("prefill_tokens", Json::num(s.prefill_tokens as f64)),
        (
            "kv_pages_quantized",
            Json::num(s.kv_pages_quantized as f64),
        ),
        ("kv_spills", Json::num(s.kv_spills as f64)),
        ("kv_restores", Json::num(s.kv_restores as f64)),
        ("codewords_decoded", Json::num(s.codewords_decoded as f64)),
        ("tok_per_sec", Json::num(s.tok_per_sec)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let shape = if smoke { SMOKE } else { FULL };
    let model = Model::random(ModelConfig::by_name("s").unwrap(), 14);
    // Identity Hessians: quantization quality is irrelevant here and
    // skipping calibration keeps the bench fast.
    let qm = Arc::new(
        quantize_model(
            &model,
            &BTreeMap::new(),
            &Method::QuipSharp { bits: 2, ft: false },
            7,
        )
        .unwrap(),
    );
    let model_arc = Arc::new(Model::new(qm.model.cfg.clone(), qm.model.params.clone()));
    // Multi-page prompts against a pool that holds two fp32 sequences
    // of that shape: pressure is certain, and the fp32 engine cannot
    // sustain more than two lanes once everyone is past page 1.
    let prompt_len = 2 * PAGE_ROWS + 4;
    let (pool_pages, max_batch) = (6usize, 8usize);
    let ideal_prefill = (shape.n_requests * prompt_len) as u64;
    println!(
        "== kv-quant A/B: fp32 vs 2-bit cold tier at {pool_pages} pool pages{} ==",
        if smoke { ", SMOKE" } else { "" }
    );
    println!(
        "({} requests, {}-token prompts, {} new tokens each)\n",
        shape.n_requests, prompt_len, shape.max_new
    );

    let fp32 = run(&model_arc, &qm, pool_pages, max_batch, prompt_len, &shape, 0);
    let quant = run(&model_arc, &qm, pool_pages, max_batch, prompt_len, &shape, 2);

    let mut t = Table::new(&[
        "kv",
        "mean batch",
        "peak",
        "preempt",
        "prefill toks",
        "pages quantized",
        "spills",
        "restores",
        "tok/s",
    ]);
    for (label, s) in [("fp32", &fp32), ("2-bit", &quant)] {
        t.row(&[
            label.to_string(),
            format!("{:.2}", s.mean_batch),
            format!("{}", s.peak_admitted),
            format!("{}", s.preemptions),
            format!("{}", s.prefill_tokens),
            format!("{}", s.kv_pages_quantized),
            format!("{}", s.kv_spills),
            format!("{}", s.kv_restores),
            format!("{:.1}", s.tok_per_sec),
        ]);
    }
    t.print();
    t.write_csv("bench_kvquant").ok();

    // The off path must not touch the machinery…
    assert_eq!(fp32.kv_pages_quantized, 0, "fp32 run quantized pages");
    assert_eq!(fp32.kv_spills, 0, "fp32 run spilled");
    assert_eq!(fp32.kv_restores, 0, "fp32 run restored");
    // …and preempt-restart re-prefills while spill/restore never does.
    assert!(
        fp32.prefill_tokens > ideal_prefill,
        "fp32 pressure run should re-prefill (got {}, ideal {ideal_prefill})",
        fp32.prefill_tokens
    );
    assert_eq!(
        quant.prefill_tokens, ideal_prefill,
        "spill/restore must decode each prompt token exactly once"
    );
    // The tier engaged, and compression bought sustained concurrency at
    // equal pool bytes.
    assert!(quant.kv_pages_quantized > 0, "compression never engaged");
    assert!(quant.kv_spills > 0 && quant.kv_restores > 0, "no spill/restore under pressure");
    assert!(
        quant.mean_batch > fp32.mean_batch,
        "2-bit KV must sustain more concurrency than fp32 at equal pool bytes \
         ({:.2} vs {:.2})",
        quant.mean_batch,
        fp32.mean_batch
    );

    let out = Json::obj(vec![
        ("model", Json::str("s-synthetic")),
        ("method", Json::str("quip#-2bit-weights")),
        ("smoke", Json::Bool(smoke)),
        ("pool_pages", Json::num(pool_pages as f64)),
        ("max_batch", Json::num(max_batch as f64)),
        ("n_requests", Json::num(shape.n_requests as f64)),
        ("prompt_tokens", Json::num(prompt_len as f64)),
        ("max_new", Json::num(shape.max_new as f64)),
        ("ideal_prefill_tokens", Json::num(ideal_prefill as f64)),
        ("fp32", stats_json(pool_pages, 0, &fp32)),
        ("kv_quant_2bit", stats_json(pool_pages, 2, &quant)),
    ]);
    if std::fs::write("BENCH_kvquant.json", out.emit()).is_ok() {
        println!("\nwrote BENCH_kvquant.json");
    }
}
