//! §6.3 roofline study: the fused E8P decode+matvec against the dense f32
//! matvec and the machine's memcpy roofline. The paper's claim is >50% of
//! peak memory bandwidth on an RTX 4090; here the CPU analog is % of the
//! multithreaded memcpy bandwidth at matched bytes.

use std::time::Duration;

use quipsharp::bench::{memcpy_roofline_gbps, memcpy_roofline_mt_gbps, Bench, Table};
use quipsharp::linalg::ldl::random_spd;
use quipsharp::linalg::Matrix;
use quipsharp::model::qlinear::{dense_matvec, QuantMatvec};
use quipsharp::quant::pipeline::{quantize_matrix, Method};
use quipsharp::util::rng::Pcg64;

fn main() {
    println!("== bench_matvec: fused E8P decode vs dense (§6.3) ==\n");
    let roof_1t = memcpy_roofline_gbps(64 << 20);
    let roof_mt = memcpy_roofline_mt_gbps(64 << 20);
    println!("memcpy roofline: {roof_1t:.1} GB/s single-thread, {roof_mt:.1} GB/s multithread\n");

    let mut table = Table::new(&["kernel", "m×n", "bytes/iter", "median", "GB/s", "% MT roofline"]);
    let mut sweep = Table::new(&["m×n", "B", "loop/step", "batched/step", "speedup", "eff B/vec"]);
    let mut rng = Pcg64::new(1);

    // 4096² exceeds the CI box budget (quantization-time, not matvec);
    // 2048² is already past LLC on this machine (memcpy 3.7 GB/s).
    for &(m, n) in &[(1024usize, 1024usize), (2048, 2048)] {
        // Quantize a random layer at 2 bits (E8P single stage).
        let w = Matrix::gaussian(m, n, 0.02, &mut rng);
        let h = random_spd(n, 0.5, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 7).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        let x: Vec<f32> = rng.gaussian_vec(n, 1.0);
        let mut y = vec![0.0f32; m];

        // Fused decode path (2 bits → m·n/4 bytes of codes).
        let bytes_q = qm.bytes_per_matvec();
        let r = Bench::new(format!("e8p-2bit {m}x{n}"))
            .bytes(bytes_q)
            .budget(Duration::from_millis(600))
            .run(|| {
                qm.matvec(&x, &mut y);
                y[0]
            });
        table.row(&[
            "e8p-2bit".into(),
            format!("{m}x{n}"),
            format!("{bytes_q}"),
            format!("{:.3} ms", r.median_ns() as f64 / 1e6),
            format!("{:.2}", r.gbps().unwrap()),
            format!("{:.1}%", 100.0 * r.gbps().unwrap() / roof_mt),
        ]);

        // Dense f32 (4 bytes/weight).
        let wd = ql.w_eff.clone();
        let bytes_d = (m * n * 4) as u64;
        let r = Bench::new(format!("dense-f32 {m}x{n}"))
            .bytes(bytes_d)
            .budget(Duration::from_millis(600))
            .run(|| {
                dense_matvec(&wd, &x, m, n, &mut y);
                y[0]
            });
        table.row(&[
            "dense-f32".into(),
            format!("{m}x{n}"),
            format!("{bytes_d}"),
            format!("{:.3} ms", r.median_ns() as f64 / 1e6),
            format!("{:.2}", r.gbps().unwrap()),
            format!("{:.1}%", 100.0 * r.gbps().unwrap() / roof_mt),
        ]);

        // Batch sweep: one decode-once/multiply-many `matmul` step against
        // B sequence-at-a-time `matvec` calls. The codes are streamed once
        // per step either way counted per *batch*, so effective bytes per
        // multiplied vector drop 1/B on the batched path.
        for &bsz in &[1usize, 2, 4, 8, 16] {
            let xs: Vec<f32> = rng.gaussian_vec(bsz * n, 1.0);
            let mut ys = vec![0.0f32; bsz * m];
            let r_loop = Bench::new(format!("e8p loop B={bsz} {m}x{n}"))
                .budget(Duration::from_millis(400))
                .run(|| {
                    for b in 0..bsz {
                        qm.matvec(&xs[b * n..(b + 1) * n], &mut ys[b * m..(b + 1) * m]);
                    }
                    ys[0]
                });
            let r_bat = Bench::new(format!("e8p batched B={bsz} {m}x{n}"))
                .budget(Duration::from_millis(400))
                .run(|| {
                    qm.matmul(&xs, bsz, &mut ys);
                    ys[0]
                });
            sweep.row(&[
                format!("{m}x{n}"),
                format!("{bsz}"),
                format!("{:.3} ms", r_loop.median_ns() as f64 / 1e6),
                format!("{:.3} ms", r_bat.median_ns() as f64 / 1e6),
                format!("{:.2}x", r_loop.median_ns() as f64 / r_bat.median_ns() as f64),
                format!("{:.0}", bytes_q as f64 / bsz as f64),
            ]);
        }
    }
    table.print();
    table.write_csv("bench_matvec").ok();
    println!("\n== batch sweep: fused decode amortized across B right-hand sides ==\n");
    sweep.print();
    sweep.write_csv("bench_matvec_batch").ok();
    println!("\n(The paper's >50% target applies at the largest shapes, where decode\n is memory-bound; see EXPERIMENTS.md §Perf for the iteration log.)");
}
