//! Self-speculative decoding bench — the RVQ base-stage draft / full
//! model verify loop (`generation::speculative`) against plain batched
//! decode, on the serving-style shared-prefix workload. Writes
//! `BENCH_speculative.json` (field reference in `BENCHMARKS.md`).
//!
//! Workload: a 4-bit (E8P ∘ E8P) synthetic model; B sequences forked
//! off one prefilled shared prompt prefix (`PagedKv::fork_prefix`, the
//! shape the engine's prefix cache produces), each greedily decoding
//! `new_tokens` tokens. For every (B, k) pair it measures:
//!
//! * **baseline** (k = 0): one `decode_batch_paged` call per token —
//!   the batch-native non-speculative hot path.
//! * **speculative**: `spec_round_paged` rounds — the embedded 2-bit
//!   base stage drafts k tokens per round (half the code bytes per
//!   step), the 4-bit target verifies all k + 1 positions in a single
//!   chunked step, both KVs roll back on rejection.
//!
//! Bit-parity preflight: every speculated token stream must equal the
//! non-speculative stream exactly — acceptance only moves throughput.
//! The coupled accept rule makes that hold in *sampled* mode too, so a
//! second sweep holds B and k fixed and sweeps the softmax temperature:
//! acceptance falls as the distribution flattens (the draft and target
//! samples decouple), and `tokens_resampled` counts the rounds whose
//! first rejected position re-drew from the target's own distribution.
//! Reported per row: tok/s, speedup over the k = 0 baseline at the
//! same B, and the draft acceptance rate. The full run asserts the
//! k = 4 sweep beats the baseline somewhere in the B sweep; `--smoke`
//! (wired as `make bench-spec-smoke`, run in CI) shrinks shapes to
//! seconds and skips the perf assertion (parity is still checked).

use std::collections::BTreeMap;
use std::time::Instant;

use quipsharp::bench::{best_of, Table};
use quipsharp::generation::paged::{pages_per_seq, KvPagePool, PagedKv};
use quipsharp::generation::sampling::{next_token, SamplingParams};
use quipsharp::generation::speculative::{effective_k, spec_round_paged, SpecLane, SpecStats};
use quipsharp::generation::Generator;
use quipsharp::model::{Arch, Model, ModelConfig};
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::util::json::Json;

struct Shape {
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    /// Small vocab keeps the per-lane fp32 lm_head from drowning the
    /// packed-weight stream the draft halves.
    vocab: usize,
    ctx: usize,
    prefix_rows: usize,
    new_tokens: usize,
    batches: &'static [usize],
    ks: &'static [usize],
    reps: usize,
}

/// Full run: the 'm'-class geometry with a serving-style prefix.
const FULL: Shape = Shape {
    d_model: 256,
    n_layers: 4,
    n_heads: 4,
    d_ff: 1024,
    vocab: 64,
    ctx: 256,
    prefix_rows: 96,
    new_tokens: 48,
    batches: &[1, 4, 8],
    ks: &[0, 2, 4, 8],
    reps: 3,
};

/// Smoke run (CI): seconds of runtime, parity checks only.
const SMOKE: Shape = Shape {
    d_model: 32,
    n_layers: 2,
    n_heads: 2,
    d_ff: 64,
    vocab: 64,
    ctx: 128,
    prefix_rows: 40,
    new_tokens: 12,
    batches: &[1, 4],
    ks: &[0, 2, 4],
    reps: 2,
};

fn build_model(shape: &Shape, seed: u64) -> Model {
    let cfg = ModelConfig {
        name: "spec-bench".into(),
        d_model: shape.d_model,
        n_layers: shape.n_layers,
        n_heads: shape.n_heads,
        d_ff: shape.d_ff,
        vocab: shape.vocab,
        ctx: shape.ctx,
        arch: Arch::Llama,
        n_experts: 2,
    };
    Model::random(cfg, seed)
}

/// Shared-prefix setup: prefill the prefix once per generator (target
/// and draft keep separate KVs of the same tokens), then fork B lanes
/// off each. Returns (target lanes, draft lanes, per-lane logits after
/// the prefix + the lane's distinct first token).
struct Lanes {
    pool: KvPagePool,
    t_kvs: Vec<PagedKv>,
    d_kvs: Vec<PagedKv>,
    logits: Vec<Vec<f32>>,
}

fn setup(target: &Generator, draft: &Generator, shape: &Shape, bsz: usize) -> Lanes {
    let m = target.model;
    let prefix: Vec<u8> =
        (0..shape.prefix_rows).map(|i| ((i * 13 + 2) % shape.vocab) as u8).collect();
    let mut pool = KvPagePool::for_model(m, 2 * (bsz + 1) * pages_per_seq(&m.cfg));
    // Parents: one target-KV and one draft-KV prefill of the shared
    // prefix (the engine's prefix cache analogue, kept pinned).
    let mut t_parent = PagedKv::new();
    target.decode_chunk_paged(&prefix, &mut pool, &mut t_parent);
    let mut d_parent = PagedKv::new();
    draft.decode_chunk_paged(&prefix, &mut pool, &mut d_parent);
    let mut t_kvs = Vec::with_capacity(bsz);
    let mut d_kvs = Vec::with_capacity(bsz);
    let mut logits = Vec::with_capacity(bsz);
    for b in 0..bsz {
        let mut t_kv = PagedKv::new();
        t_kv.fork_prefix(&mut pool, &t_parent, shape.prefix_rows);
        let mut d_kv = PagedKv::new();
        d_kv.fork_prefix(&mut pool, &d_parent, shape.prefix_rows);
        // A distinct first token diverges the lanes off the prefix.
        let tok = ((7 * b + 5) % shape.vocab) as u8;
        let l = target
            .decode_batch_paged(&[tok], &mut pool, &mut [&mut t_kv])
            .pop()
            .unwrap();
        draft.decode_batch_paged(&[tok], &mut pool, &mut [&mut d_kv]);
        t_kvs.push(t_kv);
        d_kvs.push(d_kv);
        logits.push(l);
    }
    Lanes { pool, t_kvs, d_kvs, logits }
}

/// Baseline: plain batched decode of `new_tokens` per lane through the
/// shared per-position sampling rule (greedy params fall through to the
/// exact argmax call, bit-identical to the pre-sampling bench).
fn run_baseline(
    target: &Generator,
    shape: &Shape,
    lanes: &mut Lanes,
    sampling: &[SamplingParams],
) -> Vec<Vec<u8>> {
    let bsz = lanes.t_kvs.len();
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); bsz];
    for step in 0..shape.new_tokens {
        // Absolute position of the token being emitted: shared prefix +
        // the lane's distinct first token + tokens emitted so far.
        let pos = shape.prefix_rows + 1 + step;
        let toks: Vec<u8> = lanes
            .logits
            .iter()
            .enumerate()
            .map(|(b, l)| next_token(l, &sampling[b], pos))
            .collect();
        for (o, &t) in out.iter_mut().zip(&toks) {
            o.push(t);
        }
        let next = {
            let mut refs: Vec<&mut PagedKv> = lanes.t_kvs.iter_mut().collect();
            target.decode_batch_paged(&toks, &mut lanes.pool, &mut refs)
        };
        lanes.logits = next;
    }
    out
}

/// Speculative: draft/verify rounds until every lane emitted
/// `new_tokens` tokens. Returns the emitted streams plus round stats.
fn run_speculative(
    target: &Generator,
    draft: &Generator,
    shape: &Shape,
    k: usize,
    lanes: &mut Lanes,
    sampling: &[SamplingParams],
) -> (Vec<Vec<u8>>, SpecStats) {
    let bsz = lanes.t_kvs.len();
    let ctx = target.model.cfg.ctx;
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); bsz];
    let mut pendings: Vec<Vec<u8>> = vec![Vec::new(); bsz];
    let mut stats = SpecStats::default();
    while out.iter().any(|o| o.len() < shape.new_tokens) {
        let sel: Vec<usize> = (0..bsz).filter(|&b| out[b].len() < shape.new_tokens).collect();
        let ks: Vec<usize> = sel
            .iter()
            .map(|&b| {
                effective_k(
                    k,
                    shape.new_tokens - out[b].len(),
                    ctx,
                    lanes.t_kvs[b].len,
                    lanes.d_kvs[b].len,
                    pendings[b].len(),
                )
            })
            .collect();
        let emitted = {
            let mut round: Vec<SpecLane> = Vec::with_capacity(sel.len());
            let mut t_it = lanes.t_kvs.iter_mut();
            let mut d_it = lanes.d_kvs.iter_mut();
            let mut p_it = pendings.iter_mut();
            let mut l_it = lanes.logits.iter_mut();
            let mut si = 0usize;
            let mut idx = 0usize;
            loop {
                let (Some(t), Some(d), Some(p), Some(l)) =
                    (t_it.next(), d_it.next(), p_it.next(), l_it.next())
                else {
                    break;
                };
                if si < sel.len() && sel[si] == idx {
                    round.push(SpecLane {
                        k: ks[si],
                        target_kv: t,
                        draft_kv: d,
                        pending: p,
                        logits: l,
                        sampling: sampling[idx],
                        pos: shape.prefix_rows + 1 + out[idx].len(),
                    });
                    si += 1;
                }
                idx += 1;
            }
            spec_round_paged(target, draft, &mut lanes.pool, &mut round, &mut stats)
        };
        for (em, &b) in emitted.iter().zip(&sel) {
            out[b].extend_from_slice(em);
        }
    }
    (out, stats)
}

fn run_config(
    target: &Generator,
    draft: &Generator,
    shape: &Shape,
    bsz: usize,
    k: usize,
    baseline_tps: Option<f64>,
    sampling: &[SamplingParams],
) -> (Json, f64, f64) {
    // Parity preflight: the speculated stream must equal the plain
    // stream token for token — greedy and sampled alike (the coupled
    // accept rule makes speculation sample-path-exact).
    let mut base_lanes = setup(target, draft, shape, bsz);
    let want = run_baseline(target, shape, &mut base_lanes, sampling);
    let mut spec_lanes = setup(target, draft, shape, bsz);
    let (got, preflight_stats) =
        run_speculative(target, draft, shape, k, &mut spec_lanes, sampling);
    assert_eq!(got, want, "speculative decode diverged (B={bsz}, k={k})");
    assert!(
        preflight_stats.tokens_resampled <= preflight_stats.rounds,
        "resample counter exceeds rounds (B={bsz}, k={k})"
    );
    // Timing: best of `reps` fresh runs (setup excluded).
    let tokens = (bsz * shape.new_tokens) as f64;
    let dt = best_of(shape.reps, || {
        if k == 0 {
            let mut lanes = setup(target, draft, shape, bsz);
            let t0 = Instant::now();
            run_baseline(target, shape, &mut lanes, sampling);
            t0.elapsed().as_secs_f64()
        } else {
            let mut lanes = setup(target, draft, shape, bsz);
            let t0 = Instant::now();
            run_speculative(target, draft, shape, k, &mut lanes, sampling);
            t0.elapsed().as_secs_f64()
        }
    });
    let tps = tokens / dt;
    let speedup = baseline_tps.map(|b| tps / b).unwrap_or(1.0);
    let acc = preflight_stats.acceptance_rate();
    let row = Json::obj(vec![
        ("batch", Json::num(bsz as f64)),
        ("k", Json::num(k as f64)),
        ("temperature", Json::num(sampling[0].temperature as f64)),
        ("tok_per_sec", Json::num(tps)),
        ("speedup_vs_k0", Json::num(speedup)),
        ("acceptance_rate", Json::num(acc)),
        ("tokens_drafted", Json::num(preflight_stats.tokens_drafted as f64)),
        ("tokens_accepted", Json::num(preflight_stats.tokens_accepted as f64)),
        ("rounds", Json::num(preflight_stats.rounds as f64)),
        ("tokens_resampled", Json::num(preflight_stats.tokens_resampled as f64)),
    ]);
    (row, tps, speedup)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let shape = if smoke { SMOKE } else { FULL };
    println!("== self-speculative decode: RVQ base-stage draft + chunked verify ==");
    println!(
        "(d_model {}, {} layers, vocab {}, 4-bit E8P∘E8P target / 2-bit base-stage draft, \
         {}-row shared prefix, {} new tokens{})\n",
        shape.d_model,
        shape.n_layers,
        shape.vocab,
        shape.prefix_rows,
        shape.new_tokens,
        if smoke { ", SMOKE" } else { "" }
    );
    let model = build_model(&shape, 11);
    // Identity Hessians: decode throughput does not depend on
    // quantization quality, and skipping calibration keeps setup fast.
    let qm = quantize_model(
        &model,
        &BTreeMap::new(),
        &Method::QuipSharp { bits: 4, ft: false },
        7,
    )
    .unwrap();
    assert!(qm.has_multi_stage(), "4-bit model must embed a base stage");
    let target = qm.generator();
    let draft = qm.draft_generator();
    let mut t = Table::new(&["B", "k", "tok/s", "speedup", "accept"]);
    let mut rows_json: Vec<Json> = Vec::new();
    let mut best_k4_speedup = f64::NEG_INFINITY;
    for &bsz in shape.batches {
        let greedy = vec![SamplingParams::default(); bsz];
        let mut baseline_tps = None;
        for &k in shape.ks {
            let (row, tps, speedup) =
                run_config(&target, &draft, &shape, bsz, k, baseline_tps, &greedy);
            if k == 0 {
                baseline_tps = Some(tps);
            }
            if k == 4 {
                best_k4_speedup = best_k4_speedup.max(speedup);
            }
            let acc = row.get("acceptance_rate").as_f64().unwrap();
            t.row(&[
                format!("{bsz}"),
                format!("{k}"),
                format!("{tps:.1}"),
                format!("{speedup:.2}x"),
                format!("{acc:.2}"),
            ]);
            rows_json.push(row);
        }
    }
    t.print();
    t.write_csv("bench_speculative").ok();

    // Sampled sweep: B and k fixed, softmax temperature swept. Parity
    // (speculated stream == direct sampled stream) is asserted inside
    // run_config for every row; the interesting column is acceptance,
    // which falls as the temperature flattens the distributions and the
    // per-position draft/target samples decouple.
    let sampled_bsz = *shape.batches.last().unwrap();
    let sampled_k = *shape.ks.last().unwrap();
    println!(
        "\n== sampled mode (B={sampled_bsz}, acceptance vs temperature, parity asserted) =="
    );
    let mut st = Table::new(&["temp", "k", "tok/s", "speedup", "accept", "resampled"]);
    let mut sampled_json: Vec<Json> = Vec::new();
    for &temp in &[0.5f32, 0.9, 1.4] {
        let params: Vec<SamplingParams> = (0..sampled_bsz)
            .map(|b| SamplingParams {
                temperature: temp,
                top_k: 0,
                top_p: 1.0,
                seed: 0xB_5EED + b as u64,
            })
            .collect();
        let mut baseline_tps = None;
        for k in [0usize, sampled_k] {
            let (row, tps, speedup) =
                run_config(&target, &draft, &shape, sampled_bsz, k, baseline_tps, &params);
            if k == 0 {
                baseline_tps = Some(tps);
            }
            let acc = row.get("acceptance_rate").as_f64().unwrap();
            let resampled = row.get("tokens_resampled").as_f64().unwrap();
            st.row(&[
                format!("{temp:.1}"),
                format!("{k}"),
                format!("{tps:.1}"),
                format!("{speedup:.2}x"),
                format!("{acc:.2}"),
                format!("{resampled:.0}"),
            ]);
            sampled_json.push(row);
        }
    }
    st.print();
    let out = Json::obj(vec![
        ("d_model", Json::num(shape.d_model as f64)),
        ("n_layers", Json::num(shape.n_layers as f64)),
        ("vocab", Json::num(shape.vocab as f64)),
        ("prefix_rows", Json::num(shape.prefix_rows as f64)),
        ("new_tokens", Json::num(shape.new_tokens as f64)),
        ("target_bits", Json::num(4.0)),
        ("smoke", Json::Bool(smoke)),
        ("sweep", Json::Arr(rows_json)),
        ("sampled_sweep", Json::Arr(sampled_json)),
    ]);
    if std::fs::write("BENCH_speculative.json", out.emit()).is_ok() {
        println!("\nwrote BENCH_speculative.json");
    }
    if !smoke && shape.ks.contains(&4) {
        assert!(
            best_k4_speedup > 1.0,
            "speculative decode at k=4 must beat plain decode somewhere in the B sweep \
             (best speedup {best_k4_speedup:.2}x) — check the acceptance column: a draft \
             this coarse only pays off when the target keeps agreeing with it"
        );
    }
}
