//! Table 5 — generation throughput — plus the serving batch sweep.
//!
//! Part 1 (always runs, no artifacts needed): decode-once/multiply-many
//! batch sweep on a synthetic 2-bit QuIP# model. For B ∈ {1, 2, 4, 8, 16}
//! it measures (a) the sequence-at-a-time baseline (B independent
//! `decode_one` loops — the old engine hot path, which re-decodes every
//! codeword B times per step) against (b) one batched `decode_batch`
//! call per step, and writes tokens/s, speedup and effective weight
//! bytes/token to `BENCH_generation.json`. The batched step is also
//! timed with the per-sequence attention walk (`AttnMode::PerSeq`) so
//! the attention columns isolate what the cross-sequence fused kernel
//! contributes end to end; the kernel-level picture (shared-prefix
//! block reuse) is `bench_attention.rs` / `BENCH_attention.json`.
//!
//! Part 2 (always runs): the paged-KV pool-pressure sweep — the engine
//! with a pool sized for ~half the worst-case batch, driven by more
//! requests than worst-case-ctx reservation could ever admit at once.
//! Reports peak concurrently admitted sequences, preemptions, and
//! tokens/s into the same `BENCH_generation.json`, for the fp32 pool
//! and for the 2-bit compressed KV tier (`kv_bits: 2`, which also
//! swaps preempt-restart for spill/restore — `prefill_tokens` must
//! stay at the ideal). The dedicated fp32-vs-quantized A/B with the
//! concurrency assertions is `bench_kvquant.rs`.
//!
//! Part 3 (always runs): the shared-prefix sweep — N sequences over one
//! long registered system prompt, with and without copy-on-write prefix
//! sharing, plus a constrained-pool run sized at the shared working set.
//! Reports peak pool pages, admitted sequences, skipped prefill and
//! tokens/s into the same `BENCH_generation.json`.
//!
//! Part 4 (requires `make artifacts`): the paper's Table 5 — tok/s and %
//! of memory-bandwidth roofline for 2-bit / 4-bit QuIP# vs fp32 on the
//! trained model family. The paper's shape: 2-bit > 4-bit > fp16 tok/s,
//! with %-of-roofline growing with model size.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use quipsharp::bench::{best_of, memcpy_roofline_mt_gbps, Table};
use quipsharp::experiments::Runner;
use quipsharp::generation::{argmax, AttnMode, Generator, KvCache};
use quipsharp::model::{Model, ModelConfig};
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::serve::{Engine, EngineOptions, EngineRequest, NativeEngine};
use quipsharp::util::json::Json;

/// Sequence-at-a-time baseline: B independent decode_one loops.
fn time_loop(gen: &Generator, bsz: usize, prompt: &[u8], warmup: usize, steps: usize) -> f64 {
    let mut caches: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(gen.model)).collect();
    let mut logits: Vec<Vec<f32>> = vec![Vec::new(); bsz];
    for (b, c) in caches.iter_mut().enumerate() {
        for &t in prompt {
            logits[b] = gen.decode_one(t, c);
        }
    }
    let mut advance = |logits: &mut Vec<Vec<f32>>, caches: &mut Vec<KvCache>| {
        for b in 0..bsz {
            let t = argmax(&logits[b]) as u8;
            logits[b] = gen.decode_one(t, &mut caches[b]);
        }
    };
    for _ in 0..warmup {
        advance(&mut logits, &mut caches);
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        advance(&mut logits, &mut caches);
    }
    t0.elapsed().as_secs_f64()
}

/// Batch-native path: one decode_batch call per step.
fn time_batched(gen: &Generator, bsz: usize, prompt: &[u8], warmup: usize, steps: usize) -> f64 {
    let mut caches: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(gen.model)).collect();
    let mut logits: Vec<Vec<f32>> = vec![vec![0.0f32]; bsz];
    for &t in prompt {
        let toks = vec![t; bsz];
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        logits = gen.decode_batch(&toks, &mut refs);
    }
    let mut advance = |logits: &mut Vec<Vec<f32>>, caches: &mut Vec<KvCache>| {
        let toks: Vec<u8> = logits.iter().map(|l| argmax(l) as u8).collect();
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        *logits = gen.decode_batch(&toks, &mut refs);
    };
    for _ in 0..warmup {
        advance(&mut logits, &mut caches);
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        advance(&mut logits, &mut caches);
    }
    t0.elapsed().as_secs_f64()
}

fn batch_sweep() -> Vec<(&'static str, Json)> {
    println!("== batch sweep: decode-once/multiply-many vs sequence-at-a-time ==");
    println!("(synthetic 's' model, 2-bit QuIP#, greedy decode)\n");
    let model = Model::random(ModelConfig::by_name("s").unwrap(), 11);
    // Identity Hessians: quantization quality is irrelevant to decode
    // throughput, and skipping calibration keeps the bench fast.
    let qm = quantize_model(
        &model,
        &BTreeMap::new(),
        &Method::QuipSharp { bits: 2, ft: false },
        7,
    )
    .unwrap();
    let gen = qm.generator();
    let mut gen_perseq = qm.generator();
    gen_perseq.attn_mode = AttnMode::PerSeq;
    let wbpt = gen.weight_bytes_per_token() as f64;
    let prompt: Vec<u8> = vec![10, 4, 7, 1];
    let (warmup, steps, reps) = (4usize, 32usize, 3usize);

    let mut t = Table::new(&[
        "B",
        "loop tok/s",
        "batched tok/s",
        "speedup",
        "perseq-attn tok/s",
        "attn speedup",
        "loop B/tok",
        "batched B/tok",
    ]);
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut b1_loop_tps = 0.0f64;
    for &bsz in &[1usize, 2, 4, 8, 16] {
        let dt_loop = best_of(reps, || time_loop(&gen, bsz, &prompt, warmup, steps));
        let dt_batch = best_of(reps, || time_batched(&gen, bsz, &prompt, warmup, steps));
        let dt_perseq = best_of(reps, || time_batched(&gen_perseq, bsz, &prompt, warmup, steps));
        let toks = (bsz * steps) as f64;
        let tps_loop = toks / dt_loop;
        let tps_batch = toks / dt_batch;
        let tps_perseq = toks / dt_perseq;
        if bsz == 1 {
            b1_loop_tps = tps_loop;
        }
        // Effective weight bytes streamed per generated token: the loop
        // re-decodes every codeword per sequence; the batched step
        // amortizes packed codes across the batch (the fp32 lm_head still
        // streams per lane — `weight_bytes_streamed_per_step` accounts
        // for both, so this is the honest figure, not wbpt/B).
        let bytes_loop = wbpt;
        let bytes_batch = gen.weight_bytes_streamed_per_step(bsz) as f64 / bsz as f64;
        let speedup = tps_batch / tps_loop;
        let attn_speedup = tps_batch / tps_perseq;
        t.row(&[
            format!("{bsz}"),
            format!("{tps_loop:.1}"),
            format!("{tps_batch:.1}"),
            format!("{speedup:.2}x"),
            format!("{tps_perseq:.1}"),
            format!("{attn_speedup:.2}x"),
            format!("{bytes_loop:.0}"),
            format!("{bytes_batch:.0}"),
        ]);
        sweep_rows.push(Json::obj(vec![
            ("batch", Json::num(bsz as f64)),
            ("loop_tok_per_sec", Json::num(tps_loop)),
            ("batched_tok_per_sec", Json::num(tps_batch)),
            ("speedup", Json::num(speedup)),
            ("perseq_attn_tok_per_sec", Json::num(tps_perseq)),
            ("attn_speedup", Json::num(attn_speedup)),
            ("loop_bytes_per_token", Json::num(bytes_loop)),
            ("batched_bytes_per_token", Json::num(bytes_batch)),
        ]));
    }
    t.print();
    t.write_csv("bench_generation_batch").ok();
    vec![
        ("model", Json::str("s-synthetic")),
        ("method", Json::str("quip#-2bit")),
        ("decode_steps", Json::num(steps as f64)),
        ("weight_bytes_per_token", Json::num(wbpt)),
        ("b1_loop_tok_per_sec", Json::num(b1_loop_tps)),
        ("sweep", Json::Arr(sweep_rows)),
    ]
}

/// Pool-pressure sweep: the paged engine with a KV pool sized for ~half
/// the worst-case batch. Worst-case-ctx contiguous reservation could
/// admit only `pool_pages / pages_per_seq` sequences; the paged engine
/// admits by actual usage and preempts under pressure, so it runs
/// strictly more concurrently while every request still completes.
///
/// The same workload then runs with the 2-bit KV compression tier
/// (`kv_bits: 2`): cold pages are charged at their compressed size, so
/// the pool sustains more concurrent sequences at equal pool bytes
/// (`mean_batch`), and preemptions spill to the host arena and restore
/// instead of restarting prefill (`prefill_tokens` stays at the ideal).
/// `bench_kvquant.rs` is the dedicated A/B with the tight assertions;
/// this sweep records the headline numbers alongside the fp32 run.
fn pool_pressure() -> Json {
    println!("\n== pool pressure: paged admission vs worst-case-ctx reservation ==");
    let model = Model::random(ModelConfig::by_name("s").unwrap(), 12);
    let qm = Arc::new(
        quantize_model(
            &model,
            &BTreeMap::new(),
            &Method::QuipSharp { bits: 2, ft: false },
            7,
        )
        .unwrap(),
    );
    let model_arc = Arc::new(Model::new(qm.model.cfg.clone(), qm.model.params.clone()));
    let max_batch = 8usize;
    let pages_per_seq = quipsharp::generation::paged::pages_per_seq(&model_arc.cfg);
    // Half the worst-case batch footprint.
    let pool_pages = max_batch * pages_per_seq / 2;
    let worst_case_admissible = pool_pages / pages_per_seq;
    // Sequences grow to 4 + 140 = 144 rows = 5 pages, so a full batch
    // outgrows the pool mid-flight and preemption must kick in.
    let (n_requests, max_new) = (16usize, 140usize);
    let ideal_prefill = (n_requests * 4) as u64;

    let run = |kv_bits: usize| -> Json {
        let eng = NativeEngine::start_with_opts(
            model_arc.clone(),
            Some(qm.clone()),
            EngineOptions {
                max_batch,
                pool_pages: Some(pool_pages),
                kv_bits,
                kv_hot_pages: 0,
                ..EngineOptions::default()
            },
        );
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..n_requests {
            rxs.push(eng.submit(EngineRequest {
                id: i as u64,
                prompt: vec![(i % 50) as u8, 3, 9, 27],
                max_new,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }));
        }
        let mut tokens = 0usize;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            tokens += resp.tokens.len();
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = eng.metrics();
        eng.stop();
        eng.join();
        Json::obj(vec![
            ("kv_bits", Json::num(kv_bits as f64)),
            (
                "peak_admitted",
                Json::num(m.peak_batch.load(Ordering::Relaxed) as f64),
            ),
            ("mean_batch", Json::num(m.mean_batch())),
            (
                "preemptions",
                Json::num(m.preemptions.load(Ordering::Relaxed) as f64),
            ),
            (
                "prefill_tokens",
                Json::num(m.prefill_tokens.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_pages_quantized",
                Json::num(m.kv_pages_quantized.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_spills",
                Json::num(m.kv_spills.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_restores",
                Json::num(m.kv_restores.load(Ordering::Relaxed) as f64),
            ),
            ("tok_per_sec", Json::num(tokens as f64 / dt)),
        ])
    };

    let fp32 = run(0);
    let quant = run(2);
    let peak_admitted = fp32.get("peak_admitted").as_f64().unwrap() as usize;
    let preemptions = fp32.get("preemptions").as_f64().unwrap();
    let mut t = Table::new(&[
        "kv",
        "pool pages",
        "worst-case admits",
        "peak admitted",
        "mean batch",
        "preemptions",
        "prefill toks",
        "tok/s",
    ]);
    for (label, r) in [("fp32", &fp32), ("2-bit", &quant)] {
        t.row(&[
            label.to_string(),
            format!("{pool_pages}"),
            format!("{worst_case_admissible}"),
            format!("{}", r.get("peak_admitted").as_f64().unwrap_or(0.0)),
            format!("{:.2}", r.get("mean_batch").as_f64().unwrap_or(0.0)),
            format!("{}", r.get("preemptions").as_f64().unwrap_or(0.0)),
            format!("{}", r.get("prefill_tokens").as_f64().unwrap_or(0.0)),
            format!("{:.1}", r.get("tok_per_sec").as_f64().unwrap_or(0.0)),
        ]);
    }
    t.print();
    t.write_csv("bench_generation_pool").ok();
    assert!(
        peak_admitted > worst_case_admissible,
        "paged admission ({peak_admitted}) must beat worst-case reservation ({worst_case_admissible})"
    );
    let q_prefill = quant.get("prefill_tokens").as_f64().unwrap() as u64;
    assert_eq!(
        q_prefill, ideal_prefill,
        "spill/restore must eliminate re-prefills (got {q_prefill}, ideal {ideal_prefill})"
    );
    Json::obj(vec![
        ("pool_pages", Json::num(pool_pages as f64)),
        ("pages_per_seq_worst_case", Json::num(pages_per_seq as f64)),
        (
            "worst_case_admissible",
            Json::num(worst_case_admissible as f64),
        ),
        ("peak_admitted", Json::num(peak_admitted as f64)),
        ("preemptions", Json::num(preemptions)),
        ("requests", Json::num(n_requests as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("ideal_prefill_tokens", Json::num(ideal_prefill as f64)),
        ("tok_per_sec", fp32.get("tok_per_sec").clone()),
        ("fp32", fp32),
        ("kv_quant_2bit", quant),
    ])
}

/// Shared-prefix sweep: N sequences over one long registered system
/// prompt, with and without copy-on-write prefix sharing. Sharing must
/// strictly lower peak pool pressure (the prefix's pages are held once,
/// not N times) and skip the prefix's prefill compute on every hit; a
/// constrained pool then shows the freed pages translating directly
/// into admitted concurrency.
fn shared_prefix() -> Json {
    println!("\n== shared prefix: copy-on-write forks vs per-request prefill ==");
    let model = Model::random(ModelConfig::by_name("s").unwrap(), 13);
    let qm = Arc::new(
        quantize_model(
            &model,
            &BTreeMap::new(),
            &Method::QuipSharp { bits: 2, ft: false },
            7,
        )
        .unwrap(),
    );
    let model_arc = Arc::new(Model::new(qm.model.cfg.clone(), qm.model.params.clone()));
    let page_rows = quipsharp::generation::paged::PAGE_ROWS;
    let pages_per_seq = quipsharp::generation::paged::pages_per_seq(&model_arc.cfg);
    let max_batch = 8usize;
    let n_requests = 8usize;
    // Four full pages of system prompt, a short unique suffix each.
    let prefix_tokens = 4 * page_rows;
    let prefix: Vec<u8> = (0..prefix_tokens).map(|i| ((i * 7 + 3) % 50) as u8).collect();
    let (suffix_len, max_new) = (4usize, 24usize);

    let run = |share: bool, pool_pages: usize| -> Json {
        let eng = NativeEngine::start_with_pool(
            model_arc.clone(),
            Some(qm.clone()),
            max_batch,
            pool_pages,
        );
        if share {
            assert!(eng.register_prefix(1, prefix.clone()));
        }
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..n_requests {
            let mut prompt = prefix.clone();
            prompt.extend((0..suffix_len).map(|j| ((i * 11 + j * 5 + 1) % 50) as u8));
            rxs.push(eng.submit(EngineRequest {
                id: i as u64,
                prompt,
                max_new,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }));
        }
        let mut tokens = 0usize;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            tokens += resp.tokens.len();
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = eng.metrics();
        eng.stop();
        eng.join();
        let peak_pages = m.peak_pages_in_use.load(Ordering::Relaxed);
        Json::obj(vec![
            ("sharing", Json::Bool(share)),
            ("pool_pages", Json::num(pool_pages as f64)),
            ("peak_pages_in_use", Json::num(peak_pages as f64)),
            (
                "peak_admitted",
                Json::num(m.peak_batch.load(Ordering::Relaxed) as f64),
            ),
            ("mean_batch", Json::num(m.mean_batch())),
            (
                "prefix_hits",
                Json::num(m.prefix_hits.load(Ordering::Relaxed) as f64),
            ),
            (
                "pages_saved",
                Json::num(m.pages_saved.load(Ordering::Relaxed) as f64),
            ),
            (
                "prefill_tokens",
                Json::num(m.prefill_tokens.load(Ordering::Relaxed) as f64),
            ),
            (
                "preemptions",
                Json::num(m.preemptions.load(Ordering::Relaxed) as f64),
            ),
            ("tok_per_sec", Json::num(tokens as f64 / dt)),
        ])
    };

    // An ample (worst-case) pool isolates the footprint effect…
    let ample = max_batch * pages_per_seq;
    let unshared = run(false, ample);
    let shared = run(true, ample);
    // …and a pool sized at the shared working set (prefix pages + one
    // growth page per sequence) shows the capacity effect: unshared it
    // sustains ⌊pool / pages-per-request⌋ sequences of this shape,
    // shared it runs all N at once.
    let pages_per_request = (prefix_tokens + suffix_len + max_new).div_ceil(page_rows);
    let constrained_pool = prefix_tokens / page_rows + n_requests;
    let unshared_sustainable = constrained_pool / pages_per_request;
    let shared_tight = run(true, constrained_pool);

    let mut t = Table::new(&[
        "mode",
        "pool pages",
        "peak pages",
        "peak admitted",
        "prefill toks",
        "tok/s",
    ]);
    for (label, r) in [
        ("unshared", &unshared),
        ("shared", &shared),
        ("shared (tight pool)", &shared_tight),
    ] {
        t.row(&[
            label.to_string(),
            format!("{}", r.get("pool_pages").as_f64().unwrap_or(0.0)),
            format!("{}", r.get("peak_pages_in_use").as_f64().unwrap_or(0.0)),
            format!("{}", r.get("peak_admitted").as_f64().unwrap_or(0.0)),
            format!("{}", r.get("prefill_tokens").as_f64().unwrap_or(0.0)),
            format!("{:.1}", r.get("tok_per_sec").as_f64().unwrap_or(0.0)),
        ]);
    }
    t.print();
    t.write_csv("bench_generation_shared_prefix").ok();

    let peak_unshared = unshared.get("peak_pages_in_use").as_f64().unwrap();
    let peak_shared = shared.get("peak_pages_in_use").as_f64().unwrap();
    assert!(
        peak_shared < peak_unshared,
        "sharing must strictly lower peak pool pressure ({peak_shared} vs {peak_unshared})"
    );
    let tight_admitted = shared_tight.get("peak_admitted").as_f64().unwrap() as usize;
    assert!(
        tight_admitted > unshared_sustainable,
        "a {constrained_pool}-page pool admitted {tight_admitted} shared sequences, \
         not above the unshared sustainable {unshared_sustainable}"
    );

    Json::obj(vec![
        ("prefix_tokens", Json::num(prefix_tokens as f64)),
        ("suffix_tokens", Json::num(suffix_len as f64)),
        ("n_requests", Json::num(n_requests as f64)),
        ("max_new", Json::num(max_new as f64)),
        (
            "pages_per_request_unshared",
            Json::num(pages_per_request as f64),
        ),
        (
            "unshared_sustainable_in_constrained_pool",
            Json::num(unshared_sustainable as f64),
        ),
        ("unshared", unshared),
        ("shared", shared),
        ("shared_constrained_pool", shared_tight),
    ])
}

fn table5() {
    let mut runner = match Runner::new("artifacts") {
        Ok(r) => r,
        Err(e) => {
            println!("\nTable 5 skipped (run `make artifacts`): {e}");
            return;
        }
    };
    let roof = memcpy_roofline_mt_gbps(64 << 20);
    println!("\n== Table 5: generation throughput (roofline {roof:.1} GB/s) ==\n");
    let mut t = Table::new(&["model", "variant", "tok/s", "weight GB/s", "% roofline"]);

    for size in ["s", "m"] {
        let Ok(model) = runner.model(size) else { continue };
        let variants: Vec<(String, Option<Method>)> = vec![
            ("fp32".into(), None),
            ("2bit".into(), Some(Method::QuipSharp { bits: 2, ft: false })),
            ("4bit".into(), Some(Method::QuipSharp { bits: 4, ft: false })),
        ];
        for (label, method) in variants {
            let qm = method.as_ref().map(|m| runner.qmodel(size, m).unwrap());
            let gen = match &qm {
                Some(q) => q.generator(),
                None => Generator::dense(&model),
            };
            // Generate tokens (decode-only timing after a short prompt).
            let prompt: Vec<u8> = b"the ".to_vec();
            let mut cache = KvCache::new(gen.model);
            let mut logits = vec![0.0f32; gen.model.cfg.vocab];
            for &p in &prompt {
                logits = gen.decode_one(p, &mut cache);
            }
            let n_tokens = gen.model.cfg.ctx - prompt.len() - 1;
            let t0 = Instant::now();
            for _ in 0..n_tokens {
                let next = argmax(&logits) as u8;
                logits = gen.decode_one(next, &mut cache);
            }
            let dt = t0.elapsed().as_secs_f64();
            let tok_s = n_tokens as f64 / dt;
            let bytes_per_tok = gen.weight_bytes_per_token() as f64;
            let gbps = tok_s * bytes_per_tok / 1e9;
            t.row(&[
                size.to_string(),
                label,
                format!("{tok_s:.1}"),
                format!("{gbps:.2}"),
                format!("{:.1}%", 100.0 * gbps / roof),
            ]);
        }
    }
    t.print();
    t.write_csv("bench_generation_table5").ok();
}

fn main() {
    let mut entries = batch_sweep();
    entries.push(("pool_pressure", pool_pressure()));
    entries.push(("shared_prefix", shared_prefix()));
    let out = Json::obj(entries);
    if std::fs::write("BENCH_generation.json", out.emit()).is_ok() {
        println!("\nwrote BENCH_generation.json");
    }
    table5();
}
