//! Table 5 — generation throughput: tok/s and % of memory-bandwidth
//! roofline for 2-bit / 4-bit QuIP# vs fp32, on the trained model family
//! (requires `make artifacts`). The paper's shape: 2-bit > 4-bit > fp16
//! tok/s, with %-of-roofline growing with model size.

use std::time::Instant;

use quipsharp::bench::{memcpy_roofline_mt_gbps, Table};
use quipsharp::experiments::Runner;
use quipsharp::generation::{Generator, KvCache};
use quipsharp::quant::pipeline::Method;

fn main() {
    let mut runner = match Runner::new("artifacts") {
        Ok(r) => r,
        Err(e) => {
            println!("bench_generation skipped (run `make artifacts`): {e}");
            return;
        }
    };
    let roof = memcpy_roofline_mt_gbps(64 << 20);
    println!("== Table 5: generation throughput (roofline {roof:.1} GB/s) ==\n");
    let mut t = Table::new(&["model", "variant", "tok/s", "weight GB/s", "% roofline"]);

    for size in ["s", "m"] {
        let Ok(model) = runner.model(size) else { continue };
        let variants: Vec<(String, Option<Method>)> = vec![
            ("fp32".into(), None),
            ("2bit".into(), Some(Method::QuipSharp { bits: 2, ft: false })),
            ("4bit".into(), Some(Method::QuipSharp { bits: 4, ft: false })),
        ];
        for (label, method) in variants {
            let qm = method.as_ref().map(|m| runner.qmodel(size, m).unwrap());
            let gen = match &qm {
                Some(q) => Generator::quantized(&q.model, q),
                None => Generator::dense(&model),
            };
            // Generate tokens (decode-only timing after a short prompt).
            let prompt: Vec<u8> = b"the ".to_vec();
            let mut cache = KvCache::new(gen.model);
            let mut logits = vec![0.0f32; gen.model.cfg.vocab];
            for &p in &prompt {
                logits = gen.decode_one(p, &mut cache);
            }
            let n_tokens = gen.model.cfg.ctx - prompt.len() - 1;
            let t0 = Instant::now();
            for _ in 0..n_tokens {
                let next = quipsharp::generation::argmax(&logits) as u8;
                logits = gen.decode_one(next, &mut cache);
            }
            let dt = t0.elapsed().as_secs_f64();
            let tok_s = n_tokens as f64 / dt;
            let bytes_per_tok = gen.weight_bytes_per_token() as f64;
            let gbps = tok_s * bytes_per_tok / 1e9;
            t.row(&[
                size.to_string(),
                label,
                format!("{tok_s:.1}"),
                format!("{gbps:.2}"),
                format!("{:.1}%", 100.0 * gbps / roof),
            ]);
        }
    }
    t.print();
    t.write_csv("bench_generation_table5").ok();
}
