//! Table 6 — QuIP# vs AQLM-like vs FP16 matvec throughput. The paper's
//! point: AQLM's per-layer 2^16×8 fp16 codebook (1 MiB) does not fit in
//! L1, so random-access decode is *slower than fp16*, while E8P's 1 KiB
//! table decodes faster than fp16 streams.

use std::time::Duration;

use quipsharp::bench::{Bench, Table};
use quipsharp::linalg::ldl::random_spd;
use quipsharp::linalg::Matrix;
use quipsharp::model::qlinear::{dense_matvec, BigCodebookMatvec, QuantMatvec};
use quipsharp::quant::pipeline::{quantize_matrix, Method};
use quipsharp::util::rng::Pcg64;

fn main() {
    println!("== Table 6: decode throughput — E8P vs big-codebook VQ vs fp32 ==\n");
    let mut t = Table::new(&["variant", "m×n", "codebook", "median/matvec", "rel. to fp32"]);
    let mut rng = Pcg64::new(2);

    // 2048² is already past LLC on this box; 4096² only adds
    // quantization time, not information.
    for &(m, n) in &[(1024usize, 1024usize), (2048, 2048)] {
        let x: Vec<f32> = rng.gaussian_vec(n, 1.0);
        let mut y = vec![0.0f32; m];

        // fp32 dense reference.
        let wd: Vec<f32> = rng.gaussian_vec(m * n, 0.02);
        let r_fp = Bench::new("fp32")
            .budget(Duration::from_millis(500))
            .run(|| {
                dense_matvec(&wd, &x, m, n, &mut y);
                y[0]
            });
        let fp_ns = r_fp.median_ns() as f64;
        t.row(&[
            "fp32".into(),
            format!("{m}x{n}"),
            "-".into(),
            format!("{:.3} ms", fp_ns / 1e6),
            "1.00x".into(),
        ]);

        // QuIP# E8P (8 KiB f32 table — L1-resident).
        let w = Matrix::gaussian(m, n, 0.02, &mut rng);
        let h = random_spd(n, 0.5, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 7).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        let r_q = Bench::new("e8p")
            .budget(Duration::from_millis(500))
            .run(|| {
                qm.matvec(&x, &mut y);
                y[0]
            });
        t.row(&[
            "quip#-e8p-2bit".into(),
            format!("{m}x{n}"),
            "8 KiB (L1)".into(),
            format!("{:.3} ms", r_q.median_ns() as f64 / 1e6),
            format!("{:.2}x", fp_ns / r_q.median_ns() as f64),
        ]);

        // AQLM-like: 2^16 × 8 f32 table (2 MiB) with random-access codes.
        let big = BigCodebookMatvec::random(m, n, 1 << 16, 3);
        let r_big = Bench::new("aqlm-like")
            .budget(Duration::from_millis(500))
            .run(|| {
                big.matvec(&x, &mut y);
                y[0]
            });
        t.row(&[
            "aqlm-like-2bit".into(),
            format!("{m}x{n}"),
            "2 MiB (spills L1/L2)".into(),
            format!("{:.3} ms", r_big.median_ns() as f64 / 1e6),
            format!("{:.2}x", fp_ns / r_big.median_ns() as f64),
        ]);
    }
    t.print();
    t.write_csv("bench_table6_aqlm").ok();
    println!("\n(>1.00x = faster than fp32. Paper Table 6 shape: E8P > fp16 > AQLM.)");
}
