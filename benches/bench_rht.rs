//! §3 cost claims: RHT Θ(n log n) vs QuIP's Kronecker Θ(n√n), plus the
//! H_q ⊗ H_p mixed path for non-power-of-2 dims (e.g. 384 = 12·32).

use std::time::Duration;

use quipsharp::bench::{Bench, Table};
use quipsharp::quant::incoherence::{IncoherenceKind, Transform};
use quipsharp::util::rng::Pcg64;

fn main() {
    println!("== bench_rht: incoherence transform cost (§3) ==\n");
    let mut t = Table::new(&["transform", "n", "median/apply", "ns per element"]);
    let mut rng = Pcg64::new(1);

    for &n in &[256usize, 384, 1024, 1536, 4096, 16384] {
        for kind in [IncoherenceKind::Rht, IncoherenceKind::Rfft, IncoherenceKind::Kron2] {
            let tr = Transform::new(kind, n, &mut rng);
            let mut x: Vec<f64> = rng.gaussian_vec(n, 1.0).iter().map(|&v| v as f64).collect();
            let r = Bench::new(format!("{kind:?}-{n}"))
                .budget(Duration::from_millis(250))
                .run(|| {
                    tr.apply(&mut x);
                    x[0]
                });
            t.row(&[
                format!("{kind:?}"),
                format!("{n}"),
                format!("{:.2} us", r.median_ns() as f64 / 1e3),
                format!("{:.2}", r.median_ns() as f64 / n as f64),
            ]);
        }
    }
    t.print();
    t.write_csv("bench_rht").ok();
    println!("\n(RHT per-element cost should grow ~log n; Kron ~√n — the §3 asymptotics.)");
}
