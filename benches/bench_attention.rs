//! Attention-kernel micro-bench — the cross-sequence fused block walk
//! (`fused_batch_attention`) against the per-sequence baseline
//! (`blocked_attention`), isolated from the rest of decode. Writes
//! `BENCH_attention.json` (field reference in `BENCHMARKS.md`).
//!
//! Two scenarios per batch size B:
//!
//! * **shared**: B sequences forked off one prefilled parent
//!   (`PagedKv::fork_prefix`), so every lane's page table aliases the
//!   same physical pool pages — the shape prompt-prefix sharing
//!   produces in the serving engine. The fused walk loads each K/V
//!   block from memory once per step and services all B lanes while it
//!   is cache-hot; the per-sequence walk re-streams it B times.
//! * **unshared**: B private sequences of the same length — no
//!   aliasing, so the kernels differ only in loop order and locality.
//!
//! Each measurement times full attention passes (every lane attends
//! over the whole prefix at a fixed position) and reports lanes
//! processed per second as `tok_per_sec` — the attention share of a
//! decode step, not end-to-end decode throughput (the batch sweep in
//! `BENCH_generation.json` covers that). Fused and per-sequence
//! outputs are compared bit-for-bit before any timing; the full run
//! additionally asserts that the fused kernel beats the per-sequence
//! walk on the shared-prefix B = 8 case.
//!
//! `--smoke` (wired as `make bench-attention-smoke`, run in CI)
//! shrinks the shapes to run in seconds and skips the perf assertion
//! (bit-parity is still checked); the full run
//! (`make bench-attention`) sizes the prefix well past cache so the
//! shared-block reuse is visible.

use std::time::Instant;

use quipsharp::bench::{best_of, Table};
use quipsharp::generation::paged::{
    blocked_attention, fused_batch_attention, AttnLane, KvPagePool, PagedKv, PAGE_ROWS,
};
use quipsharp::util::json::Json;
use quipsharp::util::rng::Pcg64;

/// Workload shape: one layer, `rows` prefix rows per lane, a
/// `heads × hd` attention geometry.
struct Shape {
    heads: usize,
    hd: usize,
    rows: usize,
    batches: &'static [usize],
    warmup: usize,
    steps: usize,
    reps: usize,
}

/// Full run: 32 MiB of K+V per lane image (8192 rows × 512 d_model),
/// far past any L2, so re-streaming shared blocks per sequence costs
/// real memory traffic.
const FULL: Shape = Shape {
    heads: 8,
    hd: 64,
    rows: 8192,
    batches: &[1, 2, 4, 8, 16],
    warmup: 1,
    steps: 4,
    reps: 3,
};

/// Smoke run (CI): three blocks with a partial tail, a head_dim off
/// the chunk width — seconds of runtime, parity checks only.
const SMOKE: Shape = Shape {
    heads: 2,
    hd: 12,
    rows: 2 * PAGE_ROWS + 5,
    batches: &[1, 4, 8],
    warmup: 1,
    steps: 2,
    reps: 2,
};

/// Fill rows `[0, rows)` of `kv` (layer 0) with uniform random K/V.
fn fill_rows(kv: &PagedKv, pool: &mut KvPagePool, d: usize, rows: usize, rng: &mut Pcg64) {
    let mut k = vec![0.0f32; d];
    let mut v = vec![0.0f32; d];
    for pos in 0..rows {
        for x in k.iter_mut() {
            *x = rng.f32() - 0.5;
        }
        for x in v.iter_mut() {
            *x = rng.f32() - 0.5;
        }
        kv.store(pool, 0, pos, &k, &v);
    }
}

/// Build B lanes over `rows` KV rows each: forks of one shared parent
/// (aliased page tables) or fully private sequences.
fn setup(shape: &Shape, bsz: usize, shared: bool, seed: u64) -> (KvPagePool, Vec<PagedKv>) {
    let d = shape.heads * shape.hd;
    let pages_per_lane = shape.rows.div_ceil(PAGE_ROWS);
    let mut rng = Pcg64::new(seed);
    if shared {
        let mut pool = KvPagePool::new(1, d, pages_per_lane);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, shape.rows));
        parent.len = shape.rows;
        fill_rows(&parent, &mut pool, d, shape.rows, &mut rng);
        let mut seqs = Vec::with_capacity(bsz);
        for _ in 0..bsz {
            let mut kv = PagedKv::new();
            kv.fork_prefix(&mut pool, &parent, shape.rows);
            seqs.push(kv);
        }
        // The parent's page table is dropped without releasing its
        // refs, mirroring a pinned prefix cache: the pages stay shared
        // for the lanes' lifetime. The pool is torn down per config.
        (pool, seqs)
    } else {
        let mut pool = KvPagePool::new(1, d, bsz * pages_per_lane);
        let mut seqs = Vec::with_capacity(bsz);
        for _ in 0..bsz {
            let mut kv = PagedKv::new();
            assert!(kv.reserve(&mut pool, shape.rows));
            fill_rows(&kv, &mut pool, d, shape.rows, &mut rng);
            kv.len = shape.rows;
            seqs.push(kv);
        }
        (pool, seqs)
    }
}

/// Per-sequence baseline: each lane walks its own pages through
/// `blocked_attention`.
fn perseq_walk(pool: &KvPagePool, seqs: &[&PagedKv], q: &[f32], out: &mut [f32], shape: &Shape) {
    let (heads, hd) = (shape.heads, shape.hd);
    let d = heads * hd;
    for (b, kv) in seqs.iter().enumerate() {
        let pos = kv.len - 1;
        blocked_attention(
            &q[b * d..(b + 1) * d],
            &mut out[b * d..(b + 1) * d],
            pos,
            heads,
            hd,
            |blk| {
                let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
                let page = kv.pages[blk];
                (
                    &pool.k_block(page, 0)[..rows * d],
                    &pool.v_block(page, 0)[..rows * d],
                )
            },
        );
    }
}

/// Fused cross-sequence walk: one pass over block indices, lanes
/// grouped by physical page.
fn fused_walk(pool: &KvPagePool, seqs: &[&PagedKv], q: &[f32], out: &mut [f32], shape: &Shape) {
    let (heads, hd) = (shape.heads, shape.hd);
    let d = heads * hd;
    let mut lanes: Vec<AttnLane> = out
        .chunks_exact_mut(d)
        .enumerate()
        .map(|(b, ob)| AttnLane {
            q: &q[b * d..(b + 1) * d],
            out: ob,
            pos: seqs[b].len - 1,
        })
        .collect();
    fused_batch_attention(&mut lanes, heads, hd, |b, blk| {
        let pos = seqs[b].len - 1;
        let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
        let page = seqs[b].pages[blk];
        (
            page as u64,
            &pool.k_block(page, 0)[..rows * d],
            &pool.v_block(page, 0)[..rows * d],
        )
    });
}

fn time_passes<F: FnMut()>(warmup: usize, steps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        f();
    }
    t0.elapsed().as_secs_f64()
}

fn run_config(shape: &Shape, bsz: usize, shared: bool) -> Json {
    let d = shape.heads * shape.hd;
    let (pool, seqs) = setup(shape, bsz, shared, 42 + 2 * bsz as u64 + shared as u64);
    let seq_refs: Vec<&PagedKv> = seqs.iter().collect();
    let mut rng = Pcg64::new_stream(7, bsz as u64);
    let q: Vec<f32> = (0..bsz * d).map(|_| rng.f32() - 0.5).collect();
    let mut out_seq = vec![0.0f32; bsz * d];
    let mut out_fused = vec![0.0f32; bsz * d];
    // Bit-parity before timing: the two kernels must agree exactly.
    perseq_walk(&pool, &seq_refs, &q, &mut out_seq, shape);
    fused_walk(&pool, &seq_refs, &q, &mut out_fused, shape);
    for (i, (a, b)) in out_fused.iter().zip(&out_seq).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "fused vs per-seq mismatch at {i}: {a} vs {b} (B={bsz} shared={shared})"
        );
    }
    let dt_seq = best_of(shape.reps, || {
        time_passes(shape.warmup, shape.steps, || {
            perseq_walk(&pool, &seq_refs, &q, &mut out_seq, shape)
        })
    });
    let dt_fused = best_of(shape.reps, || {
        time_passes(shape.warmup, shape.steps, || {
            fused_walk(&pool, &seq_refs, &q, &mut out_fused, shape)
        })
    });
    let lanes = (bsz * shape.steps) as f64;
    let tps_seq = lanes / dt_seq;
    let tps_fused = lanes / dt_fused;
    Json::obj(vec![
        ("batch", Json::num(bsz as f64)),
        ("shared", Json::Bool(shared)),
        ("perseq_tok_per_sec", Json::num(tps_seq)),
        ("fused_tok_per_sec", Json::num(tps_fused)),
        ("speedup", Json::num(tps_fused / tps_seq)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let shape = if smoke { SMOKE } else { FULL };
    let d = shape.heads * shape.hd;
    println!("== attention micro-bench: fused cross-sequence walk vs per-sequence ==");
    println!(
        "(1 layer, d_model {d}, {} heads x {} head_dim, {} prefix rows{})\n",
        shape.heads,
        shape.hd,
        shape.rows,
        if smoke { ", SMOKE" } else { "" }
    );
    let mut t = Table::new(&["B", "mode", "per-seq tok/s", "fused tok/s", "speedup"]);
    let mut rows_json: Vec<Json> = Vec::new();
    let mut shared_b8_speedup = None;
    for &shared in &[false, true] {
        for &bsz in shape.batches {
            let r = run_config(&shape, bsz, shared);
            let tps_seq = r.get("perseq_tok_per_sec").as_f64().unwrap();
            let tps_fused = r.get("fused_tok_per_sec").as_f64().unwrap();
            let speedup = r.get("speedup").as_f64().unwrap();
            if shared && bsz == 8 {
                shared_b8_speedup = Some(speedup);
            }
            let mode = if shared { "shared" } else { "unshared" };
            t.row(&[
                format!("{bsz}"),
                mode.to_string(),
                format!("{tps_seq:.1}"),
                format!("{tps_fused:.1}"),
                format!("{speedup:.2}x"),
            ]);
            rows_json.push(r);
        }
    }
    t.print();
    t.write_csv("bench_attention").ok();
    let out = Json::obj(vec![
        ("heads", Json::num(shape.heads as f64)),
        ("head_dim", Json::num(shape.hd as f64)),
        ("d_model", Json::num(d as f64)),
        ("prefix_rows", Json::num(shape.rows as f64)),
        ("page_rows", Json::num(PAGE_ROWS as f64)),
        ("attn_steps", Json::num(shape.steps as f64)),
        ("smoke", Json::Bool(smoke)),
        ("sweep", Json::Arr(rows_json)),
    ]);
    if std::fs::write("BENCH_attention.json", out.emit()).is_ok() {
        println!("\nwrote BENCH_attention.json");
    }
    if !smoke {
        let s = shared_b8_speedup.expect("B=8 shared row missing");
        assert!(
            s > 1.0,
            "fused attention must beat the per-sequence walk on the shared-prefix \
             B=8 case (speedup {s:.2}x)"
        );
    }
}
