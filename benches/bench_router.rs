//! Fleet routing A/B: prefix-affinity vs round-robin (and least-loaded)
//! over 2 engine replicas on a shared-prefix workload, at *equal total
//! pool bytes*.
//!
//! The workload has two registered system prefixes (A and B, two full
//! KV pages each) and a stream of requests extending them in equal
//! measure. Prefix-affinity routing sends every A-request to one
//! replica and every B-request to the other, so each replica builds
//! *one* prefix cache and its children fork it; round-robin mixes both
//! prefixes onto both replicas, so each replica builds *both* caches —
//! twice the pool spent on cache pages, and under pressure the cold one
//! thrashes (evicted, then rebuilt on the next hit). The headline
//! number is **aggregate admitted concurrency**: the sum over replicas
//! of `mean_batch`, the time-averaged number of sequences each decode
//! step carried.
//!
//! Assertions (structural, not timing-based):
//!   * every arm's tokens are bitwise-identical to a single reference
//!     engine's (routing never changes tokens);
//!   * prefix-affinity aggregate admitted concurrency strictly above
//!     round-robin at equal per-replica pool pages;
//!   * prefix-affinity prefills strictly fewer prompt tokens (one cache
//!     build per replica instead of two, no rebuild thrash).
//!
//! `--smoke` (wired as `make bench-router-smoke`, run in CI) shrinks
//! request count and decode length; the assertions are identical.
//! Results land in `BENCH_router.json`.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use quipsharp::bench::Table;
use quipsharp::generation::paged::PAGE_ROWS;
use quipsharp::model::{Model, ModelConfig};
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::serve::{
    Engine, EngineOptions, EngineRequest, NativeEngine, RoutePolicy, Router, RouterOptions,
};
use quipsharp::util::json::Json;

struct Shape {
    n_requests: usize,
    max_new: usize,
}

const FULL: Shape = Shape {
    n_requests: 16,
    max_new: 40,
};
/// CI shape: same structure, seconds-scale.
const SMOKE: Shape = Shape {
    n_requests: 8,
    max_new: 16,
};

const REPLICAS: usize = 2;
/// Per-replica KV pool. Each prefix cache is 2 full pages and each
/// child costs 2 pages of its own (4-token suffix + decode), so with
/// one resident cache a replica batches 3 children, with both resident
/// only 2 — the gap the affinity policy exists to open.
const POOL_PAGES: usize = 8;
const MAX_BATCH: usize = 6;
/// Two full pages exactly: forks alias both, no copy-on-write tail.
const PREFIX_LEN: usize = 2 * PAGE_ROWS;

fn prefix_tokens(which: usize) -> Vec<u8> {
    (0..PREFIX_LEN)
        .map(|j| ((j * 7 + which * 23 + 3) % 50) as u8)
        .collect()
}

/// Requests in A A B B A A B B … order: round-robin then lands both
/// prefixes on both replicas, while affinity partitions them no matter
/// the order.
fn requests(shape: &Shape) -> Vec<EngineRequest> {
    (0..shape.n_requests)
        .map(|i| {
            let which = (i / 2) % 2;
            let mut prompt = prefix_tokens(which);
            prompt.extend_from_slice(&[(60 + i) as u8, 9, (i % 7) as u8, 1]);
            EngineRequest {
                id: i as u64,
                prompt,
                max_new: shape.max_new,
                prefix_id: Some(which as u64 + 1),
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }
        })
        .collect()
}

struct RunStats {
    aggregate_mean_batch: f64,
    prefix_hits: u64,
    prefix_evictions: u64,
    prefill_tokens: u64,
    preemptions: u64,
    rerouted: u64,
    tok_per_sec: f64,
    outputs: BTreeMap<u64, Vec<u8>>,
}

fn run(
    model: &Arc<Model>,
    qm: &Arc<quipsharp::qmodel::QuantizedModel>,
    policy: RoutePolicy,
    shape: &Shape,
) -> RunStats {
    let replicas: Vec<Arc<NativeEngine>> = NativeEngine::start_replicas(
        model.clone(),
        Some(qm.clone()),
        REPLICAS,
        EngineOptions {
            max_batch: MAX_BATCH,
            pool_pages: Some(POOL_PAGES),
            ..EngineOptions::default()
        },
    )
    .into_iter()
    .map(Arc::new)
    .collect();
    let dyns: Vec<Arc<dyn Engine>> = replicas
        .iter()
        .map(|e| e.clone() as Arc<dyn Engine>)
        .collect();
    let router = Router::new(
        dyns,
        RouterOptions {
            policy,
            // Keep the arms clean: affinity never spills here, so the
            // A/B measures pure policy effect.
            spill_margin: 1000,
            ..RouterOptions::default()
        },
    );
    for which in 0..2 {
        assert!(router.register_prefix(which as u64 + 1, prefix_tokens(which)));
    }

    let reqs = requests(shape);
    let t0 = Instant::now();
    let rxs: Vec<_> = reqs.iter().map(|r| router.submit(r.clone())).collect();
    let mut outputs = BTreeMap::new();
    let mut tokens = 0usize;
    for (req, rx) in reqs.iter().zip(rxs) {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), shape.max_new, "request truncated");
        tokens += resp.tokens.len();
        outputs.insert(resp.id, resp.tokens);
    }
    let dt = t0.elapsed().as_secs_f64();

    let mut s = RunStats {
        aggregate_mean_batch: 0.0,
        prefix_hits: 0,
        prefix_evictions: 0,
        prefill_tokens: 0,
        preemptions: 0,
        rerouted: router.metrics().requests_rerouted.load(Ordering::Relaxed),
        tok_per_sec: tokens as f64 / dt,
        outputs,
    };
    for e in &replicas {
        let m = e.metrics();
        s.aggregate_mean_batch += m.mean_batch();
        s.prefix_hits += m.prefix_hits.load(Ordering::Relaxed);
        s.prefix_evictions += m.prefix_evictions.load(Ordering::Relaxed);
        s.prefill_tokens += m.prefill_tokens.load(Ordering::Relaxed);
        s.preemptions += m.preemptions.load(Ordering::Relaxed);
    }
    router.stop();
    drop(router);
    for e in replicas {
        e.join();
    }
    s
}

fn stats_json(s: &RunStats) -> Json {
    Json::obj(vec![
        ("aggregate_mean_batch", Json::num(s.aggregate_mean_batch)),
        ("prefix_hits", Json::num(s.prefix_hits as f64)),
        ("prefix_evictions", Json::num(s.prefix_evictions as f64)),
        ("prefill_tokens", Json::num(s.prefill_tokens as f64)),
        ("preemptions", Json::num(s.preemptions as f64)),
        ("requests_rerouted", Json::num(s.rerouted as f64)),
        ("tok_per_sec", Json::num(s.tok_per_sec)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let shape = if smoke { SMOKE } else { FULL };
    let model = Model::random(ModelConfig::by_name("s").unwrap(), 21);
    // Identity Hessians: quantization quality is irrelevant here and
    // skipping calibration keeps the bench fast.
    let qm = Arc::new(
        quantize_model(
            &model,
            &BTreeMap::new(),
            &Method::QuipSharp { bits: 2, ft: false },
            7,
        )
        .unwrap(),
    );
    // The one dense-weight copy every replica shares.
    let model_arc = qm.serving_model();
    println!(
        "== router A/B: prefix-affinity vs round-robin, {REPLICAS} replicas x \
         {POOL_PAGES} pool pages{} ==",
        if smoke { ", SMOKE" } else { "" }
    );
    println!(
        "({} requests over 2 shared prefixes of {} tokens, {} new tokens each)\n",
        shape.n_requests, PREFIX_LEN, shape.max_new
    );

    // Single-engine reference for the exactness assertion: worst-case
    // pool, no routing.
    let reqs = requests(&shape);
    let reference = NativeEngine::start(model_arc.clone(), Some(qm.clone()), MAX_BATCH);
    for which in 0..2 {
        assert!(reference.register_prefix(which as u64 + 1, prefix_tokens(which)));
    }
    let mut want = BTreeMap::new();
    let rxs: Vec<_> = reqs.iter().map(|r| reference.submit(r.clone())).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        want.insert(resp.id, resp.tokens);
    }
    reference.stop();
    reference.join();

    let arms = [
        ("prefix", RoutePolicy::Prefix),
        ("rr", RoutePolicy::RoundRobin),
        ("least-loaded", RoutePolicy::LeastLoaded),
    ];
    let mut results: Vec<(&str, RunStats)> = Vec::new();
    for (label, policy) in arms {
        let s = run(&model_arc, &qm, policy, &shape);
        assert_eq!(
            s.outputs, want,
            "{label} routing changed tokens vs the single engine"
        );
        results.push((label, s));
    }

    let mut t = Table::new(&[
        "route",
        "agg mean batch",
        "prefix hits",
        "evictions",
        "prefill toks",
        "preempt",
        "tok/s",
    ]);
    for (label, s) in &results {
        t.row(&[
            label.to_string(),
            format!("{:.2}", s.aggregate_mean_batch),
            format!("{}", s.prefix_hits),
            format!("{}", s.prefix_evictions),
            format!("{}", s.prefill_tokens),
            format!("{}", s.preemptions),
            format!("{:.1}", s.tok_per_sec),
        ]);
    }
    t.print();
    t.write_csv("bench_router").ok();

    let affinity = &results[0].1;
    let rr = &results[1].1;
    // The acceptance criterion: affinity buys strictly more sustained
    // concurrency than round-robin at equal total pool bytes.
    assert!(
        affinity.aggregate_mean_batch > rr.aggregate_mean_batch,
        "prefix-affinity must sustain more aggregate concurrency than \
         round-robin at equal pool bytes ({:.2} vs {:.2})",
        affinity.aggregate_mean_batch,
        rr.aggregate_mean_batch
    );
    // One cache build per replica instead of two (plus rebuild thrash):
    // strictly less prefill work.
    assert!(
        affinity.prefill_tokens < rr.prefill_tokens,
        "prefix-affinity should prefill less than round-robin ({} vs {})",
        affinity.prefill_tokens,
        rr.prefill_tokens
    );
    // Every request forked a registered prefix in every arm.
    for (label, s) in &results {
        assert_eq!(
            s.prefix_hits, shape.n_requests as u64,
            "{label}: every request should hit a registered prefix"
        );
        assert_eq!(s.rerouted, 0, "{label}: healthy fleet re-routed");
    }

    let out = Json::obj(vec![
        ("model", Json::str("s-synthetic")),
        ("method", Json::str("quip#-2bit-weights")),
        ("smoke", Json::Bool(smoke)),
        ("replicas", Json::num(REPLICAS as f64)),
        ("pool_pages_per_replica", Json::num(POOL_PAGES as f64)),
        ("max_batch_per_replica", Json::num(MAX_BATCH as f64)),
        ("n_requests", Json::num(shape.n_requests as f64)),
        ("prefix_tokens", Json::num(PREFIX_LEN as f64)),
        ("max_new", Json::num(shape.max_new as f64)),
        (
            "prefix_affinity",
            stats_json(&results[0].1),
        ),
        ("round_robin", stats_json(&results[1].1)),
        ("least_loaded", stats_json(&results[2].1)),
    ]);
    if std::fs::write("BENCH_router.json", out.emit()).is_ok() {
        println!("\nwrote BENCH_router.json");
    }
}
