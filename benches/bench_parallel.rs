//! Core-scaling roofline bench for the persistent decode pool: writes
//! `BENCH_parallel.json` (field reference in `BENCHMARKS.md`).
//!
//! Sweeps the worker-pool thread budget (`QUIPSHARP_THREADS`-equivalent,
//! set programmatically via `threadpool::set_num_threads`) over
//! {1, 2, 4, …, ncores} × batch ∈ {1, 8} and measures batched decode
//! throughput on a synthetic 2-bit QuIP# model. Alongside tokens/s it
//! reports the model's achieved weight-stream bandwidth — the per-step
//! packed-code bytes from `Generator::weight_bytes_streamed_per_step`
//! divided by measured step time — next to a pool-dispatched
//! multi-threaded memcpy roofline, so the table shows exactly where
//! scaling stops being core-bound and becomes bandwidth-bound: tokens/s
//! climbs with threads until model GB/s approaches memcpy GB/s, after
//! which extra cores only contend for the memory controller.
//!
//! Before timing anything the bench runs a parity preflight: a short
//! greedy decode at 1 thread and at the maximum swept budget must agree
//! bit for bit (the pool's kernels are bit-exact by construction; see
//! `rust/tests/parallel.rs` for the full matrix).
//!
//! `--smoke` shrinks the model and step counts for CI wiring checks;
//! scaling acceptance (monotonic 1→4 threads at B = 8, ≥2× at 4 threads
//! unless bandwidth-bound) is only enforced on full runs with ≥ 4 cores.

use std::collections::BTreeMap;
use std::time::Instant;

use quipsharp::bench::{best_of, memcpy_roofline_mt_gbps, Table};
use quipsharp::generation::{argmax, Generator, KvCache};
use quipsharp::model::qlinear::decode8_kernel_name;
use quipsharp::model::{Model, ModelConfig};
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::util::json::Json;
use quipsharp::util::threadpool;

/// Batch-native greedy decode: one `decode_batch` call per step, timed.
fn time_batched(gen: &Generator, bsz: usize, prompt: &[u8], warmup: usize, steps: usize) -> f64 {
    let mut caches: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(gen.model)).collect();
    let mut logits: Vec<Vec<f32>> = vec![vec![0.0f32]; bsz];
    for &t in prompt {
        let toks = vec![t; bsz];
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        logits = gen.decode_batch(&toks, &mut refs);
    }
    let mut advance = |logits: &mut Vec<Vec<f32>>, caches: &mut Vec<KvCache>| {
        let toks: Vec<u8> = logits.iter().map(|l| argmax(l) as u8).collect();
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        *logits = gen.decode_batch(&toks, &mut refs);
    };
    for _ in 0..warmup {
        advance(&mut logits, &mut caches);
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        advance(&mut logits, &mut caches);
    }
    t0.elapsed().as_secs_f64()
}

/// Short greedy decode returning the final logits as bit patterns — the
/// parity preflight payload.
fn decode_bits(gen: &Generator, bsz: usize, prompt: &[u8], steps: usize) -> Vec<u32> {
    let mut caches: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(gen.model)).collect();
    let mut logits: Vec<Vec<f32>> = vec![vec![0.0f32]; bsz];
    for &t in prompt {
        let toks = vec![t; bsz];
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        logits = gen.decode_batch(&toks, &mut refs);
    }
    for _ in 0..steps {
        let toks: Vec<u8> = logits.iter().map(|l| argmax(l) as u8).collect();
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        logits = gen.decode_batch(&toks, &mut refs);
    }
    logits.concat().iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ncores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // 1, 2, 4, … up to and always including ncores.
    let mut threads: Vec<usize> = vec![1];
    let mut t = 2;
    while t < ncores {
        threads.push(t);
        t *= 2;
    }
    if ncores > 1 {
        threads.push(ncores);
    }
    let max_t = *threads.last().unwrap();

    let model_name = if smoke { "s" } else { "m" };
    let (warmup, steps, reps) = if smoke { (2, 8, 1) } else { (4, 48, 3) };
    println!("== parallel decode scaling: persistent pool, {ncores} cores ==");
    println!(
        "(synthetic '{model_name}' model, 2-bit QuIP#, decode8 kernel: {}{})\n",
        decode8_kernel_name(),
        if smoke { ", SMOKE" } else { "" }
    );

    let model = Model::random(ModelConfig::by_name(model_name).unwrap(), 11);
    // Identity Hessians: decode throughput does not depend on
    // quantization quality, and skipping calibration keeps setup fast.
    let qm = quantize_model(
        &model,
        &BTreeMap::new(),
        &Method::QuipSharp { bits: 2, ft: false },
        7,
    )
    .unwrap();
    let gen = qm.generator();
    let prompt: Vec<u8> = vec![10, 4, 7, 1];

    // Parity preflight: serial vs widest budget, bit for bit.
    let serial = threadpool::with_threads(1, || decode_bits(&gen, 8, &prompt, 4));
    let widest = threadpool::with_threads(max_t, || decode_bits(&gen, 8, &prompt, 4));
    assert_eq!(
        serial, widest,
        "parallel decode diverged from serial at {max_t} threads"
    );
    println!("parity preflight: 1 vs {max_t} threads bit-exact over 4 greedy steps\n");

    let batches = [1usize, 8];
    let mut table = Table::new(&["threads", "B", "tok/s", "model GB/s", "speedup vs 1T"]);
    let mut rows_json: Vec<Json> = Vec::new();
    // tok/s at each swept thread count for B = 8 (the scaling criterion).
    let mut b8_tps: Vec<(usize, f64)> = Vec::new();
    let mut best_gbps = 0.0f64;
    for &nt in &threads {
        threadpool::set_num_threads(nt);
        for &bsz in &batches {
            let secs = best_of(reps, || time_batched(&gen, bsz, &prompt, warmup, steps));
            let tps = (bsz * steps) as f64 / secs;
            let streamed = gen.weight_bytes_streamed_per_step(bsz) as f64;
            let gbps = streamed * steps as f64 / secs / 1e9;
            best_gbps = best_gbps.max(gbps);
            let speedup = if bsz == 8 {
                b8_tps.push((nt, tps));
                b8_tps[0].1
            } else {
                rows_json
                    .iter()
                    .find_map(|r| {
                        (r.get("threads").as_usize() == Some(1)
                            && r.get("batch").as_usize() == Some(bsz))
                        .then(|| r.get("tok_per_sec").as_f64().unwrap())
                    })
                    .unwrap_or(tps)
            };
            table.row(&[
                format!("{nt}"),
                format!("{bsz}"),
                format!("{tps:.1}"),
                format!("{gbps:.2}"),
                format!("{:.2}x", tps / speedup.max(1e-12)),
            ]);
            rows_json.push(Json::obj(vec![
                ("threads", Json::num(nt as f64)),
                ("batch", Json::num(bsz as f64)),
                ("tok_per_sec", Json::num(tps)),
                ("model_gbps", Json::num(gbps)),
                ("streamed_bytes_per_step", Json::num(streamed)),
            ]));
        }
    }
    table.print();
    table.write_csv("bench_parallel").ok();

    // Memory-bus ceiling, measured through the same pool dispatch the
    // decode kernels use, at the widest thread budget.
    threadpool::set_num_threads(max_t);
    let roof_size = if smoke { 8 << 20 } else { 64 << 20 };
    let roof_gbps = memcpy_roofline_mt_gbps(roof_size);
    println!("\nmemcpy roofline ({max_t} threads): {roof_gbps:.2} GB/s");
    println!("best model weight-stream bandwidth: {best_gbps:.2} GB/s");

    // Scaling acceptance at B = 8: tokens/s monotonic from 1 to 4
    // threads and ≥ 2x at 4 threads — unless the sweep is already
    // bandwidth-bound (model GB/s a large fraction of memcpy GB/s),
    // in which case flat scaling is the expected roofline behavior.
    let bandwidth_bound = best_gbps >= 0.6 * roof_gbps;
    let upto4: Vec<&(usize, f64)> = b8_tps.iter().filter(|(nt, _)| *nt <= 4).collect();
    let monotonic = upto4.windows(2).all(|w| w[1].1 >= w[0].1 * 0.98);
    let speedup_at_4 = upto4
        .iter()
        .find(|(nt, _)| *nt == 4)
        .map(|(_, tps)| tps / b8_tps[0].1);
    let verdict = if ncores < 4 || smoke {
        "not-measurable (smoke run or < 4 cores)".to_string()
    } else if bandwidth_bound {
        format!(
            "bandwidth-bound: model streams {best_gbps:.1} GB/s of a {roof_gbps:.1} GB/s \
             memcpy roofline, so thread scaling is limited by the memory bus"
        )
    } else if monotonic && speedup_at_4.is_some_and(|s| s >= 2.0) {
        "core-bound scaling ok: monotonic 1->4 threads, >=2x at 4 threads".to_string()
    } else {
        format!(
            "scaling below target (monotonic={monotonic}, speedup@4={:?})",
            speedup_at_4
        )
    };
    println!("scaling verdict (B=8): {verdict}");
    if !smoke && ncores >= 4 && !bandwidth_bound {
        assert!(
            monotonic && speedup_at_4.is_some_and(|s| s >= 2.0),
            "B=8 decode failed the core-scaling target and is not bandwidth-bound: {verdict}"
        );
    }

    let stats = threadpool::stats();
    let out = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("ncores", Json::num(ncores as f64)),
        ("model", Json::str(model_name)),
        ("decode8_kernel", Json::str(decode8_kernel_name())),
        ("threads_swept", Json::arr_usize(&threads)),
        ("rows", Json::Arr(rows_json)),
        ("memcpy_roofline_gbps", Json::num(roof_gbps)),
        ("best_model_gbps", Json::num(best_gbps)),
        ("bandwidth_bound", Json::Bool(bandwidth_bound)),
        ("scaling_verdict", Json::str(verdict)),
        ("pool_jobs_dispatched", Json::num(stats.pool_jobs as f64)),
        ("pool_workers_spawned", Json::num(stats.workers_spawned as f64)),
    ]);
    if std::fs::write("BENCH_parallel.json", out.emit()).is_ok() {
        println!("\nwrote BENCH_parallel.json");
    }
}
