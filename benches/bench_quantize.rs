//! Quantization-time throughput: weights/second for each method at one
//! layer shape (supports the paper's "Llama 2 70B in <10 GPU-hours"
//! cost narrative at our scale).

use std::time::Duration;

use quipsharp::bench::{Bench, Table};
use quipsharp::linalg::ldl::random_spd;
use quipsharp::linalg::Matrix;
use quipsharp::quant::pipeline::{quantize_matrix, Method};
use quipsharp::util::rng::Pcg64;

fn main() {
    println!("== bench_quantize: per-layer quantization throughput ==\n");
    let mut t = Table::new(&["method", "m×n", "median", "Mweights/s"]);
    let mut rng = Pcg64::new(3);
    let (m, n) = (512usize, 512usize);
    let w = Matrix::gaussian(m, n, 0.02, &mut rng);
    let h = random_spd(n, 0.5, &mut rng);

    let methods = [
        Method::QuipSharp { bits: 2, ft: false },
        Method::QuipSharp { bits: 4, ft: false },
        Method::QuipSharpNoE8 { bits: 2 },
        Method::QuipKron { bits: 2 },
        Method::OmniquantLike { bits: 2, group: None },
        Method::AwqLike { bits: 2 },
    ];
    for method in methods {
        let r = Bench::new(method.label())
            .budget(Duration::from_millis(1500))
            .min_iters(3)
            .run(|| quantize_matrix(&method, &w, &h, 7).unwrap().stats.proxy_err);
        t.row(&[
            method.label(),
            format!("{m}x{n}"),
            format!("{:.1} ms", r.median_ns() as f64 / 1e6),
            format!("{:.2}", (m * n) as f64 * 1e3 / r.median_ns() as f64),
        ]);
    }
    t.print();
    t.write_csv("bench_quantize").ok();
}
