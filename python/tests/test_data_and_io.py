"""tensorio roundtrips, corpus generator determinism, zeroshot task
structure, and (when artifacts exist) AOT manifest consistency."""

import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tensorio
from compile.datagen import Language, make_zeroshot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.integers(min_value=1, max_value=100),
)
def test_tensorio_roundtrip(seed, n):
    rng = np.random.RandomState(seed)
    tensors = {
        "f": rng.randn(n, 3).astype(np.float32),
        "i": rng.randint(-5, 5, size=n).astype(np.int32),
        "u16": rng.randint(0, 2**16, size=n).astype(np.uint16),
        "u8": rng.randint(0, 255, size=n).astype(np.uint8),
    }
    path = f"/tmp/qtz_pytest_{os.getpid()}.qtz"
    tensorio.save(path, tensors)
    back = tensorio.load(path)
    for k, v in tensors.items():
        np.testing.assert_array_equal(back[k], v)
    os.remove(path)


def test_language_deterministic():
    a = Language(seed=123)
    b = Language(seed=123)
    sa = a.stream(1000, seed=1)
    sb = b.stream(1000, seed=1)
    np.testing.assert_array_equal(sa, sb)
    # Different seeds differ.
    sc = a.stream(1000, seed=2)
    assert not np.array_equal(sa, sc)


def test_corpus_is_ascii_words():
    lang = Language()
    s = lang.stream(5000, seed=3)
    assert s.min() >= 0 and s.max() < 128
    text = bytes(s.tolist()).decode("ascii")
    assert ". " in text and " " in text


def test_zeroshot_tasks_well_formed():
    lang = Language()
    for task in ["arce", "arcc", "piqa", "wino"]:
        data = make_zeroshot(lang, task, n=50, seed=7)
        n = len(data["label"])
        assert n == 50
        assert set(np.unique(data["label"])) <= {0, 1}
        # Labels not constant (options are swapped randomly).
        assert 5 < data["label"].sum() < 45
        assert data["prefix_len"].sum() == len(data["prefix"])


def test_wino_task_is_solvable_by_rule():
    # The correct pronoun always matches the last noun's class — verify the
    # generator encodes the rule (option text differs only in pronoun).
    lang = Language()
    data = make_zeroshot(lang, "wino", n=20, seed=9)
    a0 = data["opt_a"][: data["a_len"][0]]
    b0 = data["opt_b"][: data["b_len"][0]]
    sa = bytes(a0.tolist()).decode()
    sb = bytes(b0.tolist()).decode()
    assert {sa, sb} == {"zel", "vok"}


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, spec in manifest["artifacts"].items():
        path = os.path.join(ART, spec["path"])
        assert os.path.exists(path), f"{name}: missing {path}"
        text = open(path).read(200)
        assert "HloModule" in text, f"{name}: not HLO text"
        assert len(spec["inputs"]) >= 1
        assert len(spec["outputs"]) >= 1


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "e8p_tables.qtz")),
    reason="artifacts not built",
)
def test_e8p_tables_artifact_matches_construction():
    from compile.kernels.ref import build_e8p_tables

    stored = tensorio.load(os.path.join(ART, "e8p_tables.qtz"))
    abs_t, par_t = build_e8p_tables()
    np.testing.assert_array_equal(stored["abs_table"], abs_t)
    np.testing.assert_array_equal(stored["parity"], par_t)
