"""L1 Pallas kernels vs the pure-jnp/numpy oracle (`ref.py`), with
hypothesis sweeping shapes and codes. interpret=True throughout."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import e8p as e8p_kernel
from compile.kernels import hadamard as had_kernel
from compile.kernels.ref import (
    build_e8p_tables,
    e8p_matmul_ref,
    fwht_ref,
    had_factor,
    had_transform_ref,
    hadamard_matrix,
)

ABS_T, PAR_T = build_e8p_tables()


@settings(max_examples=20, deadline=None)
@given(
    logn=st.integers(min_value=1, max_value=9),
    rows=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fwht_kernel_matches_ref(logn, rows, seed):
    n = 1 << logn
    x = np.random.RandomState(seed).randn(rows, n).astype(np.float32)
    got = np.asarray(had_kernel.fwht(jnp.asarray(x)))
    want = fwht_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    mt=st.sampled_from([8, 16, 64, 128]),
    nb=st.sampled_from([1, 4, 16, 48]),
    bsz=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_e8p_matmul_kernel_matches_ref(mt, nb, bsz, seed):
    rng = np.random.RandomState(seed)
    codes = rng.randint(0, 2**16, size=(mt, nb)).astype(np.int32)
    x = rng.randn(bsz, nb * 8).astype(np.float32)
    got = np.asarray(
        e8p_kernel.e8p_matmul(
            jnp.asarray(codes), jnp.asarray(x), jnp.asarray(ABS_T),
            jnp.asarray(PAR_T), 1.0, tile_m=min(mt, 64),
        )
    )
    want = e8p_matmul_ref(codes, 1.0, x, ABS_T, PAR_T)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_e8p_scale_commutes(seed):
    rng = np.random.RandomState(seed)
    codes = rng.randint(0, 2**16, size=(16, 4)).astype(np.int32)
    x = rng.randn(2, 32).astype(np.float32)
    a = np.asarray(
        e8p_kernel.e8p_matmul(jnp.asarray(codes), jnp.asarray(x),
                              jnp.asarray(ABS_T), jnp.asarray(PAR_T), 0.37)
    )
    b = 0.37 * np.asarray(
        e8p_kernel.e8p_matmul(jnp.asarray(codes), jnp.asarray(x),
                              jnp.asarray(ABS_T), jnp.asarray(PAR_T), 1.0)
    )
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n", [128, 256, 384, 512, 1536])
def test_had_transform_orthogonal(n):
    p, q, hq = had_factor(n)
    assert p * q == n
    rng = np.random.RandomState(0)
    x = rng.randn(4, n).astype(np.float32)
    y = np.asarray(had_kernel.had_transform(
        jnp.asarray(x), None if hq is None else jnp.asarray(hq.astype(np.float32))
    ))
    # Norm preservation (orthogonality).
    np.testing.assert_allclose(
        np.linalg.norm(y, axis=1), np.linalg.norm(x, axis=1), rtol=1e-4
    )
    # Against the numpy reference.
    want = had_transform_ref(x, hq)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-3)


def test_hadamard_matrices_exist_for_model_dims():
    for n in [12, 20, 28, 128, 384, 1536]:
        p, q, hq = had_factor(n)
        assert p * q == n
        if hq is not None:
            hhT = hq @ hq.T
            np.testing.assert_allclose(hhT, q * np.eye(q), atol=1e-9)


def test_e8p_tables_shape_and_parity():
    assert ABS_T.shape == (256, 8)
    # 227 entries with norm² ≤ 10, 29 with norm² = 12.
    ns = (ABS_T.astype(np.float64) ** 2).sum(axis=1)
    assert int((ns <= 10 + 1e-9).sum()) == 227
    assert int(np.isclose(ns, 12.0).sum()) == 29
    # All entries positive half-odd-integers.
    assert (ABS_T > 0).all()
    assert np.allclose((ABS_T * 2) % 2, 1)
    # Parity definition: odd integer row-sum → 1.
    sums = np.round(ABS_T.sum(axis=1)).astype(int)
    np.testing.assert_array_equal(PAR_T, sums % 2)


def test_e8p_decode_points_in_e8_plus_quarter():
    from compile.kernels.ref import e8p_decode_ref

    rng = np.random.RandomState(1)
    codes = rng.randint(0, 2**16, size=512)
    v = e8p_decode_ref(codes, ABS_T, PAR_T).astype(np.float64)
    for row in v:
        ok = False
        for shift in (0.25, -0.25):
            w = row - shift
            half_int = np.allclose((w * 2) % 2, 1)
            int_ = np.allclose(w % 1, 0)
            s = round(float(w.sum()))
            if (half_int or int_) and abs(w.sum() - s) < 1e-9 and s % 2 == 0:
                ok = True
        assert ok, f"{row} not in E8 + 1/4"
