"""L2 model invariants: shapes, causality, prefill/decode agreement, and
the quantized (Pallas-kernel) linear path against dense reconstruction."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import e8p as e8p_kernel
from compile.kernels.ref import build_e8p_tables, e8p_decode_ref, had_factor
from compile.model import CONFIGS, QLinear, decode_step, forward, init_params, loss_fn

ABS_T, PAR_T = build_e8p_tables()


def test_forward_shapes_all_archs():
    for name in ["s", "moe", "nonllama"]:
        cfg = CONFIGS[name]
        p = init_params(cfg, 0)
        logits = forward(cfg, p, jnp.zeros((2, 16), jnp.int32))
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())


def test_causality():
    cfg = CONFIGS["s"]
    p = init_params(cfg, 1)
    t = np.zeros((1, 12), np.int32)
    t[0] = np.arange(12)
    l1 = forward(cfg, p, jnp.asarray(t))
    t2 = t.copy()
    t2[0, -1] = 99
    l2 = forward(cfg, p, jnp.asarray(t2))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)
    assert float(jnp.abs(l1[0, -1] - l2[0, -1]).max()) > 1e-4


def test_prefill_matches_decode():
    cfg = CONFIGS["s"]
    p = init_params(cfg, 2)
    toks = np.random.RandomState(0).randint(0, 256, size=(1, 6)).astype(np.int32)
    full = forward(cfg, p, jnp.asarray(toks))
    B, L, H, hd, ctx = 1, cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.ctx
    kv_k = jnp.zeros((L, B, ctx, H, hd))
    kv_v = jnp.zeros((L, B, ctx, H, hd))
    for t in range(6):
        logits, kv_k, kv_v = decode_step(
            cfg, p, jnp.asarray(toks[:, t]), jnp.asarray(t, jnp.int32), kv_k, kv_v
        )
    np.testing.assert_allclose(
        np.asarray(logits[0]), np.asarray(full[0, -1]), rtol=1e-3, atol=1e-3
    )


def test_loss_decreases_with_training_steps():
    cfg = CONFIGS["s"]
    p = init_params(cfg, 3)
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(97, 110, size=(8, 33)).astype(np.int32))
    grad_fn = jax.jit(jax.value_and_grad(lambda pp: loss_fn(cfg, pp, toks)))
    l0, g = grad_fn(p)
    for _ in range(10):
        _, g = grad_fn(p)
        p = {k: v - 1e-2 * g[k] for k, v in p.items()}
    l1, _ = grad_fn(p)
    assert float(l1) < float(l0), f"{float(l1)} !< {float(l0)}"


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_qlinear_apply_matches_dense_reconstruction(seed):
    """The full Algorithm-2 path (RHT → e8p matmul → RHTᵀ) must equal the
    dense W_eff = diag(su)·Hᵀ·Ŵ̃·H·diag(sv) reconstruction."""
    rng = np.random.RandomState(seed)
    m, n = 64, 128
    nb = n // 8
    codes = rng.randint(0, 2**16, size=(m, nb)).astype(np.int32)
    scale = 0.11
    su = rng.choice([-1.0, 1.0], size=m).astype(np.float32)
    sv = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    ql = QLinear(
        codes=[jnp.asarray(codes)],
        stage_scales=[scale],
        su=jnp.asarray(su),
        sv=jnp.asarray(sv),
        m=m,
        n=n,
        abs_table=jnp.asarray(ABS_T),
        parity=jnp.asarray(PAR_T),
        hq_m=None,
        hq_n=None,
    )
    x = rng.randn(3, n).astype(np.float32)
    got = np.asarray(e8p_kernel.qlinear_apply(ql, jnp.asarray(x)))

    # Dense reconstruction.
    w_tilde = e8p_decode_ref(codes, ABS_T, PAR_T).reshape(m, n) * scale

    def hmat(k):
        p, q, hq = had_factor(k)
        from compile.kernels.ref import fwht_ref

        eye = np.eye(k, dtype=np.float64)
        return fwht_ref(eye).T / np.sqrt(k)  # pure pow2 here

    hm = hmat(m)
    hn = hmat(n)
    w_eff = np.diag(su) @ hm.T @ w_tilde @ hn @ np.diag(sv)
    want = x @ w_eff.T
    np.testing.assert_allclose(got, want.astype(np.float32), rtol=1e-3, atol=1e-2)
