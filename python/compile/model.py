"""L2 — the JAX model: a Llama-architecture transformer family (plus MoE
and non-Llama variants for Table 9), in two modes:

* fp32 — used for training (`train.py`) and the fp16-row artifacts;
* e8p — every linear layer replaced by the L1 Pallas decode+matmul kernel
  fed packed QuIP# codewords, with the RHT applied to activations around
  it (paper Algorithm 2). This is what `aot.py` lowers for the serving
  runtime.

Weight naming (shared contract with `rust/src/model`):
  embed (V,d) | layers.{i}.attn_norm (d,) | .wq/.wk/.wv/.wo (d,d)
  | .mlp_norm (d,) | .w_gate/.w_up (ff,d) | .w_down (d,ff)
  | final_norm (d,) | lm_head (V,d)
MoE adds .router (E,d) and expert-indexed .w_gate.{e} etc.; the nonllama
variant uses .pos_embed, LayerNorm with .{name}_bias, and a GELU MLP.

Linear convention: y = W @ x with W (out,in) — Hessians are (in,in).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import e8p as e8p_kernel
from .kernels import hadamard as had_kernel


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int = 256
    ctx: int = 256
    arch: str = "llama"  # llama | moe | nonllama
    n_experts: int = 2

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# The model family (DESIGN.md §6). d=384/ff=1536 exercise the paper's
# non-power-of-2 Hadamard path (H_12 ⊗ H_32 / H_12 ⊗ H_128).
CONFIGS = {
    "s": ModelConfig("s", 128, 2, 4, 512),
    "m": ModelConfig("m", 256, 4, 8, 1024),
    "l": ModelConfig("l", 384, 4, 8, 1536),
    "moe": ModelConfig("moe", 128, 2, 4, 512, arch="moe"),
    "nonllama": ModelConfig("nonllama", 128, 2, 4, 512, arch="nonllama"),
}


def linear_layer_names(cfg: ModelConfig) -> list[str]:
    """Every quantizable linear layer, in quantization order."""
    out = []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        out += [p + "wq", p + "wk", p + "wv", p + "wo"]
        if cfg.arch == "moe":
            for e in range(cfg.n_experts):
                out += [p + f"w_gate.{e}", p + f"w_up.{e}", p + f"w_down.{e}"]
        else:
            out += [p + "w_gate", p + "w_up", p + "w_down"]
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    rng = np.random.RandomState(seed)
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def dense(m, n):
        return jnp.asarray(
            rng.normal(0, 1.0 / np.sqrt(n), size=(m, n)), dtype=jnp.float32
        )

    p = {"embed": dense(v, d), "lm_head": dense(v, d)}
    p["final_norm"] = jnp.ones((d,), jnp.float32)
    if cfg.arch == "nonllama":
        p["pos_embed"] = dense(cfg.ctx, d) * 0.1
        p["final_norm_bias"] = jnp.zeros((d,), jnp.float32)
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        p[pre + "attn_norm"] = jnp.ones((d,), jnp.float32)
        p[pre + "mlp_norm"] = jnp.ones((d,), jnp.float32)
        if cfg.arch == "nonllama":
            p[pre + "attn_norm_bias"] = jnp.zeros((d,), jnp.float32)
            p[pre + "mlp_norm_bias"] = jnp.zeros((d,), jnp.float32)
        for nm in ["wq", "wk", "wv", "wo"]:
            p[pre + nm] = dense(d, d)
        if cfg.arch == "moe":
            p[pre + "router"] = dense(cfg.n_experts, d)
            for e in range(cfg.n_experts):
                p[pre + f"w_gate.{e}"] = dense(ff, d)
                p[pre + f"w_up.{e}"] = dense(ff, d)
                p[pre + f"w_down.{e}"] = dense(d, ff)
        else:
            p[pre + "w_gate"] = dense(ff, d)
            p[pre + "w_up"] = dense(ff, d)
            p[pre + "w_down"] = dense(d, ff)
    return p


def rms_norm(x, w):
    return x * w / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def layer_norm(x, w, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * w + b


def rope(q, pos):
    """Rotary embedding. q: (..., S, H, hd); pos: (S,) absolute positions."""
    hd = q.shape[-1]
    half = hd // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
    cos = jnp.cos(ang)[:, None, :]  # (S, 1, half)
    sin = jnp.sin(ang)[:, None, :]
    q1, q2 = q[..., :half], q[..., half:]
    return jnp.concatenate([q1 * cos - q2 * sin, q1 * sin + q2 * cos], axis=-1)


def _attention(q, k, v, mask):
    """q,k,v: (B,S,H,hd) / (B,T,H,hd); mask (S,T) additive."""
    hd = q.shape[-1]
    att = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(hd)
    att = att + mask[None, None, :, :]
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", att, v)


class LinearFn:
    """Dispatch table: fp32 dense or e8p-packed linear application."""

    def __init__(self, params, qparams=None):
        self.params = params
        self.q = qparams

    def __call__(self, name: str, x):
        """x: (..., n) → (..., m)."""
        if self.q is not None and name in self.q:
            return e8p_kernel.qlinear_apply(self.q[name], x)
        w = self.params[name]
        return x @ w.T


def block_llama(cfg, lin, params, i, x, pos, kv=None, new_kv=None):
    """One transformer block. x: (B,S,d). Returns (x, new_kv)."""
    pre = f"layers.{i}."
    B, S, d = x.shape
    h = rms_norm(x, params[pre + "attn_norm"])
    q = lin(pre + "wq", h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = lin(pre + "wk", h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    v = lin(pre + "wv", h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    q = rope(q, pos)
    k = rope(k, pos)
    if kv is None:
        # Prefill: causal mask over S.
        mask = jnp.where(
            jnp.arange(S)[:, None] >= jnp.arange(S)[None, :], 0.0, -1e30
        )
        att = _attention(q, k, v, mask)
        if new_kv is not None:
            new_kv[i] = (k, v)
    else:
        # Decode: append to cache at position pos[0] (S == 1).
        k_cache, v_cache = kv  # (B, ctx, H, hd)
        p = pos[0]
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, p, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, p, 0, 0))
        t = jnp.arange(k_cache.shape[1])
        mask = jnp.where(t[None, :] <= p, 0.0, -1e30)  # (1, ctx)
        att = _attention(q, k_cache, v_cache, mask)
        new_kv[i] = (k_cache, v_cache)
    x = x + lin(pre + "wo", att.reshape(B, S, d))

    h = rms_norm(x, params[pre + "mlp_norm"])
    if cfg.arch == "moe":
        logits_r = h @ params[pre + "router"].T  # (B,S,E)
        gate = jax.nn.softmax(logits_r, axis=-1)
        outs = []
        for e in range(cfg.n_experts):
            ge = jax.nn.silu(lin(pre + f"w_gate.{e}", h)) * lin(pre + f"w_up.{e}", h)
            outs.append(lin(pre + f"w_down.{e}", ge))
        moe = sum(gate[..., e : e + 1] * outs[e] for e in range(cfg.n_experts))
        x = x + moe
    else:
        ff = jax.nn.silu(lin(pre + "w_gate", h)) * lin(pre + "w_up", h)
        x = x + lin(pre + "w_down", ff)
    return x


def block_nonllama(cfg, lin, params, i, x, pos, kv=None, new_kv=None):
    pre = f"layers.{i}."
    B, S, d = x.shape
    h = layer_norm(x, params[pre + "attn_norm"], params[pre + "attn_norm_bias"])
    q = lin(pre + "wq", h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = lin(pre + "wk", h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    v = lin(pre + "wv", h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    if kv is None:
        mask = jnp.where(jnp.arange(S)[:, None] >= jnp.arange(S)[None, :], 0.0, -1e30)
        att = _attention(q, k, v, mask)
        if new_kv is not None:
            new_kv[i] = (k, v)
    else:
        k_cache, v_cache = kv
        p = pos[0]
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, p, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, p, 0, 0))
        t = jnp.arange(k_cache.shape[1])
        mask = jnp.where(t[None, :] <= p, 0.0, -1e30)
        att = _attention(q, k_cache, v_cache, mask)
        new_kv[i] = (k_cache, v_cache)
    x = x + lin(pre + "wo", att.reshape(B, S, d))
    h = layer_norm(x, params[pre + "mlp_norm"], params[pre + "mlp_norm_bias"])
    # GeGLU MLP: same layer inventory as the llama block, different
    # nonlinearity/norm/positional scheme — the Table 9 "non-Llama" point.
    ff = jax.nn.gelu(lin(pre + "w_gate", h)) * lin(pre + "w_up", h)
    x = x + lin(pre + "w_down", ff)
    return x


def forward(cfg: ModelConfig, params, tokens, qparams=None, return_kv=False):
    """Full-sequence forward (training / prefill). tokens: (B,S) int32."""
    lin = LinearFn(params, qparams)
    B, S = tokens.shape
    x = params["embed"][tokens]  # (B,S,d)
    pos = jnp.arange(S)
    if cfg.arch == "nonllama":
        x = x + params["pos_embed"][None, :S, :]
    new_kv = [None] * cfg.n_layers if return_kv else None
    block = block_nonllama if cfg.arch == "nonllama" else block_llama
    for i in range(cfg.n_layers):
        x = block(cfg, lin, params, i, x, pos, kv=None, new_kv=new_kv)
    if cfg.arch == "nonllama":
        x = layer_norm(x, params["final_norm"], params["final_norm_bias"])
    else:
        x = rms_norm(x, params["final_norm"])
    logits = lin("lm_head", x)
    if return_kv:
        return logits, new_kv
    return logits


def decode_step(cfg: ModelConfig, params, token, pos_scalar, kv_k, kv_v, qparams=None):
    """Single-token decode with KV cache.

    token: (B,) int32; pos_scalar: () int32; kv_k/kv_v: (L,B,ctx,H,hd).
    Returns (logits (B,V), kv_k', kv_v').
    """
    lin = LinearFn(params, qparams)
    B = token.shape[0]
    x = params["embed"][token][:, None, :]  # (B,1,d)
    pos = jnp.array([0], dtype=jnp.int32) + pos_scalar
    if cfg.arch == "nonllama":
        pe = params["pos_embed"][pos]  # (1, d)
        x = x + pe[None, :, :]
    new_kv = [None] * cfg.n_layers
    block = block_nonllama if cfg.arch == "nonllama" else block_llama
    for i in range(cfg.n_layers):
        x = block(
            cfg, lin, params, i, x, pos, kv=(kv_k[i], kv_v[i]), new_kv=new_kv
        )
    if cfg.arch == "nonllama":
        x = layer_norm(x, params["final_norm"], params["final_norm_bias"])
    else:
        x = rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"].T)[:, 0, :]
    kv_k2 = jnp.stack([new_kv[i][0] for i in range(cfg.n_layers)])
    kv_v2 = jnp.stack([new_kv[i][1] for i in range(cfg.n_layers)])
    return logits, kv_k2, kv_v2


# ---------------------------------------------------------------------------
# E8P-quantized parameter containers (built by aot.py from the rust export).
# ---------------------------------------------------------------------------


@dataclass
class QLinear:
    """Packed QuIP# linear layer for the jax/Pallas path."""

    codes: list  # per-stage (m, n/8) int32 arrays of 16-bit codewords
    stage_scales: list  # python floats
    su: jnp.ndarray  # (m,)
    sv: jnp.ndarray  # (n,)
    m: int
    n: int
    # Shared decode tables:
    abs_table: jnp.ndarray  # (256, 8)
    parity: jnp.ndarray  # (256,) int32
    # Dense H_q factors for non-power-of-2 dims (None for pure FWHT):
    hq_m: jnp.ndarray | None = None
    hq_n: jnp.ndarray | None = None


def loss_fn(cfg, params, tokens):
    """Next-token cross entropy over a (B,S) batch."""
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)
