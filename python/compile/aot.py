"""AOT lowering: jax/Pallas (L2+L1) → HLO *text* → artifacts/*.hlo.txt.

Text, not `.serialize()`: jax ≥ 0.5 emits HloModuleProto with 64-bit ids
which xla_extension 0.5.1 (the version behind the rust `xla` crate)
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts produced (see manifest.json for the exact input/output specs):
  * {size}_prefill_fp   — tokens (B,S) → logits (B,S,V)         [fp32]
  * {size}_decode_fp    — (weights…, token, pos, kv) → (logits, kv')
  * {size}_decode_e8p   — same but every linear is packed QuIP# codes fed
                          to the L1 Pallas decode+matmul kernel; codes,
                          scales and sign vectors are runtime *inputs* so
                          the rust quantizer's output plugs straight in.
  * e8p_matmul_smoke    — standalone L1 kernel (runtime unit tests).
  * hadamard_smoke      — standalone FWHT kernel.
  * e8p_tables.qtz      — the (256,8) abs table + parity + H_q factors.

Python never runs at serve time; the rust runtime loads these once.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tensorio
from .kernels import e8p as e8p_kernel
from .kernels import hadamard as had_kernel
from .kernels.ref import build_e8p_tables, had_factor
from .model import CONFIGS, QLinear, decode_step, forward, linear_layer_names

DTYPE_TAG = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides big
    # constants as `constant({...})`, which xla_extension 0.5.1's text
    # parser silently replaces with garbage (observed: gathers then return
    # buffer offsets instead of values). Embedded tables (E8P codebook,
    # Hadamard factors, baked weights) would all be corrupted.
    return comp.as_hlo_text(print_large_constants=True)


def spec_of(x) -> dict:
    a = np.asarray(x)
    return {"dtype": DTYPE_TAG[a.dtype], "shape": list(a.shape)}


def lower_and_save(art, name, fn, example_args, manifest, input_names):
    """Lower fn at the example args' shapes, save HLO text + manifest entry."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = f"{name}.hlo.txt"
    with open(os.path.join(art, path), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *example_args)
    flat_outs, _ = jax.tree_util.tree_flatten(outs)
    manifest["artifacts"][name] = {
        "path": path,
        "inputs": [
            {"name": nm, **spec_of(a)} for nm, a in zip(input_names, example_args)
        ],
        "outputs": [
            {"dtype": "f32" if o.dtype == jnp.float32 else "i32", "shape": list(o.shape)}
            for o in flat_outs
        ],
    }
    print(f"lowered {name}: {len(text)} chars, {len(example_args)} inputs")


def flat_weight_order(cfg) -> list[str]:
    """Deterministic weight-input ordering for the fp decode artifact."""
    names = ["embed"]
    if cfg.arch == "nonllama":
        names.append("pos_embed")
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        names += [pre + "attn_norm"]
        if cfg.arch == "nonllama":
            names += [pre + "attn_norm_bias"]
        names += [pre + "wq", pre + "wk", pre + "wv", pre + "wo", pre + "mlp_norm"]
        if cfg.arch == "nonllama":
            names += [pre + "mlp_norm_bias"]
        if cfg.arch == "moe":
            names += [pre + "router"]
            for e in range(cfg.n_experts):
                names += [pre + f"w_gate.{e}", pre + f"w_up.{e}", pre + f"w_down.{e}"]
        else:
            names += [pre + "w_gate", pre + "w_up", pre + "w_down"]
    names += ["final_norm"]
    if cfg.arch == "nonllama":
        names += ["final_norm_bias"]
    names += ["lm_head"]
    return names


def qlinear_input_names(cfg, stages: int) -> list[tuple[str, str]]:
    """(layer, field) pairs for e8p inputs, in artifact order."""
    out = []
    for lname in linear_layer_names(cfg):
        for s in range(stages):
            out.append((lname, f"codes{s}"))
        out.append((lname, "scales"))
        out.append((lname, "su"))
        out.append((lname, "sv"))
    return out


def build_decode_e8p_fn(cfg, stages, abs_t, par_t, hq_cache):
    """Returns (fn, example_args, input_names) for the packed decode step."""
    lin_names = linear_layer_names(cfg)
    shapes = {}
    d, ff = cfg.d_model, cfg.d_ff
    for ln in lin_names:
        base = ln.split(".")[-1] if not ln.split(".")[-1].isdigit() else ln.split(".")[-2]
        if base in ("wq", "wk", "wv", "wo"):
            shapes[ln] = (d, d)
        elif base in ("w_gate", "w_up"):
            shapes[ln] = (ff, d)
        else:  # w_down
            shapes[ln] = (d, ff)

    B = 8
    L, H, hd, ctx = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.ctx
    # Non-quantized parameters (embed, norms, head, routers) come first.
    fp_names = [n for n in flat_weight_order(cfg) if n not in shapes]

    def fn(*args):
        i = 0
        params = {}
        for n in fp_names:
            params[n] = args[i]
            i += 1
        qparams = {}
        for ln in lin_names:
            m, n = shapes[ln]
            codes = []
            for _ in range(stages):
                codes.append(args[i])
                i += 1
            scales = args[i]; i += 1
            su = args[i]; i += 1
            sv = args[i]; i += 1
            ql = QLinear(
                codes=codes,
                stage_scales=[scales[s] for s in range(stages)],
                su=su, sv=sv, m=m, n=n,
                abs_table=abs_t, parity=par_t,
                hq_m=hq_cache.get(m), hq_n=hq_cache.get(n),
            )
            qparams[ln] = ql
        token, pos, kv_k, kv_v = args[i], args[i + 1], args[i + 2], args[i + 3]
        return decode_step(cfg, params, token, pos, kv_k, kv_v, qparams=qparams)

    # Example args.
    ex = []
    names = []
    rng = np.random.RandomState(0)
    dummy = {n: None for n in fp_names}
    from .model import init_params

    p0 = init_params(cfg, seed=0)
    for n in fp_names:
        ex.append(jnp.asarray(p0[n]))
        names.append(n)
        del dummy
        dummy = None
    for ln in lin_names:
        m, n = shapes[ln]
        for s in range(stages):
            ex.append(jnp.zeros((m, n // 8), jnp.int32))
            names.append(f"{ln}.codes{s}")
        ex.append(jnp.ones((stages,), jnp.float32))
        names.append(f"{ln}.scales")
        ex.append(jnp.ones((m,), jnp.float32))
        names.append(f"{ln}.su")
        ex.append(jnp.ones((n,), jnp.float32))
        names.append(f"{ln}.sv")
    ex += [
        jnp.zeros((B,), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((L, B, ctx, H, hd), jnp.float32),
        jnp.zeros((L, B, ctx, H, hd), jnp.float32),
    ]
    names += ["token", "pos", "kv_k", "kv_v"]
    _ = rng
    return fn, ex, names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="../artifacts")
    ap.add_argument("--decode-sizes", default="s,m")
    args = ap.parse_args()
    art = args.art
    manifest = {"artifacts": {}, "models": {}, "tables": "e8p_tables.qtz"}

    # --- shared decode tables -------------------------------------------------
    abs_t_np, par_t_np = build_e8p_tables()
    hq_entries = {}
    for n in sorted({c.d_model for c in CONFIGS.values()}
                    | {c.d_ff for c in CONFIGS.values()}):
        p, q, hq = had_factor(n)
        if hq is not None:
            hq_entries[f"hq_{n}"] = hq.astype(np.float32)
    tensorio.save(
        os.path.join(art, "e8p_tables.qtz"),
        {"abs_table": abs_t_np, "parity": par_t_np, **hq_entries},
    )
    abs_t = jnp.asarray(abs_t_np)
    par_t = jnp.asarray(par_t_np)
    hq_cache = {}
    for k, v in hq_entries.items():
        hq_cache[int(k.split("_")[1])] = jnp.asarray(v)

    # --- model metadata -------------------------------------------------------
    for name, cfg in CONFIGS.items():
        manifest["models"][name] = {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
            "ctx": cfg.ctx, "arch": cfg.arch,
            "weights": f"model_{name}.qtz",
        }

    # --- kernel smoke artifacts ----------------------------------------------
    def e8p_smoke(codes, x):
        return e8p_kernel.e8p_matmul(codes, x, abs_t, par_t, 1.0)

    lower_and_save(
        art, "e8p_matmul_smoke", e8p_smoke,
        [jnp.zeros((64, 32), jnp.int32), jnp.zeros((4, 256), jnp.float32)],
        manifest, ["codes", "x"],
    )

    def had_smoke(x):
        return had_kernel.fwht(x)

    lower_and_save(
        art, "hadamard_smoke", had_smoke,
        [jnp.zeros((8, 256), jnp.float32)], manifest, ["x"],
    )

    # --- model artifacts -------------------------------------------------------
    sizes = args.decode_sizes.split(",")
    for name in sizes:
        cfg = CONFIGS[name]
        weights_path = os.path.join(art, f"model_{name}.qtz")
        weights = tensorio.load(weights_path)
        order = flat_weight_order(cfg)
        B, L, H, hd, ctx = 8, cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.ctx

        # fp prefill (B=1, S=ctx) — weights as runtime inputs (baking them
        # as constants would bloat the HLO text ~100×; see to_hlo_text).
        def prefill(*wargs, _cfg=cfg, _order=tuple(order)):
            nw = len(_order)
            params = dict(zip(_order, wargs[:nw]))
            return forward(_cfg, params, wargs[nw])

        ex_prefill = [jnp.asarray(weights[n]) for n in order] + [
            jnp.zeros((1, cfg.ctx), jnp.int32)
        ]
        lower_and_save(
            art, f"{name}_prefill_fp", prefill, ex_prefill, manifest,
            list(order) + ["tokens"],
        )

        # fp decode step — weights as runtime inputs (manifest order).
        def decode_fp(*wargs, _cfg=cfg, _order=tuple(order)):
            nw = len(_order)
            params = dict(zip(_order, wargs[:nw]))
            token, pos, kv_k, kv_v = wargs[nw:]
            return decode_step(_cfg, params, token, pos, kv_k, kv_v)

        ex = [jnp.asarray(weights[n]) for n in order] + [
            jnp.zeros((B,), jnp.int32),
            jnp.zeros((), jnp.int32),
            jnp.zeros((L, B, ctx, H, hd), jnp.float32),
            jnp.zeros((L, B, ctx, H, hd), jnp.float32),
        ]
        lower_and_save(
            art, f"{name}_decode_fp", decode_fp, ex, manifest,
            list(order) + ["token", "pos", "kv_k", "kv_v"],
        )

        # e8p decode step (2-bit, 1 stage).
        fn, ex, names_in = build_decode_e8p_fn(cfg, 1, abs_t, par_t, hq_cache)
        lower_and_save(art, f"{name}_decode_e8p", fn, ex, manifest, names_in)

    with open(os.path.join(art, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
