"""Pure-jnp / numpy oracles for the L1 Pallas kernels, plus the canonical
python-side E8P table construction (must match `rust/src/quant/codebook/
e8p.rs` bit for bit — the cross-language test compares against the table
exported by the rust CLI).
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# E8P table (mirror of the rust construction: shells of |D̂8| by norm²,
# lexicographic within shell, 227 entries ≤ 10 plus first 29 of norm² 12).
# ---------------------------------------------------------------------------


def _enumerate_abs_by_norm(target_sq: float) -> list[tuple[float, ...]]:
    target_h = round(4 * target_sq)  # in quarter units (h = 2v odd)
    out = []

    def rec(pos, remaining, cur):
        if pos == 8:
            if remaining == 0:
                out.append(tuple(c / 2.0 for c in cur))
            return
        rest_min = 8 - pos - 1
        h = 1
        while h * h + rest_min <= remaining:
            rec(pos + 1, remaining - h * h, cur + [h])
            h += 2

    rec(0, target_h, [])
    return out


def build_e8p_tables() -> tuple[np.ndarray, np.ndarray]:
    """Returns (abs_table (256,8) f32, parity (256,) int32 — 1 when an odd
    number of sign flips is required to land in D̂8)."""
    abs_rows: list[tuple[float, ...]] = []
    for ns in (2.0, 4.0, 6.0, 8.0, 10.0):
        abs_rows.extend(_enumerate_abs_by_norm(ns))
    assert len(abs_rows) == 227, len(abs_rows)
    abs_rows.extend(_enumerate_abs_by_norm(12.0)[:29])
    assert len(abs_rows) == 256
    abs_table = np.array(abs_rows, dtype=np.float32)
    parity = (np.round(abs_table.sum(axis=1)).astype(np.int64) % 2).astype(np.int32)
    return abs_table, parity


def e8p_decode_ref(codes: np.ndarray, abs_table: np.ndarray, parity: np.ndarray) -> np.ndarray:
    """Decode int codes (any shape) → (..., 8) f32. Numpy reference."""
    codes = np.asarray(codes, dtype=np.int64)
    s_idx = codes & 0xFF
    sign_bits = (codes >> 8) & 0x7F
    shift_bit = (codes >> 15) & 1
    s = abs_table[s_idx]  # (..., 8)
    bits = ((sign_bits[..., None] >> np.arange(7)) & 1).astype(np.int64)  # (...,7)
    explicit = bits.sum(axis=-1)
    flip7 = ((explicit % 2) != parity[s_idx]).astype(np.int64)
    all_bits = np.concatenate([bits, flip7[..., None]], axis=-1)  # (...,8)
    signs = 1.0 - 2.0 * all_bits
    shift = np.where(shift_bit == 1, 0.25, -0.25)[..., None]
    return (s * signs + shift).astype(np.float32)


def e8p_matmul_ref(codes, scale, x, abs_table, parity):
    """y = Ŵ x for one stage. codes (m, n/8); x (..., n); returns (..., m)."""
    m, nb = codes.shape
    w = e8p_decode_ref(np.asarray(codes), abs_table, parity).reshape(m, nb * 8)
    w = w * scale
    return np.asarray(x) @ w.T


def fwht_ref(x: np.ndarray) -> np.ndarray:
    """Unnormalized Sylvester FWHT along the last axis (power of 2)."""
    x = np.array(x, dtype=np.float64, copy=True)
    n = x.shape[-1]
    assert n & (n - 1) == 0
    h = 1
    while h < n:
        y = x.reshape(*x.shape[:-1], n // (2 * h), 2, h)
        a = y[..., 0, :].copy()
        b = y[..., 1, :].copy()
        y[..., 0, :] = a + b
        y[..., 1, :] = a - b
        x = y.reshape(*x.shape[:-1], n)
        h *= 2
    return x


def had_transform_ref(x: np.ndarray, hq: np.ndarray | None = None) -> np.ndarray:
    """Orthogonal (H_q ⊗ H_p)/√n transform along the last axis, matching
    rust `HadTransform::apply`: row-wise FWHT over p, dense H_q across q."""
    n = x.shape[-1]
    if hq is None:
        return (fwht_ref(x) / np.sqrt(n)).astype(np.float32)
    q = hq.shape[0]
    p = n // q
    xr = np.asarray(x, dtype=np.float64).reshape(*x.shape[:-1], q, p)
    xr = fwht_ref(xr)
    xr = np.einsum("ij,...jp->...ip", hq.astype(np.float64), xr)
    return (xr.reshape(*x.shape[:-1], n) / np.sqrt(n)).astype(np.float32)


# ---------------------------------------------------------------------------
# Hadamard matrices (Sylvester + Paley I/II) — mirror of
# rust/src/linalg/hadamard.rs for the non-power-of-2 dims.
# ---------------------------------------------------------------------------


def _is_prime(n):
    if n < 2:
        return False
    return all(n % d for d in range(2, int(n**0.5) + 1))


def _legendre(a, p):
    a %= p
    if a == 0:
        return 0
    return 1 if pow(a, (p - 1) // 2, p) == 1 else -1


def _paley1(p):
    n = p + 1
    h = np.zeros((n, n))
    h[0, :] = 1.0
    h[1:, 0] = -1.0
    for i in range(1, n):
        for j in range(1, n):
            h[i, j] = 1.0 if i == j else _legendre(i - j, p)
    return h


def _paley2(p):
    m = p + 1
    c = np.zeros((m, m))
    c[0, 1:] = 1.0
    c[1:, 0] = 1.0
    for i in range(1, m):
        for j in range(1, m):
            if i != j:
                c[i, j] = _legendre(i - j, p)
    n = 2 * m
    h = np.zeros((n, n))
    blocks = {
        0: np.array([[1.0, -1.0], [-1.0, -1.0]]),
        1: np.array([[1.0, 1.0], [1.0, -1.0]]),
        -1: -np.array([[1.0, 1.0], [1.0, -1.0]]),
    }
    for i in range(m):
        for j in range(m):
            h[2 * i : 2 * i + 2, 2 * j : 2 * j + 2] = blocks[int(c[i, j])]
    return h


def hadamard_matrix(n: int) -> np.ndarray | None:
    if n == 1:
        return np.array([[1.0]])
    if n == 2:
        return np.array([[1.0, 1.0], [1.0, -1.0]])
    if n % 4 != 0:
        return None
    if (n & (n - 1)) == 0:  # power of two → Sylvester (matches FWHT order)
        return np.kron(hadamard_matrix(2), hadamard_matrix(n // 2))
    if n - 1 > 2 and _is_prime(n - 1) and (n - 1) % 4 == 3:
        return _paley1(n - 1)
    if n % 2 == 0:
        half = n // 2
        if half >= 2 and _is_prime(half - 1) and (half - 1) % 4 == 1:
            return _paley2(half - 1)
        h = hadamard_matrix(half)
        if h is not None:
            return np.kron(np.array([[1.0, 1.0], [1.0, -1.0]]), h)
    return None


def had_factor(n: int) -> tuple[int, int, np.ndarray | None]:
    """(p, q, H_q) with n = q·p, p the largest power of 2 with H_{n/p}
    constructible — mirror of rust `HadTransform::new`."""
    p = 1 << (n & -n).bit_length() - 1
    q = n // p
    while True:
        if q == 1:
            return p, q, None
        hq = hadamard_matrix(q)
        if hq is not None:
            return p, q, hq
        if p == 1:
            raise ValueError(f"no hadamard factorization for {n}")
        p //= 2
        q *= 2
