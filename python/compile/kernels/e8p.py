"""L1 Pallas kernel: fused E8P decode + matmul (the paper's CUDA
`decode_matvec_e8p` rethought for TPU, Algorithm 2 / Appendix C.2).

Layout per grid step (DESIGN.md §Hardware-Adaptation):
  * the (256, 8) abs table and (256,) parity vector live in VMEM for the
    whole kernel (the "1 KiB codebook in L1" property — VMEM here),
  * a (tile_m, nb) tile of 16-bit codewords streams in from HBM,
  * decode = gather + branch-free sign/parity/shift arithmetic,
  * the decoded (tile_m, n) tile hits the MXU against the activation
    panel (x is kept whole in VMEM; n ≤ 1536 for this model family).

CPU note: lowered with interpret=True; the BlockSpec schedule is still
meaningful (it is what a real Mosaic lowering would use) but wallclock on
CPU is not a TPU proxy — see EXPERIMENTS.md §Perf for the VMEM/MXU
estimate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_tile(codes, abs_table, parity):
    """codes (tm, nb) int32 → weights (tm, nb*8) f32 (codebook units)."""
    tm, nb = codes.shape
    s_idx = codes & 0xFF
    sign_bits = (codes >> 8) & 0x7F
    shift_bit = (codes >> 15) & 1
    s = abs_table[s_idx]  # (tm, nb, 8) gather from VMEM
    par = parity[s_idx]  # (tm, nb)
    bits = (sign_bits[..., None] >> jnp.arange(7, dtype=jnp.int32)) & 1
    explicit = jnp.sum(bits, axis=-1)
    flip7 = ((explicit & 1) != par).astype(jnp.int32)
    all_bits = jnp.concatenate([bits, flip7[..., None]], axis=-1)  # (tm,nb,8)
    signs = (1 - 2 * all_bits).astype(jnp.float32)
    shift = jnp.where(shift_bit == 1, 0.25, -0.25).astype(jnp.float32)
    w = s * signs + shift[..., None]
    return w.reshape(tm, nb * 8)


def _e8p_matmul_kernel(codes_ref, x_ref, abs_ref, par_ref, o_ref, *, scale: float):
    codes = codes_ref[...]
    x = x_ref[...]  # (bx, n)
    w = _decode_tile(codes, abs_ref[...], par_ref[...]) * scale  # (tm, n)
    o_ref[...] = jnp.dot(x, w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("scale", "tile_m"))
def e8p_matmul(codes, x, abs_table, parity, scale: float, tile_m: int = 64):
    """One-stage fused decode+matmul: y = x · Ŵᵀ.

    codes: (m, nb) int32 16-bit codewords; x: (B, n) f32 with n = nb*8;
    returns (B, m) f32. Ŵ = decode(codes)·scale.
    """
    m, nb = codes.shape
    bsz, n = x.shape
    assert n == nb * 8
    tile_m = min(tile_m, m)
    assert m % tile_m == 0, f"m={m} % tile_m={tile_m}"
    return pl.pallas_call(
        functools.partial(_e8p_matmul_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct((bsz, m), jnp.float32),
        grid=(m // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, nb), lambda i: (i, 0)),  # codes tile
            pl.BlockSpec((bsz, n), lambda i: (0, 0)),  # activations (VMEM)
            pl.BlockSpec((256, 8), lambda i: (0, 0)),  # codebook (VMEM)
            pl.BlockSpec((256,), lambda i: (0,)),  # parity (VMEM)
        ],
        out_specs=pl.BlockSpec((bsz, tile_m), lambda i: (0, i)),
        interpret=True,
    )(codes, x, abs_table, parity)


def qlinear_apply(q, x):
    """Apply a packed QuIP# linear layer (model.QLinear) to x (..., n):
    y = S_u ⊙ H_mᵀ( Ŵ̃ · H_n(S_v ⊙ x) ), summing RVQ stages (Alg. 2)."""
    from . import hadamard as had

    lead = x.shape[:-1]
    n = q.n
    xb = x.reshape(-1, n)
    # u = T_v x = H_n (s_v ⊙ x)
    u = had.had_transform(xb * q.sv[None, :], q.hq_n)
    # z = Ŵ̃ u  (sum of RVQ stages). The stage scale may be a traced value
    # (runtime input in the AOT path), so it multiplies *outside* the
    # kernel — scalars commute with the matmul.
    z = 0.0
    for codes, s in zip(q.codes, q.stage_scales):
        z = z + e8p_matmul(codes, u, q.abs_table, q.parity, 1.0) * s
    # y = T_uᵀ z = s_u ⊙ H_mᵀ z. H is symmetric for pure FWHT; for the
    # H_q ⊗ H_p factorization the transpose applies H_qᵀ, handled inside
    # had_transform_t.
    y = had_transform_t(z, q.hq_m)
    y = y * q.su[None, :]
    return y.reshape(*lead, q.m)


def had_transform_t(x, hq=None):
    """Transpose of kernels.hadamard.had_transform (orthogonal inverse)."""
    from . import hadamard as had

    b, n = x.shape
    if hq is None:
        return had.fwht(x) / jnp.sqrt(jnp.asarray(n, x.dtype))
    q = hq.shape[0]
    p = n // q
    # (H_q ⊗ H_p)ᵀ = H_qᵀ ⊗ H_p: dense factor first as transpose.
    xr = x.reshape(b, q, p)
    xr = jnp.einsum("ji,bjp->bip", hq.astype(x.dtype), xr)  # H_qᵀ
    xr = had.fwht(xr.reshape(b * q, p)).reshape(b, q, p)
    return xr.reshape(b, n) / jnp.sqrt(jnp.asarray(n, x.dtype))
