"""L1 Pallas kernel: blocked Fast Walsh–Hadamard transform.

TPU thinking (DESIGN.md §Hardware-Adaptation): one grid step owns a
(block_rows, n) tile resident in VMEM; the log₂(n) butterfly stages are
unrolled inside the kernel as vectorized reshapes — no HBM round-trips
between stages, which is the property the paper's fused CUDA RHT gets
from shared memory. Must run interpret=True on this image (CPU PJRT
cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(x_ref, o_ref, *, n: int):
    """FWHT along the last axis of a (rows, n) VMEM tile."""
    x = x_ref[...]
    rows = x.shape[0]
    h = 1
    while h < n:
        y = x.reshape(rows, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        x = jnp.concatenate([a + b, a - b], axis=2).reshape(rows, n)
        # NOTE: concatenate along axis=2 of (rows, g, h)+(rows, g, h) gives
        # (rows, g, 2h) = [a+b | a-b] which is exactly the butterfly layout.
        h *= 2
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("block_rows",))
def fwht(x: jnp.ndarray, block_rows: int = 8) -> jnp.ndarray:
    """Unnormalized FWHT along the last axis; x: (B, n), n a power of 2."""
    b, n = x.shape
    assert n & (n - 1) == 0, f"n={n} must be a power of 2"
    block_rows = min(block_rows, b)
    # Pad rows to a multiple of block_rows for an even grid.
    pad = (-b) % block_rows
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, n), x.dtype)], axis=0)
    rows = x.shape[0]
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        interpret=True,
    )(x)
    return out[:b]


def had_transform(x: jnp.ndarray, hq: jnp.ndarray | None = None) -> jnp.ndarray:
    """Orthogonal (H_q ⊗ H_p)/√n along the last axis (batched).

    The power-of-2 part runs through the Pallas FWHT kernel; the small
    dense H_q factor (q ∈ {12, 20, 28, ...}) is an einsum the MXU handles
    natively — mirroring rust `HadTransform::apply`.
    """
    b, n = x.shape
    if hq is None:
        return fwht(x) / jnp.sqrt(jnp.asarray(n, x.dtype))
    q = hq.shape[0]
    p = n // q
    xr = x.reshape(b, q, p).reshape(b * q, p)
    xr = fwht(xr).reshape(b, q, p)
    xr = jnp.einsum("ij,bjp->bip", hq.astype(x.dtype), xr)
    return xr.reshape(b, n) / jnp.sqrt(jnp.asarray(n, x.dtype))
