"""`.qtz` tensor container — the python half of `rust/src/util/tensorio.rs`.

Little-endian; see the rust module for the byte layout. Build-time only:
python writes corpora / trained weights / packed tables, rust reads them.
"""

from __future__ import annotations

import struct

import numpy as np

_DTYPES = {
    0: np.float32,
    1: np.int32,
    2: np.uint16,
    3: np.uint8,
    4: np.int64,
}
_DTYPE_TAGS = {np.dtype(v): k for k, v in _DTYPES.items()}

MAGIC = b"QTZ1"


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write a name→array dict. Arrays are cast-checked, not silently cast."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        # Sorted for byte-for-byte determinism (matches rust BTreeMap order).
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in _DTYPE_TAGS:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_TAGS[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            payload = arr.tobytes()
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


def load(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            tag, ndim = struct.unpack("<BB", f.read(2))
            shape = tuple(struct.unpack("<I", f.read(4))[0] for _ in range(ndim))
            (nbytes,) = struct.unpack("<Q", f.read(8))
            data = f.read(nbytes)
            arr = np.frombuffer(data, dtype=_DTYPES[tag]).reshape(shape).copy()
            out[name] = arr
    return out
