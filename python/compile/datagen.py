"""Synthetic-language corpus + zeroshot-task generator (build time).

Substitutes for Wikitext2 / C4 / RedPajama / LM-Eval in the paper's
evaluation (see DESIGN.md §1): a seeded two-level stochastic language —
a word vocabulary with byte-level spellings and a sparse first-order
Markov chain over words, plus an agreement rule (gendered noun → later
pronoun must match) that gives the zeroshot "wino" task something real
to test.

Outputs (all `.qtz`, byte-level tokens, vocab = 256):
  corpus_train / corpus_dev / corpus_calib (Hessians) /
  corpus_test_w2 (same distribution — "Wikitext2-like") /
  corpus_test_c4 (20% alternate transition matrix — "C4-like")
  zeroshot_{arce,arcc,piqa,wino}: prefix/option-pair likelihood tasks.
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from . import tensorio

SEED = 20240207
N_WORDS = 200
BRANCH = 8  # successors per word

# Special "agreement" machinery: two noun classes and two pronouns.
N_NOUNS_A = 12
N_NOUNS_B = 12
PRONOUN_A = "zel"
PRONOUN_B = "vok"


class Language:
    """Deterministic synthetic language."""

    def __init__(self, seed: int = SEED):
        rng = np.random.RandomState(seed)
        letters = np.array(list("abcdefghijklmnopqrstuvwxy"))
        spellings = set()
        words = []
        while len(words) < N_WORDS:
            L = rng.randint(2, 6)
            w = "".join(rng.choice(letters, size=L))
            if w in spellings or w in (PRONOUN_A, PRONOUN_B):
                continue
            spellings.add(w)
            words.append(w)
        # Reserve dedicated pronoun spellings.
        self.words = words + [PRONOUN_A, PRONOUN_B]
        self.pron_a = N_WORDS
        self.pron_b = N_WORDS + 1
        self.nouns_a = list(range(0, N_NOUNS_A))
        self.nouns_b = list(range(N_NOUNS_A, N_NOUNS_A + N_NOUNS_B))
        nv = len(self.words)

        # Sparse Markov successors (primary and alternate "C4" matrix).
        def make_chain(r):
            succ = np.zeros((nv, BRANCH), dtype=np.int64)
            prob = np.zeros((nv, BRANCH), dtype=np.float64)
            for i in range(nv):
                succ[i] = r.choice(nv, size=BRANCH, replace=False)
                p = r.dirichlet(np.ones(BRANCH) * 0.6)
                prob[i] = p
            return succ, prob

        self.succ, self.prob = make_chain(rng)
        self.succ_alt, self.prob_alt = make_chain(np.random.RandomState(seed + 1))
        # Unigram frequency for "plausible but wrong" distractors.
        self.unigram = rng.dirichlet(np.ones(nv) * 2.0)

    def sample_sentence(self, rng, alt=False):
        """Word-id sentence with the agreement rule applied."""
        succ = self.succ_alt if alt else self.succ
        prob = self.prob_alt if alt else self.prob
        n = rng.randint(5, 15)
        w = rng.randint(len(self.words) - 2)  # never start with a pronoun
        out = [w]
        last_gender = None
        for _ in range(n - 1):
            w = succ[w][rng.choice(BRANCH, p=prob[w])]
            # Agreement rule: pronouns are forced to match the last noun.
            if w in (self.pron_a, self.pron_b):
                if last_gender is None:
                    w = int(rng.randint(len(self.words) - 2))
                else:
                    w = self.pron_a if last_gender == "a" else self.pron_b
            if w in self.nouns_a:
                last_gender = "a"
                # Inject a matching pronoun soon with prob 1/2 — gives the
                # model training signal for the rule.
                if rng.rand() < 0.5:
                    out.append(int(w))
                    out.append(self.pron_a)
                    continue
            elif w in self.nouns_b:
                last_gender = "b"
                if rng.rand() < 0.5:
                    out.append(int(w))
                    out.append(self.pron_b)
                    continue
            out.append(int(w))
        return out

    def words_to_bytes(self, word_ids) -> bytes:
        return (" ".join(self.words[w] for w in word_ids) + ". ").encode("ascii")

    def stream(self, n_tokens: int, seed: int, alt_frac: float = 0.0) -> np.ndarray:
        """Byte-token stream of exactly n_tokens."""
        rng = np.random.RandomState(seed)
        chunks = []
        total = 0
        while total < n_tokens:
            alt = rng.rand() < alt_frac
            b = self.words_to_bytes(self.sample_sentence(rng, alt=alt))
            chunks.append(np.frombuffer(b, dtype=np.uint8))
            total += len(b)
        toks = np.concatenate(chunks)[:n_tokens]
        return toks.astype(np.int32)


def _encode_task(lang, examples):
    """Pack (prefix, opt_a, opt_b, label) byte examples into flat arrays."""
    prefix, opt_a, opt_b, labels = [], [], [], []
    p_len, a_len, b_len = [], [], []
    for p, a, b, y in examples:
        prefix.append(np.frombuffer(p, dtype=np.uint8).astype(np.int32))
        opt_a.append(np.frombuffer(a, dtype=np.uint8).astype(np.int32))
        opt_b.append(np.frombuffer(b, dtype=np.uint8).astype(np.int32))
        p_len.append(len(prefix[-1]))
        a_len.append(len(opt_a[-1]))
        b_len.append(len(opt_b[-1]))
        labels.append(y)
    return {
        "prefix": np.concatenate(prefix),
        "opt_a": np.concatenate(opt_a),
        "opt_b": np.concatenate(opt_b),
        "prefix_len": np.array(p_len, dtype=np.int32),
        "a_len": np.array(a_len, dtype=np.int32),
        "b_len": np.array(b_len, dtype=np.int32),
        "label": np.array(labels, dtype=np.int32),
    }


def make_zeroshot(lang: Language, task: str, n: int, seed: int):
    """Two-option likelihood-comparison tasks of graded difficulty."""
    rng = np.random.RandomState(seed)
    nv = len(lang.words)
    examples = []
    while len(examples) < n:
        sent = lang.sample_sentence(rng)
        if len(sent) < 6:
            continue
        k = rng.randint(3, len(sent) - 2)
        prefix_words = sent[:k]
        true_next = sent[k]

        if task == "arce":
            # Easy: true next word vs uniformly random word.
            wrong = int(rng.randint(nv - 2))
            if wrong == true_next:
                continue
            a, b = lang.words[true_next], lang.words[wrong]
        elif task == "arcc":
            # Hard: distractor is globally frequent but not a successor of
            # the previous word.
            prev = prefix_words[-1]
            succ_set = set(lang.succ[prev])
            cands = np.argsort(-lang.unigram)[:40]
            cands = [c for c in cands if c not in succ_set and c != true_next]
            if not cands:
                continue
            wrong = int(cands[rng.randint(len(cands))])
            a, b = lang.words[true_next], lang.words[wrong]
        elif task == "piqa":
            # Continuation plausibility: real next-3-words vs shuffled.
            if len(sent) < k + 3:
                continue
            cont = sent[k : k + 3]
            shuf = cont.copy()
            rng.shuffle(shuf)
            if shuf == cont:
                continue
            a = " ".join(lang.words[w] for w in cont)
            b = " ".join(lang.words[w] for w in shuf)
        elif task == "wino":
            # Agreement: noun in prefix, options are the two pronouns.
            gender = "a" if rng.rand() < 0.5 else "b"
            noun = int(
                rng.choice(lang.nouns_a if gender == "a" else lang.nouns_b)
            )
            prefix_words = sent[:k] + [noun]
            a = PRONOUN_A if gender == "a" else PRONOUN_B
            b = PRONOUN_B if gender == "a" else PRONOUN_A
        else:
            raise ValueError(task)

        p_bytes = (" ".join(lang.words[w] for w in prefix_words) + " ").encode()
        # Swap options half the time so the label isn't constant.
        if rng.rand() < 0.5:
            examples.append((p_bytes, a.encode(), b.encode(), 0))
        else:
            examples.append((p_bytes, b.encode(), a.encode(), 1))
    return _encode_task(lang, examples)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-tokens", type=int, default=2_500_000)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    lang = Language()

    specs = [
        ("corpus_train", args.train_tokens, 1, 0.0),
        ("corpus_dev", 131_072, 2, 0.0),
        ("corpus_calib", 131_072, 3, 0.0),
        ("corpus_test_w2", 131_072, 4, 0.0),
        ("corpus_test_c4", 131_072, 5, 0.2),
    ]
    for name, n, seed, alt in specs:
        toks = lang.stream(n, seed=SEED + 100 + seed, alt_frac=alt)
        tensorio.save(os.path.join(args.out, f"{name}.qtz"), {"tokens": toks})
        print(f"{name}: {len(toks)} tokens")

    for i, task in enumerate(["arce", "arcc", "piqa", "wino"]):
        data = make_zeroshot(lang, task, n=400, seed=SEED + 200 + i)
        tensorio.save(os.path.join(args.out, f"zeroshot_{task}.qtz"), data)
        print(f"zeroshot_{task}: {len(data['label'])} examples")


if __name__ == "__main__":
    main()
