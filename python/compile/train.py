"""Build-time trainer: fits the model family on the synthetic corpus so
quantization operates on *real trained weights* (outliers, anisotropic
Hessians), then writes `.qtz` checkpoints the rust side consumes.

Runs once under `make artifacts`; wall-clock is bounded by the per-size
step counts below (CPU XLA). A training log (loss curve) is saved next to
each checkpoint and summarized in EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tensorio
from .model import CONFIGS, init_params, loss_fn

# Per-size training budget (steps, batch, seqlen). A few hundred steps is
# enough for the quantization orderings to be meaningful; the loss curves
# in artifacts/train_log_*.json document convergence.
BUDGET = {
    "s": (600, 32, 128),
    "m": (400, 24, 128),
    "l": (250, 16, 128),
    "moe": (300, 32, 128),
    "nonllama": (300, 32, 128),
}


def batches(tokens: np.ndarray, batch: int, seqlen: int, seed: int):
    rng = np.random.RandomState(seed)
    n = len(tokens) - seqlen - 1
    while True:
        idx = rng.randint(0, n, size=batch)
        yield np.stack([tokens[i : i + seqlen + 1] for i in idx]).astype(np.int32)


def adam_init(params):
    return (
        {k: jnp.zeros_like(v) for k, v in params.items()},
        {k: jnp.zeros_like(v) for k, v in params.items()},
    )


def train_one(name: str, art: str, tokens: np.ndarray, seed: int = 0):
    cfg = CONFIGS[name]
    steps, batch, seqlen = BUDGET[name]
    params = init_params(cfg, seed=seed)
    m, v = adam_init(params)
    lr, b1, b2, eps = 3e-3, 0.9, 0.95, 1e-8

    @jax.jit
    def step_fn(params, m, v, toks, t):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, toks))(params)
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            new_m[k] = b1 * m[k] + (1 - b1) * g
            new_v[k] = b2 * v[k] + (1 - b2) * g * g
            mhat = new_m[k] / (1 - b1**t)
            vhat = new_v[k] / (1 - b2**t)
            new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, new_m, new_v, loss

    gen = batches(tokens, batch, seqlen, seed=123 + seed)
    log = []
    t0 = time.time()
    for t in range(1, steps + 1):
        toks = next(gen)
        params, m, v, loss = step_fn(params, m, v, toks, float(t))
        if t % 25 == 0 or t == 1:
            log.append({"step": t, "loss": float(loss)})
            print(f"[{name}] step {t}/{steps} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    out = {k: np.asarray(v_, dtype=np.float32) for k, v_ in params.items()}
    tensorio.save(os.path.join(art, f"model_{name}.qtz"), out)
    with open(os.path.join(art, f"train_log_{name}.json"), "w") as f:
        json.dump({"config": cfg.__dict__, "budget": BUDGET[name], "log": log}, f)
    print(f"[{name}] saved ({sum(a.size for a in out.values())} params, "
          f"final loss {log[-1]['loss']:.4f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="../artifacts")
    ap.add_argument("--sizes", default="s,m,l,moe,nonllama")
    args = ap.parse_args()
    tokens = tensorio.load(os.path.join(args.art, "corpus_train.qtz"))["tokens"]
    for name in args.sizes.split(","):
        train_one(name, args.art, tokens)


if __name__ == "__main__":
    main()
