//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client, and
//! executes them from the serving path. Python never runs here.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax ≥ 0.5
//! serialized protos with 64-bit ids — see /opt/xla-example/README.md).
//!
//! The `xla` bindings are an offline crate that is not always present;
//! the execution path is gated behind the `pjrt` cargo feature. Without
//! it, manifest parsing and [`HostTensor`] stay available (the native
//! engine and every test that cross-checks against PJRT artifacts skips
//! cleanly), and [`Runtime::new`] returns a descriptive error.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// dtype tags used by the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtDtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: ArtDtype,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Parsed `artifacts/manifest.json`.
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub raw: Json,
}

fn parse_iospec(j: &Json) -> Result<IoSpec> {
    let dtype = match j.get("dtype").as_str() {
        Some("f32") => ArtDtype::F32,
        Some("i32") => ArtDtype::I32,
        other => bail!("bad dtype {other:?}"),
    };
    let shape = j
        .get("shape")
        .as_arr()
        .context("shape")?
        .iter()
        .map(|v| v.as_usize().context("dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(IoSpec {
        name: j.get("name").as_str().unwrap_or("").to_string(),
        dtype,
        shape,
    })
}

impl Manifest {
    pub fn load(art_dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = art_dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let raw = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = BTreeMap::new();
        if let Some(arts) = raw.get("artifacts").as_obj() {
            for (name, spec) in arts {
                let inputs = spec
                    .get("inputs")
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .map(parse_iospec)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = spec
                    .get("outputs")
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(parse_iospec)
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        name: name.clone(),
                        path: spec.get("path").as_str().context("path")?.to_string(),
                        inputs,
                        outputs,
                    },
                );
            }
        }
        Ok(Manifest { artifacts, raw })
    }
}

/// Typed host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(s, _) => s,
            HostTensor::I32(s, _) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(_, d) => Ok(d),
            _ => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(_, d) => Ok(d),
            _ => bail!("tensor is f32, expected i32"),
        }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            HostTensor::F32(shape, data) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            HostTensor::I32(shape, data) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        })
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            ArtDtype::F32 => HostTensor::F32(spec.shape.clone(), lit.to_vec::<f32>()?),
            ArtDtype::I32 => HostTensor::I32(spec.shape.clone(), lit.to_vec::<i32>()?),
        })
    }
}

/// The PJRT runtime: client + lazily-compiled executables.
pub struct Runtime {
    pub art_dir: PathBuf,
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    compiled: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    #[cfg(feature = "pjrt")]
    pub fn new(art_dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&art_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            art_dir: art_dir.as_ref().to_path_buf(),
            manifest,
            client,
            compiled: Mutex::new(BTreeMap::new()),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn new(art_dir: impl AsRef<Path>) -> Result<Runtime> {
        // Validate the manifest so error messages stay useful, then refuse:
        // without the offline xla crate there is nothing to execute with.
        let _ = Manifest::load(&art_dir)?;
        bail!(
            "PJRT runtime disabled: rebuild with `--features pjrt` (and the \
             offline xla crate in [dependencies]) to execute AOT artifacts"
        )
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "disabled (built without the pjrt feature)".to_string()
    }

    #[cfg(feature = "pjrt")]
    fn get_exe(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.compiled.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(exe.clone());
            }
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        let path = self.art_dir.join(&spec.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with host tensors, checking shapes against the
    /// manifest, and return the (untupled) outputs.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.execute_ref(name, &refs)
    }

    /// [`Runtime::execute`] over borrowed inputs. The batched decode loop
    /// calls this every step with the same fixed weight tensors, so the
    /// host-side copy of the weights is never cloned per step.
    #[cfg(feature = "pjrt")]
    pub fn execute_ref(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}': {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "artifact '{name}' input {i} ({}): shape {:?} != manifest {:?}",
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let exe = self.get_exe(name)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}': got {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, os)| HostTensor::from_literal(lit, os))
            .collect()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn execute_ref(&self, _name: &str, _inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        bail!("PJRT runtime disabled: rebuild with `--features pjrt`")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_spec() {
        let dir = std::env::temp_dir().join(format!("rtm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":{"foo":{"path":"foo.hlo.txt",
                "inputs":[{"name":"x","dtype":"f32","shape":[2,3]}],
                "outputs":[{"dtype":"f32","shape":[2,3]}]}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = &m.artifacts["foo"];
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].dtype, ArtDtype::F32);
        assert_eq!(a.inputs[0].numel(), 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![2], vec![1.0, 2.0]);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        assert_eq!(t.shape(), &[2]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn runtime_disabled_without_pjrt_feature() {
        let dir = std::env::temp_dir().join(format!("rtd_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts":{}}"#).unwrap();
        let err = Runtime::new(&dir).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
