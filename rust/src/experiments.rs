//! Shared experiment runner behind every table/figure driver.
//!
//! Owns trained models, calibration Hessians, quantized models and a
//! disk-backed metric cache (`results/cache.json`) so that Table 2/3/4/…
//! drivers reuse each other's work: a metric is computed at most once per
//! (model, method, metric) triple across the whole reproduction.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data;
use crate::eval;
use crate::ft::{quantize_model_ft, FtConfig};
use crate::hessian::collect_hessians;
use crate::linalg::Matrix;
use crate::model::Model;
use crate::qmodel::{quantize_model, QuantizedModel};
use crate::quant::pipeline::Method;
use crate::util::json::Json;

pub const SEED: u64 = 7140;

/// Evaluation protocol constants (DESIGN.md §6): window 128 ↔ the paper's
/// ctx-2048 protocol, window 256 ↔ ctx-4096.
pub const WINDOW_SHORT: usize = 128;
pub const WINDOW_NATIVE: usize = 256;

pub struct Runner {
    pub art: PathBuf,
    cache_path: PathBuf,
    cache: BTreeMap<String, f64>,
    models: BTreeMap<String, Arc<Model>>,
    hessians: BTreeMap<String, Arc<BTreeMap<String, Matrix>>>,
    qmodels: BTreeMap<String, Arc<QuantizedModel>>,
    corpora: BTreeMap<String, Arc<Vec<u8>>>,
    /// Tokens per perplexity evaluation (speed/precision knob).
    pub eval_tokens: usize,
    pub zeroshot_examples: usize,
    /// Calibration windows for Hessian generation (paper §F.2 analog).
    pub calib_windows: usize,
}

impl Runner {
    pub fn new(art: impl Into<PathBuf>) -> Result<Runner> {
        let art = art.into();
        let cache_path = PathBuf::from("results/cache.json");
        let mut cache = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(&cache_path) {
            if let Ok(Json::Obj(map)) = Json::parse(&text) {
                for (k, v) in map {
                    if let Some(x) = v.as_f64() {
                        cache.insert(k, x);
                    }
                }
            }
        }
        Ok(Runner {
            art,
            cache_path,
            cache,
            models: BTreeMap::new(),
            hessians: BTreeMap::new(),
            qmodels: BTreeMap::new(),
            corpora: BTreeMap::new(),
            eval_tokens: std::env::var("QUIPSHARP_EVAL_TOKENS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(4096),
            zeroshot_examples: 100,
            calib_windows: 24,
        })
    }

    fn save_cache(&self) {
        std::fs::create_dir_all("results").ok();
        let obj = Json::Obj(
            self.cache
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        std::fs::write(&self.cache_path, obj.emit()).ok();
    }

    pub fn model(&mut self, name: &str) -> Result<Arc<Model>> {
        if let Some(m) = self.models.get(name) {
            return Ok(m.clone());
        }
        let m = Arc::new(Model::load(&self.art, name).with_context(|| {
            format!("loading model '{name}' — run `make artifacts` first")
        })?);
        self.models.insert(name.to_string(), m.clone());
        Ok(m)
    }

    pub fn corpus(&mut self, name: &str) -> Result<Arc<Vec<u8>>> {
        if let Some(c) = self.corpora.get(name) {
            return Ok(c.clone());
        }
        let c = Arc::new(data::load_corpus(&self.art, name)?);
        self.corpora.insert(name.to_string(), c.clone());
        Ok(c)
    }

    pub fn hessians(&mut self, model_name: &str) -> Result<Arc<BTreeMap<String, Matrix>>> {
        if let Some(h) = self.hessians.get(model_name) {
            return Ok(h.clone());
        }
        let model = self.model(model_name)?;
        let calib = self.corpus("corpus_calib")?;
        eprintln!("[runner] collecting hessians for '{model_name}' …");
        let hs = Arc::new(collect_hessians(
            &model,
            &calib,
            self.calib_windows,
            model.cfg.ctx,
        ));
        self.hessians.insert(model_name.to_string(), hs.clone());
        Ok(hs)
    }

    /// Quantize (with FT when the method requests it), memoized in-process.
    pub fn qmodel(&mut self, model_name: &str, method: &Method) -> Result<Arc<QuantizedModel>> {
        let key = format!("{model_name}|{}", method.label());
        if let Some(q) = self.qmodels.get(&key) {
            return Ok(q.clone());
        }
        let model = self.model(model_name)?;
        let hs = self.hessians(model_name)?;
        eprintln!("[runner] quantizing '{model_name}' with {} …", method.label());
        let qm = match method {
            Method::QuipSharp { bits, ft: true } => {
                let dev = self.corpus("corpus_dev")?;
                let cfg = FtConfig {
                    steps_block: 6,
                    steps_e2e: 10,
                    window: 96,
                    n_train: 5,
                    n_valid: 2,
                    lr: 5e-4,
                    sign_lr_mult: 10.0,
                };
                quantize_model_ft(&model, &hs, *bits, SEED, &dev, &cfg)?
            }
            m => quantize_model(&model, &hs, m, SEED)?,
        };
        let qm = Arc::new(qm);
        self.qmodels.insert(key, qm.clone());
        Ok(qm)
    }

    fn eval_model(&mut self, model_name: &str, method: &Method) -> Result<Arc<Model>> {
        if matches!(method, Method::Fp16) {
            self.model(model_name)
        } else {
            Ok(Arc::new(Model::new(
                self.qmodel(model_name, method)?.model.cfg.clone(),
                self.qmodel(model_name, method)?.model.params.clone(),
            )))
        }
    }

    fn cached<F: FnOnce(&mut Self) -> Result<f64>>(
        &mut self,
        key: String,
        f: F,
    ) -> Result<f64> {
        if let Some(&v) = self.cache.get(&key) {
            return Ok(v);
        }
        let v = f(self)?;
        self.cache.insert(key, v);
        self.save_cache();
        Ok(v)
    }

    /// Perplexity: corpus ∈ {"w2", "c4"}, window ∈ {WINDOW_SHORT, WINDOW_NATIVE}.
    pub fn ppl(
        &mut self,
        model_name: &str,
        method: &Method,
        corpus: &str,
        window: usize,
    ) -> Result<f64> {
        let key = format!("{model_name}|{}|ppl_{corpus}_{window}", method.label());
        let corpus_file = format!("corpus_test_{corpus}");
        self.cached(key, |me| {
            let m = me.eval_model(model_name, method)?;
            let toks = me.corpus(&corpus_file)?;
            Ok(eval::perplexity(&m, &toks, window, me.eval_tokens))
        })
    }

    /// Zeroshot accuracy on one of the four tasks.
    pub fn zeroshot(&mut self, model_name: &str, method: &Method, task: &str) -> Result<f64> {
        let key = format!("{model_name}|{}|zs_{task}", method.label());
        self.cached(key, |me| {
            let m = me.eval_model(model_name, method)?;
            let t = data::load_zeroshot(&me.art, task)?;
            Ok(eval::zeroshot_accuracy(&m, &t, me.zeroshot_examples))
        })
    }

    /// Effective bits/weight (codes + signs + scales + codebook).
    pub fn bits(&mut self, model_name: &str, method: &Method) -> Result<f64> {
        if matches!(method, Method::Fp16) {
            return Ok(16.0);
        }
        let key = format!("{model_name}|{}|bits", method.label());
        self.cached(key, |me| Ok(me.qmodel(model_name, method)?.avg_bits()))
    }

    /// Mean relative proxy error (quality diagnostic used by ablations).
    pub fn proxy_rel(&mut self, model_name: &str, method: &Method) -> Result<f64> {
        let key = format!("{model_name}|{}|proxy", method.label());
        self.cached(key, |me| Ok(me.qmodel(model_name, method)?.mean_proxy_rel()))
    }

    /// Model parameter count (for scaling plots: x-axis = total bits).
    pub fn num_params(&mut self, model_name: &str) -> Result<usize> {
        Ok(self.model(model_name)?.num_params())
    }
}
