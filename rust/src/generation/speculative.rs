//! Self-speculative decoding: the RVQ base stage drafts, the full model
//! verifies, and greedy accept/reject keeps the output bit-identical to
//! target-only decode.
//!
//! QuIP#'s RVQ construction (paper §4.3) means every multi-stage model
//! *contains* a coarser model for free: truncating a 4-bit
//! (E8P ∘ E8P) layer's codes to stage 0 yields exactly the 2-bit model
//! ([`crate::model::qlinear::QuantMatvec::base_stage`] — the codes stay
//! `Arc`-shared, only the decoded stage count changes). Speculative
//! decoding turns that embedded model into decode throughput:
//!
//! 1. **Draft.** The base-stage model greedily proposes up to `k`
//!    tokens against its *own* KV, streaming roughly half the code
//!    bytes per step (one E8P stage instead of two).
//! 2. **Verify.** The target model scores all `k + 1` positions — the
//!    already-determined next token plus the `k` drafts — in **one**
//!    prefill-style chunked step ([`Generator::decode_chunks_paged`]),
//!    so each packed codeword is decoded once for every position
//!    instead of once per token.
//! 3. **Accept / roll back.** Greedy decode accepts draft `d_j` while
//!    the target's argmax at the preceding position equals `d_j`; on
//!    the first disagreement both KVs are truncated back to the last
//!    accepted row ([`PagedKv::truncate`] / [`KvCache::truncate`] —
//!    whole pages past the new length return to the pool, respecting
//!    copy-on-write refcounts).
//!
//! # Bit-exactness
//!
//! Greedy target-only decode emits the argmax chain
//! `t_{i+1} = argmax(logits(t_0..t_i))`. A speculative round emits the
//! known next token `n_0 = argmax(last_logits)` plus drafts accepted
//! *only while* they equal the target argmax at their position, and the
//! verify logits come from chunked decode, which is bitwise identical
//! to one-token-at-a-time decode (per-lane linear accumulation order is
//! batch-invariant, attention walks the same rows through the same
//! kernels — see [`Generator::decode_chunks`]). So every emitted token
//! and every carried-forward logits row is bit-for-bit the one
//! target-only decode would have produced: drafting changes *when* work
//! happens, never *what* is computed. The draft model's quality affects
//! only the acceptance rate (throughput), never the output — pinned by
//! parity tests at B ∈ {1, 4, 8} over dense and fused-E8P paths, paged
//! and contiguous KV.
//!
//! The serving engine drives [`spec_round_paged`] with draft KV pages
//! drawn from the same [`KvPagePool`] as the targets (per-request
//! `speculate_k`); [`Speculator::generate`] is the offline
//! contiguous-KV form, and `benches/bench_speculative.rs` sweeps
//! k × batch into `BENCH_speculative.json`.
//!
//! Draft and verify steps are plain batched decode calls, so both ride
//! the persistent worker pool ([`crate::util::threadpool`]) — the
//! chunked verify in particular parallelizes well, since all `k + 1`
//! positions of every lane form one wide batch. Thread count never
//! changes any emitted token or logit (`rust/tests/parallel.rs` pins a
//! full round at {1, 2, 7} threads).
//!
//! # Sampled mode
//!
//! With a non-greedy [`SamplingParams`] the accept rule generalizes from
//! "argmax equality" to *coupled-sample equality*: every next-token
//! decision — the known token `n_0`, each draft proposal, and each
//! verify comparison — goes through the one shared
//! [`next_token`] rule, which draws the position's uniform from the
//! request's `(seed, absolute position)` RNG. The draft proposes
//! `next_token(draft logits, pos)` and the target accepts while its own
//! `next_token(verify logits, pos)` agrees; on the first disagreement
//! the target's sample at that position *is* the emitted token (counted
//! as `tokens_resampled`). Every emitted token is therefore exactly the
//! token direct sampled decode would emit at that position — speculation
//! stays *sample-path-exact* (bitwise, at any k), which is strictly
//! stronger than distribution-exact.
//!
//! The textbook rejection-sampling acceptance rule — accept draft `d`
//! with probability `min(1, p_target(d) / p_draft(d))`, on rejection
//! resample from the normalized residual `max(0, p_target − p_draft)` —
//! ships alongside as distribution-level library functions
//! ([`rejection_sample_round`] / [`residual_dist`]): per round it
//! accepts more drafts in expectation, but the emitted *sample path*
//! depends on k, which would break the serving tier's
//! same-stream-on-any-replica contract, so the engine couples instead.
//! Its distribution-exactness identity
//! `p_d(x)·min(1, p_t(x)/p_d(x)) + P(reject)·residual(x) = p_t(x)`
//! is pinned by a brute-force enumeration oracle here and by
//! chi-square/TV histogram tests at k ∈ {2, 4, 8} in
//! `rust/tests/sampling.rs`.

use super::paged::{KvPagePool, PagedKv};
use super::sampling::{draw, next_token, SamplingParams};
use super::{Generator, KvCache};
use crate::util::phase::{self, Phase};
use crate::util::rng::Pcg64;

/// Running totals of the draft/verify loop (monotonic counters).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecStats {
    /// Per-lane speculative rounds executed.
    pub rounds: u64,
    /// Draft tokens proposed (k per lane-round, after caps).
    pub tokens_drafted: u64,
    /// Draft tokens the target accepted.
    pub tokens_accepted: u64,
    /// Tokens emitted by speculative rounds (1 + accepted per round).
    pub tokens_emitted: u64,
    /// Sampled-mode rounds whose first rejected position re-drew the
    /// token from the target's own distribution (the coupled-sampling
    /// analogue of a rejection-rule resample; always 0 in greedy mode).
    pub tokens_resampled: u64,
}

impl SpecStats {
    /// Fraction of drafted tokens the target accepted (0 when nothing
    /// was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.tokens_drafted == 0 {
            return 0.0;
        }
        self.tokens_accepted as f64 / self.tokens_drafted as f64
    }
}

/// Longest accepted draft prefix: drafts `d_1..d_k` are accepted while
/// `next_token(verify[j-1], pos + j) == d_j` — `verify[j-1]` being the
/// target logits *after* the previous accepted token, i.e. exactly the
/// logits direct decode samples at absolute position `pos + j` (`pos`
/// is `n_0`'s position). Greedy params reduce this to argmax equality;
/// sampled params to coupled-sample equality at the position's shared
/// uniform.
fn accept_prefix(
    drafts: &[u8],
    verify: &[Vec<f32>],
    sampling: &SamplingParams,
    pos: usize,
) -> usize {
    let mut a = 0usize;
    while a < drafts.len() && next_token(&verify[a], sampling, pos + 1 + a) == drafts[a] {
        a += 1;
    }
    a
}

/// Normalized residual distribution `max(0, p_target − p_draft) / Z` of
/// the textbook rejection-sampling rule. When the distributions are
/// identical the residual is empty; rejection then has probability 0,
/// and re-drawing from the target itself is returned as the (never
/// normally reached) fallback.
pub fn residual_dist(target: &[f64], draft: &[f64]) -> Vec<f64> {
    assert_eq!(target.len(), draft.len(), "residual over mismatched supports");
    let mut r: Vec<f64> = target.iter().zip(draft).map(|(&t, &d)| (t - d).max(0.0)).collect();
    let z: f64 = r.iter().sum();
    if z <= 0.0 {
        return target.to_vec();
    }
    for x in &mut r {
        *x /= z;
    }
    r
}

/// One round of the standard (SpecInfer/speculative-sampling) rejection
/// rule over probability vectors: draft token `d_j` (sampled from
/// `draft_dists[j]`) is accepted with probability
/// `min(1, p_target(d_j) / p_draft(d_j))`; the first rejection emits a
/// draw from [`residual_dist`] and ends the round; accepting all `k`
/// drafts emits a bonus draw from `target_dists[k]`. Per emitted
/// position the output is distributed exactly as `target_dists` —
/// the enumeration oracle and the k ∈ {2, 4, 8} histogram tests pin
/// this — but the realized sample *path* depends on `rng` and `k`,
/// which is why the serving engine uses the coupled per-position rule
/// instead (see the module docs).
pub fn rejection_sample_round(
    target_dists: &[Vec<f64>],
    draft_tokens: &[u8],
    draft_dists: &[Vec<f64>],
    rng: &mut Pcg64,
) -> Vec<u8> {
    let k = draft_tokens.len();
    assert_eq!(draft_dists.len(), k, "one draft distribution per draft token");
    assert_eq!(target_dists.len(), k + 1, "target must score all k + 1 positions");
    let mut out = Vec::with_capacity(k + 1);
    for j in 0..k {
        let d = draft_tokens[j] as usize;
        let pt = target_dists[j][d];
        let pd = draft_dists[j][d];
        // A zero-probability proposal can only come from a caller
        // feeding tokens the draft could not have sampled; accept iff
        // the target supports it (min(1, pt/0⁺) = 1 when pt > 0).
        let accept = if pd <= 0.0 {
            if pt > 0.0 {
                1.0
            } else {
                0.0
            }
        } else {
            (pt / pd).min(1.0)
        };
        if rng.f64() < accept {
            out.push(draft_tokens[j]);
        } else {
            let r = residual_dist(&target_dists[j], &draft_dists[j]);
            out.push(draw(&r, rng.f64()) as u8);
            return out;
        }
    }
    out.push(draw(&target_dists[k], rng.f64()) as u8);
    out
}

/// Largest draft length a lane can run this round, respecting the
/// remaining token budget (a round emits up to `k + 1` tokens), the
/// target context (the verify chunk writes `k + 1` rows), and the draft
/// context (drafting consumes `pending + k` rows).
pub fn effective_k(
    k: usize,
    remaining_new: usize,
    ctx: usize,
    target_len: usize,
    draft_len: usize,
    pending: usize,
) -> usize {
    k.min(remaining_new.saturating_sub(1))
        .min(ctx.saturating_sub(target_len + 1))
        .min(ctx.saturating_sub(draft_len + pending))
}

/// One sequence's mutable state for a paged speculative round. The
/// target and draft page tables must index the same [`KvPagePool`]
/// passed to [`spec_round_paged`].
pub struct SpecLane<'x> {
    /// Draft tokens to propose this round (0 = plain decode through the
    /// verify path; see [`effective_k`] for the caps).
    pub k: usize,
    /// The sequence's target-model KV.
    pub target_kv: &'x mut PagedKv,
    /// The sequence's draft-model KV (same pool).
    pub draft_kv: &'x mut PagedKv,
    /// Accepted tokens the draft has not consumed yet (≤ 1 after any
    /// round that drafted; fed as a catch-up chunk before drafting).
    pub pending: &'x mut Vec<u8>,
    /// Target logits predicting this sequence's next token; overwritten
    /// with the post-round logits (bitwise the sequential-decode row).
    pub logits: &'x mut Vec<f32>,
    /// Stochastic-decode controls (the default is greedy; see
    /// [`SamplingParams`]).
    pub sampling: SamplingParams,
    /// Absolute position of the next emitted token — the sequence's
    /// prompt length plus tokens generated so far. Keys the per-position
    /// RNG in sampled mode (ignored when greedy); callers recompute it
    /// per round, nothing carries over.
    pub pos: usize,
}

/// One draft/verify/rollback round over a batch of paged lanes.
/// Returns the tokens each lane emitted (`1 + accepted`, first always
/// `next_token(lane.logits, lane.pos)` — argmax when greedy), in true
/// direct-decode order.
///
/// Page reservations happen inside the decode calls and panic on pool
/// exhaustion; schedulers must pre-reserve (target `len + k + 1` rows,
/// draft `len + pending + k` rows) or preempt before calling, exactly
/// as with [`Generator::decode_batch_paged`].
pub fn spec_round_paged(
    target: &Generator,
    draft: &Generator,
    pool: &mut KvPagePool,
    lanes: &mut [SpecLane],
    stats: &mut SpecStats,
) -> Vec<Vec<u8>> {
    let bsz = lanes.len();
    assert!(bsz > 0, "empty speculative round");
    // The known next token per lane; correct by definition of direct
    // decode (greedy argmax or the position-keyed sample), so it is
    // emitted regardless of draft quality.
    let n0: Vec<u8> = lanes
        .iter()
        .map(|l| next_token(l.logits, &l.sampling, l.pos))
        .collect();
    let target_base: Vec<usize> = lanes.iter().map(|l| l.target_kv.len).collect();
    let draft_base: Vec<usize> = lanes.iter().map(|l| l.draft_kv.len).collect();
    let pend_len: Vec<usize> = lanes.iter().map(|l| l.pending.len()).collect();
    let max_k = lanes.iter().map(|l| l.k).max().unwrap_or(0);

    // Draft phase: lanes with k > 0 first consume their catch-up tokens
    // plus n0 in one chunk (the draft may lag the true stream by the
    // final accepted draft of an all-accept round), then advance one
    // token at a time, each lane feeding its own previous proposal.
    let mut drafts: Vec<Vec<u8>> = vec![Vec::new(); bsz];
    if max_k > 0 {
        // Inclusive timing: draft-model matmul/attention inside this
        // block counts as `spec_draft` (outermost scope wins).
        let _scope = phase::scope(Phase::SpecDraft);
        let sel: Vec<usize> = (0..bsz).filter(|&b| lanes[b].k > 0).collect();
        let chunks: Vec<Vec<u8>> = sel
            .iter()
            .map(|&b| {
                let mut c = lanes[b].pending.clone();
                c.push(n0[b]);
                c
            })
            .collect();
        let chunk_refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
        let outs = {
            let mut kv_refs: Vec<&mut PagedKv> = lanes
                .iter_mut()
                .filter(|l| l.k > 0)
                .map(|l| &mut *l.draft_kv)
                .collect();
            draft.decode_chunks_paged(&chunk_refs, pool, &mut kv_refs)
        };
        for (rows, &b) in outs.iter().zip(&sel) {
            // The draft's proposal for position pos + 1, drawn with that
            // position's shared uniform against the draft's own
            // distribution (argmax when greedy).
            drafts[b].push(next_token(
                rows.last().unwrap(),
                &lanes[b].sampling,
                lanes[b].pos + 1,
            ));
            lanes[b].pending.clear();
        }
        for j in 1..max_k {
            let sel: Vec<usize> = (0..bsz).filter(|&b| lanes[b].k > j).collect();
            if sel.is_empty() {
                break;
            }
            let toks: Vec<u8> = sel.iter().map(|&b| *drafts[b].last().unwrap()).collect();
            let outs = {
                let mut kv_refs: Vec<&mut PagedKv> = lanes
                    .iter_mut()
                    .filter(|l| l.k > j)
                    .map(|l| &mut *l.draft_kv)
                    .collect();
                draft.decode_batch_paged(&toks, pool, &mut kv_refs)
            };
            for (row, &b) in outs.iter().zip(&sel) {
                drafts[b].push(next_token(row, &lanes[b].sampling, lanes[b].pos + j + 1));
            }
        }
    }

    // Verify phase: one chunked target step over every lane's
    // [n0, d_1..d_k] — all positions of all lanes in a single batched
    // decode call, each packed codeword decoded once for all of them.
    let chunks: Vec<Vec<u8>> = (0..bsz)
        .map(|b| {
            let mut c = vec![n0[b]];
            c.extend_from_slice(&drafts[b]);
            c
        })
        .collect();
    let chunk_refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
    let verify = {
        // Inclusive timing: the target's chunked decode counts as
        // `spec_verify` (outermost scope wins).
        let _scope = phase::scope(Phase::SpecVerify);
        let mut kv_refs: Vec<&mut PagedKv> =
            lanes.iter_mut().map(|l| &mut *l.target_kv).collect();
        target.decode_chunks_paged(&chunk_refs, pool, &mut kv_refs)
    };

    // Accept + rollback.
    let mut emitted = Vec::with_capacity(bsz);
    for (b, lane) in lanes.iter_mut().enumerate() {
        let k = lane.k;
        let a = accept_prefix(&drafts[b], &verify[b], &lane.sampling, lane.pos);
        let mut em = vec![n0[b]];
        em.extend_from_slice(&drafts[b][..a]);
        // The target wrote 1 + k rows; rows past the last accepted
        // token encode rejected context and roll back.
        lane.target_kv.truncate(pool, target_base[b] + 1 + a);
        if k > 0 {
            // The draft fed pending + n0 + d_1..d_{k-1}. Of the k
            // tokens fed this round, n0..d_{min(a, k-1)} are on the
            // true stream; later rows encode rejected drafts.
            let fed_valid = 1 + a.min(k - 1);
            lane.draft_kv
                .truncate(pool, draft_base[b] + pend_len[b] + fed_valid);
            // All accepted: d_k is emitted but the draft never consumed
            // it — carry it into the next round's catch-up chunk.
            if a == k {
                lane.pending.push(drafts[b][k - 1]);
            }
        } else {
            // Nothing drafted: the draft did not see n0 either.
            lane.pending.push(n0[b]);
        }
        // The logits after the last accepted token — bitwise the row
        // sequential target-only decode would carry forward.
        *lane.logits = verify[b][a].clone();
        stats.rounds += 1;
        stats.tokens_drafted += k as u64;
        stats.tokens_accepted += a as u64;
        stats.tokens_emitted += em.len() as u64;
        if !lane.sampling.is_greedy() && a < k {
            // A rejected draft in sampled mode: the emitted token at the
            // first disagreeing position came from the target's own
            // distribution instead of the draft's proposal.
            stats.tokens_resampled += 1;
        }
        emitted.push(em);
    }
    emitted
}

/// Contiguous-KV lane state — the parity-baseline layout (see
/// [`SpecLane`]).
pub struct SpecLaneContig<'x> {
    pub k: usize,
    pub target_kv: &'x mut KvCache,
    pub draft_kv: &'x mut KvCache,
    pub pending: &'x mut Vec<u8>,
    pub logits: &'x mut Vec<f32>,
    /// See [`SpecLane::sampling`].
    pub sampling: SamplingParams,
    /// See [`SpecLane::pos`].
    pub pos: usize,
}

/// [`spec_round_paged`] over per-sequence contiguous caches — identical
/// draft/verify/rollback logic, bit-exact with the paged form (both
/// layouts run the same chunked decode kernels over the same row
/// ranges).
pub fn spec_round(
    target: &Generator,
    draft: &Generator,
    lanes: &mut [SpecLaneContig],
    stats: &mut SpecStats,
) -> Vec<Vec<u8>> {
    let bsz = lanes.len();
    assert!(bsz > 0, "empty speculative round");
    let n0: Vec<u8> = lanes
        .iter()
        .map(|l| next_token(l.logits, &l.sampling, l.pos))
        .collect();
    let target_base: Vec<usize> = lanes.iter().map(|l| l.target_kv.len).collect();
    let draft_base: Vec<usize> = lanes.iter().map(|l| l.draft_kv.len).collect();
    let pend_len: Vec<usize> = lanes.iter().map(|l| l.pending.len()).collect();
    let max_k = lanes.iter().map(|l| l.k).max().unwrap_or(0);

    let mut drafts: Vec<Vec<u8>> = vec![Vec::new(); bsz];
    if max_k > 0 {
        let _scope = phase::scope(Phase::SpecDraft);
        let sel: Vec<usize> = (0..bsz).filter(|&b| lanes[b].k > 0).collect();
        let chunks: Vec<Vec<u8>> = sel
            .iter()
            .map(|&b| {
                let mut c = lanes[b].pending.clone();
                c.push(n0[b]);
                c
            })
            .collect();
        let chunk_refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
        let outs = {
            let mut kv_refs: Vec<&mut KvCache> = lanes
                .iter_mut()
                .filter(|l| l.k > 0)
                .map(|l| &mut *l.draft_kv)
                .collect();
            draft.decode_chunks(&chunk_refs, &mut kv_refs)
        };
        for (rows, &b) in outs.iter().zip(&sel) {
            // The draft's proposal for position pos + 1, drawn with that
            // position's shared uniform against the draft's own
            // distribution (argmax when greedy).
            drafts[b].push(next_token(
                rows.last().unwrap(),
                &lanes[b].sampling,
                lanes[b].pos + 1,
            ));
            lanes[b].pending.clear();
        }
        for j in 1..max_k {
            let sel: Vec<usize> = (0..bsz).filter(|&b| lanes[b].k > j).collect();
            if sel.is_empty() {
                break;
            }
            let toks: Vec<u8> = sel.iter().map(|&b| *drafts[b].last().unwrap()).collect();
            let outs = {
                let mut kv_refs: Vec<&mut KvCache> = lanes
                    .iter_mut()
                    .filter(|l| l.k > j)
                    .map(|l| &mut *l.draft_kv)
                    .collect();
                draft.decode_batch(&toks, &mut kv_refs)
            };
            for (row, &b) in outs.iter().zip(&sel) {
                drafts[b].push(next_token(row, &lanes[b].sampling, lanes[b].pos + j + 1));
            }
        }
    }

    let chunks: Vec<Vec<u8>> = (0..bsz)
        .map(|b| {
            let mut c = vec![n0[b]];
            c.extend_from_slice(&drafts[b]);
            c
        })
        .collect();
    let chunk_refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
    let verify = {
        let _scope = phase::scope(Phase::SpecVerify);
        let mut kv_refs: Vec<&mut KvCache> =
            lanes.iter_mut().map(|l| &mut *l.target_kv).collect();
        target.decode_chunks(&chunk_refs, &mut kv_refs)
    };

    let mut emitted = Vec::with_capacity(bsz);
    for (b, lane) in lanes.iter_mut().enumerate() {
        let k = lane.k;
        let a = accept_prefix(&drafts[b], &verify[b], &lane.sampling, lane.pos);
        let mut em = vec![n0[b]];
        em.extend_from_slice(&drafts[b][..a]);
        lane.target_kv.truncate(target_base[b] + 1 + a);
        if k > 0 {
            let fed_valid = 1 + a.min(k - 1);
            lane.draft_kv
                .truncate(draft_base[b] + pend_len[b] + fed_valid);
            if a == k {
                lane.pending.push(drafts[b][k - 1]);
            }
        } else {
            lane.pending.push(n0[b]);
        }
        *lane.logits = verify[b][a].clone();
        stats.rounds += 1;
        stats.tokens_drafted += k as u64;
        stats.tokens_accepted += a as u64;
        stats.tokens_emitted += em.len() as u64;
        if !lane.sampling.is_greedy() && a < k {
            // A rejected draft in sampled mode: the emitted token at the
            // first disagreeing position came from the target's own
            // distribution instead of the draft's proposal.
            stats.tokens_resampled += 1;
        }
        emitted.push(em);
    }
    emitted
}

/// Offline speculative generation: a target/draft generator pair over
/// contiguous KVs, mirroring [`Generator::generate`] — and emitting the
/// bit-identical token stream (only faster when the draft is cheap and
/// agreeable).
pub struct Speculator<'m, 'g> {
    pub target: &'g Generator<'m>,
    pub draft: &'g Generator<'m>,
    /// Draft tokens per round (0 degrades to plain decode through the
    /// verify path).
    pub k: usize,
    /// Stochastic-decode controls; the default is greedy, under which
    /// [`Speculator::generate`] emits the exact
    /// [`Generator::generate`] stream. Sampled params emit the exact
    /// [`Generator::generate_sampled`] stream instead — either way,
    /// bitwise at every k.
    pub sampling: SamplingParams,
}

impl Speculator<'_, '_> {
    /// Speculative generation: prefill both models on the prompt, then
    /// draft/verify rounds until `max_new` tokens or the context fills.
    /// Returns the tokens plus the round statistics.
    pub fn generate(&self, prompt: &[u8], max_new: usize) -> (Vec<u8>, SpecStats) {
        let cfg = &self.target.model.cfg;
        let mut target_kv = KvCache::new(self.target.model);
        let mut draft_kv = KvCache::new(self.draft.model);
        let mut logits = vec![0.0f32; cfg.vocab];
        if !prompt.is_empty() {
            logits = self
                .target
                .decode_chunk(prompt, &mut target_kv)
                .pop()
                .unwrap();
            self.draft.decode_chunk(prompt, &mut draft_kv);
        }
        let mut pending: Vec<u8> = Vec::new();
        let mut stats = SpecStats::default();
        let mut out = Vec::with_capacity(max_new);
        while out.len() < max_new && target_kv.len < cfg.ctx {
            let k = effective_k(
                self.k,
                max_new - out.len(),
                cfg.ctx,
                target_kv.len,
                draft_kv.len,
                pending.len(),
            );
            let em = spec_round(
                self.target,
                self.draft,
                &mut [SpecLaneContig {
                    k,
                    target_kv: &mut target_kv,
                    draft_kv: &mut draft_kv,
                    pending: &mut pending,
                    logits: &mut logits,
                    sampling: self.sampling,
                    pos: prompt.len() + out.len(),
                }],
                &mut stats,
            )
            .pop()
            .unwrap();
            out.extend_from_slice(&em);
        }
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generation::paged::{pages_per_seq, PAGE_ROWS};
    use crate::model::tests_support::tiny_model;
    use crate::model::{Arch, Model, ModelConfig};
    use crate::qmodel::quantize_model;
    use crate::quant::pipeline::Method;
    use std::collections::BTreeMap;

    /// Power-of-two shapes (fused E8P applies) with a multi-page ctx.
    fn spec_model(seed: u64) -> Model {
        let cfg = ModelConfig {
            name: "tinyspec".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            vocab: 64,
            ctx: 4 * PAGE_ROWS,
            arch: Arch::Llama,
            n_experts: 2,
        };
        Model::random(cfg, seed)
    }

    #[test]
    fn chunk_decode_matches_sequential_bitwise() {
        // The verify primitive: feeding a chunk of tokens in one call
        // must reproduce one-at-a-time decode bit-for-bit, dense and
        // quantized, contiguous and paged.
        let m = spec_model(21);
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        for gen in [Generator::dense(&m), Generator::quantized(&qm.model, &qm)] {
            let tokens: Vec<u8> = (0..PAGE_ROWS + 5).map(|i| ((i * 7 + 3) % 60) as u8).collect();
            // Sequential reference.
            let mut c_ref = KvCache::new(gen.model);
            let mut seq_logits = Vec::new();
            for &t in &tokens {
                seq_logits.push(gen.decode_one(t, &mut c_ref));
            }
            // One contiguous chunk.
            let mut c_chunk = KvCache::new(gen.model);
            let chunk_logits = gen.decode_chunk(&tokens, &mut c_chunk);
            assert_eq!(c_chunk.len, tokens.len());
            // One paged chunk.
            let mut pool = crate::generation::paged::KvPagePool::for_model(
                gen.model,
                pages_per_seq(&gen.model.cfg),
            );
            let mut pkv = PagedKv::new();
            let paged_logits = gen.decode_chunk_paged(&tokens, &mut pool, &mut pkv);
            for (step, want) in seq_logits.iter().enumerate() {
                for (i, (x, y)) in chunk_logits[step].iter().zip(want).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "contig chunk step {step} logit {i}: {x} vs {y}"
                    );
                }
                for (i, (x, y)) in paged_logits[step].iter().zip(want).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "paged chunk step {step} logit {i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn base_stage_is_coarser_but_valid() {
        let m = spec_model(22);
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        assert!(qm.has_multi_stage());
        let target = qm.generator();
        let draft = qm.draft_generator();
        // Same layers packed, fewer active stages, code payload shared.
        assert_eq!(target.qlayers.len(), draft.qlayers.len());
        for (name, tq) in &target.qlayers {
            let dq = &draft.qlayers[name];
            assert_eq!(tq.active_stages, 2);
            assert_eq!(dq.active_stages, 1);
            assert!(std::sync::Arc::ptr_eq(&tq.stage_codes, &dq.stage_codes));
            assert_eq!(dq.bytes_per_matvec() * 2, tq.bytes_per_matvec());
        }
        // The draft decodes *something* (a valid coarse model): tokens
        // stay in-vocab and generation is deterministic.
        let out = draft.generate(&[1, 2, 3], 8);
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|&t| (t as usize) < m.cfg.vocab));
        assert_eq!(out, draft.generate(&[1, 2, 3], 8));
    }

    /// Speculative generation must emit exactly the target-only greedy
    /// stream for every k, including k beyond the acceptance horizon.
    fn spec_parity(target: &Generator, draft: &Generator, prompt: &[u8], max_new: usize) {
        let want = target.generate(prompt, max_new);
        for k in [0usize, 1, 2, 4, 8] {
            let spec = Speculator { target, draft, k, sampling: SamplingParams::default() };
            let (got, stats) = spec.generate(prompt, max_new);
            assert_eq!(got, want, "k={k} diverged from greedy decode");
            assert_eq!(stats.tokens_emitted as usize, want.len());
            if k == 0 {
                assert_eq!(stats.tokens_drafted, 0);
            }
        }
    }

    #[test]
    fn speculative_matches_greedy_dense() {
        let m = spec_model(23);
        let gen = Generator::dense(&m);
        // Dense self-draft: acceptance is total, output identical.
        spec_parity(&gen, &gen, &[5, 9, 1, 33], 12);
        let spec = Speculator {
            target: &gen,
            draft: &gen,
            k: 4,
            sampling: SamplingParams::default(),
        };
        let (_, stats) = spec.generate(&[5, 9, 1, 33], 12);
        assert_eq!(
            stats.tokens_accepted, stats.tokens_drafted,
            "self-draft must accept everything"
        );
    }

    #[test]
    fn speculative_matches_greedy_quantized_base_stage() {
        let m = spec_model(24);
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        let target = qm.generator();
        let draft = qm.draft_generator();
        assert!(!target.qlayers.is_empty());
        spec_parity(&target, &draft, &[1, 2, 3, 4], 12);
        // A deliberately *bad* draft (dense weights of a different
        // random model) still yields the exact greedy stream — only
        // acceptance suffers.
        let other = spec_model(99);
        let bad_draft = Generator::dense(&other);
        spec_parity(&target, &bad_draft, &[1, 2, 3, 4], 10);
    }

    /// Batched paged speculative decode vs offline direct decode (greedy
    /// or sampled per `sampling`), with unequal prompt lengths and
    /// per-lane k caps, over a shared pool.
    fn paged_spec_parity(
        target: &Generator,
        draft: &Generator,
        bsz: usize,
        k: usize,
        sampling: SamplingParams,
    ) {
        let m = target.model;
        let max_new = 10usize;
        let mut pool = crate::generation::paged::KvPagePool::for_model(
            m,
            2 * bsz * pages_per_seq(&m.cfg),
        );
        let prompts: Vec<Vec<u8>> = (0..bsz)
            .map(|b| {
                let plen = 2 + (b % 3);
                (0..plen).map(|i| ((i * 11 + b * 17 + 3) % 60) as u8).collect()
            })
            .collect();
        // generate_sampled reproduces generate bit-for-bit when greedy,
        // so one reference covers both modes.
        let want: Vec<Vec<u8>> = prompts
            .iter()
            .map(|p| target.generate_sampled(p, max_new, &sampling))
            .collect();
        // Prefill both models per lane (chunked, positions diverge).
        let mut t_kvs: Vec<PagedKv> = (0..bsz).map(|_| PagedKv::new()).collect();
        let mut d_kvs: Vec<PagedKv> = (0..bsz).map(|_| PagedKv::new()).collect();
        let mut logits: Vec<Vec<f32>> = Vec::new();
        for b in 0..bsz {
            logits.push(
                target
                    .decode_chunk_paged(&prompts[b], &mut pool, &mut t_kvs[b])
                    .pop()
                    .unwrap(),
            );
            draft.decode_chunk_paged(&prompts[b], &mut pool, &mut d_kvs[b]);
        }
        let mut pendings: Vec<Vec<u8>> = vec![Vec::new(); bsz];
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); bsz];
        let mut stats = SpecStats::default();
        // Advance every lane in joint rounds until all are done.
        while out.iter().any(|o| o.len() < max_new) {
            let sel: Vec<usize> = (0..bsz).filter(|&b| out[b].len() < max_new).collect();
            let ks: Vec<usize> = sel
                .iter()
                .map(|&b| {
                    effective_k(
                        k,
                        max_new - out[b].len(),
                        m.cfg.ctx,
                        t_kvs[b].len,
                        d_kvs[b].len,
                        pendings[b].len(),
                    )
                })
                .collect();
            let emitted = {
                let mut lanes: Vec<SpecLane> = Vec::with_capacity(sel.len());
                let mut t_it = t_kvs.iter_mut();
                let mut d_it = d_kvs.iter_mut();
                let mut p_it = pendings.iter_mut();
                let mut l_it = logits.iter_mut();
                let mut si = 0usize;
                let mut idx = 0usize;
                loop {
                    let (Some(t), Some(d), Some(p), Some(l)) =
                        (t_it.next(), d_it.next(), p_it.next(), l_it.next())
                    else {
                        break;
                    };
                    if si < sel.len() && sel[si] == idx {
                        lanes.push(SpecLane {
                            k: ks[si],
                            target_kv: t,
                            draft_kv: d,
                            pending: p,
                            logits: l,
                            sampling,
                            pos: prompts[idx].len() + out[idx].len(),
                        });
                        si += 1;
                    }
                    idx += 1;
                }
                spec_round_paged(target, draft, &mut pool, &mut lanes, &mut stats)
            };
            for (em, &b) in emitted.iter().zip(&sel) {
                out[b].extend_from_slice(em);
            }
        }
        for b in 0..bsz {
            assert_eq!(out[b], want[b], "lane {b} diverged (B={bsz}, k={k})");
        }
        // Rollbacks leaked nothing: releasing everything empties the pool.
        for kv in t_kvs.iter_mut().chain(d_kvs.iter_mut()) {
            kv.release(&mut pool);
        }
        assert_eq!(pool.pages_free(), pool.pages_total());
    }

    #[test]
    fn paged_speculative_matches_greedy_dense() {
        let m = spec_model(25);
        let gen = Generator::dense(&m);
        for &bsz in &[1usize, 4, 8] {
            paged_spec_parity(&gen, &gen, bsz, 4, SamplingParams::default());
        }
    }

    #[test]
    fn paged_speculative_matches_greedy_quantized() {
        let m = spec_model(26);
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        let target = qm.generator();
        let draft = qm.draft_generator();
        for &bsz in &[1usize, 4, 8] {
            for &k in &[2usize, 4] {
                paged_spec_parity(&target, &draft, bsz, k, SamplingParams::default());
            }
        }
    }

    #[test]
    fn paged_speculative_matches_direct_sampled() {
        // Sampled mode: batched paged speculation must emit the exact
        // stream direct sampled decode emits — the coupled per-position
        // rule makes speculation sample-path-exact, not merely
        // distribution-exact.
        let m = spec_model(28);
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        let target = qm.generator();
        let draft = qm.draft_generator();
        let sampling = SamplingParams {
            temperature: 0.9,
            top_k: 24,
            top_p: 0.95,
            seed: 1234,
        };
        for &bsz in &[1usize, 4] {
            for &k in &[2usize, 4] {
                paged_spec_parity(&target, &draft, bsz, k, sampling);
            }
        }
    }

    #[test]
    fn sampled_speculator_matches_generate_sampled_at_every_k() {
        let m = spec_model(29);
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        let target = qm.generator();
        let draft = qm.draft_generator();
        let sampling = SamplingParams {
            temperature: 1.1,
            top_k: 0,
            top_p: 1.0,
            seed: 77,
        };
        let prompt = [1u8, 2, 3, 4];
        let want = target.generate_sampled(&prompt, 12, &sampling);
        let mut resampled_seen = false;
        for k in [0usize, 1, 2, 4, 8] {
            let spec = Speculator { target: &target, draft: &draft, k, sampling };
            let (got, stats) = spec.generate(&prompt, 12);
            assert_eq!(got, want, "sampled k={k} diverged from direct sampled decode");
            assert_eq!(stats.tokens_emitted as usize, want.len());
            if k == 0 {
                assert_eq!(
                    stats.tokens_resampled, 0,
                    "nothing drafted, nothing to resample"
                );
            }
            resampled_seen |= stats.tokens_resampled > 0;
            assert!(
                stats.tokens_resampled <= stats.rounds,
                "at most one resample per round"
            );
        }
        // The base-stage draft disagrees with the target somewhere over
        // these ks at temperature 1.1; if it never did, the counter
        // would be untested.
        assert!(resampled_seen, "no round ever resampled — counter untested");
        // Greedy rounds never resample, whatever the draft does.
        let greedy = Speculator {
            target: &target,
            draft: &draft,
            k: 4,
            sampling: SamplingParams::default(),
        };
        let (_, stats) = greedy.generate(&prompt, 12);
        assert_eq!(stats.tokens_resampled, 0);
    }

    #[test]
    fn rejection_rule_matches_brute_force_enumeration() {
        // The distribution-exactness identity on tiny vocabularies,
        // checked by exact enumeration (no RNG): for every draft token d,
        //   p_d(d) · min(1, p_t(d)/p_d(d))          → mass emitted as d
        //   p_d(d) · (1 − min(1, p_t(d)/p_d(d))) · residual(x)
        //                                           → mass emitted as x
        // must sum to exactly p_t(x) for every token x.
        crate::util::proptest_lite::check("rejection enumeration", 24, |rng| {
            let v = 2 + rng.below_usize(5); // vocab 2..=6
            let mk_dist = |rng: &mut Pcg64| -> Vec<f64> {
                let w: Vec<f64> = (0..v).map(|_| rng.range_f64(0.05, 1.0)).collect();
                let s: f64 = w.iter().sum();
                w.into_iter().map(|x| x / s).collect()
            };
            let pt = mk_dist(rng);
            let pd = mk_dist(rng);
            let mut emitted = vec![0.0f64; v];
            for d in 0..v {
                let accept = (pt[d] / pd[d]).min(1.0);
                emitted[d] += pd[d] * accept;
                let reject_mass = pd[d] * (1.0 - accept);
                if reject_mass > 0.0 {
                    let r = residual_dist(&pt, &pd);
                    for (x, &rx) in r.iter().enumerate() {
                        emitted[x] += reject_mass * rx;
                    }
                }
            }
            for (x, (&e, &t)) in emitted.iter().zip(&pt).enumerate() {
                crate::prop_assert!(
                    (e - t).abs() < 1e-12,
                    "token {x}: emitted mass {e} vs target {t}"
                );
            }
            // Identical dists: acceptance is certain, the residual
            // degenerates, and the fallback resamples the target.
            let r = residual_dist(&pt, &pt);
            crate::prop_assert!(r == pt, "empty residual must fall back to target");
            Ok(())
        });
        // Empirically too: one round of the real sampler on a fixed
        // pair, histogram of the first emitted token against the target.
        let pt = vec![0.5f64, 0.3, 0.15, 0.05];
        let pd = vec![0.1f64, 0.2, 0.3, 0.4];
        let mut rng = Pcg64::new(4242);
        let mut counts = vec![0u64; 4];
        for _ in 0..30_000 {
            let d = draw(&pd, rng.f64()) as u8;
            let out = rejection_sample_round(
                &[pt.clone(), pt.clone()],
                &[d],
                &[pd.clone()],
                &mut rng,
            );
            counts[out[0] as usize] += 1;
        }
        crate::util::proptest_lite::assert_histogram_close(&counts, &pt).unwrap();
    }

    #[test]
    fn speculative_respects_max_new_and_stats() {
        let m = tiny_model(27);
        let gen = Generator::dense(&m);
        let spec = Speculator {
            target: &gen,
            draft: &gen,
            k: 8,
            sampling: SamplingParams::default(),
        };
        for max_new in [0usize, 1, 2, 5] {
            let (out, stats) = spec.generate(&[3, 1, 4], max_new);
            assert_eq!(out.len(), max_new);
            assert_eq!(stats.tokens_emitted as usize, max_new);
            assert_eq!(out, gen.generate(&[3, 1, 4], max_new));
        }
    }
}
