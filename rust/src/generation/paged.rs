//! Paged KV storage: fixed-size KV pages owned by a shared pool, with
//! per-sequence page tables — the serving engine's KV subsystem.
//!
//! A contiguous per-sequence cache forces admission control to reason
//! about worst-case context (`ctx × d_model` per layer per sequence).
//! Paging breaks that coupling: the pool owns `pages` blocks of
//! [`PAGE_ROWS`] token rows each (all layers, K and V), sequences
//! allocate pages on demand as they lengthen, release them on
//! completion, and the engine can preempt a sequence — returning its
//! pages to the pool and requeueing its request — when allocation
//! fails. Admission is then bounded by *actual* KV usage, so a pool
//! sized well below `max_batch × ctx` still serves full batches of
//! typical requests (the over-subscription behavior the ROADMAP
//! north-star asks for).
//!
//! # Attention kernels
//!
//! The same module owns the decode attention kernels. Both are
//! flash-style blocked passes (running max, per-block
//! score/softmax/weighted-sum) over [`PAGE_ROWS`]-row K/V blocks, so
//! paged sequences never need their rows gathered into one contiguous
//! buffer, and both run their inner loops through the shared chunked
//! primitives ([`dot_chunked`], [`axpy_chunked`], [`rescale_chunked`]:
//! fixed [`ATTN_CHUNK`]-wide slices the compiler autovectorizes, with
//! scalar oracles pinning bit-parity):
//!
//! * [`blocked_attention`] walks one sequence's blocks — the
//!   per-sequence baseline and parity oracle.
//! * [`fused_batch_attention`] walks the step's block indices once for
//!   the whole batch: at each index every sequence (and head) still
//!   attending to that block is serviced before the walk moves on,
//!   with sequences grouped by *physical* block so forked siblings
//!   whose page tables alias the same pool pages load each K/V block
//!   once per step instead of once per sequence.
//!
//! The fused walk additionally shards across the persistent worker
//! pool by **whole lane groups** (lanes sorted by first physical block
//! so forked siblings stay in one group; see the sharding notes on
//! [`fused_batch_attention`] for why per-lane block ranges are never
//! split). Per-sequence state is independent and every sequence still
//! meets its blocks in ascending order, so the fused walk executes the
//! identical per-sequence floating-point ops as [`blocked_attention`]
//! — the two kernels are bit-exact at any thread count (see the
//! bit-exactness notes on [`fused_batch_attention`]). The contiguous
//! [`crate::generation::KvCache`] path drives the same kernels over
//! [`PAGE_ROWS`]-sized slices of its slab, which keeps paged and
//! contiguous decode bit-exact (same floating-point operation order).
//!
//! # Copy-on-write prefix sharing
//!
//! Pages are **refcounted**, which makes prompt-prefix sharing a page
//! table operation instead of a KV copy: [`PagedKv::fork_prefix`] builds
//! a new sequence whose first `prefix_rows` rows alias a parent's pages
//! (each shared page's refcount is incremented; no payload moves). The
//! invariants that keep this sound:
//!
//! * **Reads are always safe.** Attention only ever reads rows
//!   `< seq.len` through the sequence's own page table, and a forked
//!   sequence's aliased rows are, by construction, the rows it would
//!   have computed itself (KV rows at position `p` depend only on tokens
//!   `0..=p`, which fork requires to match). So shared pages need no
//!   synchronization and decode stays bit-exact.
//! * **Writes require unique ownership.** [`PagedKv::reserve`] — which a
//!   scheduler must call (directly or via
//!   [`crate::generation::Generator::decode_batch_paged`]) before any
//!   row in `[len, new_len)` is stored — clones any still-shared page
//!   that the upcoming rows land in (allocate + memcpy + move one ref),
//!   so [`PagedKv::store`] only ever touches pages with refcount 1. In
//!   practice only the partial tail page at fork time is ever cloned;
//!   fully occupied prefix pages are never written again and stay shared
//!   for the sequences' whole lifetime.
//! * **Release drops one reference, never the page.** [`PagedKv::release`]
//!   decrements each page's refcount and only pages reaching zero return
//!   to the free list — preempting or retiring a forked sequence can
//!   never free pages a parent (or sibling fork) still reads, and the
//!   parent's release symmetrically leaves the children's shared pages
//!   alive.
//! * On exhaustion, `reserve` rolls back everything *it* did (fresh
//!   pages freed, clones undone by re-retaining the original), so a
//!   failed grow leaves the sequence exactly as it was.

use crate::model::{Model, ModelConfig};
use crate::util::threadpool;

/// Token rows per KV page. Equal to the contiguous cache's growth slab
/// so the blocked attention traversal covers identical row ranges in
/// both layouts.
pub const PAGE_ROWS: usize = 32;

/// KV pages a worst-case (full-context) sequence pins — the unit
/// contiguous admission would have to reserve per sequence, and the
/// unit the paged pool oversubscribes against. Engines size their
/// default (preemption-free) pool as `max_batch ×` this.
pub fn pages_per_seq(cfg: &ModelConfig) -> usize {
    cfg.ctx.div_ceil(PAGE_ROWS)
}

/// Shared KV page pool: one flat f32 arena, a free list, and per-page
/// refcounts. Pages are identified by index; a page's payload is laid
/// out per layer as `[K rows | V rows]`, each `PAGE_ROWS × d_model`
/// row-major.
///
/// Sizing: one page holds [`PAGE_ROWS`] token rows of K and V across
/// every layer, i.e. `n_layers × 2 × PAGE_ROWS × d_model` f32 slots. A
/// worst-case (full-context) sequence pins [`pages_per_seq`] pages;
/// sizing the pool below `max_batch ×` that enables over-subscription
/// with preemption.
///
/// Refcount rules: freshly allocated pages start at refcount 1;
/// [`PagedKv::fork_prefix`] retains (increments) pages it shares;
/// releasing decrements and only a page reaching refcount 0 re-enters
/// the free list. A page with refcount > 1 is *shared* and must never
/// be written (see [`PagedKv::reserve`] for the copy-on-write path).
pub struct KvPagePool {
    n_layers: usize,
    d: usize,
    data: Vec<f32>,
    free: Vec<u32>,
    /// Per-page reference count: 0 = free, 1 = uniquely owned,
    /// >1 = shared read-only across forked sequences.
    refs: Vec<u32>,
    /// Pages with refcount > 1, maintained incrementally on the 1 ↔ 2
    /// crossings so the scheduler's per-step gauge read is O(1).
    shared: usize,
    capacity: usize,
}

impl KvPagePool {
    pub fn new(n_layers: usize, d_model: usize, pages: usize) -> Self {
        assert!(n_layers > 0 && d_model > 0 && pages > 0, "empty KV pool");
        let stride = n_layers * 2 * PAGE_ROWS * d_model;
        KvPagePool {
            n_layers,
            d: d_model,
            data: vec![0.0; pages * stride],
            // Pop order is LIFO; ids are handed out low-first initially.
            free: (0..pages as u32).rev().collect(),
            refs: vec![0; pages],
            shared: 0,
            capacity: pages,
        }
    }

    /// Pool over a model's geometry.
    pub fn for_model(model: &Model, pages: usize) -> Self {
        Self::new(model.cfg.n_layers, model.cfg.d_model, pages)
    }

    pub fn pages_total(&self) -> usize {
        self.capacity
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Pages currently shared by more than one sequence (refcount > 1).
    pub fn shared_pages(&self) -> usize {
        self.shared
    }

    /// Reference count of `page` (0 = free).
    pub fn refcount(&self, page: u32) -> u32 {
        self.refs[page as usize]
    }

    /// f32 slots per page (all layers, K and V).
    pub fn page_stride(&self) -> usize {
        self.n_layers * 2 * PAGE_ROWS * self.d
    }

    fn try_alloc(&mut self) -> Option<u32> {
        let page = self.free.pop()?;
        debug_assert_eq!(self.refs[page as usize], 0, "free page {page} had refs");
        self.refs[page as usize] = 1;
        Some(page)
    }

    /// Add one reference to an already-allocated page (prefix sharing).
    fn retain_page(&mut self, page: u32) {
        let r = self.refs[page as usize];
        debug_assert!(r > 0, "retain of free page {page}");
        if r == 1 {
            self.shared += 1;
        }
        self.refs[page as usize] = r + 1;
    }

    /// Drop one reference; the page returns to the free list only when
    /// no sequence holds it any more. This is the only way pages are
    /// freed, so releasing a forked sequence can never free pages its
    /// parent (or a sibling fork) still reads.
    fn release_page(&mut self, page: u32) {
        debug_assert!((page as usize) < self.capacity);
        let r = self.refs[page as usize];
        debug_assert!(r > 0, "release of free page {page}");
        if r == 2 {
            self.shared -= 1;
        }
        self.refs[page as usize] = r - 1;
        if r == 1 {
            debug_assert!(!self.free.contains(&page), "double free of page {page}");
            self.free.push(page);
        }
    }

    /// Copy-on-write clone: allocate a fresh page and copy `src`'s whole
    /// payload into it. Refcounts are the caller's business (the caller
    /// swaps its table entry to the clone and releases its ref on `src`).
    fn clone_page(&mut self, src: u32) -> Option<u32> {
        let dst = self.try_alloc()?;
        let stride = self.page_stride();
        let lo = src as usize * stride;
        self.data.copy_within(lo..lo + stride, dst as usize * stride);
        Some(dst)
    }

    fn layer_base(&self, page: u32, layer: usize) -> usize {
        debug_assert!(layer < self.n_layers);
        page as usize * self.page_stride() + layer * 2 * PAGE_ROWS * self.d
    }

    /// K rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn k_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer);
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// V rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn v_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer) + PAGE_ROWS * self.d;
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// Write the K/V rows for one token at `row` within `page`. The page
    /// must be uniquely owned (refcount 1): shared pages are read-only
    /// and must be cloned first (see [`PagedKv::reserve`]).
    pub fn store_row(&mut self, page: u32, layer: usize, row: usize, k: &[f32], v: &[f32]) {
        debug_assert!(row < PAGE_ROWS);
        debug_assert_eq!(
            self.refs[page as usize], 1,
            "store into shared or free page {page}"
        );
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        let base = self.layer_base(page, layer);
        let ko = base + row * self.d;
        self.data[ko..ko + self.d].copy_from_slice(k);
        let vo = base + PAGE_ROWS * self.d + row * self.d;
        self.data[vo..vo + self.d].copy_from_slice(v);
    }
}

/// Per-sequence view into a [`KvPagePool`]: a page table plus the
/// sequence length. Rows `[i·PAGE_ROWS, (i+1)·PAGE_ROWS)` live in
/// `pages[i]`.
#[derive(Default)]
pub struct PagedKv {
    pub pages: Vec<u32>,
    pub len: usize,
}

impl PagedKv {
    pub fn new() -> Self {
        PagedKv::default()
    }

    /// Pages a sequence of `len` rows occupies.
    pub fn pages_needed(len: usize) -> usize {
        len.div_ceil(PAGE_ROWS)
    }

    /// Fork this (empty) sequence off `parent`'s first `prefix_rows`
    /// rows by *sharing* the covering pages: each shared page's refcount
    /// is incremented and its id copied into this table — no KV payload
    /// is touched, so forking costs O(pages), not O(tokens).
    ///
    /// `prefix_rows` may end mid-page; the partial tail page is shared
    /// too and lazily cloned by [`PagedKv::reserve`] the first time
    /// either side grows into it (copy-on-write). Requires `self` to be
    /// empty and `prefix_rows ≤ parent.len`, and never allocates, so it
    /// cannot fail.
    pub fn fork_prefix(&mut self, pool: &mut KvPagePool, parent: &PagedKv, prefix_rows: usize) {
        assert!(
            self.pages.is_empty() && self.len == 0,
            "fork into a non-empty sequence"
        );
        assert!(
            prefix_rows <= parent.len,
            "prefix of {prefix_rows} rows exceeds parent length {}",
            parent.len
        );
        for &p in &parent.pages[..Self::pages_needed(prefix_rows)] {
            pool.retain_page(p);
            self.pages.push(p);
        }
        self.len = prefix_rows;
    }

    /// Ensure the page table covers `new_len` rows *writably*: the rows
    /// `[len, new_len)` an upcoming decode step will store must land in
    /// uniquely owned pages, so any still-shared page in that range is
    /// first cloned (copy-on-write: allocate, memcpy, swap the table
    /// entry, drop the ref on the original), then missing pages are
    /// allocated from the pool.
    ///
    /// On exhaustion everything *this call* did is rolled back — fresh
    /// pages freed, clones undone by re-retaining the original — and
    /// `false` comes back; the caller (engine) preempts or fails the
    /// request. Nothing is half-grown.
    pub fn reserve(&mut self, pool: &mut KvPagePool, new_len: usize) -> bool {
        let need = Self::pages_needed(new_len);
        // Copy-on-write: un-share existing pages the rows [len, new_len)
        // will be written into. After a fork this is at most the partial
        // tail page; fully occupied prefix pages are never written again.
        let first_write = self.len / PAGE_ROWS;
        let mut cloned: Vec<(usize, u32)> = Vec::new();
        let rollback_cow = |pages: &mut [u32], pool: &mut KvPagePool, cloned: &[(usize, u32)]| {
            for &(idx, orig) in cloned {
                pool.retain_page(orig);
                pool.release_page(pages[idx]);
                pages[idx] = orig;
            }
        };
        for idx in first_write..need.min(self.pages.len()) {
            let page = self.pages[idx];
            if pool.refcount(page) > 1 {
                match pool.clone_page(page) {
                    Some(fresh) => {
                        pool.release_page(page);
                        self.pages[idx] = fresh;
                        cloned.push((idx, page));
                    }
                    None => {
                        rollback_cow(&mut self.pages, pool, &cloned);
                        return false;
                    }
                }
            }
        }
        let start = self.pages.len();
        while self.pages.len() < need {
            match pool.try_alloc() {
                Some(p) => self.pages.push(p),
                None => {
                    for p in self.pages.drain(start..) {
                        pool.release_page(p);
                    }
                    rollback_cow(&mut self.pages, pool, &cloned);
                    return false;
                }
            }
        }
        true
    }

    /// Store the K/V rows for position `pos` in `layer`. The page table
    /// must already cover `pos` writably (see [`PagedKv::reserve`]).
    pub fn store(&self, pool: &mut KvPagePool, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let page = self.pages[pos / PAGE_ROWS];
        pool.store_row(page, layer, pos % PAGE_ROWS, k, v);
    }

    /// Roll the sequence back to `new_len` rows — the speculative-decode
    /// rejection path. Page-table entries wholly past the new length drop
    /// this sequence's reference (each returns to the free list only when
    /// no fork or parent still holds it, exactly like [`PagedKv::release`]);
    /// the partially occupied tail page is kept in place. Rows in
    /// `[new_len, old_len)` of the tail page become stale but are never
    /// read (attention reads rows `< len` only) and are fully overwritten
    /// by [`PagedKv::store`] before the length covers them again — and if
    /// the tail page is still shared with a fork, the next
    /// [`PagedKv::reserve`] clones it before any such write
    /// (copy-on-write), so truncation can never corrupt a sibling's KV.
    pub fn truncate(&mut self, pool: &mut KvPagePool, new_len: usize) {
        assert!(
            new_len <= self.len,
            "truncate to {new_len} rows but the sequence holds {}",
            self.len
        );
        let keep = Self::pages_needed(new_len);
        for p in self.pages.drain(keep..) {
            pool.release_page(p);
        }
        self.len = new_len;
    }

    /// Drop this sequence's reference on every page and reset it — the
    /// completion and preemption path. Pages shared with a parent or a
    /// fork stay allocated until their last holder releases; only pages
    /// this sequence uniquely owned return to the free list.
    pub fn release(&mut self, pool: &mut KvPagePool) {
        for p in self.pages.drain(..) {
            pool.release_page(p);
        }
        self.len = 0;
    }

    /// f32 slots currently pinned in the pool by this sequence.
    pub fn allocated_f32(&self, pool: &KvPagePool) -> usize {
        self.pages.len() * pool.page_stride()
    }
}

/// Fixed chunk width of the attention inner loops ([`dot_chunked`],
/// [`axpy_chunked`], [`rescale_chunked`]): slices are processed in
/// `ATTN_CHUNK`-wide fixed-size pieces (bounds hoisted into one check
/// per chunk, no cross-lane dependency inside a chunk) so the compiler
/// autovectorizes each piece into SIMD lanes — the same pattern as
/// `decode8`'s sign loop in [`crate::model::qlinear`].
pub const ATTN_CHUNK: usize = 8;

// The reduction trees in `dot_chunked` / `dot_chunked_scalar` spell out
// all eight lanes explicitly; keep the width in sync.
const _: () = assert!(ATTN_CHUNK == 8, "dot_chunked's reduction tree assumes 8 lanes");

/// Chunked dot product — the attention score (q·k) inner loop.
///
/// Accumulates into [`ATTN_CHUNK`] independent lane sums over
/// fixed-width chunks (so the loop autovectorizes into SIMD FMAs),
/// adds the sub-chunk tail scalarly, then reduces the lanes in a fixed
/// pairwise tree. The lane split changes the summation order versus a
/// plain sequential dot, so the order spelled out here *is* the
/// kernel's numerical contract: [`dot_chunked_scalar`] replays it
/// exactly and a property test pins the two bit-for-bit.
#[inline(always)]
pub fn dot_chunked(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % ATTN_CHUNK;
    let mut acc = [0.0f32; ATTN_CHUNK];
    let ca = a[..split].chunks_exact(ATTN_CHUNK);
    let cb = b[..split].chunks_exact(ATTN_CHUNK);
    for (xs, ys) in ca.zip(cb) {
        let xs: &[f32; ATTN_CHUNK] = xs.try_into().unwrap();
        let ys: &[f32; ATTN_CHUNK] = ys.try_into().unwrap();
        for (l, (&x, &y)) in acc.iter_mut().zip(xs.iter().zip(ys.iter())) {
            *l += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in a[split..].iter().zip(&b[split..]) {
        tail += x * y;
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// Scalar reference for [`dot_chunked`] — identical arithmetic (same
/// lane split, same reduction tree) written as plain indexed loops,
/// kept as the bit-parity oracle.
pub fn dot_chunked_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % ATTN_CHUNK;
    let mut acc = [0.0f32; ATTN_CHUNK];
    for i in 0..split {
        acc[i % ATTN_CHUNK] += a[i] * b[i];
    }
    let mut tail = 0.0f32;
    for i in split..a.len() {
        tail += a[i] * b[i];
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// Chunked in-place `out += p · v` — the attention weighted-sum (AV)
/// inner loop. Purely elementwise, so chunking only vectorizes it:
/// each output element sees the same single multiply-add a scalar loop
/// would apply ([`axpy_chunked_scalar`] is the oracle).
#[inline(always)]
pub fn axpy_chunked(p: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    let split = out.len() - out.len() % ATTN_CHUNK;
    let co = out[..split].chunks_exact_mut(ATTN_CHUNK);
    let cv = v[..split].chunks_exact(ATTN_CHUNK);
    for (os, xs) in co.zip(cv) {
        let os: &mut [f32; ATTN_CHUNK] = os.try_into().unwrap();
        let xs: &[f32; ATTN_CHUNK] = xs.try_into().unwrap();
        for (o, &x) in os.iter_mut().zip(xs.iter()) {
            *o += p * x;
        }
    }
    for (o, &x) in out[split..].iter_mut().zip(&v[split..]) {
        *o += p * x;
    }
}

/// Scalar reference for [`axpy_chunked`] (bit-parity oracle).
pub fn axpy_chunked_scalar(p: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    for (o, &x) in out.iter_mut().zip(v) {
        *o += p * x;
    }
}

/// Chunked in-place `out *= c` — the running-max softmax rescale and
/// the final `1/l` normalization. Elementwise like [`axpy_chunked`];
/// [`rescale_chunked_scalar`] is the oracle.
#[inline(always)]
pub fn rescale_chunked(c: f32, out: &mut [f32]) {
    let split = out.len() - out.len() % ATTN_CHUNK;
    for os in out[..split].chunks_exact_mut(ATTN_CHUNK) {
        let os: &mut [f32; ATTN_CHUNK] = os.try_into().unwrap();
        for o in os.iter_mut() {
            *o *= c;
        }
    }
    for o in out[split..].iter_mut() {
        *o *= c;
    }
}

/// Scalar reference for [`rescale_chunked`] (bit-parity oracle).
pub fn rescale_chunked_scalar(c: f32, out: &mut [f32]) {
    for o in out.iter_mut() {
        *o *= c;
    }
}

/// Flash-style blocked attention for one sequence, all heads: walk KV
/// rows `0..=pos` in [`PAGE_ROWS`]-sized blocks, keeping a per-head
/// running max `m`, running normalizer `l`, and unnormalized output
/// accumulator — score/softmax/weighted-sum fused per block, so no
/// full-length score vector is ever materialized and paged KV needs no
/// gather. The inner loops run through the chunked primitives
/// ([`dot_chunked`], [`rescale_chunked`], [`axpy_chunked`]); see
/// [`fused_batch_attention`] for the cross-sequence walk that services
/// a whole batch per block — this per-sequence kernel remains as the
/// parity oracle and the micro-bench baseline
/// (`benches/bench_attention.rs`).
///
/// `blocks(i)` returns the K and V rows for block `i` (row range
/// `[i·PAGE_ROWS, min((i+1)·PAGE_ROWS, pos+1))`), each `rows × d_model`
/// row-major. Both the paged and the contiguous layout satisfy this
/// with plain slices, and because the routine is shared, the two decode
/// paths execute identical floating-point operations in identical
/// order — the bit-exactness the parity tests pin down.
///
/// `q` and `out` are `heads × hd` (= `d_model`) vectors.
pub fn blocked_attention<'a, F>(
    q: &[f32],
    out: &mut [f32],
    pos: usize,
    heads: usize,
    hd: usize,
    blocks: F,
) where
    F: Fn(usize) -> (&'a [f32], &'a [f32]),
{
    let d = heads * hd;
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (hd as f32).sqrt();
    let n_rows = pos + 1;
    let n_blocks = n_rows.div_ceil(PAGE_ROWS);
    let mut run_max = vec![f32::NEG_INFINITY; heads];
    let mut run_sum = vec![0.0f32; heads];
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let mut scores = [0.0f32; PAGE_ROWS];
    for blk in 0..n_blocks {
        let (kb, vb) = blocks(blk);
        let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
        debug_assert!(kb.len() >= rows * d && vb.len() >= rows * d);
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let mut blk_max = f32::NEG_INFINITY;
            for (r, sc) in scores.iter_mut().enumerate().take(rows) {
                let kr = &kb[r * d + h * hd..r * d + (h + 1) * hd];
                let s = dot_chunked(qh, kr) * scale;
                *sc = s;
                blk_max = blk_max.max(s);
            }
            let oh = &mut out[h * hd..(h + 1) * hd];
            if blk_max > run_max[h] {
                // New running max: rescale the accumulated sum/output.
                // First block: exp(-inf - finite) = 0 zeroes the (already
                // zero) state.
                let c = (run_max[h] - blk_max).exp();
                run_sum[h] *= c;
                rescale_chunked(c, oh);
                run_max[h] = blk_max;
            }
            for (r, &sc) in scores.iter().enumerate().take(rows) {
                let p = (sc - run_max[h]).exp();
                run_sum[h] += p;
                axpy_chunked(p, &vb[r * d + h * hd..r * d + (h + 1) * hd], oh);
            }
        }
    }
    for h in 0..heads {
        let inv = 1.0 / run_sum[h];
        rescale_chunked(inv, &mut out[h * hd..(h + 1) * hd]);
    }
}

/// One sequence's slot in a [`fused_batch_attention`] pass: its query
/// row and output row (each `heads × hd` = `d_model`), and the last KV
/// position to attend to (the kernel reads rows `0..=pos`).
pub struct AttnLane<'a> {
    pub q: &'a [f32],
    pub out: &'a mut [f32],
    pub pos: usize,
}

/// Cross-sequence fused blocked attention: one walk over K/V block
/// indices per step that services **every sequence and head** still
/// attending to that block, instead of walking each sequence's blocks
/// separately.
///
/// `blocks(lane, blk)` returns `(key, k_rows, v_rows)` for lane
/// `lane`'s block `blk` (row range
/// `[blk·PAGE_ROWS, min((blk+1)·PAGE_ROWS, pos+1))`, each
/// `rows × d_model` row-major). `key` names the *physical* block: at
/// each block index, lanes are visited in ascending `(key, lane)`
/// order, so lanes whose page tables alias the same pool page (forked
/// siblings after [`PagedKv::fork_prefix`]) process it back to back —
/// the block's K/V rows are loaded from memory once per step and stay
/// cache-hot for the whole group, instead of being re-streamed once
/// per sequence. Layouts without aliasing (the contiguous
/// [`crate::generation::KvCache`] slabs) pass a unique key per
/// `(lane, blk)`, which degrades the walk to a plain per-block batch
/// loop.
///
/// # Parallel sharding
///
/// The walk shards **whole lanes** across the persistent worker pool
/// ([`crate::util::threadpool`]): lanes are sorted by their first
/// physical block key (so forked siblings whose tables alias the same
/// pages stay in one group and keep their shared blocks cache-hot),
/// cut into contiguous near-equal-work groups, and each group runs the
/// full serial walk with group-local state. Splitting one lane's block
/// range across workers was rejected deliberately: merging flash
/// partials (`out₁·exp(m₁−m) + out₂·exp(m₂−m)`) performs different
/// rescale sequences than the serial walk and is therefore *not*
/// bit-exact — whole-lane sharding keeps every lane's op sequence
/// untouched, so results are bitwise identical at any thread count.
/// Below [`crate::util::threadpool::PAR_MIN_WORK`] (and always at
/// B = 1) the walk stays on the calling thread.
///
/// # Bit-exactness
///
/// Per-lane state (running max `m`, normalizer `l`, unnormalized
/// output accumulator) is kept independently, every lane still meets
/// its blocks in ascending block order, and the score / rescale /
/// weighted-sum inner loops are the same chunked primitives
/// ([`dot_chunked`], [`rescale_chunked`], [`axpy_chunked`]) applied in
/// the same per-head order as [`blocked_attention`]. The only
/// reorderings are *across* lanes (the grouping) and *across* heads
/// within a block (scores and weighted sums run row-outer so each K/V
/// row is streamed once) — neither touches any single head's
/// dependency chain, and the per-block max is an exact reduction
/// regardless of order. Each lane's floating-point op sequence is
/// therefore identical to a per-sequence walk: fused and per-sequence
/// attention are bit-exact, which keeps batched, paged, and
/// shared-prefix decode bit-identical in turn.
pub fn fused_batch_attention<'a, F>(lanes: &mut [AttnLane<'_>], heads: usize, hd: usize, blocks: F)
where
    F: Fn(usize, usize) -> (u64, &'a [f32], &'a [f32]) + Sync,
{
    let d = heads * hd;
    let bsz = lanes.len();
    if bsz == 0 {
        return;
    }
    let mut total_rows = 0usize;
    for lane in lanes.iter_mut() {
        debug_assert_eq!(lane.q.len(), d);
        debug_assert_eq!(lane.out.len(), d);
        lane.out.fill(0.0);
        total_rows += lane.pos + 1;
    }
    // Group lanes by their first physical block so aliased tables
    // (forked siblings) share one worker's cache.
    let mut ids: Vec<usize> = (0..bsz).collect();
    let first_key: Vec<u64> = (0..bsz).map(|b| blocks(b, 0).0).collect();
    ids.sort_unstable_by_key(|&b| (first_key[b], b));
    // ~2·d flops per KV row (scores + weighted sum); stay serial below
    // the dispatch threshold. Group boundaries never affect values
    // (per-lane state is independent), only which thread runs a lane.
    let nt = if 2 * total_rows * d < threadpool::PAR_MIN_WORK {
        1
    } else {
        threadpool::num_threads()
    };
    let n_groups = nt.min(bsz).max(1);
    // Cut the sorted lane list into contiguous groups of near-equal row
    // count (lane cost is proportional to its rows).
    let mut bounds = Vec::with_capacity(n_groups + 1);
    bounds.push(0usize);
    let mut acc = 0usize;
    let mut cut = 1usize;
    for (i, &b) in ids.iter().enumerate() {
        acc += lanes[b].pos + 1;
        while cut < n_groups && acc * n_groups >= cut * total_rows {
            bounds.push(i + 1);
            cut += 1;
        }
    }
    while bounds.len() < n_groups + 1 {
        bounds.push(bsz);
    }
    let shared = LanesPtr(lanes.as_mut_ptr());
    threadpool::par_tasks(n_groups, |g| {
        let group = &ids[bounds[g]..bounds[g + 1]];
        fused_walk(&shared, group, heads, hd, &blocks);
    });
}

/// Raw-pointer courier handing disjoint lane subsets of one
/// [`fused_batch_attention`] dispatch to pool workers.
struct LanesPtr<'l>(*mut AttnLane<'l>);
// SAFETY: each worker dereferences only the lanes of the group it
// claimed, and groups partition the lane indices — no `&mut` aliases.
unsafe impl Send for LanesPtr<'_> {}
unsafe impl Sync for LanesPtr<'_> {}

/// The fused block walk restricted to one lane group — exactly the
/// serial kernel over `group`'s lanes, with group-local running state,
/// so disjoint groups can run concurrently without sharing anything.
/// `group` holds indices into the dispatch's lane array; within the
/// group, lanes are visited in ascending `(key, lane)` order per block
/// index, exactly as the single-group (serial) walk would visit them.
fn fused_walk<'l, 'a, F>(lanes: &LanesPtr<'l>, group: &[usize], heads: usize, hd: usize, blocks: &F)
where
    F: Fn(usize, usize) -> (u64, &'a [f32], &'a [f32]) + Sync,
{
    if group.is_empty() {
        return;
    }
    let d = heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let glen = group.len();
    let mut run_max = vec![f32::NEG_INFINITY; glen * heads];
    let mut run_sum = vec![0.0f32; glen * heads];
    let mut max_blocks = 0usize;
    for &b in group {
        // SAFETY: lane `b` belongs to this group alone (groups partition
        // the indices) and the dispatch barrier keeps the array alive.
        let lane = unsafe { &*lanes.0.add(b) };
        max_blocks = max_blocks.max((lane.pos + 1).div_ceil(PAGE_ROWS));
    }
    // Scores scratch for one (lane, block) visit: head-major so each
    // head's row slice is contiguous for the rescale/AV passes.
    let mut scores = vec![0.0f32; heads * PAGE_ROWS];
    let mut order: Vec<(u64, usize, usize, &'a [f32], &'a [f32])> = Vec::with_capacity(glen);
    for blk in 0..max_blocks {
        // Lanes still attending at this block index, grouped by
        // physical block so aliased pages are walked while cache-hot.
        order.clear();
        for (li, &b) in group.iter().enumerate() {
            // SAFETY: as above — exclusive access to this group's lanes.
            let lane = unsafe { &*lanes.0.add(b) };
            if blk * PAGE_ROWS <= lane.pos {
                let (key, kb, vb) = blocks(b, blk);
                order.push((key, b, li, kb, vb));
            }
        }
        order.sort_unstable_by_key(|&(key, b, ..)| (key, b));
        for &(_, b, li, kb, vb) in order.iter() {
            // SAFETY: as above — exclusive access to this group's lanes.
            let lane = unsafe { &mut *lanes.0.add(b) };
            let rows = (lane.pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
            debug_assert!(kb.len() >= rows * d && vb.len() >= rows * d);
            // Scores row-outer: each K row (contiguous d floats) is
            // streamed exactly once while every head dots against it.
            for r in 0..rows {
                let kr = &kb[r * d..(r + 1) * d];
                for h in 0..heads {
                    let qh = &lane.q[h * hd..(h + 1) * hd];
                    let s = dot_chunked(qh, &kr[h * hd..(h + 1) * hd]) * scale;
                    scores[h * PAGE_ROWS + r] = s;
                }
            }
            // Running-max rescale per head. The separate max pass
            // changes no value: f32::max is exact in any order, and the
            // rescale ops per head match the per-sequence kernel's.
            for h in 0..heads {
                let mut blk_max = f32::NEG_INFINITY;
                for &s in &scores[h * PAGE_ROWS..h * PAGE_ROWS + rows] {
                    blk_max = blk_max.max(s);
                }
                if blk_max > run_max[li * heads + h] {
                    // First block: exp(-inf - finite) = 0 zeroes the
                    // (already zero) state, as in the per-seq kernel.
                    let c = (run_max[li * heads + h] - blk_max).exp();
                    run_sum[li * heads + h] *= c;
                    rescale_chunked(c, &mut lane.out[h * hd..(h + 1) * hd]);
                    run_max[li * heads + h] = blk_max;
                }
            }
            // Weighted sum row-outer: each V row is streamed once; for
            // a fixed head the accumulation still visits rows in
            // ascending order, preserving the per-sequence op sequence.
            for r in 0..rows {
                let vr = &vb[r * d..(r + 1) * d];
                for h in 0..heads {
                    let p = (scores[h * PAGE_ROWS + r] - run_max[li * heads + h]).exp();
                    run_sum[li * heads + h] += p;
                    let oh = &mut lane.out[h * hd..(h + 1) * hd];
                    axpy_chunked(p, &vr[h * hd..(h + 1) * hd], oh);
                }
            }
        }
    }
    for (li, &b) in group.iter().enumerate() {
        // SAFETY: as above — exclusive access to this group's lanes.
        let lane = unsafe { &mut *lanes.0.add(b) };
        for h in 0..heads {
            let inv = 1.0 / run_sum[li * heads + h];
            rescale_chunked(inv, &mut lane.out[h * hd..(h + 1) * hd]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool(pages: usize) -> KvPagePool {
        KvPagePool::new(2, 8, pages)
    }

    #[test]
    fn alloc_free_accounting() {
        let mut pool = tiny_pool(3);
        assert_eq!(pool.pages_total(), 3);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(pool.pages_in_use(), 0);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 1));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_in_use(), 1);
        // Same page covers the whole first PAGE_ROWS rows.
        assert!(a.reserve(&mut pool, PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        // One row past the boundary takes a second page.
        assert!(a.reserve(&mut pool, PAGE_ROWS + 1));
        assert_eq!(a.pages.len(), 2);
        assert_eq!(pool.pages_free(), 1);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(a.pages.len(), 0);
        assert_eq!(a.len, 0);
    }

    #[test]
    fn reserve_rolls_back_on_exhaustion() {
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS)); // 1 page
        // Needs 3 more pages but only 1 is free: the partial grab must be
        // returned, and the existing allocation stay intact.
        assert!(!a.reserve(&mut pool, 4 * PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_free(), 1);
        // A request that fits still succeeds afterwards.
        assert!(a.reserve(&mut pool, 2 * PAGE_ROWS));
        assert_eq!(pool.pages_free(), 0);
    }

    #[test]
    fn store_roundtrip_across_pages() {
        let d = 8;
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS + 2));
        for pos in [0usize, 1, PAGE_ROWS - 1, PAGE_ROWS, PAGE_ROWS + 1] {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| (pos * 100 + layer * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                a.store(&mut pool, layer, pos, &k, &v);
                let page = a.pages[pos / PAGE_ROWS];
                let row = pos % PAGE_ROWS;
                let kb = pool.k_block(page, layer);
                let vb = pool.v_block(page, layer);
                assert_eq!(&kb[row * d..(row + 1) * d], &k[..]);
                assert_eq!(&vb[row * d..(row + 1) * d], &v[..]);
            }
        }
        assert_eq!(a.allocated_f32(&pool), 2 * pool.page_stride());
    }

    /// Fill rows `[0, len)` of `kv` with position-tagged values.
    fn fill(kv: &PagedKv, pool: &mut KvPagePool, d: usize, upto: usize, tag: f32) {
        for pos in 0..upto {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| tag + (pos * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                kv.store(pool, layer, pos, &k, &v);
            }
        }
    }

    #[test]
    fn fork_shares_pages_and_refcounts() {
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, PAGE_ROWS + 5));
        parent.len = PAGE_ROWS + 5;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, PAGE_ROWS + 5);
        // Same physical pages, two references each, no new allocation.
        assert_eq!(child.pages, parent.pages);
        assert_eq!(child.len, PAGE_ROWS + 5);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.shared_pages(), 2);
        for &p in &parent.pages {
            assert_eq!(pool.refcount(p), 2);
        }
        child.release(&mut pool);
        assert_eq!(pool.shared_pages(), 0);
        assert_eq!(pool.pages_in_use(), 2, "parent pages must survive child release");
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn fork_at_exact_page_boundary_never_clones() {
        let d = 8;
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, 2 * PAGE_ROWS));
        parent.len = 2 * PAGE_ROWS;
        fill(&parent, &mut pool, d, 2 * PAGE_ROWS, 1000.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, 2 * PAGE_ROWS);
        // Growing past a boundary prefix allocates a fresh page; the two
        // shared pages stay shared (no copy-on-write needed — nothing
        // writes into a fully occupied prefix page).
        assert!(child.reserve(&mut pool, 2 * PAGE_ROWS + 1));
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.shared_pages(), 2);
        assert_eq!(&child.pages[..2], &parent.pages[..]);
        assert_ne!(child.pages[2], parent.pages[0]);
        assert_ne!(child.pages[2], parent.pages[1]);
        child.store(&mut pool, 0, 2 * PAGE_ROWS, &[5.0; 8], &[6.0; 8]);
        // Parent's payload is untouched.
        assert_eq!(pool.k_block(parent.pages[0], 0)[0], 1000.0);
    }

    #[test]
    fn cow_clones_partial_tail_on_first_write() {
        let d = 8;
        let prefix = PAGE_ROWS + 5; // partial second page
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        let shared_tail = parent.pages[1];
        // First growth writes into the shared tail page → it must be
        // cloned for the child; the full first page stays shared.
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages[0], parent.pages[0], "full prefix page stays shared");
        assert_ne!(child.pages[1], shared_tail, "tail page must be cloned");
        assert_eq!(pool.refcount(shared_tail), 1, "parent keeps the original tail");
        assert_eq!(pool.refcount(child.pages[1]), 1);
        assert_eq!(pool.pages_in_use(), 3);
        // The clone carried the prefix rows and diverges after a write.
        let row = 4; // pos PAGE_ROWS+4, within the shared prefix
        let want: Vec<f32> = (0..d).map(|j| ((PAGE_ROWS + row) * 10 + j) as f32).collect();
        let got = &pool.k_block(child.pages[1], 0)[row * d..(row + 1) * d];
        assert_eq!(got, &want[..]);
        child.store(&mut pool, 0, prefix, &[9.0; 8], &[8.0; 8]);
        child.len = prefix + 1;
        let parent_tail_row5 = pool.k_block(shared_tail, 0)[5 * d];
        let child_tail_row5 = pool.k_block(child.pages[1], 0)[5 * d];
        assert_eq!(child_tail_row5, 9.0);
        assert_ne!(parent_tail_row5, 9.0, "CoW write leaked into the parent");
        // The parent growing into its (now uniquely owned) tail page
        // clones nothing further.
        assert!(parent.reserve(&mut pool, prefix + 1));
        assert_eq!(parent.pages[1], shared_tail);
        assert_eq!(pool.pages_in_use(), 3);
    }

    #[test]
    fn fork_then_parent_release_keeps_shared_pages_alive() {
        let d = 8;
        let prefix = PAGE_ROWS + 3;
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let pages = parent.pages.clone();
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        // Parent preempted/retired immediately after the fork: its
        // release drops refs but the child still holds both pages.
        parent.release(&mut pool);
        assert_eq!(parent.pages.len(), 0);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.shared_pages(), 0);
        for &p in &pages {
            assert_eq!(pool.refcount(p), 1);
        }
        // The child's view of the prefix is intact and now writable
        // without any clone (it is the sole owner).
        let want: Vec<f32> = (0..d).map(|j| j as f32).collect();
        assert_eq!(&pool.k_block(child.pages[0], 0)[..d], &want[..]);
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages[..2], pages[..]);
        assert_eq!(pool.pages_in_use(), 2);
        child.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn double_release_is_safe_and_exact() {
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, 2 * PAGE_ROWS));
        parent.len = 2 * PAGE_ROWS;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, 2 * PAGE_ROWS);
        child.release(&mut pool);
        // A second release of the same sequence is a no-op (its table is
        // empty), not a double-decrement of the parent's pages.
        child.release(&mut pool);
        assert_eq!(pool.pages_in_use(), 2);
        for &p in &parent.pages {
            assert_eq!(pool.refcount(p), 1);
        }
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn cow_rolls_back_on_exhaustion() {
        let prefix = PAGE_ROWS + 2;
        let mut pool = tiny_pool(2); // exactly the prefix, nothing spare
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        let before: Vec<u32> = child.pages.clone();
        // Growing the child needs a CoW clone of the tail but the pool is
        // exhausted: reserve must fail and restore the shared state.
        assert!(!child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages, before);
        assert_eq!(pool.refcount(child.pages[1]), 2);
        assert_eq!(pool.pages_free(), 0);
        // Preempting the parent frees nothing (pages shared) but makes
        // the child sole owner, and growth then succeeds without allocating.
        parent.release(&mut pool);
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn truncate_frees_whole_pages_and_keeps_tail() {
        let mut pool = tiny_pool(4);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 3 * PAGE_ROWS + 5)); // 4 pages
        a.len = 3 * PAGE_ROWS + 5;
        assert_eq!(pool.pages_in_use(), 4);
        // Truncating into page 1 frees pages 2 and 3 only; the
        // partially occupied tail page stays.
        a.truncate(&mut pool, PAGE_ROWS + 3);
        assert_eq!(a.pages.len(), 2);
        assert_eq!(a.len, PAGE_ROWS + 3);
        assert_eq!(pool.pages_in_use(), 2);
        // An exact page-boundary truncate keeps exactly len/PAGE_ROWS
        // pages (the boundary page is fully *used*, not fully free).
        a.truncate(&mut pool, PAGE_ROWS);
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_in_use(), 1);
        // Release after truncate frees exactly the remaining pages.
        let before = pool.pages_free();
        let remaining = a.pages.len();
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), before + remaining);
        assert_eq!(pool.pages_free(), pool.pages_total());
        // Truncate to zero on an empty table is a no-op.
        a.truncate(&mut pool, 0);
        assert_eq!(pool.pages_in_use(), 0);
    }

    /// Property: any interleaving of grows (`reserve` + len bump) and
    /// `truncate`s keeps the page table exactly `pages_needed(len)`
    /// pages, the pool accounting in sync, and releases everything at
    /// the end — the truncate → reserve round-trip the speculative
    /// rollback path depends on.
    #[test]
    fn truncate_reserve_roundtrips() {
        use crate::util::proptest_lite::check;
        check("truncate-reserve-roundtrip", 64, |rng| {
            let mut pool = KvPagePool::new(1, 4, 8);
            let mut kv = PagedKv::new();
            let mut len = 0usize;
            for step in 0..16 {
                if rng.bernoulli(0.55) {
                    let grow = rng.below_usize(PAGE_ROWS + 10);
                    let new_len = (len + grow).min(8 * PAGE_ROWS);
                    if !kv.reserve(&mut pool, new_len) {
                        return Err(format!("step {step}: reserve({new_len}) failed"));
                    }
                    kv.len = new_len;
                    len = new_len;
                } else {
                    let new_len = rng.below_usize(len + 1);
                    kv.truncate(&mut pool, new_len);
                    len = new_len;
                }
                if kv.pages.len() != PagedKv::pages_needed(len) {
                    return Err(format!(
                        "step {step}: {} pages cover {len} rows (want {})",
                        kv.pages.len(),
                        PagedKv::pages_needed(len)
                    ));
                }
                if pool.pages_in_use() != kv.pages.len() {
                    return Err(format!(
                        "step {step}: pool says {} in use, table holds {}",
                        pool.pages_in_use(),
                        kv.pages.len()
                    ));
                }
            }
            kv.release(&mut pool);
            if pool.pages_free() != pool.pages_total() {
                return Err("pages leaked through truncate/reserve".into());
            }
            Ok(())
        });
    }

    #[test]
    fn truncate_respects_cow_siblings() {
        // A forked child that speculated ahead (CoW tail clone + growth
        // page) and rolls back must free only its own pages — the
        // parent keeps reading the shared prefix untouched.
        let d = 8;
        let prefix = PAGE_ROWS + 5;
        let mut pool = tiny_pool(6);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        assert!(child.reserve(&mut pool, 2 * PAGE_ROWS + 3));
        child.len = 2 * PAGE_ROWS + 3;
        let cloned_tail = child.pages[1];
        assert_ne!(cloned_tail, parent.pages[1], "tail must have been CoW-cloned");
        assert_eq!(pool.pages_in_use(), 4); // parent 2 + clone + growth
        // Rejection rolls the child back inside the shared full page:
        // the clone and the growth page free, the shared page survives
        // with both references.
        child.truncate(&mut pool, PAGE_ROWS);
        assert_eq!(child.pages.len(), 1);
        assert_eq!(child.pages[0], parent.pages[0]);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.refcount(parent.pages[0]), 2);
        assert_eq!(pool.refcount(parent.pages[1]), 1, "parent's tail must survive");
        // Parent payload is intact after the child's rollback.
        let want: Vec<f32> = (0..d).map(|j| ((PAGE_ROWS + 4) * 10 + j) as f32).collect();
        let row = 4 * d;
        assert_eq!(&pool.k_block(parent.pages[1], 0)[row..row + d], &want[..]);
        // Truncating to zero drops the child's shared ref without
        // freeing the parent's page.
        child.truncate(&mut pool, 0);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.refcount(parent.pages[0]), 1);
        // And the child can regrow from empty afterwards.
        assert!(child.reserve(&mut pool, 1));
        child.len = 1;
        assert_eq!(pool.pages_in_use(), 3);
        child.release(&mut pool);
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), pool.pages_total());
    }

    #[test]
    fn chunked_primitives_match_scalar_oracles() {
        use crate::util::proptest_lite::check;
        check("chunked-oracles", 64, |rng| {
            // Lengths straddling the chunk width: sub-chunk slices,
            // exact multiples, and multi-chunk slices with tails.
            let n = 1 + rng.below_usize(3 * ATTN_CHUNK);
            let a = rng.gaussian_vec(n, 1.0);
            let b = rng.gaussian_vec(n, 1.0);
            let dv = dot_chunked(&a, &b);
            let ds = dot_chunked_scalar(&a, &b);
            if dv.to_bits() != ds.to_bits() {
                return Err(format!("dot {dv} vs {ds} at n={n}"));
            }
            let p = rng.gaussian() as f32;
            let c = rng.gaussian() as f32;
            let mut o1 = rng.gaussian_vec(n, 1.0);
            let mut o2 = o1.clone();
            axpy_chunked(p, &a, &mut o1);
            axpy_chunked_scalar(p, &a, &mut o2);
            rescale_chunked(c, &mut o1);
            rescale_chunked_scalar(c, &mut o2);
            for (i, (x, y)) in o1.iter().zip(&o2).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("axpy/rescale elem {i}: {x} vs {y} at n={n}"));
                }
            }
            Ok(())
        });
    }

    /// Fill rows `[lo, hi)` of `kv` (layer 0) with random K/V rows.
    /// The covering pages must be uniquely owned (post-`reserve`).
    fn fill_rows(
        kv: &PagedKv,
        pool: &mut KvPagePool,
        d: usize,
        lo: usize,
        hi: usize,
        rng: &mut crate::util::rng::Pcg64,
    ) {
        for pos in lo..hi {
            let k = rng.gaussian_vec(d, 1.0);
            let v = rng.gaussian_vec(d, 1.0);
            kv.store(pool, 0, pos, &k, &v);
        }
    }

    /// Naive reference: materialize every score, one softmax, one
    /// weighted sum — no blocking, no running max.
    fn two_pass_reference(q: &[f32], kc: &[f32], vc: &[f32], heads: usize, hd: usize) -> Vec<f32> {
        let d = heads * hd;
        let n_rows = kc.len() / d;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = vec![0.0f32; d];
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let scores: Vec<f32> = (0..n_rows)
                .map(|t| {
                    let kt = &kc[t * d + h * hd..t * d + (h + 1) * hd];
                    qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            for (t, &e) in exps.iter().enumerate() {
                let w = e / z;
                for j in 0..hd {
                    out[h * hd + j] += w * vc[t * d + h * hd + j];
                }
            }
        }
        out
    }

    /// Property-style fused-kernel parity: random batch sizes
    /// (B ∈ {1, 2, 4, 8, 16}), unequal lengths, head dims off the chunk
    /// width, and half the lanes forked off a shared parent so page
    /// tables alias. The fused walk must be bit-exact against per-lane
    /// [`blocked_attention`] and close to the naive two-pass oracle.
    #[test]
    fn fused_batch_attention_parity_random_shapes() {
        use crate::util::proptest_lite::{assert_close, check};
        check("fused-attn-parity", 20, |rng| {
            let bsz = [1usize, 2, 4, 8, 16][rng.below_usize(5)];
            let heads = 1 + rng.below_usize(3);
            let hd = [4usize, 5, 8, 12, 16][rng.below_usize(5)];
            let d = heads * hd;
            let mut pool = KvPagePool::new(1, d, 4 * (bsz + 1));
            // Parent prefix shared by the even lanes (aliased tables).
            let plen = 1 + rng.below_usize(2 * PAGE_ROWS);
            let mut parent = PagedKv::new();
            assert!(parent.reserve(&mut pool, plen));
            parent.len = plen;
            fill_rows(&parent, &mut pool, d, 0, plen, rng);
            let mut seqs: Vec<PagedKv> = Vec::new();
            for b in 0..bsz {
                let mut kv = PagedKv::new();
                if b % 2 == 0 {
                    // Forked lane: alias a random parent prefix, then
                    // grow a private tail of random length.
                    let fork = 1 + rng.below_usize(plen);
                    kv.fork_prefix(&mut pool, &parent, fork);
                    let extra = rng.below_usize(PAGE_ROWS);
                    if extra > 0 {
                        assert!(kv.reserve(&mut pool, fork + extra));
                        fill_rows(&kv, &mut pool, d, fork, fork + extra, rng);
                    }
                    kv.len = fork + extra;
                } else {
                    // Private lane of unrelated length.
                    let len = 1 + rng.below_usize(3 * PAGE_ROWS);
                    assert!(kv.reserve(&mut pool, len));
                    fill_rows(&kv, &mut pool, d, 0, len, rng);
                    kv.len = len;
                }
                seqs.push(kv);
            }
            let q = rng.gaussian_vec(bsz * d, 1.0);
            // Per-sequence walk — the oracle kernel.
            let mut out_seq = vec![0.0f32; bsz * d];
            for (b, kv) in seqs.iter().enumerate() {
                let pos = kv.len - 1;
                blocked_attention(
                    &q[b * d..(b + 1) * d],
                    &mut out_seq[b * d..(b + 1) * d],
                    pos,
                    heads,
                    hd,
                    |blk| {
                        let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
                        let page = kv.pages[blk];
                        (
                            &pool.k_block(page, 0)[..rows * d],
                            &pool.v_block(page, 0)[..rows * d],
                        )
                    },
                );
            }
            // Fused cross-sequence walk.
            let mut out_fused = vec![0.0f32; bsz * d];
            {
                let mut lanes: Vec<AttnLane> = out_fused
                    .chunks_exact_mut(d)
                    .enumerate()
                    .map(|(b, ob)| AttnLane {
                        q: &q[b * d..(b + 1) * d],
                        out: ob,
                        pos: seqs[b].len - 1,
                    })
                    .collect();
                fused_batch_attention(&mut lanes, heads, hd, |b, blk| {
                    let pos = seqs[b].len - 1;
                    let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
                    let page = seqs[b].pages[blk];
                    (
                        page as u64,
                        &pool.k_block(page, 0)[..rows * d],
                        &pool.v_block(page, 0)[..rows * d],
                    )
                });
            }
            for (i, (x, y)) in out_fused.iter().zip(&out_seq).enumerate() {
                if x.to_bits() != y.to_bits() {
                    let (lane, coord) = (i / d, i % d);
                    return Err(format!("fused vs per-seq lane {lane} coord {coord}: {x} vs {y}"));
                }
            }
            // Two-pass oracle per lane (gather rows, softmax once).
            for (b, kv) in seqs.iter().enumerate() {
                let n_rows = kv.len;
                let mut kc = vec![0.0f32; n_rows * d];
                let mut vc = vec![0.0f32; n_rows * d];
                for pos in 0..n_rows {
                    let page = kv.pages[pos / PAGE_ROWS];
                    let row = pos % PAGE_ROWS;
                    kc[pos * d..(pos + 1) * d]
                        .copy_from_slice(&pool.k_block(page, 0)[row * d..(row + 1) * d]);
                    vc[pos * d..(pos + 1) * d]
                        .copy_from_slice(&pool.v_block(page, 0)[row * d..(row + 1) * d]);
                }
                let want = two_pass_reference(&q[b * d..(b + 1) * d], &kc, &vc, heads, hd);
                assert_close(&out_fused[b * d..(b + 1) * d], &want, 1e-4, 1e-4)
                    .map_err(|e| format!("lane {b} vs two-pass oracle: {e}"))?;
            }
            // Releases return every page — no leak through fork/CoW.
            for kv in seqs.iter_mut() {
                kv.release(&mut pool);
            }
            parent.release(&mut pool);
            if pool.pages_free() != pool.pages_total() {
                return Err("pages leaked".into());
            }
            Ok(())
        });
    }

    /// The parallel lane-group sharding must be bitwise invariant across
    /// thread counts — including an oversubscribed non-power-of-two count
    /// that exercises uneven group cuts.
    #[test]
    fn fused_attention_bitwise_invariant_across_thread_counts() {
        // Large enough that 2·total_rows·d clears PAR_MIN_WORK, so the
        // nt > 1 runs really take the parallel sharding path.
        let (heads, hd) = (4usize, 16usize);
        let d = heads * hd;
        let bsz = 8usize;
        let mut rng = crate::util::rng::Pcg64::new(11);
        // Unequal lengths; buffers padded to whole blocks.
        let lens: Vec<usize> = (0..bsz).map(|b| 1 + (b * 37) % (3 * PAGE_ROWS)).collect();
        let kbuf: Vec<Vec<f32>> = lens
            .iter()
            .map(|&l| rng.gaussian_vec(l.div_ceil(PAGE_ROWS) * PAGE_ROWS * d, 1.0))
            .collect();
        let vbuf: Vec<Vec<f32>> = lens
            .iter()
            .map(|&l| rng.gaussian_vec(l.div_ceil(PAGE_ROWS) * PAGE_ROWS * d, 1.0))
            .collect();
        let q = rng.gaussian_vec(bsz * d, 1.0);
        let run = |nt: usize| {
            crate::util::threadpool::with_threads(nt, || {
                let mut out = vec![0.0f32; bsz * d];
                let mut lanes: Vec<AttnLane> = out
                    .chunks_exact_mut(d)
                    .enumerate()
                    .map(|(b, ob)| AttnLane {
                        q: &q[b * d..(b + 1) * d],
                        out: ob,
                        pos: lens[b] - 1,
                    })
                    .collect();
                fused_batch_attention(&mut lanes, heads, hd, |b, blk| {
                    let lo = blk * PAGE_ROWS * d;
                    (
                        ((b as u64) << 32) | blk as u64,
                        &kbuf[b][lo..lo + PAGE_ROWS * d],
                        &vbuf[b][lo..lo + PAGE_ROWS * d],
                    )
                });
                drop(lanes);
                out
            })
        };
        let want = run(1);
        for nt in [2usize, 7] {
            let got = run(nt);
            for (i, (x, y)) in got.iter().zip(&want).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "thread count {nt} lane {} coord {}: {x} vs {y}",
                    i / d,
                    i % d
                );
            }
        }
    }

    #[test]
    fn blocked_attention_matches_two_pass_softmax() {
        // Reference: materialize all scores, softmax once, weighted sum.
        let (heads, hd) = (2usize, 4usize);
        let d = heads * hd;
        let n_rows = 2 * PAGE_ROWS + 5; // three blocks, last partial
        let mut rng = crate::util::rng::Pcg64::new(9);
        let q: Vec<f32> = rng.gaussian_vec(d, 1.0);
        let kv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let vv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let mut out = vec![0.0f32; d];
        blocked_attention(&q, &mut out, n_rows - 1, heads, hd, |blk| {
            let lo = blk * PAGE_ROWS * d;
            let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
            (&kv[lo..lo + rows * d], &vv[lo..lo + rows * d])
        });
        let scale = 1.0 / (hd as f32).sqrt();
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let scores: Vec<f32> = (0..n_rows)
                .map(|t| {
                    let kt = &kv[t * d + h * hd..t * d + (h + 1) * hd];
                    qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            for j in 0..hd {
                let want: f32 = (0..n_rows)
                    .map(|t| exps[t] / z * vv[t * d + h * hd + j])
                    .sum();
                let got = out[h * hd + j];
                assert!(
                    (got - want).abs() < 1e-4,
                    "head {h} coord {j}: {got} vs {want}"
                );
            }
        }
    }
}
