//! Paged KV storage: fixed-size KV pages owned by a shared pool, with
//! per-sequence page tables — the serving engine's KV subsystem.
//!
//! A contiguous per-sequence cache forces admission control to reason
//! about worst-case context (`ctx × d_model` per layer per sequence).
//! Paging breaks that coupling: the pool owns `pages` blocks of
//! [`PAGE_ROWS`] token rows each (all layers, K and V), sequences
//! allocate pages on demand as they lengthen, release them on
//! completion, and the engine can preempt a sequence — returning its
//! pages to the pool and requeueing its request — when allocation
//! fails. Admission is then bounded by *actual* KV usage, so a pool
//! sized well below `max_batch × ctx` still serves full batches of
//! typical requests (the over-subscription behavior the ROADMAP
//! north-star asks for).
//!
//! # Attention kernels
//!
//! The same module owns the decode attention kernels. Both are
//! flash-style blocked passes (running max, per-block
//! score/softmax/weighted-sum) over [`PAGE_ROWS`]-row K/V blocks, so
//! paged sequences never need their rows gathered into one contiguous
//! buffer, and both run their inner loops through the shared chunked
//! primitives ([`dot_chunked`], [`axpy_chunked`], [`rescale_chunked`]:
//! fixed [`ATTN_CHUNK`]-wide slices the compiler autovectorizes, with
//! scalar oracles pinning bit-parity):
//!
//! * [`blocked_attention`] walks one sequence's blocks — the
//!   per-sequence baseline and parity oracle.
//! * [`fused_batch_attention`] walks the step's block indices once for
//!   the whole batch: at each index every sequence (and head) still
//!   attending to that block is serviced before the walk moves on,
//!   with sequences grouped by *physical* block so forked siblings
//!   whose page tables alias the same pool pages load each K/V block
//!   once per step instead of once per sequence.
//!
//! The fused walk additionally shards across the persistent worker
//! pool by **whole lane groups** (lanes sorted by first physical block
//! so forked siblings stay in one group; see the sharding notes on
//! [`fused_batch_attention`] for why per-lane block ranges are never
//! split). Per-sequence state is independent and every sequence still
//! meets its blocks in ascending order, so the fused walk executes the
//! identical per-sequence floating-point ops as [`blocked_attention`]
//! — the two kernels are bit-exact at any thread count (see the
//! bit-exactness notes on [`fused_batch_attention`]). The contiguous
//! [`crate::generation::KvCache`] path drives the same kernels over
//! [`PAGE_ROWS`]-sized slices of its slab, which keeps paged and
//! contiguous decode bit-exact (same floating-point operation order).
//!
//! # Copy-on-write prefix sharing
//!
//! Pages are **refcounted**, which makes prompt-prefix sharing a page
//! table operation instead of a KV copy: [`PagedKv::fork_prefix`] builds
//! a new sequence whose first `prefix_rows` rows alias a parent's pages
//! (each shared page's refcount is incremented; no payload moves). The
//! invariants that keep this sound:
//!
//! * **Reads are always safe.** Attention only ever reads rows
//!   `< seq.len` through the sequence's own page table, and a forked
//!   sequence's aliased rows are, by construction, the rows it would
//!   have computed itself (KV rows at position `p` depend only on tokens
//!   `0..=p`, which fork requires to match). So shared pages need no
//!   synchronization and decode stays bit-exact.
//! * **Writes require unique ownership.** [`PagedKv::reserve`] — which a
//!   scheduler must call (directly or via
//!   [`crate::generation::Generator::decode_batch_paged`]) before any
//!   row in `[len, new_len)` is stored — clones any still-shared page
//!   that the upcoming rows land in (allocate + memcpy + move one ref),
//!   so [`PagedKv::store`] only ever touches pages with refcount 1. In
//!   practice only the partial tail page at fork time is ever cloned;
//!   fully occupied prefix pages are never written again and stay shared
//!   for the sequences' whole lifetime.
//! * **Release drops one reference, never the page.** [`PagedKv::release`]
//!   decrements each page's refcount and only pages reaching zero return
//!   to the free list — preempting or retiring a forked sequence can
//!   never free pages a parent (or sibling fork) still reads, and the
//!   parent's release symmetrically leaves the children's shared pages
//!   alive.
//! * On exhaustion, `reserve` rolls back everything *it* did (fresh
//!   pages freed, clones undone by re-retaining the original), so a
//!   failed grow leaves the sequence exactly as it was.
//!
//! # KV compression tier (cold pages)
//!
//! With a [`KvQuantSpec`], each sequence keeps a *hot* fp32 tail (the
//! page currently being written plus `hot_pages` recent full pages)
//! and E8P/RVQ-quantizes every older full page in place
//! ([`PagedKv::compress_cold`] → [`KvPagePool::quantize_page`]): the
//! page's arena slot returns to the free list and the page is charged
//! at its compressed size against the same byte budget, so effective
//! pool capacity multiplies (~16× at 2 bits, ~8× at 4 — the
//! pool-pressure lever `benches/bench_kvquant.rs` measures). The
//! attention kernels consume blocks as [`KvBlock`] values and decode
//! cold pages inline through [`RowCodec::decode_slab`] — the same
//! `decode8` AVX2 sign-LUT tables as the weight matmuls, sharded
//! across the worker pool with the lane groups that already shard the
//! fused walk. Store/truncate/CoW semantics are untouched because
//! writes only ever target hot pages: [`PagedKv::reserve`] *reheats*
//! (decodes back to a fresh slot) any cold page a row in
//! `[len, new_len)` would land in, which only arises on the
//! truncate-then-regrow (speculative rollback) path. Quantizing a
//! shared page is safe — the representation change is deterministic,
//! so every fork decodes bit-identical values. With quantization off,
//! every page stays hot and the pool behaves bit-for-bit like the
//! slot-per-page design it replaces (page ids, free-list order, and
//! accounting included). [`KvPagePool::export_page`] /
//! [`KvPagePool::import_page`] lift page content out of the pool and
//! back for the engine's host-side spill arena; hot exports carry raw
//! f32 rows and cold exports carry the codes unchanged, so a
//! spill→restore round trip is exact in both representations.

use crate::model::{Model, ModelConfig};
use crate::quant::codebook::rowq::RowCodec;
use crate::util::phase::{self, Phase};
use crate::util::threadpool;

/// Token rows per KV page. Equal to the contiguous cache's growth slab
/// so the blocked attention traversal covers identical row ranges in
/// both layouts.
pub const PAGE_ROWS: usize = 32;

/// KV pages a worst-case (full-context) sequence pins — the unit
/// contiguous admission would have to reserve per sequence, and the
/// unit the paged pool oversubscribes against. Engines size their
/// default (preemption-free) pool as `max_batch ×` this.
pub fn pages_per_seq(cfg: &ModelConfig) -> usize {
    cfg.ctx.div_ceil(PAGE_ROWS)
}

/// Shared KV page pool: one flat f32 arena, a free list, and per-page
/// refcounts. Pages are identified by index; a page's payload is laid
/// out per layer as `[K rows | V rows]`, each `PAGE_ROWS × d_model`
/// row-major.
///
/// Sizing: one page holds [`PAGE_ROWS`] token rows of K and V across
/// every layer, i.e. `n_layers × 2 × PAGE_ROWS × d_model` f32 slots. A
/// worst-case (full-context) sequence pins [`pages_per_seq`] pages;
/// sizing the pool below `max_batch ×` that enables over-subscription
/// with preemption.
///
/// Refcount rules: freshly allocated pages start at refcount 1;
/// [`PagedKv::fork_prefix`] retains (increments) pages it shares;
/// releasing decrements and only a page reaching refcount 0 re-enters
/// the free list. A page with refcount > 1 is *shared* and must never
/// be written (see [`PagedKv::reserve`] for the copy-on-write path).
pub struct KvPagePool {
    n_layers: usize,
    d: usize,
    /// Hot-slot arena: `budget_pages × page_stride()` f32s. Page ids
    /// are decoupled from arena slots ([`PageState::slot`]) so cold
    /// pages occupy no slot at all.
    data: Vec<f32>,
    /// Free arena slots (LIFO).
    free_slots: Vec<u32>,
    /// Recycled page ids (LIFO). With quantization off this mirrors
    /// `free_slots` exactly — ids behave as in the slot-per-page design
    /// this replaces; with it on, `states` grows past `budget_pages`
    /// when cold pages multiply effective capacity.
    free_ids: Vec<u32>,
    /// Per-page state, indexed by page id.
    states: Vec<PageState>,
    /// Pages with refcount > 1, maintained incrementally on the 1 ↔ 2
    /// crossings so the scheduler's per-step gauge read is O(1).
    shared: usize,
    /// fp32-page budget: the arena size, and the byte budget cold
    /// pages are charged against (in f32 units).
    budget_pages: usize,
    /// f32-equivalent units in use: `page_stride()` per hot page,
    /// [`Self::cold_units`] per cold page. Never exceeds
    /// `budget_pages × page_stride()`, which also guarantees a free
    /// slot whenever a hot page's worth of units is available.
    used_units: usize,
    quant: Option<KvQuant>,
    /// Cold pages currently allocated (gauge).
    cold_count: usize,
    /// Pages ever quantized (monotone counter for metrics).
    pages_quantized: u64,
    /// Cold pages ever decoded back to hot (monotone counter).
    reheats: u64,
}

/// KV-cache compression configuration for [`KvPagePool::with_quant`].
#[derive(Clone, Copy, Debug)]
pub struct KvQuantSpec {
    /// E8P bits per KV element: 2 (one stage) or 4 (RVQ, two stages).
    pub bits: usize,
    /// Recent *full* pages per sequence kept fp32 in addition to the
    /// page currently being written (the hot tail window).
    pub hot_pages: usize,
}

struct KvQuant {
    codec: RowCodec,
    hot_pages: usize,
}

struct PageState {
    /// Reference count: 0 = free, 1 = uniquely owned, >1 = shared
    /// read-only across forked sequences.
    refs: u32,
    /// Arena slot holding this page's fp32 rows; meaningless while
    /// `cold` is `Some`.
    slot: u32,
    cold: Option<Box<QuantPage>>,
}

/// A cold page's payload: E8P/RVQ codes plus one RMS scale per
/// `(layer, K|V)` slab, produced by [`RowCodec::encode_slab`]. Slabs
/// are ordered `[(layer 0, K), (layer 0, V), (layer 1, K), …]` — the
/// arena's layer layout — with each slab's codes stage-major.
#[derive(Clone)]
pub struct QuantPage {
    codes: Vec<u16>,
    scales: Vec<f32>,
}

/// A page's content lifted out of the pool — the engine's spill-arena
/// payload. `Hot` carries the raw f32 rows (spill→restore of hot pages
/// is bit-exact); `Cold` carries the compressed codes unchanged (the
/// restored page decodes bit-identically).
pub enum PageExport {
    Hot(Vec<f32>),
    Cold(Box<QuantPage>),
}

impl PageExport {
    /// Heap bytes this export holds while parked in a spill arena.
    pub fn bytes(&self) -> usize {
        match self {
            PageExport::Hot(rows) => rows.len() * 4,
            PageExport::Cold(qp) => qp.codes.len() * 2 + qp.scales.len() * 4,
        }
    }
}

/// Decode every slab of a cold page into `out` (one whole page
/// stride). Free function so callers can borrow the codec and the
/// arena from disjoint pool fields.
fn decode_cold(codec: &RowCodec, qp: &QuantPage, slab: usize, out: &mut [f32]) {
    let cps = codec.codes_per_slab(slab);
    for (si, &sc) in qp.scales.iter().enumerate() {
        codec.decode_slab(
            &qp.codes[si * cps..(si + 1) * cps],
            sc,
            &mut out[si * slab..(si + 1) * slab],
        );
    }
}

impl KvPagePool {
    pub fn new(n_layers: usize, d_model: usize, pages: usize) -> Self {
        Self::with_quant(n_layers, d_model, pages, None)
    }

    /// Pool with an optional KV compression tier. `None` is the plain
    /// fp32 pool ([`Self::new`]), bit-for-bit.
    pub fn with_quant(
        n_layers: usize,
        d_model: usize,
        pages: usize,
        quant: Option<KvQuantSpec>,
    ) -> Self {
        assert!(n_layers > 0 && d_model > 0 && pages > 0, "empty KV pool");
        let stride = n_layers * 2 * PAGE_ROWS * d_model;
        let quant = quant.map(|spec| KvQuant {
            codec: RowCodec::new(spec.bits),
            hot_pages: spec.hot_pages,
        });
        KvPagePool {
            n_layers,
            d: d_model,
            data: vec![0.0; pages * stride],
            // Pop order is LIFO; slots and ids are handed out low-first
            // initially.
            free_slots: (0..pages as u32).rev().collect(),
            free_ids: (0..pages as u32).rev().collect(),
            states: (0..pages)
                .map(|_| PageState {
                    refs: 0,
                    slot: 0,
                    cold: None,
                })
                .collect(),
            shared: 0,
            budget_pages: pages,
            used_units: 0,
            quant,
            cold_count: 0,
            pages_quantized: 0,
            reheats: 0,
        }
    }

    /// Pool over a model's geometry.
    pub fn for_model(model: &Model, pages: usize) -> Self {
        Self::new(model.cfg.n_layers, model.cfg.d_model, pages)
    }

    /// [`Self::for_model`] with an optional KV compression tier.
    pub fn for_model_quant(model: &Model, pages: usize, quant: Option<KvQuantSpec>) -> Self {
        Self::with_quant(model.cfg.n_layers, model.cfg.d_model, pages, quant)
    }

    pub fn pages_total(&self) -> usize {
        self.budget_pages
    }

    /// Whole fp32 pages' worth of unused budget — the admission gate.
    /// With quantization on, cold pages consume a fraction of a page
    /// each, so this recovers capacity as pages go cold.
    pub fn pages_free(&self) -> usize {
        (self.budget_units() - self.used_units) / self.page_stride()
    }

    /// Allocated page ids. With quantization on this can *exceed*
    /// [`Self::pages_total`] — that surplus is the admitted-concurrency
    /// multiplier the compression tier exists for.
    pub fn pages_in_use(&self) -> usize {
        self.states.len() - self.free_ids.len()
    }

    fn budget_units(&self) -> usize {
        self.budget_pages * self.page_stride()
    }

    /// f32-equivalent units a cold page is charged: its u16 codes at 2
    /// bytes each plus one f32 scale per slab.
    fn cold_units(&self) -> usize {
        let stages = self.quant.as_ref().map_or(0, |q| q.codec.stages());
        self.page_stride() * stages / 16 + self.n_layers * 2
    }

    /// Configured KV bits (0 = compression off).
    pub fn quant_bits(&self) -> usize {
        self.quant.as_ref().map_or(0, |q| q.codec.bits())
    }

    /// Hot-tail window in full pages, `None` when compression is off.
    pub fn hot_window(&self) -> Option<usize> {
        self.quant.as_ref().map(|q| q.hot_pages)
    }

    /// Cold (quantized) pages currently allocated.
    pub fn cold_pages(&self) -> usize {
        self.cold_count
    }

    /// Pages ever quantized (monotone; metrics counter).
    pub fn pages_quantized_total(&self) -> u64 {
        self.pages_quantized
    }

    /// Cold pages ever decoded back to a hot slot (monotone).
    pub fn reheats_total(&self) -> u64 {
        self.reheats
    }

    /// Whether `page` currently holds codes rather than fp32 rows.
    pub fn is_cold(&self, page: u32) -> bool {
        self.states[page as usize].cold.is_some()
    }

    /// Pages currently shared by more than one sequence (refcount > 1).
    pub fn shared_pages(&self) -> usize {
        self.shared
    }

    /// Reference count of `page` (0 = free).
    pub fn refcount(&self, page: u32) -> u32 {
        self.states[page as usize].refs
    }

    /// f32 slots per page (all layers, K and V).
    pub fn page_stride(&self) -> usize {
        self.n_layers * 2 * PAGE_ROWS * self.d
    }

    fn try_alloc(&mut self) -> Option<u32> {
        let stride = self.page_stride();
        if self.budget_units() - self.used_units < stride {
            return None;
        }
        // used_units ≤ budget − stride bounds hot pages below
        // budget_pages, so a slot is always free here.
        let slot = self.free_slots.pop().expect("unit budget guarantees a free slot");
        let page = match self.free_ids.pop() {
            Some(id) => id,
            None => {
                self.states.push(PageState {
                    refs: 0,
                    slot: 0,
                    cold: None,
                });
                (self.states.len() - 1) as u32
            }
        };
        let st = &mut self.states[page as usize];
        debug_assert_eq!(st.refs, 0, "free page {page} had refs");
        debug_assert!(st.cold.is_none(), "free page {page} held codes");
        st.refs = 1;
        st.slot = slot;
        self.used_units += stride;
        Some(page)
    }

    /// Add one reference to an already-allocated page (prefix sharing).
    fn retain_page(&mut self, page: u32) {
        let r = self.states[page as usize].refs;
        debug_assert!(r > 0, "retain of free page {page}");
        if r == 1 {
            self.shared += 1;
        }
        self.states[page as usize].refs = r + 1;
    }

    /// Drop one reference; the page returns to the free list only when
    /// no sequence holds it any more. This is the only way pages are
    /// freed, so releasing a forked sequence can never free pages its
    /// parent (or a sibling fork) still reads.
    fn release_page(&mut self, page: u32) {
        debug_assert!((page as usize) < self.states.len());
        let r = self.states[page as usize].refs;
        debug_assert!(r > 0, "release of free page {page}");
        if r == 2 {
            self.shared -= 1;
        }
        self.states[page as usize].refs = r - 1;
        if r == 1 {
            debug_assert!(!self.free_ids.contains(&page), "double free of page {page}");
            self.free_page_storage(page);
            self.free_ids.push(page);
        }
    }

    /// Return a dead page's storage: its slot (hot) or its codes
    /// (cold), with matching unit accounting.
    fn free_page_storage(&mut self, page: u32) {
        let stride = self.page_stride();
        let cu = self.cold_units();
        let st = &mut self.states[page as usize];
        if st.cold.take().is_some() {
            self.used_units -= cu;
            self.cold_count -= 1;
        } else {
            let slot = st.slot;
            self.free_slots.push(slot);
            self.used_units -= stride;
        }
    }

    /// Copy-on-write clone: allocate a fresh page and copy `src`'s whole
    /// payload into it. A cold `src` is *decoded* into the clone — the
    /// caller is about to write rows into it, and writes only target
    /// hot pages. Refcounts are the caller's business (the caller swaps
    /// its table entry to the clone and releases its ref on `src`).
    fn clone_page(&mut self, src: u32) -> Option<u32> {
        let dst = self.try_alloc()?;
        let stride = self.page_stride();
        let slab = PAGE_ROWS * self.d;
        let dst_lo = self.states[dst as usize].slot as usize * stride;
        match &self.states[src as usize].cold {
            None => {
                let src_lo = self.states[src as usize].slot as usize * stride;
                self.data.copy_within(src_lo..src_lo + stride, dst_lo);
            }
            Some(qp) => {
                let codec = &self.quant.as_ref().expect("cold page without quant").codec;
                decode_cold(codec, qp, slab, &mut self.data[dst_lo..dst_lo + stride]);
            }
        }
        Some(dst)
    }

    fn layer_base(&self, page: u32, layer: usize) -> usize {
        debug_assert!(layer < self.n_layers);
        let st = &self.states[page as usize];
        debug_assert!(st.cold.is_none(), "fp32 access to cold page {page}");
        st.slot as usize * self.page_stride() + layer * 2 * PAGE_ROWS * self.d
    }

    /// K rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn k_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer);
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// V rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn v_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer) + PAGE_ROWS * self.d;
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// Write the K/V rows for one token at `row` within `page`. The page
    /// must be uniquely owned (refcount 1): shared pages are read-only
    /// and must be cloned first (see [`PagedKv::reserve`]).
    pub fn store_row(&mut self, page: u32, layer: usize, row: usize, k: &[f32], v: &[f32]) {
        debug_assert!(row < PAGE_ROWS);
        debug_assert_eq!(
            self.states[page as usize].refs, 1,
            "store into shared or free page {page}"
        );
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        let base = self.layer_base(page, layer);
        let ko = base + row * self.d;
        self.data[ko..ko + self.d].copy_from_slice(k);
        let vo = base + PAGE_ROWS * self.d + row * self.d;
        self.data[vo..vo + self.d].copy_from_slice(v);
    }

    /// Quantize a *filled* page in place: encode every `(layer, K|V)`
    /// slab with the pool's [`RowCodec`], free the arena slot, and
    /// charge the page at its compressed size. No-op when the page is
    /// already cold (forked siblings race benignly through their own
    /// [`PagedKv::compress_cold`] frontiers) or when compression is
    /// off. The page's logical content changes from exact fp32 rows to
    /// their E8P reconstruction; callers only quantize full pages
    /// outside every sequence's hot tail. Quantizing a shared page is
    /// safe: decode is deterministic, so every fork reads identical
    /// values.
    pub fn quantize_page(&mut self, page: u32) {
        if self.states[page as usize].cold.is_some() {
            return;
        }
        let Some(q) = self.quant.as_ref() else { return };
        let _scope = phase::scope(Phase::KvCompress);
        let stride = self.page_stride();
        let slab = PAGE_ROWS * self.d;
        let cps = q.codec.codes_per_slab(slab);
        let n_slabs = self.n_layers * 2;
        let mut codes = vec![0u16; n_slabs * cps];
        let mut scales = vec![0.0f32; n_slabs];
        let lo = self.states[page as usize].slot as usize * stride;
        for si in 0..n_slabs {
            scales[si] = q.codec.encode_slab(
                &self.data[lo + si * slab..lo + (si + 1) * slab],
                &mut codes[si * cps..(si + 1) * cps],
            );
        }
        let cu = self.cold_units();
        let slot = self.states[page as usize].slot;
        self.free_slots.push(slot);
        self.states[page as usize].cold = Some(Box::new(QuantPage { codes, scales }));
        self.used_units = self.used_units - stride + cu;
        self.cold_count += 1;
        self.pages_quantized += 1;
    }

    /// Decode a cold page back into a fresh arena slot so it is
    /// writable again — the truncate-then-regrow (speculative
    /// rollback) path. Returns `false` when the unit budget cannot
    /// absorb the hot−cold size difference; no-op `true` on hot pages.
    /// The decoded rows are the cold page's exact represented values;
    /// if the page is later re-quantized, the un-overwritten rows
    /// compound a second generation of quantization error (bounded,
    /// and never arises in fp32 mode).
    fn reheat_page(&mut self, page: u32) -> bool {
        if self.states[page as usize].cold.is_none() {
            return true;
        }
        let _scope = phase::scope(Phase::KvDecode);
        let stride = self.page_stride();
        let cu = self.cold_units();
        if self.budget_units() - self.used_units < stride - cu {
            return false;
        }
        let slot = self.free_slots.pop().expect("unit budget guarantees a free slot");
        let slab = PAGE_ROWS * self.d;
        let qp = self.states[page as usize].cold.take().expect("checked cold above");
        let lo = slot as usize * stride;
        {
            let codec = &self.quant.as_ref().expect("cold page without quant").codec;
            decode_cold(codec, &qp, slab, &mut self.data[lo..lo + stride]);
        }
        self.states[page as usize].slot = slot;
        self.used_units = self.used_units - cu + stride;
        self.cold_count -= 1;
        self.reheats += 1;
        true
    }

    /// Copy `page`'s content out of the pool and drop this holder's
    /// reference — the host-side spill path. The export carries the
    /// page's representation unchanged (raw f32 rows or codes), so
    /// [`Self::import_page`] restores it exactly. A shared page's
    /// content is copied and the other holders keep the original.
    pub fn export_page(&mut self, page: u32) -> PageExport {
        let exp = match &self.states[page as usize].cold {
            Some(qp) => PageExport::Cold(qp.clone()),
            None => {
                let stride = self.page_stride();
                let lo = self.states[page as usize].slot as usize * stride;
                PageExport::Hot(self.data[lo..lo + stride].to_vec())
            }
        };
        self.release_page(page);
        exp
    }

    /// Re-admit a spilled page under a fresh id. Hot exports need a
    /// full fp32 page of budget plus an arena slot; cold exports only
    /// their compressed size (no slot, no decode — the codes move back
    /// verbatim). When the pool cannot take the page right now, the
    /// export comes back unchanged in `Err` so the caller can retry.
    pub fn import_page(&mut self, exp: PageExport) -> Result<u32, PageExport> {
        match exp {
            PageExport::Hot(rows) => {
                let stride = self.page_stride();
                assert_eq!(rows.len(), stride, "hot import of a foreign page size");
                let Some(page) = self.try_alloc() else {
                    return Err(PageExport::Hot(rows));
                };
                let lo = self.states[page as usize].slot as usize * stride;
                self.data[lo..lo + stride].copy_from_slice(&rows);
                Ok(page)
            }
            PageExport::Cold(qp) => {
                let q = self.quant.as_ref().expect("cold import into an fp32 pool");
                let slab = PAGE_ROWS * self.d;
                assert_eq!(
                    qp.codes.len(),
                    self.n_layers * 2 * q.codec.codes_per_slab(slab),
                    "cold import of a foreign page shape"
                );
                let cu = self.cold_units();
                if self.budget_units() - self.used_units < cu {
                    return Err(PageExport::Cold(qp));
                }
                let page = match self.free_ids.pop() {
                    Some(id) => id,
                    None => {
                        self.states.push(PageState {
                            refs: 0,
                            slot: 0,
                            cold: None,
                        });
                        (self.states.len() - 1) as u32
                    }
                };
                let st = &mut self.states[page as usize];
                debug_assert_eq!(st.refs, 0, "free page {page} had refs");
                st.refs = 1;
                st.cold = Some(qp);
                self.used_units += cu;
                self.cold_count += 1;
                Ok(page)
            }
        }
    }

    /// The K/V rows of `page` at `layer` as the attention kernels
    /// consume them: fp32 slices for hot pages, borrowed codes +
    /// scales for cold ones (decoded inline by the kernel).
    pub fn kv_block(&self, page: u32, layer: usize) -> KvBlock<'_> {
        match &self.states[page as usize].cold {
            None => KvBlock::F32(self.k_block(page, layer), self.v_block(page, layer)),
            Some(qp) => {
                let codec = &self.quant.as_ref().expect("cold page without quant").codec;
                let cps = codec.codes_per_slab(PAGE_ROWS * self.d);
                let (k_si, v_si) = (layer * 2, layer * 2 + 1);
                KvBlock::Quant {
                    codec,
                    k_codes: &qp.codes[k_si * cps..(k_si + 1) * cps],
                    v_codes: &qp.codes[v_si * cps..(v_si + 1) * cps],
                    k_scale: qp.scales[k_si],
                    v_scale: qp.scales[v_si],
                }
            }
        }
    }
}

/// Per-sequence view into a [`KvPagePool`]: a page table plus the
/// sequence length. Rows `[i·PAGE_ROWS, (i+1)·PAGE_ROWS)` live in
/// `pages[i]`.
#[derive(Default)]
pub struct PagedKv {
    pub pages: Vec<u32>,
    pub len: usize,
    /// Compression frontier: pages `[0, cold_upto)` have been offered
    /// to [`KvPagePool::quantize_page`] by this sequence. Monotone
    /// between truncates; [`Self::truncate`] and [`Self::reserve`]
    /// lower it so reheated tail pages re-qualify.
    cold_upto: usize,
}

impl PagedKv {
    pub fn new() -> Self {
        PagedKv::default()
    }

    /// Pages a sequence of `len` rows occupies.
    pub fn pages_needed(len: usize) -> usize {
        len.div_ceil(PAGE_ROWS)
    }

    /// Fork this (empty) sequence off `parent`'s first `prefix_rows`
    /// rows by *sharing* the covering pages: each shared page's refcount
    /// is incremented and its id copied into this table — no KV payload
    /// is touched, so forking costs O(pages), not O(tokens).
    ///
    /// `prefix_rows` may end mid-page; the partial tail page is shared
    /// too and lazily cloned by [`PagedKv::reserve`] the first time
    /// either side grows into it (copy-on-write). Requires `self` to be
    /// empty and `prefix_rows ≤ parent.len`, and never allocates, so it
    /// cannot fail.
    pub fn fork_prefix(&mut self, pool: &mut KvPagePool, parent: &PagedKv, prefix_rows: usize) {
        assert!(
            self.pages.is_empty() && self.len == 0,
            "fork into a non-empty sequence"
        );
        assert!(
            prefix_rows <= parent.len,
            "prefix of {prefix_rows} rows exceeds parent length {}",
            parent.len
        );
        for &p in &parent.pages[..Self::pages_needed(prefix_rows)] {
            pool.retain_page(p);
            self.pages.push(p);
        }
        self.len = prefix_rows;
    }

    /// Ensure the page table covers `new_len` rows *writably*: the rows
    /// `[len, new_len)` an upcoming decode step will store must land in
    /// uniquely owned pages, so any still-shared page in that range is
    /// first cloned (copy-on-write: allocate, memcpy, swap the table
    /// entry, drop the ref on the original), then missing pages are
    /// allocated from the pool.
    ///
    /// On exhaustion everything *this call* did is rolled back — fresh
    /// pages freed, clones undone by re-retaining the original — and
    /// `false` comes back; the caller (engine) preempts or fails the
    /// request. Nothing is half-grown.
    pub fn reserve(&mut self, pool: &mut KvPagePool, new_len: usize) -> bool {
        let need = Self::pages_needed(new_len);
        // Copy-on-write: un-share existing pages the rows [len, new_len)
        // will be written into. After a fork this is at most the partial
        // tail page; fully occupied prefix pages are never written again.
        let first_write = self.len / PAGE_ROWS;
        let mut cloned: Vec<(usize, u32)> = Vec::new();
        let rollback_cow = |pages: &mut [u32], pool: &mut KvPagePool, cloned: &[(usize, u32)]| {
            for &(idx, orig) in cloned {
                pool.retain_page(orig);
                pool.release_page(pages[idx]);
                pages[idx] = orig;
            }
        };
        for idx in first_write..need.min(self.pages.len()) {
            let page = self.pages[idx];
            if pool.refcount(page) > 1 {
                match pool.clone_page(page) {
                    Some(fresh) => {
                        pool.release_page(page);
                        self.pages[idx] = fresh;
                        cloned.push((idx, page));
                    }
                    None => {
                        rollback_cow(&mut self.pages, pool, &cloned);
                        return false;
                    }
                }
            } else if !pool.reheat_page(page) {
                // A uniquely owned *cold* page in the write range (a
                // truncated tail) must be decoded back to fp32 before
                // any row in it is rewritten. Successful reheats are
                // deliberately not rolled back on a later failure —
                // a hot page with the same represented values is
                // semantically identical and will re-quantize when it
                // next leaves the hot window.
                rollback_cow(&mut self.pages, pool, &cloned);
                return false;
            }
        }
        // Reheated (or about-to-be-rewritten) pages re-qualify for
        // compression once they refill and age out of the hot window.
        self.cold_upto = self.cold_upto.min(first_write);
        let start = self.pages.len();
        while self.pages.len() < need {
            match pool.try_alloc() {
                Some(p) => self.pages.push(p),
                None => {
                    for p in self.pages.drain(start..) {
                        pool.release_page(p);
                    }
                    rollback_cow(&mut self.pages, pool, &cloned);
                    return false;
                }
            }
        }
        true
    }

    /// Store the K/V rows for position `pos` in `layer`. The page table
    /// must already cover `pos` writably (see [`PagedKv::reserve`]).
    pub fn store(&self, pool: &mut KvPagePool, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let page = self.pages[pos / PAGE_ROWS];
        pool.store_row(page, layer, pos % PAGE_ROWS, k, v);
    }

    /// Roll the sequence back to `new_len` rows — the speculative-decode
    /// rejection path. Page-table entries wholly past the new length drop
    /// this sequence's reference (each returns to the free list only when
    /// no fork or parent still holds it, exactly like [`PagedKv::release`]);
    /// the partially occupied tail page is kept in place. Rows in
    /// `[new_len, old_len)` of the tail page become stale but are never
    /// read (attention reads rows `< len` only) and are fully overwritten
    /// by [`PagedKv::store`] before the length covers them again — and if
    /// the tail page is still shared with a fork, the next
    /// [`PagedKv::reserve`] clones it before any such write
    /// (copy-on-write), so truncation can never corrupt a sibling's KV.
    pub fn truncate(&mut self, pool: &mut KvPagePool, new_len: usize) {
        assert!(
            new_len <= self.len,
            "truncate to {new_len} rows but the sequence holds {}",
            self.len
        );
        let keep = Self::pages_needed(new_len);
        for p in self.pages.drain(keep..) {
            pool.release_page(p);
        }
        self.len = new_len;
        self.cold_upto = self.cold_upto.min(keep);
    }

    /// Quantize this sequence's *cold* pages: every full page strictly
    /// below the hot tail (the page currently being written plus the
    /// pool's `hot_pages` recent full pages). The decode loop calls
    /// this after each length bump; it is a no-op on fp32 pools, and
    /// [`KvPagePool::quantize_page`] is idempotent, so forked siblings
    /// advancing their own frontiers over shared pages quantize each
    /// page once.
    pub fn compress_cold(&mut self, pool: &mut KvPagePool) {
        let Some(hot) = pool.hot_window() else { return };
        let limit = (self.len / PAGE_ROWS).saturating_sub(hot);
        while self.cold_upto < limit {
            pool.quantize_page(self.pages[self.cold_upto]);
            self.cold_upto += 1;
        }
    }

    /// Drop this sequence's reference on every page and reset it — the
    /// completion and preemption path. Pages shared with a parent or a
    /// fork stay allocated until their last holder releases; only pages
    /// this sequence uniquely owned return to the free list.
    pub fn release(&mut self, pool: &mut KvPagePool) {
        for p in self.pages.drain(..) {
            pool.release_page(p);
        }
        self.len = 0;
        self.cold_upto = 0;
    }

    /// Spill every page to the caller's arena and reset the sequence —
    /// the preempt-with-spill path. Returns the exports in table
    /// order; [`Self::restore`] rebuilds the identical sequence. Pages
    /// reserved beyond the stored rows (a reservation the preempted
    /// round never wrote into) are simply released: restore only needs
    /// — and [`Self::restore`] only accepts — `pages_needed(len)`
    /// exports.
    pub fn spill(&mut self, pool: &mut KvPagePool) -> Vec<PageExport> {
        let keep = Self::pages_needed(self.len);
        while self.pages.len() > keep {
            pool.release_page(self.pages.pop().unwrap());
        }
        self.cold_upto = 0;
        self.len = 0;
        self.pages.drain(..).map(|p| pool.export_page(p)).collect()
    }

    /// Rebuild a sequence from [`Self::spill`]'s exports. All-or-
    /// nothing: on mid-way exhaustion the already-imported pages are
    /// re-exported back into `exports` (contents unchanged — the
    /// export/import round trip is exact) and `false` comes back, so
    /// the caller can retry later.
    pub fn restore(
        &mut self,
        pool: &mut KvPagePool,
        exports: &mut Vec<PageExport>,
        len: usize,
    ) -> bool {
        assert!(self.pages.is_empty() && self.len == 0, "restore into a live sequence");
        assert_eq!(Self::pages_needed(len), exports.len(), "export count mismatch");
        let mut imported: Vec<u32> = Vec::with_capacity(exports.len());
        let mut failed: Option<PageExport> = None;
        while !exports.is_empty() {
            match pool.import_page(exports.remove(0)) {
                Ok(page) => imported.push(page),
                Err(exp) => {
                    failed = Some(exp);
                    break;
                }
            }
        }
        if let Some(exp) = failed {
            // Roll back: lift the imported prefix out again (contents
            // unchanged) and hand everything back in original order.
            let mut restored: Vec<PageExport> =
                imported.drain(..).map(|p| pool.export_page(p)).collect();
            restored.push(exp);
            restored.append(exports);
            *exports = restored;
            return false;
        }
        self.pages = imported;
        self.len = len;
        true
    }

    /// f32 slots currently pinned in the pool by this sequence.
    pub fn allocated_f32(&self, pool: &KvPagePool) -> usize {
        self.pages.len() * pool.page_stride()
    }
}

/// Fixed chunk width of the attention inner loops ([`dot_chunked`],
/// [`axpy_chunked`], [`rescale_chunked`]): slices are processed in
/// `ATTN_CHUNK`-wide fixed-size pieces (bounds hoisted into one check
/// per chunk, no cross-lane dependency inside a chunk) so the compiler
/// autovectorizes each piece into SIMD lanes — the same pattern as
/// `decode8`'s sign loop in [`crate::model::qlinear`].
pub const ATTN_CHUNK: usize = 8;

// The reduction trees in `dot_chunked` / `dot_chunked_scalar` spell out
// all eight lanes explicitly; keep the width in sync.
const _: () = assert!(ATTN_CHUNK == 8, "dot_chunked's reduction tree assumes 8 lanes");

/// Chunked dot product — the attention score (q·k) inner loop.
///
/// Accumulates into [`ATTN_CHUNK`] independent lane sums over
/// fixed-width chunks (so the loop autovectorizes into SIMD FMAs),
/// adds the sub-chunk tail scalarly, then reduces the lanes in a fixed
/// pairwise tree. The lane split changes the summation order versus a
/// plain sequential dot, so the order spelled out here *is* the
/// kernel's numerical contract: [`dot_chunked_scalar`] replays it
/// exactly and a property test pins the two bit-for-bit.
#[inline(always)]
pub fn dot_chunked(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % ATTN_CHUNK;
    let mut acc = [0.0f32; ATTN_CHUNK];
    let ca = a[..split].chunks_exact(ATTN_CHUNK);
    let cb = b[..split].chunks_exact(ATTN_CHUNK);
    for (xs, ys) in ca.zip(cb) {
        let xs: &[f32; ATTN_CHUNK] = xs.try_into().unwrap();
        let ys: &[f32; ATTN_CHUNK] = ys.try_into().unwrap();
        for (l, (&x, &y)) in acc.iter_mut().zip(xs.iter().zip(ys.iter())) {
            *l += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in a[split..].iter().zip(&b[split..]) {
        tail += x * y;
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// Scalar reference for [`dot_chunked`] — identical arithmetic (same
/// lane split, same reduction tree) written as plain indexed loops,
/// kept as the bit-parity oracle.
pub fn dot_chunked_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % ATTN_CHUNK;
    let mut acc = [0.0f32; ATTN_CHUNK];
    for i in 0..split {
        acc[i % ATTN_CHUNK] += a[i] * b[i];
    }
    let mut tail = 0.0f32;
    for i in split..a.len() {
        tail += a[i] * b[i];
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// Chunked in-place `out += p · v` — the attention weighted-sum (AV)
/// inner loop. Purely elementwise, so chunking only vectorizes it:
/// each output element sees the same single multiply-add a scalar loop
/// would apply ([`axpy_chunked_scalar`] is the oracle).
#[inline(always)]
pub fn axpy_chunked(p: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    let split = out.len() - out.len() % ATTN_CHUNK;
    let co = out[..split].chunks_exact_mut(ATTN_CHUNK);
    let cv = v[..split].chunks_exact(ATTN_CHUNK);
    for (os, xs) in co.zip(cv) {
        let os: &mut [f32; ATTN_CHUNK] = os.try_into().unwrap();
        let xs: &[f32; ATTN_CHUNK] = xs.try_into().unwrap();
        for (o, &x) in os.iter_mut().zip(xs.iter()) {
            *o += p * x;
        }
    }
    for (o, &x) in out[split..].iter_mut().zip(&v[split..]) {
        *o += p * x;
    }
}

/// Scalar reference for [`axpy_chunked`] (bit-parity oracle).
pub fn axpy_chunked_scalar(p: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    for (o, &x) in out.iter_mut().zip(v) {
        *o += p * x;
    }
}

/// Chunked in-place `out *= c` — the running-max softmax rescale and
/// the final `1/l` normalization. Elementwise like [`axpy_chunked`];
/// [`rescale_chunked_scalar`] is the oracle.
#[inline(always)]
pub fn rescale_chunked(c: f32, out: &mut [f32]) {
    let split = out.len() - out.len() % ATTN_CHUNK;
    for os in out[..split].chunks_exact_mut(ATTN_CHUNK) {
        let os: &mut [f32; ATTN_CHUNK] = os.try_into().unwrap();
        for o in os.iter_mut() {
            *o *= c;
        }
    }
    for o in out[split..].iter_mut() {
        *o *= c;
    }
}

/// Scalar reference for [`rescale_chunked`] (bit-parity oracle).
pub fn rescale_chunked_scalar(c: f32, out: &mut [f32]) {
    for o in out.iter_mut() {
        *o *= c;
    }
}

/// One [`PAGE_ROWS`]-row K/V block as the attention kernels consume
/// it: plain fp32 row slices (hot pages, contiguous caches), or a cold
/// page's codes that the kernel decodes inline into local scratch
/// through [`RowCodec::decode_slab`] — the same `decode8` sign-LUT
/// path as the weight matmuls. Decode is deterministic, so a block
/// shared by CoW forks yields bit-identical rows in every lane, and
/// the fused and per-sequence kernels (each decoding into its own
/// scratch) stay bit-exact with each other.
#[derive(Clone, Copy)]
pub enum KvBlock<'a> {
    /// `(k_rows, v_rows)`, each at least `rows × d_model` f32s.
    F32(&'a [f32], &'a [f32]),
    /// A cold page's K and V slabs (always a full page's worth —
    /// pages are only quantized once filled).
    Quant {
        codec: &'a RowCodec,
        k_codes: &'a [u16],
        v_codes: &'a [u16],
        k_scale: f32,
        v_scale: f32,
    },
}

/// Flash-style blocked attention for one sequence, all heads: walk KV
/// rows `0..=pos` in [`PAGE_ROWS`]-sized blocks, keeping a per-head
/// running max `m`, running normalizer `l`, and unnormalized output
/// accumulator — score/softmax/weighted-sum fused per block, so no
/// full-length score vector is ever materialized and paged KV needs no
/// gather. The inner loops run through the chunked primitives
/// ([`dot_chunked`], [`rescale_chunked`], [`axpy_chunked`]); see
/// [`fused_batch_attention`] for the cross-sequence walk that services
/// a whole batch per block — this per-sequence kernel remains as the
/// parity oracle and the micro-bench baseline
/// (`benches/bench_attention.rs`).
///
/// `blocks(i)` returns the K and V rows for block `i` (row range
/// `[i·PAGE_ROWS, min((i+1)·PAGE_ROWS, pos+1))`), each `rows × d_model`
/// row-major. Both the paged and the contiguous layout satisfy this
/// with plain slices, and because the routine is shared, the two decode
/// paths execute identical floating-point operations in identical
/// order — the bit-exactness the parity tests pin down.
///
/// `q` and `out` are `heads × hd` (= `d_model`) vectors.
pub fn blocked_attention<'a, F>(
    q: &[f32],
    out: &mut [f32],
    pos: usize,
    heads: usize,
    hd: usize,
    blocks: F,
) where
    F: Fn(usize) -> (&'a [f32], &'a [f32]),
{
    blocked_attention_kv(q, out, pos, heads, hd, |blk| {
        let (kb, vb) = blocks(blk);
        KvBlock::F32(kb, vb)
    });
}

/// [`blocked_attention`] over [`KvBlock`] blocks: identical walk and
/// identical floating-point ops on fp32 blocks (the plain entry point
/// is a thin adapter onto this one), plus inline decode of cold
/// blocks into local scratch before the unchanged score/AV loops.
pub fn blocked_attention_kv<'a, F>(
    q: &[f32],
    out: &mut [f32],
    pos: usize,
    heads: usize,
    hd: usize,
    blocks: F,
) where
    F: Fn(usize) -> KvBlock<'a>,
{
    let d = heads * hd;
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (hd as f32).sqrt();
    let n_rows = pos + 1;
    let n_blocks = n_rows.div_ceil(PAGE_ROWS);
    let mut run_max = vec![f32::NEG_INFINITY; heads];
    let mut run_sum = vec![0.0f32; heads];
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let mut scores = [0.0f32; PAGE_ROWS];
    // Decode scratch for cold blocks, allocated on first use so the
    // all-fp32 walk stays allocation-free.
    let mut kd: Vec<f32> = Vec::new();
    let mut vd: Vec<f32> = Vec::new();
    for blk in 0..n_blocks {
        let (kb, vb): (&[f32], &[f32]) = match blocks(blk) {
            KvBlock::F32(kb, vb) => (kb, vb),
            KvBlock::Quant {
                codec,
                k_codes,
                v_codes,
                k_scale,
                v_scale,
            } => {
                if kd.is_empty() {
                    kd.resize(PAGE_ROWS * d, 0.0);
                    vd.resize(PAGE_ROWS * d, 0.0);
                }
                codec.decode_slab(k_codes, k_scale, &mut kd);
                codec.decode_slab(v_codes, v_scale, &mut vd);
                (kd.as_slice(), vd.as_slice())
            }
        };
        let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
        debug_assert!(kb.len() >= rows * d && vb.len() >= rows * d);
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let mut blk_max = f32::NEG_INFINITY;
            for (r, sc) in scores.iter_mut().enumerate().take(rows) {
                let kr = &kb[r * d + h * hd..r * d + (h + 1) * hd];
                let s = dot_chunked(qh, kr) * scale;
                *sc = s;
                blk_max = blk_max.max(s);
            }
            let oh = &mut out[h * hd..(h + 1) * hd];
            if blk_max > run_max[h] {
                // New running max: rescale the accumulated sum/output.
                // First block: exp(-inf - finite) = 0 zeroes the (already
                // zero) state.
                let c = (run_max[h] - blk_max).exp();
                run_sum[h] *= c;
                rescale_chunked(c, oh);
                run_max[h] = blk_max;
            }
            for (r, &sc) in scores.iter().enumerate().take(rows) {
                let p = (sc - run_max[h]).exp();
                run_sum[h] += p;
                axpy_chunked(p, &vb[r * d + h * hd..r * d + (h + 1) * hd], oh);
            }
        }
    }
    for h in 0..heads {
        let inv = 1.0 / run_sum[h];
        rescale_chunked(inv, &mut out[h * hd..(h + 1) * hd]);
    }
}

/// One sequence's slot in a [`fused_batch_attention`] pass: its query
/// row and output row (each `heads × hd` = `d_model`), and the last KV
/// position to attend to (the kernel reads rows `0..=pos`).
pub struct AttnLane<'a> {
    pub q: &'a [f32],
    pub out: &'a mut [f32],
    pub pos: usize,
}

/// Cross-sequence fused blocked attention: one walk over K/V block
/// indices per step that services **every sequence and head** still
/// attending to that block, instead of walking each sequence's blocks
/// separately.
///
/// `blocks(lane, blk)` returns `(key, k_rows, v_rows)` for lane
/// `lane`'s block `blk` (row range
/// `[blk·PAGE_ROWS, min((blk+1)·PAGE_ROWS, pos+1))`, each
/// `rows × d_model` row-major). `key` names the *physical* block: at
/// each block index, lanes are visited in ascending `(key, lane)`
/// order, so lanes whose page tables alias the same pool page (forked
/// siblings after [`PagedKv::fork_prefix`]) process it back to back —
/// the block's K/V rows are loaded from memory once per step and stay
/// cache-hot for the whole group, instead of being re-streamed once
/// per sequence. Layouts without aliasing (the contiguous
/// [`crate::generation::KvCache`] slabs) pass a unique key per
/// `(lane, blk)`, which degrades the walk to a plain per-block batch
/// loop.
///
/// # Parallel sharding
///
/// The walk shards **whole lanes** across the persistent worker pool
/// ([`crate::util::threadpool`]): lanes are sorted by their first
/// physical block key (so forked siblings whose tables alias the same
/// pages stay in one group and keep their shared blocks cache-hot),
/// cut into contiguous near-equal-work groups, and each group runs the
/// full serial walk with group-local state. Splitting one lane's block
/// range across workers was rejected deliberately: merging flash
/// partials (`out₁·exp(m₁−m) + out₂·exp(m₂−m)`) performs different
/// rescale sequences than the serial walk and is therefore *not*
/// bit-exact — whole-lane sharding keeps every lane's op sequence
/// untouched, so results are bitwise identical at any thread count.
/// Below [`crate::util::threadpool::PAR_MIN_WORK`] (and always at
/// B = 1) the walk stays on the calling thread.
///
/// # Bit-exactness
///
/// Per-lane state (running max `m`, normalizer `l`, unnormalized
/// output accumulator) is kept independently, every lane still meets
/// its blocks in ascending block order, and the score / rescale /
/// weighted-sum inner loops are the same chunked primitives
/// ([`dot_chunked`], [`rescale_chunked`], [`axpy_chunked`]) applied in
/// the same per-head order as [`blocked_attention`]. The only
/// reorderings are *across* lanes (the grouping) and *across* heads
/// within a block (scores and weighted sums run row-outer so each K/V
/// row is streamed once) — neither touches any single head's
/// dependency chain, and the per-block max is an exact reduction
/// regardless of order. Each lane's floating-point op sequence is
/// therefore identical to a per-sequence walk: fused and per-sequence
/// attention are bit-exact, which keeps batched, paged, and
/// shared-prefix decode bit-identical in turn.
pub fn fused_batch_attention<'a, F>(lanes: &mut [AttnLane<'_>], heads: usize, hd: usize, blocks: F)
where
    F: Fn(usize, usize) -> (u64, &'a [f32], &'a [f32]) + Sync,
{
    fused_batch_attention_kv(lanes, heads, hd, |b, blk| {
        let (key, kb, vb) = blocks(b, blk);
        (key, KvBlock::F32(kb, vb))
    });
}

/// [`fused_batch_attention`] over [`KvBlock`] blocks: identical walk,
/// sharding, and floating-point ops on fp32 blocks (the plain entry
/// point is a thin adapter onto this one). Cold blocks are decoded
/// inline by each worker into group-local scratch; because lanes at a
/// block index are visited in ascending `(key, lane)` order, forked
/// siblings aliasing one cold page decode it **once per group per
/// step** (the decode cache keys on the physical block key), and the
/// decode work shards across the pool with the same lane groups as
/// the rest of the walk. Decode is deterministic, so caching changes
/// no value and every fork reads bit-identical rows.
pub fn fused_batch_attention_kv<'a, F>(
    lanes: &mut [AttnLane<'_>],
    heads: usize,
    hd: usize,
    blocks: F,
) where
    F: Fn(usize, usize) -> (u64, KvBlock<'a>) + Sync,
{
    let d = heads * hd;
    let bsz = lanes.len();
    if bsz == 0 {
        return;
    }
    let mut total_rows = 0usize;
    for lane in lanes.iter_mut() {
        debug_assert_eq!(lane.q.len(), d);
        debug_assert_eq!(lane.out.len(), d);
        lane.out.fill(0.0);
        total_rows += lane.pos + 1;
    }
    // Group lanes by their first physical block so aliased tables
    // (forked siblings) share one worker's cache.
    let mut ids: Vec<usize> = (0..bsz).collect();
    let first_key: Vec<u64> = (0..bsz).map(|b| blocks(b, 0).0).collect();
    ids.sort_unstable_by_key(|&b| (first_key[b], b));
    // ~2·d flops per KV row (scores + weighted sum); stay serial below
    // the dispatch threshold. Group boundaries never affect values
    // (per-lane state is independent), only which thread runs a lane.
    let nt = if 2 * total_rows * d < threadpool::PAR_MIN_WORK {
        1
    } else {
        threadpool::num_threads()
    };
    let n_groups = nt.min(bsz).max(1);
    // Cut the sorted lane list into contiguous groups of near-equal row
    // count (lane cost is proportional to its rows).
    let mut bounds = Vec::with_capacity(n_groups + 1);
    bounds.push(0usize);
    let mut acc = 0usize;
    let mut cut = 1usize;
    for (i, &b) in ids.iter().enumerate() {
        acc += lanes[b].pos + 1;
        while cut < n_groups && acc * n_groups >= cut * total_rows {
            bounds.push(i + 1);
            cut += 1;
        }
    }
    while bounds.len() < n_groups + 1 {
        bounds.push(bsz);
    }
    let shared = LanesPtr(lanes.as_mut_ptr());
    threadpool::par_tasks(n_groups, |g| {
        let group = &ids[bounds[g]..bounds[g + 1]];
        fused_walk(&shared, group, heads, hd, &blocks);
    });
}

/// Raw-pointer courier handing disjoint lane subsets of one
/// [`fused_batch_attention`] dispatch to pool workers.
struct LanesPtr<'l>(*mut AttnLane<'l>);
// SAFETY: each worker dereferences only the lanes of the group it
// claimed, and groups partition the lane indices — no `&mut` aliases.
unsafe impl Send for LanesPtr<'_> {}
unsafe impl Sync for LanesPtr<'_> {}

/// The fused block walk restricted to one lane group — exactly the
/// serial kernel over `group`'s lanes, with group-local running state,
/// so disjoint groups can run concurrently without sharing anything.
/// `group` holds indices into the dispatch's lane array; within the
/// group, lanes are visited in ascending `(key, lane)` order per block
/// index, exactly as the single-group (serial) walk would visit them.
fn fused_walk<'l, 'a, F>(lanes: &LanesPtr<'l>, group: &[usize], heads: usize, hd: usize, blocks: &F)
where
    F: Fn(usize, usize) -> (u64, KvBlock<'a>) + Sync,
{
    if group.is_empty() {
        return;
    }
    let d = heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let glen = group.len();
    let mut run_max = vec![f32::NEG_INFINITY; glen * heads];
    let mut run_sum = vec![0.0f32; glen * heads];
    let mut max_blocks = 0usize;
    for &b in group {
        // SAFETY: lane `b` belongs to this group alone (groups partition
        // the indices) and the dispatch barrier keeps the array alive.
        let lane = unsafe { &*lanes.0.add(b) };
        max_blocks = max_blocks.max((lane.pos + 1).div_ceil(PAGE_ROWS));
    }
    // Scores scratch for one (lane, block) visit: head-major so each
    // head's row slice is contiguous for the rescale/AV passes.
    let mut scores = vec![0.0f32; heads * PAGE_ROWS];
    // Group-local decode scratch for cold blocks (allocated on first
    // use), with a one-entry cache keyed on the physical block key:
    // the `(key, lane)` visit order puts forked siblings sharing a
    // cold page back to back, so the page decodes once per group.
    let mut kd: Vec<f32> = Vec::new();
    let mut vd: Vec<f32> = Vec::new();
    let mut order: Vec<(u64, usize, usize, KvBlock<'a>)> = Vec::with_capacity(glen);
    for blk in 0..max_blocks {
        // Lanes still attending at this block index, grouped by
        // physical block so aliased pages are walked while cache-hot.
        order.clear();
        let mut decoded_key: Option<u64> = None;
        for (li, &b) in group.iter().enumerate() {
            // SAFETY: as above — exclusive access to this group's lanes.
            let lane = unsafe { &*lanes.0.add(b) };
            if blk * PAGE_ROWS <= lane.pos {
                let (key, block) = blocks(b, blk);
                order.push((key, b, li, block));
            }
        }
        order.sort_unstable_by_key(|&(key, b, ..)| (key, b));
        for &(key, b, li, block) in order.iter() {
            // SAFETY: as above — exclusive access to this group's lanes.
            let lane = unsafe { &mut *lanes.0.add(b) };
            let rows = (lane.pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
            let (kb, vb): (&[f32], &[f32]) = match block {
                KvBlock::F32(kb, vb) => (kb, vb),
                KvBlock::Quant {
                    codec,
                    k_codes,
                    v_codes,
                    k_scale,
                    v_scale,
                } => {
                    if decoded_key != Some(key) {
                        if kd.is_empty() {
                            kd.resize(PAGE_ROWS * d, 0.0);
                            vd.resize(PAGE_ROWS * d, 0.0);
                        }
                        codec.decode_slab(k_codes, k_scale, &mut kd);
                        codec.decode_slab(v_codes, v_scale, &mut vd);
                        decoded_key = Some(key);
                    }
                    (kd.as_slice(), vd.as_slice())
                }
            };
            debug_assert!(kb.len() >= rows * d && vb.len() >= rows * d);
            // Scores row-outer: each K row (contiguous d floats) is
            // streamed exactly once while every head dots against it.
            for r in 0..rows {
                let kr = &kb[r * d..(r + 1) * d];
                for h in 0..heads {
                    let qh = &lane.q[h * hd..(h + 1) * hd];
                    let s = dot_chunked(qh, &kr[h * hd..(h + 1) * hd]) * scale;
                    scores[h * PAGE_ROWS + r] = s;
                }
            }
            // Running-max rescale per head. The separate max pass
            // changes no value: f32::max is exact in any order, and the
            // rescale ops per head match the per-sequence kernel's.
            for h in 0..heads {
                let mut blk_max = f32::NEG_INFINITY;
                for &s in &scores[h * PAGE_ROWS..h * PAGE_ROWS + rows] {
                    blk_max = blk_max.max(s);
                }
                if blk_max > run_max[li * heads + h] {
                    // First block: exp(-inf - finite) = 0 zeroes the
                    // (already zero) state, as in the per-seq kernel.
                    let c = (run_max[li * heads + h] - blk_max).exp();
                    run_sum[li * heads + h] *= c;
                    rescale_chunked(c, &mut lane.out[h * hd..(h + 1) * hd]);
                    run_max[li * heads + h] = blk_max;
                }
            }
            // Weighted sum row-outer: each V row is streamed once; for
            // a fixed head the accumulation still visits rows in
            // ascending order, preserving the per-sequence op sequence.
            for r in 0..rows {
                let vr = &vb[r * d..(r + 1) * d];
                for h in 0..heads {
                    let p = (scores[h * PAGE_ROWS + r] - run_max[li * heads + h]).exp();
                    run_sum[li * heads + h] += p;
                    let oh = &mut lane.out[h * hd..(h + 1) * hd];
                    axpy_chunked(p, &vr[h * hd..(h + 1) * hd], oh);
                }
            }
        }
    }
    for (li, &b) in group.iter().enumerate() {
        // SAFETY: as above — exclusive access to this group's lanes.
        let lane = unsafe { &mut *lanes.0.add(b) };
        for h in 0..heads {
            let inv = 1.0 / run_sum[li * heads + h];
            rescale_chunked(inv, &mut lane.out[h * hd..(h + 1) * hd]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool(pages: usize) -> KvPagePool {
        KvPagePool::new(2, 8, pages)
    }

    #[test]
    fn alloc_free_accounting() {
        let mut pool = tiny_pool(3);
        assert_eq!(pool.pages_total(), 3);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(pool.pages_in_use(), 0);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 1));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_in_use(), 1);
        // Same page covers the whole first PAGE_ROWS rows.
        assert!(a.reserve(&mut pool, PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        // One row past the boundary takes a second page.
        assert!(a.reserve(&mut pool, PAGE_ROWS + 1));
        assert_eq!(a.pages.len(), 2);
        assert_eq!(pool.pages_free(), 1);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(a.pages.len(), 0);
        assert_eq!(a.len, 0);
    }

    #[test]
    fn reserve_rolls_back_on_exhaustion() {
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS)); // 1 page
        // Needs 3 more pages but only 1 is free: the partial grab must be
        // returned, and the existing allocation stay intact.
        assert!(!a.reserve(&mut pool, 4 * PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_free(), 1);
        // A request that fits still succeeds afterwards.
        assert!(a.reserve(&mut pool, 2 * PAGE_ROWS));
        assert_eq!(pool.pages_free(), 0);
    }

    #[test]
    fn store_roundtrip_across_pages() {
        let d = 8;
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS + 2));
        for pos in [0usize, 1, PAGE_ROWS - 1, PAGE_ROWS, PAGE_ROWS + 1] {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| (pos * 100 + layer * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                a.store(&mut pool, layer, pos, &k, &v);
                let page = a.pages[pos / PAGE_ROWS];
                let row = pos % PAGE_ROWS;
                let kb = pool.k_block(page, layer);
                let vb = pool.v_block(page, layer);
                assert_eq!(&kb[row * d..(row + 1) * d], &k[..]);
                assert_eq!(&vb[row * d..(row + 1) * d], &v[..]);
            }
        }
        assert_eq!(a.allocated_f32(&pool), 2 * pool.page_stride());
    }

    /// Fill rows `[0, len)` of `kv` with position-tagged values.
    fn fill(kv: &PagedKv, pool: &mut KvPagePool, d: usize, upto: usize, tag: f32) {
        for pos in 0..upto {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| tag + (pos * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                kv.store(pool, layer, pos, &k, &v);
            }
        }
    }

    #[test]
    fn fork_shares_pages_and_refcounts() {
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, PAGE_ROWS + 5));
        parent.len = PAGE_ROWS + 5;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, PAGE_ROWS + 5);
        // Same physical pages, two references each, no new allocation.
        assert_eq!(child.pages, parent.pages);
        assert_eq!(child.len, PAGE_ROWS + 5);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.shared_pages(), 2);
        for &p in &parent.pages {
            assert_eq!(pool.refcount(p), 2);
        }
        child.release(&mut pool);
        assert_eq!(pool.shared_pages(), 0);
        assert_eq!(pool.pages_in_use(), 2, "parent pages must survive child release");
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn fork_at_exact_page_boundary_never_clones() {
        let d = 8;
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, 2 * PAGE_ROWS));
        parent.len = 2 * PAGE_ROWS;
        fill(&parent, &mut pool, d, 2 * PAGE_ROWS, 1000.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, 2 * PAGE_ROWS);
        // Growing past a boundary prefix allocates a fresh page; the two
        // shared pages stay shared (no copy-on-write needed — nothing
        // writes into a fully occupied prefix page).
        assert!(child.reserve(&mut pool, 2 * PAGE_ROWS + 1));
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.shared_pages(), 2);
        assert_eq!(&child.pages[..2], &parent.pages[..]);
        assert_ne!(child.pages[2], parent.pages[0]);
        assert_ne!(child.pages[2], parent.pages[1]);
        child.store(&mut pool, 0, 2 * PAGE_ROWS, &[5.0; 8], &[6.0; 8]);
        // Parent's payload is untouched.
        assert_eq!(pool.k_block(parent.pages[0], 0)[0], 1000.0);
    }

    #[test]
    fn cow_clones_partial_tail_on_first_write() {
        let d = 8;
        let prefix = PAGE_ROWS + 5; // partial second page
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        let shared_tail = parent.pages[1];
        // First growth writes into the shared tail page → it must be
        // cloned for the child; the full first page stays shared.
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages[0], parent.pages[0], "full prefix page stays shared");
        assert_ne!(child.pages[1], shared_tail, "tail page must be cloned");
        assert_eq!(pool.refcount(shared_tail), 1, "parent keeps the original tail");
        assert_eq!(pool.refcount(child.pages[1]), 1);
        assert_eq!(pool.pages_in_use(), 3);
        // The clone carried the prefix rows and diverges after a write.
        let row = 4; // pos PAGE_ROWS+4, within the shared prefix
        let want: Vec<f32> = (0..d).map(|j| ((PAGE_ROWS + row) * 10 + j) as f32).collect();
        let got = &pool.k_block(child.pages[1], 0)[row * d..(row + 1) * d];
        assert_eq!(got, &want[..]);
        child.store(&mut pool, 0, prefix, &[9.0; 8], &[8.0; 8]);
        child.len = prefix + 1;
        let parent_tail_row5 = pool.k_block(shared_tail, 0)[5 * d];
        let child_tail_row5 = pool.k_block(child.pages[1], 0)[5 * d];
        assert_eq!(child_tail_row5, 9.0);
        assert_ne!(parent_tail_row5, 9.0, "CoW write leaked into the parent");
        // The parent growing into its (now uniquely owned) tail page
        // clones nothing further.
        assert!(parent.reserve(&mut pool, prefix + 1));
        assert_eq!(parent.pages[1], shared_tail);
        assert_eq!(pool.pages_in_use(), 3);
    }

    #[test]
    fn fork_then_parent_release_keeps_shared_pages_alive() {
        let d = 8;
        let prefix = PAGE_ROWS + 3;
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let pages = parent.pages.clone();
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        // Parent preempted/retired immediately after the fork: its
        // release drops refs but the child still holds both pages.
        parent.release(&mut pool);
        assert_eq!(parent.pages.len(), 0);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.shared_pages(), 0);
        for &p in &pages {
            assert_eq!(pool.refcount(p), 1);
        }
        // The child's view of the prefix is intact and now writable
        // without any clone (it is the sole owner).
        let want: Vec<f32> = (0..d).map(|j| j as f32).collect();
        assert_eq!(&pool.k_block(child.pages[0], 0)[..d], &want[..]);
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages[..2], pages[..]);
        assert_eq!(pool.pages_in_use(), 2);
        child.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn double_release_is_safe_and_exact() {
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, 2 * PAGE_ROWS));
        parent.len = 2 * PAGE_ROWS;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, 2 * PAGE_ROWS);
        child.release(&mut pool);
        // A second release of the same sequence is a no-op (its table is
        // empty), not a double-decrement of the parent's pages.
        child.release(&mut pool);
        assert_eq!(pool.pages_in_use(), 2);
        for &p in &parent.pages {
            assert_eq!(pool.refcount(p), 1);
        }
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn cow_rolls_back_on_exhaustion() {
        let prefix = PAGE_ROWS + 2;
        let mut pool = tiny_pool(2); // exactly the prefix, nothing spare
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        let before: Vec<u32> = child.pages.clone();
        // Growing the child needs a CoW clone of the tail but the pool is
        // exhausted: reserve must fail and restore the shared state.
        assert!(!child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages, before);
        assert_eq!(pool.refcount(child.pages[1]), 2);
        assert_eq!(pool.pages_free(), 0);
        // Preempting the parent frees nothing (pages shared) but makes
        // the child sole owner, and growth then succeeds without allocating.
        parent.release(&mut pool);
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn truncate_frees_whole_pages_and_keeps_tail() {
        let mut pool = tiny_pool(4);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 3 * PAGE_ROWS + 5)); // 4 pages
        a.len = 3 * PAGE_ROWS + 5;
        assert_eq!(pool.pages_in_use(), 4);
        // Truncating into page 1 frees pages 2 and 3 only; the
        // partially occupied tail page stays.
        a.truncate(&mut pool, PAGE_ROWS + 3);
        assert_eq!(a.pages.len(), 2);
        assert_eq!(a.len, PAGE_ROWS + 3);
        assert_eq!(pool.pages_in_use(), 2);
        // An exact page-boundary truncate keeps exactly len/PAGE_ROWS
        // pages (the boundary page is fully *used*, not fully free).
        a.truncate(&mut pool, PAGE_ROWS);
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_in_use(), 1);
        // Release after truncate frees exactly the remaining pages.
        let before = pool.pages_free();
        let remaining = a.pages.len();
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), before + remaining);
        assert_eq!(pool.pages_free(), pool.pages_total());
        // Truncate to zero on an empty table is a no-op.
        a.truncate(&mut pool, 0);
        assert_eq!(pool.pages_in_use(), 0);
    }

    /// Property: any interleaving of grows (`reserve` + len bump) and
    /// `truncate`s keeps the page table exactly `pages_needed(len)`
    /// pages, the pool accounting in sync, and releases everything at
    /// the end — the truncate → reserve round-trip the speculative
    /// rollback path depends on.
    #[test]
    fn truncate_reserve_roundtrips() {
        use crate::util::proptest_lite::check;
        check("truncate-reserve-roundtrip", 64, |rng| {
            let mut pool = KvPagePool::new(1, 4, 8);
            let mut kv = PagedKv::new();
            let mut len = 0usize;
            for step in 0..16 {
                if rng.bernoulli(0.55) {
                    let grow = rng.below_usize(PAGE_ROWS + 10);
                    let new_len = (len + grow).min(8 * PAGE_ROWS);
                    if !kv.reserve(&mut pool, new_len) {
                        return Err(format!("step {step}: reserve({new_len}) failed"));
                    }
                    kv.len = new_len;
                    len = new_len;
                } else {
                    let new_len = rng.below_usize(len + 1);
                    kv.truncate(&mut pool, new_len);
                    len = new_len;
                }
                if kv.pages.len() != PagedKv::pages_needed(len) {
                    return Err(format!(
                        "step {step}: {} pages cover {len} rows (want {})",
                        kv.pages.len(),
                        PagedKv::pages_needed(len)
                    ));
                }
                if pool.pages_in_use() != kv.pages.len() {
                    return Err(format!(
                        "step {step}: pool says {} in use, table holds {}",
                        pool.pages_in_use(),
                        kv.pages.len()
                    ));
                }
            }
            kv.release(&mut pool);
            if pool.pages_free() != pool.pages_total() {
                return Err("pages leaked through truncate/reserve".into());
            }
            Ok(())
        });
    }

    #[test]
    fn truncate_respects_cow_siblings() {
        // A forked child that speculated ahead (CoW tail clone + growth
        // page) and rolls back must free only its own pages — the
        // parent keeps reading the shared prefix untouched.
        let d = 8;
        let prefix = PAGE_ROWS + 5;
        let mut pool = tiny_pool(6);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        assert!(child.reserve(&mut pool, 2 * PAGE_ROWS + 3));
        child.len = 2 * PAGE_ROWS + 3;
        let cloned_tail = child.pages[1];
        assert_ne!(cloned_tail, parent.pages[1], "tail must have been CoW-cloned");
        assert_eq!(pool.pages_in_use(), 4); // parent 2 + clone + growth
        // Rejection rolls the child back inside the shared full page:
        // the clone and the growth page free, the shared page survives
        // with both references.
        child.truncate(&mut pool, PAGE_ROWS);
        assert_eq!(child.pages.len(), 1);
        assert_eq!(child.pages[0], parent.pages[0]);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.refcount(parent.pages[0]), 2);
        assert_eq!(pool.refcount(parent.pages[1]), 1, "parent's tail must survive");
        // Parent payload is intact after the child's rollback.
        let want: Vec<f32> = (0..d).map(|j| ((PAGE_ROWS + 4) * 10 + j) as f32).collect();
        let row = 4 * d;
        assert_eq!(&pool.k_block(parent.pages[1], 0)[row..row + d], &want[..]);
        // Truncating to zero drops the child's shared ref without
        // freeing the parent's page.
        child.truncate(&mut pool, 0);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.refcount(parent.pages[0]), 1);
        // And the child can regrow from empty afterwards.
        assert!(child.reserve(&mut pool, 1));
        child.len = 1;
        assert_eq!(pool.pages_in_use(), 3);
        child.release(&mut pool);
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), pool.pages_total());
    }

    #[test]
    fn chunked_primitives_match_scalar_oracles() {
        use crate::util::proptest_lite::check;
        check("chunked-oracles", 64, |rng| {
            // Lengths straddling the chunk width: sub-chunk slices,
            // exact multiples, and multi-chunk slices with tails.
            let n = 1 + rng.below_usize(3 * ATTN_CHUNK);
            let a = rng.gaussian_vec(n, 1.0);
            let b = rng.gaussian_vec(n, 1.0);
            let dv = dot_chunked(&a, &b);
            let ds = dot_chunked_scalar(&a, &b);
            if dv.to_bits() != ds.to_bits() {
                return Err(format!("dot {dv} vs {ds} at n={n}"));
            }
            let p = rng.gaussian() as f32;
            let c = rng.gaussian() as f32;
            let mut o1 = rng.gaussian_vec(n, 1.0);
            let mut o2 = o1.clone();
            axpy_chunked(p, &a, &mut o1);
            axpy_chunked_scalar(p, &a, &mut o2);
            rescale_chunked(c, &mut o1);
            rescale_chunked_scalar(c, &mut o2);
            for (i, (x, y)) in o1.iter().zip(&o2).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("axpy/rescale elem {i}: {x} vs {y} at n={n}"));
                }
            }
            Ok(())
        });
    }

    /// Fill rows `[lo, hi)` of `kv` (layer 0) with random K/V rows.
    /// The covering pages must be uniquely owned (post-`reserve`).
    fn fill_rows(
        kv: &PagedKv,
        pool: &mut KvPagePool,
        d: usize,
        lo: usize,
        hi: usize,
        rng: &mut crate::util::rng::Pcg64,
    ) {
        for pos in lo..hi {
            let k = rng.gaussian_vec(d, 1.0);
            let v = rng.gaussian_vec(d, 1.0);
            kv.store(pool, 0, pos, &k, &v);
        }
    }

    /// Naive reference: materialize every score, one softmax, one
    /// weighted sum — no blocking, no running max.
    fn two_pass_reference(q: &[f32], kc: &[f32], vc: &[f32], heads: usize, hd: usize) -> Vec<f32> {
        let d = heads * hd;
        let n_rows = kc.len() / d;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = vec![0.0f32; d];
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let scores: Vec<f32> = (0..n_rows)
                .map(|t| {
                    let kt = &kc[t * d + h * hd..t * d + (h + 1) * hd];
                    qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            for (t, &e) in exps.iter().enumerate() {
                let w = e / z;
                for j in 0..hd {
                    out[h * hd + j] += w * vc[t * d + h * hd + j];
                }
            }
        }
        out
    }

    /// Property-style fused-kernel parity: random batch sizes
    /// (B ∈ {1, 2, 4, 8, 16}), unequal lengths, head dims off the chunk
    /// width, and half the lanes forked off a shared parent so page
    /// tables alias. The fused walk must be bit-exact against per-lane
    /// [`blocked_attention`] and close to the naive two-pass oracle.
    #[test]
    fn fused_batch_attention_parity_random_shapes() {
        use crate::util::proptest_lite::{assert_close, check};
        check("fused-attn-parity", 20, |rng| {
            let bsz = [1usize, 2, 4, 8, 16][rng.below_usize(5)];
            let heads = 1 + rng.below_usize(3);
            let hd = [4usize, 5, 8, 12, 16][rng.below_usize(5)];
            let d = heads * hd;
            let mut pool = KvPagePool::new(1, d, 4 * (bsz + 1));
            // Parent prefix shared by the even lanes (aliased tables).
            let plen = 1 + rng.below_usize(2 * PAGE_ROWS);
            let mut parent = PagedKv::new();
            assert!(parent.reserve(&mut pool, plen));
            parent.len = plen;
            fill_rows(&parent, &mut pool, d, 0, plen, rng);
            let mut seqs: Vec<PagedKv> = Vec::new();
            for b in 0..bsz {
                let mut kv = PagedKv::new();
                if b % 2 == 0 {
                    // Forked lane: alias a random parent prefix, then
                    // grow a private tail of random length.
                    let fork = 1 + rng.below_usize(plen);
                    kv.fork_prefix(&mut pool, &parent, fork);
                    let extra = rng.below_usize(PAGE_ROWS);
                    if extra > 0 {
                        assert!(kv.reserve(&mut pool, fork + extra));
                        fill_rows(&kv, &mut pool, d, fork, fork + extra, rng);
                    }
                    kv.len = fork + extra;
                } else {
                    // Private lane of unrelated length.
                    let len = 1 + rng.below_usize(3 * PAGE_ROWS);
                    assert!(kv.reserve(&mut pool, len));
                    fill_rows(&kv, &mut pool, d, 0, len, rng);
                    kv.len = len;
                }
                seqs.push(kv);
            }
            let q = rng.gaussian_vec(bsz * d, 1.0);
            // Per-sequence walk — the oracle kernel.
            let mut out_seq = vec![0.0f32; bsz * d];
            for (b, kv) in seqs.iter().enumerate() {
                let pos = kv.len - 1;
                blocked_attention(
                    &q[b * d..(b + 1) * d],
                    &mut out_seq[b * d..(b + 1) * d],
                    pos,
                    heads,
                    hd,
                    |blk| {
                        let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
                        let page = kv.pages[blk];
                        (
                            &pool.k_block(page, 0)[..rows * d],
                            &pool.v_block(page, 0)[..rows * d],
                        )
                    },
                );
            }
            // Fused cross-sequence walk.
            let mut out_fused = vec![0.0f32; bsz * d];
            {
                let mut lanes: Vec<AttnLane> = out_fused
                    .chunks_exact_mut(d)
                    .enumerate()
                    .map(|(b, ob)| AttnLane {
                        q: &q[b * d..(b + 1) * d],
                        out: ob,
                        pos: seqs[b].len - 1,
                    })
                    .collect();
                fused_batch_attention(&mut lanes, heads, hd, |b, blk| {
                    let pos = seqs[b].len - 1;
                    let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
                    let page = seqs[b].pages[blk];
                    (
                        page as u64,
                        &pool.k_block(page, 0)[..rows * d],
                        &pool.v_block(page, 0)[..rows * d],
                    )
                });
            }
            for (i, (x, y)) in out_fused.iter().zip(&out_seq).enumerate() {
                if x.to_bits() != y.to_bits() {
                    let (lane, coord) = (i / d, i % d);
                    return Err(format!("fused vs per-seq lane {lane} coord {coord}: {x} vs {y}"));
                }
            }
            // Two-pass oracle per lane (gather rows, softmax once).
            for (b, kv) in seqs.iter().enumerate() {
                let n_rows = kv.len;
                let mut kc = vec![0.0f32; n_rows * d];
                let mut vc = vec![0.0f32; n_rows * d];
                for pos in 0..n_rows {
                    let page = kv.pages[pos / PAGE_ROWS];
                    let row = pos % PAGE_ROWS;
                    kc[pos * d..(pos + 1) * d]
                        .copy_from_slice(&pool.k_block(page, 0)[row * d..(row + 1) * d]);
                    vc[pos * d..(pos + 1) * d]
                        .copy_from_slice(&pool.v_block(page, 0)[row * d..(row + 1) * d]);
                }
                let want = two_pass_reference(&q[b * d..(b + 1) * d], &kc, &vc, heads, hd);
                assert_close(&out_fused[b * d..(b + 1) * d], &want, 1e-4, 1e-4)
                    .map_err(|e| format!("lane {b} vs two-pass oracle: {e}"))?;
            }
            // Releases return every page — no leak through fork/CoW.
            for kv in seqs.iter_mut() {
                kv.release(&mut pool);
            }
            parent.release(&mut pool);
            if pool.pages_free() != pool.pages_total() {
                return Err("pages leaked".into());
            }
            Ok(())
        });
    }

    /// The parallel lane-group sharding must be bitwise invariant across
    /// thread counts — including an oversubscribed non-power-of-two count
    /// that exercises uneven group cuts.
    #[test]
    fn fused_attention_bitwise_invariant_across_thread_counts() {
        // Large enough that 2·total_rows·d clears PAR_MIN_WORK, so the
        // nt > 1 runs really take the parallel sharding path.
        let (heads, hd) = (4usize, 16usize);
        let d = heads * hd;
        let bsz = 8usize;
        let mut rng = crate::util::rng::Pcg64::new(11);
        // Unequal lengths; buffers padded to whole blocks.
        let lens: Vec<usize> = (0..bsz).map(|b| 1 + (b * 37) % (3 * PAGE_ROWS)).collect();
        let kbuf: Vec<Vec<f32>> = lens
            .iter()
            .map(|&l| rng.gaussian_vec(l.div_ceil(PAGE_ROWS) * PAGE_ROWS * d, 1.0))
            .collect();
        let vbuf: Vec<Vec<f32>> = lens
            .iter()
            .map(|&l| rng.gaussian_vec(l.div_ceil(PAGE_ROWS) * PAGE_ROWS * d, 1.0))
            .collect();
        let q = rng.gaussian_vec(bsz * d, 1.0);
        let run = |nt: usize| {
            crate::util::threadpool::with_threads(nt, || {
                let mut out = vec![0.0f32; bsz * d];
                let mut lanes: Vec<AttnLane> = out
                    .chunks_exact_mut(d)
                    .enumerate()
                    .map(|(b, ob)| AttnLane {
                        q: &q[b * d..(b + 1) * d],
                        out: ob,
                        pos: lens[b] - 1,
                    })
                    .collect();
                fused_batch_attention(&mut lanes, heads, hd, |b, blk| {
                    let lo = blk * PAGE_ROWS * d;
                    (
                        ((b as u64) << 32) | blk as u64,
                        &kbuf[b][lo..lo + PAGE_ROWS * d],
                        &vbuf[b][lo..lo + PAGE_ROWS * d],
                    )
                });
                drop(lanes);
                out
            })
        };
        let want = run(1);
        for nt in [2usize, 7] {
            let got = run(nt);
            for (i, (x, y)) in got.iter().zip(&want).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "thread count {nt} lane {} coord {}: {x} vs {y}",
                    i / d,
                    i % d
                );
            }
        }
    }

    #[test]
    fn blocked_attention_matches_two_pass_softmax() {
        // Reference: materialize all scores, softmax once, weighted sum.
        let (heads, hd) = (2usize, 4usize);
        let d = heads * hd;
        let n_rows = 2 * PAGE_ROWS + 5; // three blocks, last partial
        let mut rng = crate::util::rng::Pcg64::new(9);
        let q: Vec<f32> = rng.gaussian_vec(d, 1.0);
        let kv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let vv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let mut out = vec![0.0f32; d];
        blocked_attention(&q, &mut out, n_rows - 1, heads, hd, |blk| {
            let lo = blk * PAGE_ROWS * d;
            let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
            (&kv[lo..lo + rows * d], &vv[lo..lo + rows * d])
        });
        let scale = 1.0 / (hd as f32).sqrt();
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let scores: Vec<f32> = (0..n_rows)
                .map(|t| {
                    let kt = &kv[t * d + h * hd..t * d + (h + 1) * hd];
                    qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            for j in 0..hd {
                let want: f32 = (0..n_rows)
                    .map(|t| exps[t] / z * vv[t * d + h * hd + j])
                    .sum();
                let got = out[h * hd + j];
                assert!(
                    (got - want).abs() < 1e-4,
                    "head {h} coord {j}: {got} vs {want}"
                );
            }
        }
    }

    fn quant_pool(pages: usize, bits: usize, hot_pages: usize) -> KvPagePool {
        KvPagePool::with_quant(2, 8, pages, Some(KvQuantSpec { bits, hot_pages }))
    }

    /// Gather a sequence's rows for `layer` exactly as the attention
    /// kernels consume them: raw f32 rows from hot pages, the codec's
    /// deterministic reconstruction from cold ones.
    fn effective_rows(kv: &PagedKv, pool: &KvPagePool, layer: usize) -> (Vec<f32>, Vec<f32>) {
        let d = pool.d;
        let mut kc = Vec::new();
        let mut vc = Vec::new();
        for (blk, &page) in kv.pages.iter().enumerate() {
            let rows = (kv.len - blk * PAGE_ROWS).min(PAGE_ROWS);
            match pool.kv_block(page, layer) {
                KvBlock::F32(kb, vb) => {
                    kc.extend_from_slice(&kb[..rows * d]);
                    vc.extend_from_slice(&vb[..rows * d]);
                }
                KvBlock::Quant {
                    codec,
                    k_codes,
                    v_codes,
                    k_scale,
                    v_scale,
                } => {
                    let mut buf = vec![0.0f32; PAGE_ROWS * d];
                    codec.decode_slab(k_codes, k_scale, &mut buf);
                    kc.extend_from_slice(&buf[..rows * d]);
                    codec.decode_slab(v_codes, v_scale, &mut buf);
                    vc.extend_from_slice(&buf[..rows * d]);
                }
            }
        }
        (kc, vc)
    }

    /// Quantizing a page returns most of its budget: a two-page pool
    /// holding two cold pages has room for a third hot page, so
    /// allocated page ids exceed the fp32 page count — the admitted-
    /// concurrency multiplier the compression tier exists for.
    #[test]
    fn quantize_frees_budget_and_multiplies_capacity() {
        let mut pool = quant_pool(2, 2, 0);
        assert_eq!(pool.quant_bits(), 2);
        assert_eq!(pool.hot_window(), Some(0));
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 2 * PAGE_ROWS));
        a.len = 2 * PAGE_ROWS;
        fill(&a, &mut pool, 8, 2 * PAGE_ROWS, 0.0);
        assert_eq!(pool.pages_free(), 0);
        a.compress_cold(&mut pool);
        assert_eq!(pool.cold_pages(), 2);
        assert_eq!(pool.pages_quantized_total(), 2);
        for &p in &a.pages {
            assert!(pool.is_cold(p));
        }
        // Cold pages are charged at their compressed size, so a whole
        // fp32 page of budget is free again...
        assert_eq!(pool.pages_free(), 1);
        // ...and a third page fits in a two-page pool.
        let mut b = PagedKv::new();
        assert!(b.reserve(&mut pool, 1));
        assert_eq!(pool.pages_in_use(), 3);
        assert!(pool.pages_in_use() > pool.pages_total());
        b.release(&mut pool);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), 2);
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.used_units, 0);
    }

    /// `compress_cold` stops short of the hot tail: the page being
    /// written plus `hot_pages` recent full pages stay fp32, and a
    /// second sweep quantizes nothing new (idempotence — forked
    /// siblings advance their own frontiers over shared pages).
    #[test]
    fn compress_cold_respects_hot_window() {
        let mut pool = quant_pool(4, 2, 1);
        let mut a = PagedKv::new();
        let len = 3 * PAGE_ROWS + 4;
        assert!(a.reserve(&mut pool, len));
        a.len = len;
        fill(&a, &mut pool, 8, len, 0.0);
        a.compress_cold(&mut pool);
        assert_eq!(pool.cold_pages(), 2);
        assert!(pool.is_cold(a.pages[0]) && pool.is_cold(a.pages[1]));
        assert!(!pool.is_cold(a.pages[2]), "full page inside the hot window");
        assert!(!pool.is_cold(a.pages[3]), "page being written stays hot");
        a.compress_cold(&mut pool);
        assert_eq!(pool.pages_quantized_total(), 2);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    /// The kernels' inline decode must equal offline decode + the
    /// exact-fp32 oracle: `blocked_attention_kv` over a mixed
    /// cold/hot walk matches `two_pass_reference` on the effective
    /// (reconstructed) rows, the fused walk is bit-exact with the
    /// per-lane walk, and CoW forks sharing a cold page read
    /// bit-identical values in every lane.
    #[test]
    fn cold_attention_matches_offline_decode_and_forks_agree() {
        let mut rng = crate::util::rng::Pcg64::new(41);
        let (heads, hd) = (2usize, 4usize); // d = 8, the pool geometry
        let d = heads * hd;
        let mut pool = quant_pool(4, 2, 0);
        let mut a = PagedKv::new();
        let len = 2 * PAGE_ROWS + 11; // two full (→ cold) pages + hot tail
        assert!(a.reserve(&mut pool, len));
        a.len = len;
        fill_rows(&a, &mut pool, d, 0, len, &mut rng);
        a.compress_cold(&mut pool);
        assert_eq!(pool.cold_pages(), 2);
        // Oracle on the reconstruction the kernels must see.
        let (kc, vc) = effective_rows(&a, &pool, 0);
        let q = rng.gaussian_vec(3 * d, 1.0);
        let want = two_pass_reference(&q[..d], &kc, &vc, heads, hd);
        let mut out_a = vec![0.0f32; d];
        blocked_attention_kv(&q[..d], &mut out_a, len - 1, heads, hd, |blk| {
            pool.kv_block(a.pages[blk], 0)
        });
        crate::util::proptest_lite::assert_close(&out_a, &want, 1e-4, 1e-4).unwrap();
        // Two forks aliasing the parent's cold pages, attending over
        // the shared prefix only, with the *same* query: decode is
        // deterministic, so their outputs must be bitwise identical.
        let mut f1 = PagedKv::new();
        f1.fork_prefix(&mut pool, &a, 2 * PAGE_ROWS);
        let mut f2 = PagedKv::new();
        f2.fork_prefix(&mut pool, &a, 2 * PAGE_ROWS);
        let seqs = [&a, &f1, &f2];
        let lens = [len, 2 * PAGE_ROWS, 2 * PAGE_ROWS];
        let qs = [&q[..d], &q[d..2 * d], &q[d..2 * d]];
        // Per-lane walk — the oracle for the fused kernel.
        let mut out_seq = vec![0.0f32; 3 * d];
        for b in 0..3 {
            blocked_attention_kv(
                qs[b],
                &mut out_seq[b * d..(b + 1) * d],
                lens[b] - 1,
                heads,
                hd,
                |blk| pool.kv_block(seqs[b].pages[blk], 0),
            );
        }
        let mut out_fused = vec![0.0f32; 3 * d];
        {
            let mut lanes: Vec<AttnLane> = out_fused
                .chunks_exact_mut(d)
                .enumerate()
                .map(|(b, ob)| AttnLane {
                    q: qs[b],
                    out: ob,
                    pos: lens[b] - 1,
                })
                .collect();
            fused_batch_attention_kv(&mut lanes, heads, hd, |b, blk| {
                let page = seqs[b].pages[blk];
                (page as u64, pool.kv_block(page, 0))
            });
        }
        for (i, (x, y)) in out_fused.iter().zip(&out_seq).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "fused vs per-seq lane {} coord {}: {x} vs {y}",
                i / d,
                i % d
            );
        }
        for j in 0..d {
            assert!(
                out_fused[d + j].to_bits() == out_fused[2 * d + j].to_bits(),
                "forked lanes diverged at coord {j}"
            );
        }
        f2.release(&mut pool);
        f1.release(&mut pool);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), pool.pages_total());
    }

    /// Spill → restore is exact: the cold page's codes move verbatim,
    /// the hot page's rows move bitwise, and a restore that cannot fit
    /// rolls back all-or-nothing with the exports intact.
    #[test]
    fn spill_restore_round_trip_is_exact() {
        let mut pool = quant_pool(3, 2, 0);
        let mut a = PagedKv::new();
        let len = PAGE_ROWS + 7;
        assert!(a.reserve(&mut pool, len));
        a.len = len;
        fill(&a, &mut pool, 8, len, 3.0);
        a.compress_cold(&mut pool);
        assert!(pool.is_cold(a.pages[0]) && !pool.is_cold(a.pages[1]));
        let before: Vec<_> = (0..2).map(|l| effective_rows(&a, &pool, l)).collect();
        let used = pool.used_units;
        let mut exports = a.spill(&mut pool);
        assert_eq!(exports.len(), 2);
        assert!(matches!(exports[0], PageExport::Cold(_)));
        assert!(matches!(exports[1], PageExport::Hot(_)));
        assert!(a.pages.is_empty() && a.len == 0);
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.used_units, 0);
        // Pressure the pool so the cold page imports but the hot page
        // cannot: restore must undo the partial import and hand every
        // export back unchanged, in order.
        let mut blocker = PagedKv::new();
        assert!(blocker.reserve(&mut pool, 2 * PAGE_ROWS));
        let mut b = PagedKv::new();
        assert!(!b.restore(&mut pool, &mut exports, len));
        assert_eq!(exports.len(), 2);
        assert!(matches!(exports[0], PageExport::Cold(_)));
        assert!(matches!(exports[1], PageExport::Hot(_)));
        assert!(b.pages.is_empty() && b.len == 0);
        blocker.release(&mut pool);
        assert!(b.restore(&mut pool, &mut exports, len));
        assert!(exports.is_empty());
        assert_eq!(b.len, len);
        assert_eq!(pool.used_units, used);
        assert!(pool.is_cold(b.pages[0]) && !pool.is_cold(b.pages[1]));
        for (l, want) in before.iter().enumerate() {
            assert_eq!(&effective_rows(&b, &pool, l), want, "layer {l} changed");
        }
        b.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
    }

    /// A speculative rollback into the cold region followed by regrowth
    /// must reheat the written-into tail page — decoded back to the
    /// exact values its codes represented — while pages before the
    /// write range stay cold.
    #[test]
    fn reserve_reheats_cold_tail_after_truncate() {
        let mut pool = quant_pool(3, 2, 0);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 2 * PAGE_ROWS));
        a.len = 2 * PAGE_ROWS;
        fill(&a, &mut pool, 8, a.len, 1.0);
        a.compress_cold(&mut pool);
        assert_eq!(pool.cold_pages(), 2);
        let (kc, _) = effective_rows(&a, &pool, 0);
        a.truncate(&mut pool, PAGE_ROWS + 9);
        assert!(a.reserve(&mut pool, PAGE_ROWS + 10));
        assert!(!pool.is_cold(a.pages[1]), "write-range page must be reheated");
        assert!(pool.is_cold(a.pages[0]), "page before the write range stays cold");
        assert_eq!(pool.reheats_total(), 1);
        assert_eq!(pool.cold_pages(), 1);
        // The reheated rows below the truncation point are the decode
        // of the codes the page held — not garbage, not stale fp32.
        let kb = pool.k_block(a.pages[1], 0);
        assert_eq!(&kb[..9 * 8], &kc[PAGE_ROWS * 8..(PAGE_ROWS + 9) * 8]);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
    }
}
