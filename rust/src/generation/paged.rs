//! Paged KV storage: fixed-size KV pages owned by a shared pool, with
//! per-sequence page tables — the serving engine's KV subsystem.
//!
//! A contiguous per-sequence cache forces admission control to reason
//! about worst-case context (`ctx × d_model` per layer per sequence).
//! Paging breaks that coupling: the pool owns `pages` blocks of
//! [`PAGE_ROWS`] token rows each (all layers, K and V), sequences
//! allocate pages on demand as they lengthen, release them on
//! completion, and the engine can preempt a sequence — returning its
//! pages to the pool and requeueing its request — when allocation
//! fails. Admission is then bounded by *actual* KV usage, so a pool
//! sized well below `max_batch × ctx` still serves full batches of
//! typical requests (the over-subscription behavior the ROADMAP
//! north-star asks for).
//!
//! The same module owns [`blocked_attention`]: a flash-style
//! score/softmax/weighted-sum pass that walks KV rows block-by-block
//! with a running max, so paged sequences never need their KV rows
//! gathered into one contiguous buffer. The contiguous
//! [`crate::generation::KvCache`] path drives the identical routine over
//! [`PAGE_ROWS`]-sized slices of its slab, which keeps paged and
//! contiguous decode bit-exact (same floating-point operation order).
//!
//! # Copy-on-write prefix sharing
//!
//! Pages are **refcounted**, which makes prompt-prefix sharing a page
//! table operation instead of a KV copy: [`PagedKv::fork_prefix`] builds
//! a new sequence whose first `prefix_rows` rows alias a parent's pages
//! (each shared page's refcount is incremented; no payload moves). The
//! invariants that keep this sound:
//!
//! * **Reads are always safe.** Attention only ever reads rows
//!   `< seq.len` through the sequence's own page table, and a forked
//!   sequence's aliased rows are, by construction, the rows it would
//!   have computed itself (KV rows at position `p` depend only on tokens
//!   `0..=p`, which fork requires to match). So shared pages need no
//!   synchronization and decode stays bit-exact.
//! * **Writes require unique ownership.** [`PagedKv::reserve`] — which a
//!   scheduler must call (directly or via
//!   [`crate::generation::Generator::decode_batch_paged`]) before any
//!   row in `[len, new_len)` is stored — clones any still-shared page
//!   that the upcoming rows land in (allocate + memcpy + move one ref),
//!   so [`PagedKv::store`] only ever touches pages with refcount 1. In
//!   practice only the partial tail page at fork time is ever cloned;
//!   fully occupied prefix pages are never written again and stay shared
//!   for the sequences' whole lifetime.
//! * **Release drops one reference, never the page.** [`PagedKv::release`]
//!   decrements each page's refcount and only pages reaching zero return
//!   to the free list — preempting or retiring a forked sequence can
//!   never free pages a parent (or sibling fork) still reads, and the
//!   parent's release symmetrically leaves the children's shared pages
//!   alive.
//! * On exhaustion, `reserve` rolls back everything *it* did (fresh
//!   pages freed, clones undone by re-retaining the original), so a
//!   failed grow leaves the sequence exactly as it was.

use crate::model::{Model, ModelConfig};

/// Token rows per KV page. Equal to the contiguous cache's growth slab
/// so the blocked attention traversal covers identical row ranges in
/// both layouts.
pub const PAGE_ROWS: usize = 32;

/// KV pages a worst-case (full-context) sequence pins — the unit
/// contiguous admission would have to reserve per sequence, and the
/// unit the paged pool oversubscribes against. Engines size their
/// default (preemption-free) pool as `max_batch ×` this.
pub fn pages_per_seq(cfg: &ModelConfig) -> usize {
    cfg.ctx.div_ceil(PAGE_ROWS)
}

/// Shared KV page pool: one flat f32 arena, a free list, and per-page
/// refcounts. Pages are identified by index; a page's payload is laid
/// out per layer as `[K rows | V rows]`, each `PAGE_ROWS × d_model`
/// row-major.
///
/// Sizing: one page holds [`PAGE_ROWS`] token rows of K and V across
/// every layer, i.e. `n_layers × 2 × PAGE_ROWS × d_model` f32 slots. A
/// worst-case (full-context) sequence pins [`pages_per_seq`] pages;
/// sizing the pool below `max_batch ×` that enables over-subscription
/// with preemption.
///
/// Refcount rules: freshly allocated pages start at refcount 1;
/// [`PagedKv::fork_prefix`] retains (increments) pages it shares;
/// releasing decrements and only a page reaching refcount 0 re-enters
/// the free list. A page with refcount > 1 is *shared* and must never
/// be written (see [`PagedKv::reserve`] for the copy-on-write path).
pub struct KvPagePool {
    n_layers: usize,
    d: usize,
    data: Vec<f32>,
    free: Vec<u32>,
    /// Per-page reference count: 0 = free, 1 = uniquely owned,
    /// >1 = shared read-only across forked sequences.
    refs: Vec<u32>,
    /// Pages with refcount > 1, maintained incrementally on the 1 ↔ 2
    /// crossings so the scheduler's per-step gauge read is O(1).
    shared: usize,
    capacity: usize,
}

impl KvPagePool {
    pub fn new(n_layers: usize, d_model: usize, pages: usize) -> Self {
        assert!(n_layers > 0 && d_model > 0 && pages > 0, "empty KV pool");
        let stride = n_layers * 2 * PAGE_ROWS * d_model;
        KvPagePool {
            n_layers,
            d: d_model,
            data: vec![0.0; pages * stride],
            // Pop order is LIFO; ids are handed out low-first initially.
            free: (0..pages as u32).rev().collect(),
            refs: vec![0; pages],
            shared: 0,
            capacity: pages,
        }
    }

    /// Pool over a model's geometry.
    pub fn for_model(model: &Model, pages: usize) -> Self {
        Self::new(model.cfg.n_layers, model.cfg.d_model, pages)
    }

    pub fn pages_total(&self) -> usize {
        self.capacity
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Pages currently shared by more than one sequence (refcount > 1).
    pub fn shared_pages(&self) -> usize {
        self.shared
    }

    /// Reference count of `page` (0 = free).
    pub fn refcount(&self, page: u32) -> u32 {
        self.refs[page as usize]
    }

    /// f32 slots per page (all layers, K and V).
    pub fn page_stride(&self) -> usize {
        self.n_layers * 2 * PAGE_ROWS * self.d
    }

    fn try_alloc(&mut self) -> Option<u32> {
        let page = self.free.pop()?;
        debug_assert_eq!(self.refs[page as usize], 0, "free page {page} had refs");
        self.refs[page as usize] = 1;
        Some(page)
    }

    /// Add one reference to an already-allocated page (prefix sharing).
    fn retain_page(&mut self, page: u32) {
        let r = self.refs[page as usize];
        debug_assert!(r > 0, "retain of free page {page}");
        if r == 1 {
            self.shared += 1;
        }
        self.refs[page as usize] = r + 1;
    }

    /// Drop one reference; the page returns to the free list only when
    /// no sequence holds it any more. This is the only way pages are
    /// freed, so releasing a forked sequence can never free pages its
    /// parent (or a sibling fork) still reads.
    fn release_page(&mut self, page: u32) {
        debug_assert!((page as usize) < self.capacity);
        let r = self.refs[page as usize];
        debug_assert!(r > 0, "release of free page {page}");
        if r == 2 {
            self.shared -= 1;
        }
        self.refs[page as usize] = r - 1;
        if r == 1 {
            debug_assert!(!self.free.contains(&page), "double free of page {page}");
            self.free.push(page);
        }
    }

    /// Copy-on-write clone: allocate a fresh page and copy `src`'s whole
    /// payload into it. Refcounts are the caller's business (the caller
    /// swaps its table entry to the clone and releases its ref on `src`).
    fn clone_page(&mut self, src: u32) -> Option<u32> {
        let dst = self.try_alloc()?;
        let stride = self.page_stride();
        let lo = src as usize * stride;
        self.data.copy_within(lo..lo + stride, dst as usize * stride);
        Some(dst)
    }

    fn layer_base(&self, page: u32, layer: usize) -> usize {
        debug_assert!(layer < self.n_layers);
        page as usize * self.page_stride() + layer * 2 * PAGE_ROWS * self.d
    }

    /// K rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn k_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer);
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// V rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn v_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer) + PAGE_ROWS * self.d;
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// Write the K/V rows for one token at `row` within `page`. The page
    /// must be uniquely owned (refcount 1): shared pages are read-only
    /// and must be cloned first (see [`PagedKv::reserve`]).
    pub fn store_row(&mut self, page: u32, layer: usize, row: usize, k: &[f32], v: &[f32]) {
        debug_assert!(row < PAGE_ROWS);
        debug_assert_eq!(
            self.refs[page as usize], 1,
            "store into shared or free page {page}"
        );
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        let base = self.layer_base(page, layer);
        let ko = base + row * self.d;
        self.data[ko..ko + self.d].copy_from_slice(k);
        let vo = base + PAGE_ROWS * self.d + row * self.d;
        self.data[vo..vo + self.d].copy_from_slice(v);
    }
}

/// Per-sequence view into a [`KvPagePool`]: a page table plus the
/// sequence length. Rows `[i·PAGE_ROWS, (i+1)·PAGE_ROWS)` live in
/// `pages[i]`.
#[derive(Default)]
pub struct PagedKv {
    pub pages: Vec<u32>,
    pub len: usize,
}

impl PagedKv {
    pub fn new() -> Self {
        PagedKv::default()
    }

    /// Pages a sequence of `len` rows occupies.
    pub fn pages_needed(len: usize) -> usize {
        len.div_ceil(PAGE_ROWS)
    }

    /// Fork this (empty) sequence off `parent`'s first `prefix_rows`
    /// rows by *sharing* the covering pages: each shared page's refcount
    /// is incremented and its id copied into this table — no KV payload
    /// is touched, so forking costs O(pages), not O(tokens).
    ///
    /// `prefix_rows` may end mid-page; the partial tail page is shared
    /// too and lazily cloned by [`PagedKv::reserve`] the first time
    /// either side grows into it (copy-on-write). Requires `self` to be
    /// empty and `prefix_rows ≤ parent.len`, and never allocates, so it
    /// cannot fail.
    pub fn fork_prefix(&mut self, pool: &mut KvPagePool, parent: &PagedKv, prefix_rows: usize) {
        assert!(
            self.pages.is_empty() && self.len == 0,
            "fork into a non-empty sequence"
        );
        assert!(
            prefix_rows <= parent.len,
            "prefix of {prefix_rows} rows exceeds parent length {}",
            parent.len
        );
        for &p in &parent.pages[..Self::pages_needed(prefix_rows)] {
            pool.retain_page(p);
            self.pages.push(p);
        }
        self.len = prefix_rows;
    }

    /// Ensure the page table covers `new_len` rows *writably*: the rows
    /// `[len, new_len)` an upcoming decode step will store must land in
    /// uniquely owned pages, so any still-shared page in that range is
    /// first cloned (copy-on-write: allocate, memcpy, swap the table
    /// entry, drop the ref on the original), then missing pages are
    /// allocated from the pool.
    ///
    /// On exhaustion everything *this call* did is rolled back — fresh
    /// pages freed, clones undone by re-retaining the original — and
    /// `false` comes back; the caller (engine) preempts or fails the
    /// request. Nothing is half-grown.
    pub fn reserve(&mut self, pool: &mut KvPagePool, new_len: usize) -> bool {
        let need = Self::pages_needed(new_len);
        // Copy-on-write: un-share existing pages the rows [len, new_len)
        // will be written into. After a fork this is at most the partial
        // tail page; fully occupied prefix pages are never written again.
        let first_write = self.len / PAGE_ROWS;
        let mut cloned: Vec<(usize, u32)> = Vec::new();
        let rollback_cow = |pages: &mut [u32], pool: &mut KvPagePool, cloned: &[(usize, u32)]| {
            for &(idx, orig) in cloned {
                pool.retain_page(orig);
                pool.release_page(pages[idx]);
                pages[idx] = orig;
            }
        };
        for idx in first_write..need.min(self.pages.len()) {
            let page = self.pages[idx];
            if pool.refcount(page) > 1 {
                match pool.clone_page(page) {
                    Some(fresh) => {
                        pool.release_page(page);
                        self.pages[idx] = fresh;
                        cloned.push((idx, page));
                    }
                    None => {
                        rollback_cow(&mut self.pages, pool, &cloned);
                        return false;
                    }
                }
            }
        }
        let start = self.pages.len();
        while self.pages.len() < need {
            match pool.try_alloc() {
                Some(p) => self.pages.push(p),
                None => {
                    for p in self.pages.drain(start..) {
                        pool.release_page(p);
                    }
                    rollback_cow(&mut self.pages, pool, &cloned);
                    return false;
                }
            }
        }
        true
    }

    /// Store the K/V rows for position `pos` in `layer`. The page table
    /// must already cover `pos` writably (see [`PagedKv::reserve`]).
    pub fn store(&self, pool: &mut KvPagePool, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let page = self.pages[pos / PAGE_ROWS];
        pool.store_row(page, layer, pos % PAGE_ROWS, k, v);
    }

    /// Drop this sequence's reference on every page and reset it — the
    /// completion and preemption path. Pages shared with a parent or a
    /// fork stay allocated until their last holder releases; only pages
    /// this sequence uniquely owned return to the free list.
    pub fn release(&mut self, pool: &mut KvPagePool) {
        for p in self.pages.drain(..) {
            pool.release_page(p);
        }
        self.len = 0;
    }

    /// f32 slots currently pinned in the pool by this sequence.
    pub fn allocated_f32(&self, pool: &KvPagePool) -> usize {
        self.pages.len() * pool.page_stride()
    }
}

/// Flash-style blocked attention for one sequence, all heads: walk KV
/// rows `0..=pos` in [`PAGE_ROWS`]-sized blocks, keeping a per-head
/// running max `m`, running normalizer `l`, and unnormalized output
/// accumulator — score/softmax/weighted-sum fused per block, so no
/// full-length score vector is ever materialized and paged KV needs no
/// gather.
///
/// `blocks(i)` returns the K and V rows for block `i` (row range
/// `[i·PAGE_ROWS, min((i+1)·PAGE_ROWS, pos+1))`), each `rows × d_model`
/// row-major. Both the paged and the contiguous layout satisfy this
/// with plain slices, and because the routine is shared, the two decode
/// paths execute identical floating-point operations in identical
/// order — the bit-exactness the parity tests pin down.
///
/// `q` and `out` are `heads × hd` (= `d_model`) vectors.
pub fn blocked_attention<'a, F>(
    q: &[f32],
    out: &mut [f32],
    pos: usize,
    heads: usize,
    hd: usize,
    blocks: F,
) where
    F: Fn(usize) -> (&'a [f32], &'a [f32]),
{
    let d = heads * hd;
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (hd as f32).sqrt();
    let n_rows = pos + 1;
    let n_blocks = n_rows.div_ceil(PAGE_ROWS);
    let mut run_max = vec![f32::NEG_INFINITY; heads];
    let mut run_sum = vec![0.0f32; heads];
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let mut scores = [0.0f32; PAGE_ROWS];
    for blk in 0..n_blocks {
        let (kb, vb) = blocks(blk);
        let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
        debug_assert!(kb.len() >= rows * d && vb.len() >= rows * d);
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let mut blk_max = f32::NEG_INFINITY;
            for (r, sc) in scores.iter_mut().enumerate().take(rows) {
                let kr = &kb[r * d + h * hd..r * d + (h + 1) * hd];
                let mut s = 0.0f32;
                for (a, b) in qh.iter().zip(kr) {
                    s += a * b;
                }
                let s = s * scale;
                *sc = s;
                blk_max = blk_max.max(s);
            }
            let oh = &mut out[h * hd..(h + 1) * hd];
            if blk_max > run_max[h] {
                // New running max: rescale the accumulated sum/output.
                // First block: exp(-inf - finite) = 0 zeroes the (already
                // zero) state.
                let c = (run_max[h] - blk_max).exp();
                run_sum[h] *= c;
                for o in oh.iter_mut() {
                    *o *= c;
                }
                run_max[h] = blk_max;
            }
            for (r, &sc) in scores.iter().enumerate().take(rows) {
                let p = (sc - run_max[h]).exp();
                run_sum[h] += p;
                let vr = &vb[r * d + h * hd..r * d + (h + 1) * hd];
                for (o, &vv) in oh.iter_mut().zip(vr) {
                    *o += p * vv;
                }
            }
        }
    }
    for h in 0..heads {
        let inv = 1.0 / run_sum[h];
        for o in out[h * hd..(h + 1) * hd].iter_mut() {
            *o *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool(pages: usize) -> KvPagePool {
        KvPagePool::new(2, 8, pages)
    }

    #[test]
    fn alloc_free_accounting() {
        let mut pool = tiny_pool(3);
        assert_eq!(pool.pages_total(), 3);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(pool.pages_in_use(), 0);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 1));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_in_use(), 1);
        // Same page covers the whole first PAGE_ROWS rows.
        assert!(a.reserve(&mut pool, PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        // One row past the boundary takes a second page.
        assert!(a.reserve(&mut pool, PAGE_ROWS + 1));
        assert_eq!(a.pages.len(), 2);
        assert_eq!(pool.pages_free(), 1);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(a.pages.len(), 0);
        assert_eq!(a.len, 0);
    }

    #[test]
    fn reserve_rolls_back_on_exhaustion() {
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS)); // 1 page
        // Needs 3 more pages but only 1 is free: the partial grab must be
        // returned, and the existing allocation stay intact.
        assert!(!a.reserve(&mut pool, 4 * PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_free(), 1);
        // A request that fits still succeeds afterwards.
        assert!(a.reserve(&mut pool, 2 * PAGE_ROWS));
        assert_eq!(pool.pages_free(), 0);
    }

    #[test]
    fn store_roundtrip_across_pages() {
        let d = 8;
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS + 2));
        for pos in [0usize, 1, PAGE_ROWS - 1, PAGE_ROWS, PAGE_ROWS + 1] {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| (pos * 100 + layer * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                a.store(&mut pool, layer, pos, &k, &v);
                let page = a.pages[pos / PAGE_ROWS];
                let row = pos % PAGE_ROWS;
                let kb = pool.k_block(page, layer);
                let vb = pool.v_block(page, layer);
                assert_eq!(&kb[row * d..(row + 1) * d], &k[..]);
                assert_eq!(&vb[row * d..(row + 1) * d], &v[..]);
            }
        }
        assert_eq!(a.allocated_f32(&pool), 2 * pool.page_stride());
    }

    /// Fill rows `[0, len)` of `kv` with position-tagged values.
    fn fill(kv: &PagedKv, pool: &mut KvPagePool, d: usize, upto: usize, tag: f32) {
        for pos in 0..upto {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| tag + (pos * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                kv.store(pool, layer, pos, &k, &v);
            }
        }
    }

    #[test]
    fn fork_shares_pages_and_refcounts() {
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, PAGE_ROWS + 5));
        parent.len = PAGE_ROWS + 5;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, PAGE_ROWS + 5);
        // Same physical pages, two references each, no new allocation.
        assert_eq!(child.pages, parent.pages);
        assert_eq!(child.len, PAGE_ROWS + 5);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.shared_pages(), 2);
        for &p in &parent.pages {
            assert_eq!(pool.refcount(p), 2);
        }
        child.release(&mut pool);
        assert_eq!(pool.shared_pages(), 0);
        assert_eq!(pool.pages_in_use(), 2, "parent pages must survive child release");
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn fork_at_exact_page_boundary_never_clones() {
        let d = 8;
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, 2 * PAGE_ROWS));
        parent.len = 2 * PAGE_ROWS;
        fill(&parent, &mut pool, d, 2 * PAGE_ROWS, 1000.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, 2 * PAGE_ROWS);
        // Growing past a boundary prefix allocates a fresh page; the two
        // shared pages stay shared (no copy-on-write needed — nothing
        // writes into a fully occupied prefix page).
        assert!(child.reserve(&mut pool, 2 * PAGE_ROWS + 1));
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.shared_pages(), 2);
        assert_eq!(&child.pages[..2], &parent.pages[..]);
        assert_ne!(child.pages[2], parent.pages[0]);
        assert_ne!(child.pages[2], parent.pages[1]);
        child.store(&mut pool, 0, 2 * PAGE_ROWS, &[5.0; 8], &[6.0; 8]);
        // Parent's payload is untouched.
        assert_eq!(pool.k_block(parent.pages[0], 0)[0], 1000.0);
    }

    #[test]
    fn cow_clones_partial_tail_on_first_write() {
        let d = 8;
        let prefix = PAGE_ROWS + 5; // partial second page
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        let shared_tail = parent.pages[1];
        // First growth writes into the shared tail page → it must be
        // cloned for the child; the full first page stays shared.
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages[0], parent.pages[0], "full prefix page stays shared");
        assert_ne!(child.pages[1], shared_tail, "tail page must be cloned");
        assert_eq!(pool.refcount(shared_tail), 1, "parent keeps the original tail");
        assert_eq!(pool.refcount(child.pages[1]), 1);
        assert_eq!(pool.pages_in_use(), 3);
        // The clone carried the prefix rows and diverges after a write.
        let row = 4; // pos PAGE_ROWS+4, within the shared prefix
        let want: Vec<f32> = (0..d).map(|j| ((PAGE_ROWS + row) * 10 + j) as f32).collect();
        let got = &pool.k_block(child.pages[1], 0)[row * d..(row + 1) * d];
        assert_eq!(got, &want[..]);
        child.store(&mut pool, 0, prefix, &[9.0; 8], &[8.0; 8]);
        child.len = prefix + 1;
        let parent_tail_row5 = pool.k_block(shared_tail, 0)[5 * d];
        let child_tail_row5 = pool.k_block(child.pages[1], 0)[5 * d];
        assert_eq!(child_tail_row5, 9.0);
        assert_ne!(parent_tail_row5, 9.0, "CoW write leaked into the parent");
        // The parent growing into its (now uniquely owned) tail page
        // clones nothing further.
        assert!(parent.reserve(&mut pool, prefix + 1));
        assert_eq!(parent.pages[1], shared_tail);
        assert_eq!(pool.pages_in_use(), 3);
    }

    #[test]
    fn fork_then_parent_release_keeps_shared_pages_alive() {
        let d = 8;
        let prefix = PAGE_ROWS + 3;
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        fill(&parent, &mut pool, d, prefix, 0.0);
        let pages = parent.pages.clone();
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        // Parent preempted/retired immediately after the fork: its
        // release drops refs but the child still holds both pages.
        parent.release(&mut pool);
        assert_eq!(parent.pages.len(), 0);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.shared_pages(), 0);
        for &p in &pages {
            assert_eq!(pool.refcount(p), 1);
        }
        // The child's view of the prefix is intact and now writable
        // without any clone (it is the sole owner).
        let want: Vec<f32> = (0..d).map(|j| j as f32).collect();
        assert_eq!(&pool.k_block(child.pages[0], 0)[..d], &want[..]);
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages[..2], pages[..]);
        assert_eq!(pool.pages_in_use(), 2);
        child.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn double_release_is_safe_and_exact() {
        let mut pool = tiny_pool(4);
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, 2 * PAGE_ROWS));
        parent.len = 2 * PAGE_ROWS;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, 2 * PAGE_ROWS);
        child.release(&mut pool);
        // A second release of the same sequence is a no-op (its table is
        // empty), not a double-decrement of the parent's pages.
        child.release(&mut pool);
        assert_eq!(pool.pages_in_use(), 2);
        for &p in &parent.pages {
            assert_eq!(pool.refcount(p), 1);
        }
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), 4);
    }

    #[test]
    fn cow_rolls_back_on_exhaustion() {
        let prefix = PAGE_ROWS + 2;
        let mut pool = tiny_pool(2); // exactly the prefix, nothing spare
        let mut parent = PagedKv::new();
        assert!(parent.reserve(&mut pool, prefix));
        parent.len = prefix;
        let mut child = PagedKv::new();
        child.fork_prefix(&mut pool, &parent, prefix);
        let before: Vec<u32> = child.pages.clone();
        // Growing the child needs a CoW clone of the tail but the pool is
        // exhausted: reserve must fail and restore the shared state.
        assert!(!child.reserve(&mut pool, prefix + 1));
        assert_eq!(child.pages, before);
        assert_eq!(pool.refcount(child.pages[1]), 2);
        assert_eq!(pool.pages_free(), 0);
        // Preempting the parent frees nothing (pages shared) but makes
        // the child sole owner, and growth then succeeds without allocating.
        parent.release(&mut pool);
        assert!(child.reserve(&mut pool, prefix + 1));
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn blocked_attention_matches_two_pass_softmax() {
        // Reference: materialize all scores, softmax once, weighted sum.
        let (heads, hd) = (2usize, 4usize);
        let d = heads * hd;
        let n_rows = 2 * PAGE_ROWS + 5; // three blocks, last partial
        let mut rng = crate::util::rng::Pcg64::new(9);
        let q: Vec<f32> = rng.gaussian_vec(d, 1.0);
        let kv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let vv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let mut out = vec![0.0f32; d];
        blocked_attention(&q, &mut out, n_rows - 1, heads, hd, |blk| {
            let lo = blk * PAGE_ROWS * d;
            let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
            (&kv[lo..lo + rows * d], &vv[lo..lo + rows * d])
        });
        let scale = 1.0 / (hd as f32).sqrt();
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let scores: Vec<f32> = (0..n_rows)
                .map(|t| {
                    let kt = &kv[t * d + h * hd..t * d + (h + 1) * hd];
                    qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            for j in 0..hd {
                let want: f32 = (0..n_rows)
                    .map(|t| exps[t] / z * vv[t * d + h * hd + j])
                    .sum();
                let got = out[h * hd + j];
                assert!(
                    (got - want).abs() < 1e-4,
                    "head {h} coord {j}: {got} vs {want}"
                );
            }
        }
    }
}
