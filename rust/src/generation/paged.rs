//! Paged KV storage: fixed-size KV pages owned by a shared pool, with
//! per-sequence page tables — the serving engine's KV subsystem.
//!
//! A contiguous per-sequence cache forces admission control to reason
//! about worst-case context (`ctx × d_model` per layer per sequence).
//! Paging breaks that coupling: the pool owns `pages` blocks of
//! [`PAGE_ROWS`] token rows each (all layers, K and V), sequences
//! allocate pages on demand as they lengthen, release them on
//! completion, and the engine can preempt a sequence — returning its
//! pages to the pool and requeueing its request — when allocation
//! fails. Admission is then bounded by *actual* KV usage, so a pool
//! sized well below `max_batch × ctx` still serves full batches of
//! typical requests (the over-subscription behavior the ROADMAP
//! north-star asks for).
//!
//! The same module owns [`blocked_attention`]: a flash-style
//! score/softmax/weighted-sum pass that walks KV rows block-by-block
//! with a running max, so paged sequences never need their KV rows
//! gathered into one contiguous buffer. The contiguous
//! [`crate::generation::KvCache`] path drives the identical routine over
//! [`PAGE_ROWS`]-sized slices of its slab, which keeps paged and
//! contiguous decode bit-exact (same floating-point operation order).

use crate::model::{Model, ModelConfig};

/// Token rows per KV page. Equal to the contiguous cache's growth slab
/// so the blocked attention traversal covers identical row ranges in
/// both layouts.
pub const PAGE_ROWS: usize = 32;

/// KV pages a worst-case (full-context) sequence pins — the unit
/// contiguous admission would have to reserve per sequence, and the
/// unit the paged pool oversubscribes against. Engines size their
/// default (preemption-free) pool as `max_batch ×` this.
pub fn pages_per_seq(cfg: &ModelConfig) -> usize {
    cfg.ctx.div_ceil(PAGE_ROWS)
}

/// Shared KV page pool: one flat f32 arena plus a free list. Pages are
/// identified by index; a page's payload is laid out per layer as
/// `[K rows | V rows]`, each `PAGE_ROWS × d_model` row-major.
///
/// Sizing: one page holds [`PAGE_ROWS`] token rows of K and V across
/// every layer, i.e. `n_layers × 2 × PAGE_ROWS × d_model` f32 slots. A
/// worst-case (full-context) sequence pins [`pages_per_seq`] pages;
/// sizing the pool below `max_batch ×` that enables over-subscription
/// with preemption.
pub struct KvPagePool {
    n_layers: usize,
    d: usize,
    data: Vec<f32>,
    free: Vec<u32>,
    capacity: usize,
}

impl KvPagePool {
    pub fn new(n_layers: usize, d_model: usize, pages: usize) -> Self {
        assert!(n_layers > 0 && d_model > 0 && pages > 0, "empty KV pool");
        let stride = n_layers * 2 * PAGE_ROWS * d_model;
        KvPagePool {
            n_layers,
            d: d_model,
            data: vec![0.0; pages * stride],
            // Pop order is LIFO; ids are handed out low-first initially.
            free: (0..pages as u32).rev().collect(),
            capacity: pages,
        }
    }

    /// Pool over a model's geometry.
    pub fn for_model(model: &Model, pages: usize) -> Self {
        Self::new(model.cfg.n_layers, model.cfg.d_model, pages)
    }

    pub fn pages_total(&self) -> usize {
        self.capacity
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// f32 slots per page (all layers, K and V).
    pub fn page_stride(&self) -> usize {
        self.n_layers * 2 * PAGE_ROWS * self.d
    }

    fn try_alloc(&mut self) -> Option<u32> {
        self.free.pop()
    }

    fn free_page(&mut self, page: u32) {
        debug_assert!((page as usize) < self.capacity);
        debug_assert!(!self.free.contains(&page), "double free of page {page}");
        self.free.push(page);
    }

    fn layer_base(&self, page: u32, layer: usize) -> usize {
        debug_assert!(layer < self.n_layers);
        page as usize * self.page_stride() + layer * 2 * PAGE_ROWS * self.d
    }

    /// K rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn k_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer);
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// V rows of `page` for `layer`: `PAGE_ROWS × d_model` row-major.
    pub fn v_block(&self, page: u32, layer: usize) -> &[f32] {
        let base = self.layer_base(page, layer) + PAGE_ROWS * self.d;
        &self.data[base..base + PAGE_ROWS * self.d]
    }

    /// Write the K/V rows for one token at `row` within `page`.
    pub fn store_row(&mut self, page: u32, layer: usize, row: usize, k: &[f32], v: &[f32]) {
        debug_assert!(row < PAGE_ROWS);
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        let base = self.layer_base(page, layer);
        let ko = base + row * self.d;
        self.data[ko..ko + self.d].copy_from_slice(k);
        let vo = base + PAGE_ROWS * self.d + row * self.d;
        self.data[vo..vo + self.d].copy_from_slice(v);
    }
}

/// Per-sequence view into a [`KvPagePool`]: a page table plus the
/// sequence length. Rows `[i·PAGE_ROWS, (i+1)·PAGE_ROWS)` live in
/// `pages[i]`.
#[derive(Default)]
pub struct PagedKv {
    pub pages: Vec<u32>,
    pub len: usize,
}

impl PagedKv {
    pub fn new() -> Self {
        PagedKv::default()
    }

    /// Pages a sequence of `len` rows occupies.
    pub fn pages_needed(len: usize) -> usize {
        len.div_ceil(PAGE_ROWS)
    }

    /// Ensure the page table covers `new_len` rows, allocating from the
    /// pool on demand. On exhaustion every page allocated by *this call*
    /// is returned to the pool and `false` comes back — the caller
    /// (engine) preempts or fails the request; nothing is half-grown.
    pub fn reserve(&mut self, pool: &mut KvPagePool, new_len: usize) -> bool {
        let need = Self::pages_needed(new_len);
        let start = self.pages.len();
        while self.pages.len() < need {
            match pool.try_alloc() {
                Some(p) => self.pages.push(p),
                None => {
                    for p in self.pages.drain(start..) {
                        pool.free_page(p);
                    }
                    return false;
                }
            }
        }
        true
    }

    /// Store the K/V rows for position `pos` in `layer`. The page table
    /// must already cover `pos` (see [`PagedKv::reserve`]).
    pub fn store(&self, pool: &mut KvPagePool, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let page = self.pages[pos / PAGE_ROWS];
        pool.store_row(page, layer, pos % PAGE_ROWS, k, v);
    }

    /// Return every page to the pool and reset the sequence — the
    /// completion and preemption path.
    pub fn release(&mut self, pool: &mut KvPagePool) {
        for p in self.pages.drain(..) {
            pool.free_page(p);
        }
        self.len = 0;
    }

    /// f32 slots currently pinned in the pool by this sequence.
    pub fn allocated_f32(&self, pool: &KvPagePool) -> usize {
        self.pages.len() * pool.page_stride()
    }
}

/// Flash-style blocked attention for one sequence, all heads: walk KV
/// rows `0..=pos` in [`PAGE_ROWS`]-sized blocks, keeping a per-head
/// running max `m`, running normalizer `l`, and unnormalized output
/// accumulator — score/softmax/weighted-sum fused per block, so no
/// full-length score vector is ever materialized and paged KV needs no
/// gather.
///
/// `blocks(i)` returns the K and V rows for block `i` (row range
/// `[i·PAGE_ROWS, min((i+1)·PAGE_ROWS, pos+1))`), each `rows × d_model`
/// row-major. Both the paged and the contiguous layout satisfy this
/// with plain slices, and because the routine is shared, the two decode
/// paths execute identical floating-point operations in identical
/// order — the bit-exactness the parity tests pin down.
///
/// `q` and `out` are `heads × hd` (= `d_model`) vectors.
pub fn blocked_attention<'a, F>(
    q: &[f32],
    out: &mut [f32],
    pos: usize,
    heads: usize,
    hd: usize,
    blocks: F,
) where
    F: Fn(usize) -> (&'a [f32], &'a [f32]),
{
    let d = heads * hd;
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (hd as f32).sqrt();
    let n_rows = pos + 1;
    let n_blocks = n_rows.div_ceil(PAGE_ROWS);
    let mut run_max = vec![f32::NEG_INFINITY; heads];
    let mut run_sum = vec![0.0f32; heads];
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let mut scores = [0.0f32; PAGE_ROWS];
    for blk in 0..n_blocks {
        let (kb, vb) = blocks(blk);
        let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
        debug_assert!(kb.len() >= rows * d && vb.len() >= rows * d);
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let mut blk_max = f32::NEG_INFINITY;
            for (r, sc) in scores.iter_mut().enumerate().take(rows) {
                let kr = &kb[r * d + h * hd..r * d + (h + 1) * hd];
                let mut s = 0.0f32;
                for (a, b) in qh.iter().zip(kr) {
                    s += a * b;
                }
                let s = s * scale;
                *sc = s;
                blk_max = blk_max.max(s);
            }
            let oh = &mut out[h * hd..(h + 1) * hd];
            if blk_max > run_max[h] {
                // New running max: rescale the accumulated sum/output.
                // First block: exp(-inf - finite) = 0 zeroes the (already
                // zero) state.
                let c = (run_max[h] - blk_max).exp();
                run_sum[h] *= c;
                for o in oh.iter_mut() {
                    *o *= c;
                }
                run_max[h] = blk_max;
            }
            for (r, &sc) in scores.iter().enumerate().take(rows) {
                let p = (sc - run_max[h]).exp();
                run_sum[h] += p;
                let vr = &vb[r * d + h * hd..r * d + (h + 1) * hd];
                for (o, &vv) in oh.iter_mut().zip(vr) {
                    *o += p * vv;
                }
            }
        }
    }
    for h in 0..heads {
        let inv = 1.0 / run_sum[h];
        for o in out[h * hd..(h + 1) * hd].iter_mut() {
            *o *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool(pages: usize) -> KvPagePool {
        KvPagePool::new(2, 8, pages)
    }

    #[test]
    fn alloc_free_accounting() {
        let mut pool = tiny_pool(3);
        assert_eq!(pool.pages_total(), 3);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(pool.pages_in_use(), 0);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, 1));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_in_use(), 1);
        // Same page covers the whole first PAGE_ROWS rows.
        assert!(a.reserve(&mut pool, PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        // One row past the boundary takes a second page.
        assert!(a.reserve(&mut pool, PAGE_ROWS + 1));
        assert_eq!(a.pages.len(), 2);
        assert_eq!(pool.pages_free(), 1);
        a.release(&mut pool);
        assert_eq!(pool.pages_free(), 3);
        assert_eq!(a.pages.len(), 0);
        assert_eq!(a.len, 0);
    }

    #[test]
    fn reserve_rolls_back_on_exhaustion() {
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS)); // 1 page
        // Needs 3 more pages but only 1 is free: the partial grab must be
        // returned, and the existing allocation stay intact.
        assert!(!a.reserve(&mut pool, 4 * PAGE_ROWS));
        assert_eq!(a.pages.len(), 1);
        assert_eq!(pool.pages_free(), 1);
        // A request that fits still succeeds afterwards.
        assert!(a.reserve(&mut pool, 2 * PAGE_ROWS));
        assert_eq!(pool.pages_free(), 0);
    }

    #[test]
    fn store_roundtrip_across_pages() {
        let d = 8;
        let mut pool = tiny_pool(2);
        let mut a = PagedKv::new();
        assert!(a.reserve(&mut pool, PAGE_ROWS + 2));
        for pos in [0usize, 1, PAGE_ROWS - 1, PAGE_ROWS, PAGE_ROWS + 1] {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| (pos * 100 + layer * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                a.store(&mut pool, layer, pos, &k, &v);
                let page = a.pages[pos / PAGE_ROWS];
                let row = pos % PAGE_ROWS;
                let kb = pool.k_block(page, layer);
                let vb = pool.v_block(page, layer);
                assert_eq!(&kb[row * d..(row + 1) * d], &k[..]);
                assert_eq!(&vb[row * d..(row + 1) * d], &v[..]);
            }
        }
        assert_eq!(a.allocated_f32(&pool), 2 * pool.page_stride());
    }

    #[test]
    fn blocked_attention_matches_two_pass_softmax() {
        // Reference: materialize all scores, softmax once, weighted sum.
        let (heads, hd) = (2usize, 4usize);
        let d = heads * hd;
        let n_rows = 2 * PAGE_ROWS + 5; // three blocks, last partial
        let mut rng = crate::util::rng::Pcg64::new(9);
        let q: Vec<f32> = rng.gaussian_vec(d, 1.0);
        let kv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let vv: Vec<f32> = rng.gaussian_vec(n_rows * d, 1.0);
        let mut out = vec![0.0f32; d];
        blocked_attention(&q, &mut out, n_rows - 1, heads, hd, |blk| {
            let lo = blk * PAGE_ROWS * d;
            let rows = (n_rows - blk * PAGE_ROWS).min(PAGE_ROWS);
            (&kv[lo..lo + rows * d], &vv[lo..lo + rows * d])
        });
        let scale = 1.0 / (hd as f32).sqrt();
        for h in 0..heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let scores: Vec<f32> = (0..n_rows)
                .map(|t| {
                    let kt = &kv[t * d + h * hd..t * d + (h + 1) * hd];
                    qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            for j in 0..hd {
                let want: f32 = (0..n_rows)
                    .map(|t| exps[t] / z * vv[t * d + h * hd + j])
                    .sum();
                let got = out[h * hd + j];
                assert!(
                    (got - want).abs() < 1e-4,
                    "head {h} coord {j}: {got} vs {want}"
                );
            }
        }
    }
}
