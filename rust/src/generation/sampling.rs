//! Seeded stochastic decode: temperature / top-k / top-p sampling with a
//! per-request, per-position RNG.
//!
//! Every sampled token is a pure function of
//! `(logits, SamplingParams, absolute position)`:
//!
//! 1. **Distribution.** [`sampled_dist`] softmaxes the logits at the
//!    request temperature in f64, truncates to the `top_k` most probable
//!    tokens, then to the smallest probability-ordered nucleus whose mass
//!    reaches `top_p`, and renormalizes. Ties order by probability
//!    descending then index ascending, so truncation is deterministic.
//! 2. **Uniform.** [`token_rng`] derives a fresh [`Pcg64`] from
//!    `(request seed, absolute position)` — *not* a long-lived stream
//!    that must be carried across scheduler events — and draws one
//!    `f64` in `[0, 1)`.
//! 3. **Draw.** [`draw`] inverts the CDF of the truncated distribution.
//!
//! Keying the RNG by absolute position (prompt length + tokens emitted
//! so far) is what makes sampled decode reproducible under every
//! scheduling decision the engine can take: a sequence that is
//! preempted, spilled to the host arena, restored, re-routed to another
//! replica, or re-decoded from scratch re-derives the identical uniform
//! at every position, because nothing about the RNG depends on *when* or
//! *where* a position was decoded. Batch composition and thread count
//! cannot interfere either, since the logits themselves are bitwise
//! batch- and thread-invariant (the decode kernels' pinned contract) and
//! steps 1–3 are scalar f64 arithmetic.
//!
//! `temperature == 0` is greedy decode: [`next_token`] falls through to
//! the exact [`argmax`] call the greedy paths use, drawing nothing, so
//! greedy output is bit-identical with sampling code in or out of the
//! loop. Speculative decode composes with sampling in
//! [`super::speculative`]: the draft proposes with the *same*
//! per-position uniforms against its own distribution, acceptance
//! compares against the target's sample, and the emitted stream stays
//! bitwise equal to direct sampled decode at any draft length.

use super::{argmax, Generator, KvCache};
use crate::util::phase::{self, Phase};
use crate::util::rng::Pcg64;

/// Per-request stochastic-decode controls, threaded from the TCP wire
/// fields (`temperature` / `top_k` / `top_p` / `seed`) through
/// [`crate::serve::EngineRequest`] into every decode path. The default
/// is greedy argmax decode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `0.0` (or anything non-positive) selects
    /// greedy argmax decode and ignores the other fields.
    pub temperature: f32,
    /// Keep only the `top_k` most probable tokens before the draw
    /// (`0` = no top-k truncation).
    pub top_k: usize,
    /// Keep the smallest probability-ordered set of tokens whose mass
    /// reaches `top_p`, after top-k (`1.0` = no nucleus truncation).
    pub top_p: f32,
    /// Request seed. Together with the absolute token position it fully
    /// determines every uniform drawn for this request.
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
        }
    }
}

impl SamplingParams {
    /// Greedy argmax decode (the default).
    pub fn greedy() -> Self {
        Self::default()
    }

    /// Whether these parameters select the greedy path (no RNG at all).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// The RNG for one `(request seed, absolute position)` pair. A fresh
/// generator per position — seed and stream both mix the position, so
/// positions are independent streams and no RNG state ever needs to
/// survive preemption, spill, restore, or re-route.
pub fn token_rng(seed: u64, position: usize) -> Pcg64 {
    let pos = position as u64;
    Pcg64::new_stream(
        seed ^ pos.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        pos.wrapping_mul(2).wrapping_add(0x5EED),
    )
}

/// Temperature-softmax the logits in f64, truncate (top-k, then the
/// top-p nucleus within what top-k kept), renormalize. At least one
/// token always survives; ties break by index ascending.
///
/// Callers must have excluded the greedy case (`temperature <= 0`).
pub fn sampled_dist(logits: &[f32], p: &SamplingParams) -> Vec<f64> {
    debug_assert!(!p.is_greedy(), "sampled_dist on greedy params");
    let t = p.temperature as f64;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let probs: Vec<f64> = logits.iter().map(|&l| ((l as f64 - mx) / t).exp()).collect();
    // Probability descending, index ascending — the deterministic
    // truncation order shared by top-k and top-p.
    let mut order: Vec<usize> = (0..probs.len()).collect();
    order.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]).then(a.cmp(&b)));
    let mut keep = order.len();
    if p.top_k > 0 {
        keep = keep.min(p.top_k);
    }
    if (p.top_p as f64) < 1.0 {
        let kept_mass: f64 = order[..keep].iter().map(|&i| probs[i]).sum();
        let threshold = kept_mass * (p.top_p.max(0.0) as f64);
        let mut cum = 0.0;
        let mut nucleus = 0usize;
        for &i in &order[..keep] {
            cum += probs[i];
            nucleus += 1;
            if cum >= threshold {
                break;
            }
        }
        keep = nucleus.max(1);
    }
    let norm: f64 = order[..keep].iter().map(|&i| probs[i]).sum();
    let mut dist = vec![0.0f64; probs.len()];
    for &i in &order[..keep] {
        dist[i] = probs[i] / norm;
    }
    dist
}

/// Invert the CDF of a normalized distribution at uniform `u ∈ [0, 1)`.
/// Zero-probability entries are skipped, so rounding in the running sum
/// can never emit a truncated-away token; if accumulated rounding keeps
/// the total fractionally below `u`, the last positive entry wins.
pub fn draw(dist: &[f64], u: f64) -> usize {
    let mut cum = 0.0f64;
    let mut last = 0usize;
    for (i, &w) in dist.iter().enumerate() {
        if w <= 0.0 {
            continue;
        }
        cum += w;
        last = i;
        if u < cum {
            return i;
        }
    }
    last
}

/// The one next-token rule every decode path shares: greedy params fall
/// through to the exact [`argmax`] call greedy decode uses (no RNG
/// constructed, bit-identical to the pre-sampling code); otherwise
/// sample the truncated distribution at this position's uniform.
pub fn next_token(logits: &[f32], p: &SamplingParams, position: usize) -> u8 {
    if p.is_greedy() {
        return argmax(logits) as u8;
    }
    let _scope = phase::scope(Phase::Sampling);
    let dist = sampled_dist(logits, p);
    let u = token_rng(p.seed, position).f64();
    draw(&dist, u) as u8
}

impl Generator<'_> {
    /// [`Generator::generate`] with per-request sampling: prefill the
    /// prompt, then emit [`next_token`] at each absolute position
    /// (prompt length + tokens emitted). Greedy params reproduce
    /// [`Generator::generate`] bit for bit.
    pub fn generate_sampled(&self, prompt: &[u8], max_new: usize, p: &SamplingParams) -> Vec<u8> {
        let mut cache = KvCache::new(self.model);
        let mut logits = vec![0.0f32; self.model.cfg.vocab];
        for &t in prompt {
            logits = self.decode_one(t, &mut cache);
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            if cache.len >= self.model.cfg.ctx {
                break;
            }
            let next = next_token(&logits, p, prompt.len() + out.len());
            out.push(next);
            logits = self.decode_one(next, &mut cache);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::tiny_model;
    use crate::util::proptest_lite::{assert_histogram_close, check};

    fn params(temperature: f32, top_k: usize, top_p: f32, seed: u64) -> SamplingParams {
        SamplingParams {
            temperature,
            top_k,
            top_p,
            seed,
        }
    }

    #[test]
    fn greedy_params_fall_through_to_argmax() {
        check("greedy is argmax", 32, |rng| {
            let logits: Vec<f32> = (0..17).map(|_| rng.gaussian() as f32 * 3.0).collect();
            let p = params(0.0, 5, 0.5, rng.next_u64());
            crate::prop_assert!(
                next_token(&logits, &p, 3) as usize == argmax(&logits),
                "greedy fell away from argmax"
            );
            Ok(())
        });
    }

    #[test]
    fn dist_is_normalized_and_truncated() {
        check("dist normalized", 32, |rng| {
            let n = 2 + rng.below_usize(30);
            let logits: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32 * 2.0).collect();
            let top_k = rng.below_usize(n + 2);
            let p = params(0.25 + rng.f32(), top_k, rng.f32(), 0);
            let d = sampled_dist(&logits, &p);
            let total: f64 = d.iter().sum();
            crate::prop_assert!((total - 1.0).abs() < 1e-12, "sum {total}");
            let support = d.iter().filter(|&&w| w > 0.0).count();
            crate::prop_assert!(support >= 1, "empty support");
            if top_k > 0 {
                crate::prop_assert!(support <= top_k, "top_k={top_k} support={support}");
            }
            Ok(())
        });
    }

    #[test]
    fn top_p_keeps_smallest_sufficient_nucleus() {
        // Hand-built distribution: softmax of ln-weights 8:4:2:1 at
        // temperature 1 is exactly [8,4,2,1]/15.
        let logits: Vec<f32> = [8.0f64, 4.0, 2.0, 1.0].iter().map(|w| w.ln() as f32).collect();
        // 8/15 ≈ 0.533 covers 0.5 alone.
        let d = sampled_dist(&logits, &params(1.0, 0, 0.5, 0));
        assert_eq!(d.iter().filter(|&&w| w > 0.0).count(), 1);
        assert!((d[0] - 1.0).abs() < 1e-12);
        // 12/15 = 0.8 is the smallest prefix reaching 0.75.
        let d = sampled_dist(&logits, &params(1.0, 0, 0.75, 0));
        assert_eq!(d.iter().filter(|&&w| w > 0.0).count(), 2);
        assert!((d[0] - 8.0 / 12.0).abs() < 1e-12);
        assert!((d[1] - 4.0 / 12.0).abs() < 1e-12);
        // top_p = 0 still keeps the mode.
        let d = sampled_dist(&logits, &params(1.0, 0, 0.0, 0));
        assert_eq!(d.iter().filter(|&&w| w > 0.0).count(), 1);
    }

    #[test]
    fn truncation_ties_break_by_index() {
        // Equal logits: top-k must keep the lowest indices.
        let logits = vec![1.0f32; 6];
        let d = sampled_dist(&logits, &params(1.0, 3, 1.0, 0));
        assert_eq!(
            d.iter()
                .enumerate()
                .filter(|(_, &w)| w > 0.0)
                .map(|(i, _)| i)
                .collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
    }

    #[test]
    fn draw_inverts_the_cdf() {
        let dist = [0.25f64, 0.0, 0.5, 0.25];
        assert_eq!(draw(&dist, 0.0), 0);
        assert_eq!(draw(&dist, 0.2499), 0);
        assert_eq!(draw(&dist, 0.25), 2);
        assert_eq!(draw(&dist, 0.7499), 2);
        assert_eq!(draw(&dist, 0.75), 3);
        assert_eq!(draw(&dist, 0.999_999), 3);
    }

    #[test]
    fn position_keying_is_pure_and_position_sensitive() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 7 + 1) % 13) as f32 * 0.3).collect();
        let p = params(1.0, 0, 1.0, 42);
        for pos in [0usize, 1, 5, 1000] {
            assert_eq!(next_token(&logits, &p, pos), next_token(&logits, &p, pos));
        }
        // Across positions the uniforms differ, so over many positions
        // the sampled tokens cannot all collapse onto one value.
        let toks: Vec<u8> = (0..64).map(|pos| next_token(&logits, &p, pos)).collect();
        assert!(toks.iter().any(|&t| t != toks[0]), "positions never varied");
        // And across seeds the streams differ somewhere.
        let q = params(1.0, 0, 1.0, 43);
        let toks_q: Vec<u8> = (0..64).map(|pos| next_token(&logits, &q, pos)).collect();
        assert_ne!(toks, toks_q, "seed did not enter the stream");
    }

    #[test]
    fn empirical_histogram_matches_dist() {
        // Many positions of one request sample the same distribution →
        // the empirical histogram must match it (chi-square + TV at
        // fixed seed; the positions are the per-draw entropy).
        let logits: Vec<f32> = (0..8).map(|i| (i as f32) * 0.4).collect();
        let p = params(0.8, 0, 1.0, 7);
        let dist = sampled_dist(&logits, &p);
        let mut counts = vec![0u64; 8];
        for pos in 0..20_000usize {
            counts[next_token(&logits, &p, pos) as usize] += 1;
        }
        assert_histogram_close(&counts, &dist).unwrap();
    }

    #[test]
    fn generate_sampled_reduces_to_generate_when_greedy() {
        let m = tiny_model(31);
        let gen = Generator::dense(&m);
        let prompt = [3u8, 1, 4, 1];
        let want = gen.generate(&prompt, 8);
        let got = gen.generate_sampled(&prompt, 8, &SamplingParams::greedy());
        assert_eq!(got, want);
    }

    #[test]
    fn generate_sampled_is_reproducible_and_seed_sensitive() {
        let m = tiny_model(32);
        let gen = Generator::dense(&m);
        let prompt = [2u8, 7, 2];
        let p = params(1.0, 0, 1.0, 11);
        let a = gen.generate_sampled(&prompt, 12, &p);
        let b = gen.generate_sampled(&prompt, 12, &p);
        assert_eq!(a, b, "same seed must reproduce bitwise");
        assert_eq!(a.len(), 12);
        let other = gen.generate_sampled(&prompt, 12, &params(1.0, 0, 1.0, 12));
        // Distinct seeds at temperature 1 on a random tiny model:
        // identical 12-token streams would mean the seed never reached
        // the draw.
        assert_ne!(a, other, "seed did not affect the stream");
    }
}
