//! Hand-written reverse-mode differentiation for the Llama block — the
//! substrate for fine-tuning during quantization (paper §5, Algorithm 5).
//!
//! A quantized linear is y = S_u ⊙ (A · (S_v ⊙ x)) with A = H_mᵀ Ŵ̃ H_n
//! *frozen* and the sign vectors S_u/S_v relaxed to reals ("By optimizing
//! the sign vectors as real vectors … we allow the incoherence processing
//! step to shape the weight matrix to the codebook"). Dense linears keep
//! trainable W. Everything is checked against central finite differences.

use std::collections::BTreeMap;

use crate::model::ops::*;

/// Gradient store: flat name → grad buffer.
pub type Grads = BTreeMap<String, Vec<f32>>;

pub fn acc_grad(grads: &mut Grads, name: &str, add: &[f32]) {
    let g = grads
        .entry(name.to_string())
        .or_insert_with(|| vec![0.0; add.len()]);
    for (a, b) in g.iter_mut().zip(add) {
        *a += b;
    }
}

/// A fine-tunable linear layer.
pub enum FtLinear {
    /// Dense trainable weight (out,in).
    Dense { w: Vec<f32>, m: usize, n: usize, trainable: bool },
    /// Frozen quantized core A (m,n) with trainable sign vectors.
    Quant { a: Vec<f32>, su: Vec<f32>, sv: Vec<f32>, m: usize, n: usize },
}

impl FtLinear {
    pub fn shape(&self) -> (usize, usize) {
        match self {
            FtLinear::Dense { m, n, .. } => (*m, *n),
            FtLinear::Quant { m, n, .. } => (*m, *n),
        }
    }

    /// y (s,m) = layer(x (s,n)); `cache` receives what backward needs.
    pub fn forward(&self, x: &[f32], s: usize, cache: &mut LinCache) -> Vec<f32> {
        let (m, n) = self.shape();
        let mut y = vec![0.0f32; s * m];
        match self {
            FtLinear::Dense { w, .. } => {
                matmul_nt(x, w, s, n, m, &mut y);
                cache.x = x.to_vec();
            }
            FtLinear::Quant { a, su, sv, .. } => {
                // xs = sv ⊙ x ; z = xs Aᵀ ; y = su ⊙ z
                let mut xs = x.to_vec();
                for row in xs.chunks_mut(n) {
                    for (v, &s_) in row.iter_mut().zip(sv) {
                        *v *= s_;
                    }
                }
                matmul_nt(&xs, a, s, n, m, &mut y);
                cache.z = y.clone();
                for row in y.chunks_mut(m) {
                    for (v, &s_) in row.iter_mut().zip(su) {
                        *v *= s_;
                    }
                }
                cache.x = x.to_vec();
                cache.xs = xs;
            }
        }
        y
    }

    /// Backward: given dy (s,m), return dx (s,n) and accumulate parameter
    /// grads under `name` (dense: `name.w`; quant: `name.su`, `name.sv`).
    pub fn backward(
        &self,
        name: &str,
        dy: &[f32],
        s: usize,
        cache: &LinCache,
        grads: &mut Grads,
    ) -> Vec<f32> {
        let (m, n) = self.shape();
        let mut dx = vec![0.0f32; s * n];
        match self {
            FtLinear::Dense { w, trainable, .. } => {
                // dx = dy W ; dW += dyᵀ x
                matmul_nn_acc_from_nt(dy, w, s, m, n, &mut dx);
                if *trainable {
                    let mut dw = vec![0.0f32; m * n];
                    matmul_tn_acc(dy, &cache.x, s, m, n, &mut dw);
                    acc_grad(grads, &format!("{name}.w"), &dw);
                }
            }
            FtLinear::Quant { a, su, sv, .. } => {
                // y = su ⊙ z, z = A xs, xs = sv ⊙ x
                // dsu += Σ_s dy ⊙ z ; dz = dy ⊙ su
                let mut dsu = vec![0.0f32; m];
                let mut dz = vec![0.0f32; s * m];
                for i in 0..s {
                    for j in 0..m {
                        let dyv = dy[i * m + j];
                        dsu[j] += dyv * cache.z[i * m + j];
                        dz[i * m + j] = dyv * su[j];
                    }
                }
                acc_grad(grads, &format!("{name}.su"), &dsu);
                // dxs = dz A  (A is (m,n) row-major; dz (s,m))
                let mut dxs = vec![0.0f32; s * n];
                matmul_nn_acc_from_nt(&dz, a, s, m, n, &mut dxs);
                // dsv += Σ_s dxs ⊙ x ; dx = dxs ⊙ sv
                let mut dsv = vec![0.0f32; n];
                for i in 0..s {
                    for j in 0..n {
                        let dxsv = dxs[i * n + j];
                        dsv[j] += dxsv * cache.x[i * n + j];
                        dx[i * n + j] = dxsv * sv[j];
                    }
                }
                acc_grad(grads, &format!("{name}.sv"), &dsv);
            }
        }
        dx
    }
}

/// dx (s,n) += dy (s,m) · W (m,n)  — input-gradient through y = x Wᵀ.
fn matmul_nn_acc_from_nt(dy: &[f32], w: &[f32], _s: usize, m: usize, n: usize, dx: &mut [f32]) {
    crate::util::threadpool::par_rows(dx, n, |i, dxrow| {
        let dyrow = &dy[i * m..(i + 1) * m];
        for (o, &dyv) in dyrow.iter().enumerate() {
            if dyv == 0.0 {
                continue;
            }
            let wrow = &w[o * n..(o + 1) * n];
            for (d, &wv) in dxrow.iter_mut().zip(wrow) {
                *d += dyv * wv;
            }
        }
    });
}

/// Per-linear forward cache.
#[derive(Default, Clone)]
pub struct LinCache {
    pub x: Vec<f32>,
    pub xs: Vec<f32>,
    pub z: Vec<f32>,
}

/// RMSNorm backward. y = x·w/rms(x). Given dy, caches (x, inv), returns
/// dx and accumulates dw.
pub fn rms_norm_backward(
    name: &str,
    dy: &[f32],
    x: &[f32],
    w: &[f32],
    inv: &[f32],
    s: usize,
    d: usize,
    grads: &mut Grads,
) -> Vec<f32> {
    let mut dx = vec![0.0f32; s * d];
    let mut dw = vec![0.0f32; d];
    for i in 0..s {
        let xrow = &x[i * d..(i + 1) * d];
        let dyrow = &dy[i * d..(i + 1) * d];
        let r = inv[i]; // 1/rms
        // y_j = x_j * r * w_j, r = (mean(x²)+eps)^{-1/2}
        // dL/dx_k = r·w_k·dy_k − r³/d · x_k · Σ_j dy_j w_j x_j
        let mut dot = 0.0f32;
        for j in 0..d {
            dot += dyrow[j] * w[j] * xrow[j];
            dw[j] += dyrow[j] * xrow[j] * r;
        }
        let c = r * r * r * dot / d as f32;
        for j in 0..d {
            dx[i * d + j] = r * w[j] * dyrow[j] - c * xrow[j];
        }
    }
    acc_grad(grads, name, &dw);
    dx
}

/// Softmax backward for row-wise softmax p = softmax(z):
/// dz = p ⊙ (dp − Σ p·dp).
pub fn softmax_backward_row(p: &[f32], dp: &[f32], dz: &mut [f32]) {
    let dot: f32 = p.iter().zip(dp).map(|(a, b)| a * b).sum();
    for ((dzv, &pv), &dpv) in dz.iter_mut().zip(p).zip(dp) {
        *dzv = pv * (dpv - dot);
    }
}

/// RoPE backward: the rotation is orthogonal per (j, j+half) pair, so the
/// gradient is rotated by the inverse (transpose) rotation.
pub fn rope_backward(dx: &mut [f32], heads: usize, hd: usize, p: usize, cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    for h in 0..heads {
        let row = &mut dx[h * hd..(h + 1) * hd];
        for j in 0..half {
            let (c, s) = (cos[p * half + j], sin[p * half + j]);
            let (a, b) = (row[j], row[half + j]);
            row[j] = a * c + b * s;
            row[half + j] = -a * s + b * c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn fd_check<F: FnMut(&[f32]) -> f32>(
        mut f: F,
        theta: &[f32],
        analytic: &[f32],
        eps: f32,
        tol: f32,
    ) {
        for i in 0..theta.len() {
            let mut tp = theta.to_vec();
            tp[i] += eps;
            let fp = f(&tp);
            tp[i] -= 2.0 * eps;
            let fm = f(&tp);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - analytic[i]).abs() < tol * (1.0 + fd.abs().max(analytic[i].abs())),
                "param {i}: fd={fd} analytic={}",
                analytic[i]
            );
        }
    }

    #[test]
    fn dense_linear_grads() {
        let mut rng = Pcg64::new(1);
        let (s, m, n) = (3usize, 4usize, 5usize);
        let w0 = rng.gaussian_vec(m * n, 0.5);
        let x = rng.gaussian_vec(s * n, 1.0);
        let dy = rng.gaussian_vec(s * m, 1.0); // loss = Σ dy ⊙ y
        let layer = FtLinear::Dense { w: w0.clone(), m, n, trainable: true };
        let mut cache = LinCache::default();
        let _y = layer.forward(&x, s, &mut cache);
        let mut grads = Grads::new();
        let dx = layer.backward("lin", &dy, s, &cache, &mut grads);
        // check dW by finite differences
        fd_check(
            |w| {
                let l = FtLinear::Dense { w: w.to_vec(), m, n, trainable: false };
                let mut c = LinCache::default();
                let y = l.forward(&x, s, &mut c);
                y.iter().zip(&dy).map(|(a, b)| a * b).sum()
            },
            &w0,
            &grads["lin.w"],
            1e-3,
            1e-2,
        );
        // check dx
        fd_check(
            |xx| {
                let mut c = LinCache::default();
                let y = layer.forward(xx, s, &mut c);
                y.iter().zip(&dy).map(|(a, b)| a * b).sum()
            },
            &x,
            &dx,
            1e-3,
            1e-2,
        );
    }

    #[test]
    fn quant_linear_sign_grads() {
        let mut rng = Pcg64::new(2);
        let (s, m, n) = (2usize, 4usize, 6usize);
        let a = rng.gaussian_vec(m * n, 0.5);
        let su0 = rng.sign_vec(m);
        let sv0 = rng.sign_vec(n);
        let x = rng.gaussian_vec(s * n, 1.0);
        let dy = rng.gaussian_vec(s * m, 1.0);
        let layer = FtLinear::Quant { a: a.clone(), su: su0.clone(), sv: sv0.clone(), m, n };
        let mut cache = LinCache::default();
        layer.forward(&x, s, &mut cache);
        let mut grads = Grads::new();
        let dx = layer.backward("q", &dy, s, &cache, &mut grads);
        let loss_with = |su: &[f32], sv: &[f32], xx: &[f32]| -> f32 {
            let l = FtLinear::Quant { a: a.clone(), su: su.to_vec(), sv: sv.to_vec(), m, n };
            let mut c = LinCache::default();
            let y = l.forward(xx, s, &mut c);
            y.iter().zip(&dy).map(|(p, q)| p * q).sum()
        };
        fd_check(|su| loss_with(su, &sv0, &x), &su0, &grads["q.su"], 1e-3, 1e-2);
        fd_check(|sv| loss_with(&su0, sv, &x), &sv0, &grads["q.sv"], 1e-3, 1e-2);
        fd_check(|xx| loss_with(&su0, &sv0, xx), &x, &dx, 1e-3, 1e-2);
    }

    #[test]
    fn rms_norm_grads() {
        let mut rng = Pcg64::new(3);
        let (s, d) = (2usize, 6usize);
        let x = rng.gaussian_vec(s * d, 1.0);
        let w0: Vec<f32> = (0..d).map(|_| 1.0 + rng.f32() * 0.2).collect();
        let dy = rng.gaussian_vec(s * d, 1.0);
        let loss = |x_: &[f32], w_: &[f32]| -> f32 {
            let mut y = vec![0.0f32; s * d];
            rms_norm(x_, w_, s, d, &mut y);
            y.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        let mut y = vec![0.0f32; s * d];
        let inv = rms_norm(&x, &w0, s, d, &mut y);
        let mut grads = Grads::new();
        let dx = rms_norm_backward("nw", &dy, &x, &w0, &inv, s, d, &mut grads);
        fd_check(|xx| loss(xx, &w0), &x, &dx, 1e-3, 2e-2);
        fd_check(|ww| loss(&x, ww), &w0, &grads["nw"], 1e-3, 2e-2);
    }

    #[test]
    fn softmax_backward_correct() {
        let mut rng = Pcg64::new(4);
        let n = 5;
        let z0 = rng.gaussian_vec(n, 1.0);
        let dp = rng.gaussian_vec(n, 1.0);
        let loss = |z: &[f32]| -> f32 {
            let mut p = z.to_vec();
            softmax_rows(&mut p, 1, n);
            p.iter().zip(&dp).map(|(a, b)| a * b).sum()
        };
        let mut p = z0.clone();
        softmax_rows(&mut p, 1, n);
        let mut dz = vec![0.0f32; n];
        softmax_backward_row(&p, &dp, &mut dz);
        fd_check(loss, &z0, &dz, 1e-3, 1e-2);
    }

    #[test]
    fn rope_backward_is_inverse_rotation() {
        let (cos, sin) = rope_tables(8, 4);
        let mut rng = Pcg64::new(5);
        let x0 = rng.gaussian_vec(4, 1.0);
        let dy = rng.gaussian_vec(4, 1.0);
        let loss = |x: &[f32]| -> f32 {
            let mut y = x.to_vec();
            rope_apply(&mut y, 1, 4, 5, &cos, &sin);
            y.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        let mut dx = dy.clone();
        rope_backward(&mut dx, 1, 4, 5, &cos, &sin);
        fd_check(loss, &x0, &dx, 1e-3, 1e-2);
    }
}
