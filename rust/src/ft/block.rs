//! Fine-tunable Llama transformer block: forward with activation cache +
//! full reverse-mode backward. Used both for within-block fine-tuning
//! (block-output MSE) and end-to-end fine-tuning (soft-target CE through
//! the whole stack).

use std::collections::BTreeMap;

use super::autograd::*;
use crate::model::ops::*;

/// Trainable block parameters. Linears are `FtLinear` (dense or
/// quantized-with-sign-vectors); norms are always trainable.
pub struct FtBlock {
    pub name: String,
    pub d: usize,
    pub heads: usize,
    pub hd: usize,
    pub ff: usize,
    pub lin: BTreeMap<String, FtLinear>, // wq wk wv wo w_gate w_up w_down
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub rope_cos: Vec<f32>,
    pub rope_sin: Vec<f32>,
}

/// Everything backward needs from one block forward.
pub struct BlockCache {
    pub s: usize,
    pub x_in: Vec<f32>,
    pub h1: Vec<f32>,
    pub inv1: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub probs: Vec<Vec<f32>>, // per head (s,s)
    pub att_out: Vec<f32>,
    pub x_mid: Vec<f32>,
    pub h2: Vec<f32>,
    pub inv2: Vec<f32>,
    pub g_pre: Vec<f32>, // gate pre-activation
    pub u: Vec<f32>,
    pub a: Vec<f32>, // silu(g)*u
    pub lin_caches: BTreeMap<String, LinCache>,
}

impl FtBlock {
    fn lin_fwd(&self, nm: &str, x: &[f32], s: usize, cache: &mut BlockCache) -> Vec<f32> {
        let mut lc = LinCache::default();
        let y = self.lin[nm].forward(x, s, &mut lc);
        cache.lin_caches.insert(nm.to_string(), lc);
        y
    }

    /// Forward over (s, d) activations.
    pub fn forward(&self, x: &[f32], s: usize) -> (Vec<f32>, BlockCache) {
        let (d, heads, hd) = (self.d, self.heads, self.hd);
        let mut cache = BlockCache {
            s,
            x_in: x.to_vec(),
            h1: vec![0.0; s * d],
            inv1: vec![],
            q: vec![],
            k: vec![],
            v: vec![],
            probs: vec![],
            att_out: vec![0.0; s * d],
            x_mid: vec![],
            h2: vec![0.0; s * d],
            inv2: vec![],
            g_pre: vec![],
            u: vec![],
            a: vec![],
            lin_caches: BTreeMap::new(),
        };
        let mut h1 = vec![0.0f32; s * d];
        cache.inv1 = rms_norm(x, &self.attn_norm, s, d, &mut h1);
        cache.h1 = h1.clone();
        let mut q = self.lin_fwd("wq", &h1, s, &mut cache);
        let mut k = self.lin_fwd("wk", &h1, s, &mut cache);
        let v = self.lin_fwd("wv", &h1, s, &mut cache);
        for i in 0..s {
            rope_apply(&mut q[i * d..(i + 1) * d], heads, hd, i, &self.rope_cos, &self.rope_sin);
            rope_apply(&mut k[i * d..(i + 1) * d], heads, hd, i, &self.rope_cos, &self.rope_sin);
        }
        cache.q = q.clone();
        cache.k = k.clone();
        cache.v = v.clone();
        // attention
        let scale = 1.0 / (hd as f32).sqrt();
        let mut att = vec![0.0f32; s * d];
        for hh in 0..heads {
            let mut scores = vec![0.0f32; s * s];
            for i in 0..s {
                for j in 0..=i {
                    let qi = &q[i * d + hh * hd..i * d + (hh + 1) * hd];
                    let kj = &k[j * d + hh * hd..j * d + (hh + 1) * hd];
                    let mut sdot = 0.0f32;
                    for t in 0..hd {
                        sdot += qi[t] * kj[t];
                    }
                    scores[i * s + j] = sdot * scale;
                }
                for j in i + 1..s {
                    scores[i * s + j] = f32::NEG_INFINITY;
                }
            }
            softmax_rows(&mut scores, s, s);
            for i in 0..s {
                let out = &mut att[i * d + hh * hd..i * d + (hh + 1) * hd];
                for j in 0..=i {
                    let p = scores[i * s + j];
                    let vj = &v[j * d + hh * hd..j * d + (hh + 1) * hd];
                    for t in 0..hd {
                        out[t] += p * vj[t];
                    }
                }
            }
            cache.probs.push(scores);
        }
        cache.att_out = att.clone();
        let o = self.lin_fwd("wo", &att, s, &mut cache);
        let mut x_mid = x.to_vec();
        for (xv, &ov) in x_mid.iter_mut().zip(&o) {
            *xv += ov;
        }
        cache.x_mid = x_mid.clone();
        // mlp
        let mut h2 = vec![0.0f32; s * d];
        cache.inv2 = rms_norm(&x_mid, &self.mlp_norm, s, d, &mut h2);
        cache.h2 = h2.clone();
        let g = self.lin_fwd("w_gate", &h2, s, &mut cache);
        let u = self.lin_fwd("w_up", &h2, s, &mut cache);
        cache.g_pre = g.clone();
        cache.u = u.clone();
        let mut a = g;
        for (av, &uv) in a.iter_mut().zip(&u) {
            *av = silu(*av) * uv;
        }
        cache.a = a.clone();
        let dn = self.lin_fwd("w_down", &a, s, &mut cache);
        let mut out = x_mid;
        for (xv, &dv) in out.iter_mut().zip(&dn) {
            *xv += dv;
        }
        (out, cache)
    }

    /// Backward: given d(out), accumulate grads (keys prefixed with the
    /// block name) and return d(x_in).
    pub fn backward(&self, dout: &[f32], cache: &BlockCache, grads: &mut Grads) -> Vec<f32> {
        let (s, d, heads, hd) = (cache.s, self.d, self.heads, self.hd);
        let pfx = &self.name;
        // out = x_mid + w_down(a)
        let d_dn = dout; // grad into w_down output
        let da = self.lin["w_down"].backward(
            &format!("{pfx}.w_down"),
            d_dn,
            s,
            &cache.lin_caches["w_down"],
            grads,
        );
        // a = silu(g) * u
        let mut dg = vec![0.0f32; da.len()];
        let mut du = vec![0.0f32; da.len()];
        for i in 0..da.len() {
            let g = cache.g_pre[i];
            dg[i] = da[i] * cache.u[i] * silu_grad(g);
            du[i] = da[i] * silu(g);
        }
        let dh2_a = self.lin["w_gate"].backward(
            &format!("{pfx}.w_gate"),
            &dg,
            s,
            &cache.lin_caches["w_gate"],
            grads,
        );
        let dh2_b = self.lin["w_up"].backward(
            &format!("{pfx}.w_up"),
            &du,
            s,
            &cache.lin_caches["w_up"],
            grads,
        );
        let dh2: Vec<f32> = dh2_a.iter().zip(&dh2_b).map(|(a, b)| a + b).collect();
        let dx_mid_norm = rms_norm_backward(
            &format!("{pfx}.mlp_norm"),
            &dh2,
            &cache.x_mid,
            &self.mlp_norm,
            &cache.inv2,
            s,
            d,
            grads,
        );
        // x_mid gets gradient from both the residual (dout) and the norm.
        let mut dx_mid: Vec<f32> = dout.to_vec();
        for (a, &b) in dx_mid.iter_mut().zip(&dx_mid_norm) {
            *a += b;
        }
        // x_mid = x_in + wo(att)
        let datt = self.lin["wo"].backward(
            &format!("{pfx}.wo"),
            &dx_mid,
            s,
            &cache.lin_caches["wo"],
            grads,
        );
        // attention backward
        let scale = 1.0 / (hd as f32).sqrt();
        let mut dq = vec![0.0f32; s * d];
        let mut dk = vec![0.0f32; s * d];
        let mut dv = vec![0.0f32; s * d];
        for hh in 0..heads {
            let probs = &cache.probs[hh];
            for i in 0..s {
                // dP row and dV accumulation
                let dout_i = &datt[i * d + hh * hd..i * d + (hh + 1) * hd];
                let mut dp = vec![0.0f32; s];
                for j in 0..=i {
                    let vj = &cache.v[j * d + hh * hd..j * d + (hh + 1) * hd];
                    let mut acc = 0.0f32;
                    for t in 0..hd {
                        acc += dout_i[t] * vj[t];
                    }
                    dp[j] = acc;
                    let p = probs[i * s + j];
                    let dvj = &mut dv[j * d + hh * hd..j * d + (hh + 1) * hd];
                    for t in 0..hd {
                        dvj[t] += p * dout_i[t];
                    }
                }
                // softmax backward on row i (only 0..=i entries are live)
                let prow = &probs[i * s..i * s + i + 1];
                let mut dz = vec![0.0f32; i + 1];
                softmax_backward_row(prow, &dp[..i + 1], &mut dz);
                // scores = scale · q_i · k_j
                let qi = &cache.q[i * d + hh * hd..i * d + (hh + 1) * hd];
                let dqi = &mut dq[i * d + hh * hd..i * d + (hh + 1) * hd];
                for j in 0..=i {
                    let z = dz[j] * scale;
                    let kj = &cache.k[j * d + hh * hd..j * d + (hh + 1) * hd];
                    for t in 0..hd {
                        dqi[t] += z * kj[t];
                    }
                    let dkj = &mut dk[j * d + hh * hd..j * d + (hh + 1) * hd];
                    for t in 0..hd {
                        dkj[t] += z * qi[t];
                    }
                }
            }
        }
        // RoPE backward on dq, dk.
        for i in 0..s {
            rope_backward(&mut dq[i * d..(i + 1) * d], heads, hd, i, &self.rope_cos, &self.rope_sin);
            rope_backward(&mut dk[i * d..(i + 1) * d], heads, hd, i, &self.rope_cos, &self.rope_sin);
        }
        let dh1_q = self.lin["wq"].backward(&format!("{pfx}.wq"), &dq, s, &cache.lin_caches["wq"], grads);
        let dh1_k = self.lin["wk"].backward(&format!("{pfx}.wk"), &dk, s, &cache.lin_caches["wk"], grads);
        let dh1_v = self.lin["wv"].backward(&format!("{pfx}.wv"), &dv, s, &cache.lin_caches["wv"], grads);
        let dh1: Vec<f32> = dh1_q
            .iter()
            .zip(&dh1_k)
            .zip(&dh1_v)
            .map(|((a, b), c)| a + b + c)
            .collect();
        let dx_norm = rms_norm_backward(
            &format!("{pfx}.attn_norm"),
            &dh1,
            &cache.x_in,
            &self.attn_norm,
            &cache.inv1,
            s,
            d,
            grads,
        );
        let mut dx: Vec<f32> = dx_mid;
        for (a, &b) in dx.iter_mut().zip(&dx_norm) {
            *a += b;
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    pub fn random_block(seed: u64, quant_wq: bool) -> FtBlock {
        let (d, heads, hd, ff) = (16usize, 2usize, 8usize, 32usize);
        let mut rng = Pcg64::new(seed);
        let mut lin = BTreeMap::new();
        let mut dense = |m: usize, n: usize, rng: &mut Pcg64| FtLinear::Dense {
            w: rng.gaussian_vec(m * n, 1.0 / (n as f32).sqrt()),
            m,
            n,
            trainable: true,
        };
        if quant_wq {
            lin.insert(
                "wq".into(),
                FtLinear::Quant {
                    a: rng.gaussian_vec(d * d, 1.0 / (d as f32).sqrt()),
                    su: rng.sign_vec(d),
                    sv: rng.sign_vec(d),
                    m: d,
                    n: d,
                },
            );
        } else {
            lin.insert("wq".into(), dense(d, d, &mut rng));
        }
        lin.insert("wk".into(), dense(d, d, &mut rng));
        lin.insert("wv".into(), dense(d, d, &mut rng));
        lin.insert("wo".into(), dense(d, d, &mut rng));
        lin.insert("w_gate".into(), dense(ff, d, &mut rng));
        lin.insert("w_up".into(), dense(ff, d, &mut rng));
        lin.insert("w_down".into(), dense(d, ff, &mut rng));
        let (rope_cos, rope_sin) = rope_tables(32, hd);
        FtBlock {
            name: "blk".into(),
            d,
            heads,
            hd,
            ff,
            lin,
            attn_norm: vec![1.0; d],
            mlp_norm: vec![1.0; d],
            rope_cos,
            rope_sin,
        }
    }

    fn loss_of(block: &FtBlock, x: &[f32], s: usize, dy: &[f32]) -> f32 {
        let (y, _) = block.forward(x, s);
        y.iter().zip(dy).map(|(a, b)| a * b).sum()
    }

    #[test]
    fn block_input_gradient_matches_fd() {
        let block = random_block(1, false);
        let mut rng = Pcg64::new(10);
        let s = 3;
        let x = rng.gaussian_vec(s * block.d, 1.0);
        let dy = rng.gaussian_vec(s * block.d, 1.0);
        let (_, cache) = block.forward(&x, s);
        let mut grads = Grads::new();
        let dx = block.backward(&dy, &cache, &mut grads);
        let eps = 1e-2f32;
        for i in (0..x.len()).step_by(7) {
            let mut xp = x.clone();
            xp[i] += eps;
            let fp = loss_of(&block, &xp, s, &dy);
            xp[i] -= 2.0 * eps;
            let fm = loss_of(&block, &xp, s, &dy);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - dx[i]).abs() < 2e-2 * (1.0 + fd.abs().max(dx[i].abs())),
                "x[{i}]: fd={fd} got={}",
                dx[i]
            );
        }
    }

    #[test]
    fn block_sign_vector_gradients_match_fd() {
        let block = random_block(2, true);
        let mut rng = Pcg64::new(11);
        let s = 2;
        let x = rng.gaussian_vec(s * block.d, 1.0);
        let dy = rng.gaussian_vec(s * block.d, 1.0);
        let (_, cache) = block.forward(&x, s);
        let mut grads = Grads::new();
        block.backward(&dy, &cache, &mut grads);
        let gsu = grads["blk.wq.su"].clone();
        let eps = 1e-2f32;
        for i in 0..block.d {
            let mut b2 = random_block(2, true); // identical reconstruction
            let probe = |delta: f32, b2: &mut FtBlock| -> f32 {
                if let FtLinear::Quant { su, .. } = b2.lin.get_mut("wq").unwrap() {
                    su[i] += delta;
                }
                let l = loss_of(b2, &x, s, &dy);
                if let FtLinear::Quant { su, .. } = b2.lin.get_mut("wq").unwrap() {
                    su[i] -= delta;
                }
                l
            };
            let fp = probe(eps, &mut b2);
            let fm = probe(-eps, &mut b2);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - gsu[i]).abs() < 2e-2 * (1.0 + fd.abs().max(gsu[i].abs())),
                "su[{i}]: fd={fd} got={}",
                gsu[i]
            );
        }
    }

    #[test]
    fn block_norm_gradients_match_fd() {
        let block = random_block(3, false);
        let mut rng = Pcg64::new(12);
        let s = 2;
        let x = rng.gaussian_vec(s * block.d, 1.0);
        let dy = rng.gaussian_vec(s * block.d, 1.0);
        let (_, cache) = block.forward(&x, s);
        let mut grads = Grads::new();
        block.backward(&dy, &cache, &mut grads);
        let gn = grads["blk.attn_norm"].clone();
        let eps = 1e-2f32;
        for i in (0..block.d).step_by(3) {
            let mut b2 = random_block(3, false);
            b2.attn_norm[i] += eps;
            let fp = loss_of(&b2, &x, s, &dy);
            b2.attn_norm[i] -= 2.0 * eps;
            let fm = loss_of(&b2, &x, s, &dy);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - gn[i]).abs() < 2e-2 * (1.0 + fd.abs().max(gn[i].abs())),
                "attn_norm[{i}]: fd={fd} got={}",
                gn[i]
            );
        }
    }
}
