//! Fine-tuning during quantization (paper §5, Appendix D): hand-written
//! reverse-mode autodiff for the transformer block, Adam, and the
//! two-stage Algorithm 5 driver.

pub mod adam;
pub mod autograd;
pub mod block;
pub mod finetune;

pub use finetune::{quantize_model_ft, FtConfig};
