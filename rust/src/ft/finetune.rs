//! Fine-tuning during quantization (paper §5 + Appendix D, Algorithm 5):
//!
//! 1. **Within-block**: for each transformer block, quantize its linear
//!    layers one at a time; after each, tune the block's remaining
//!    unquantized linears, norms, and the sign vectors (as reals) of the
//!    already-quantized layers to match the *original* block's output
//!    (MSE, Adam, early stopping on a held-out split).
//! 2. **End-to-end**: after all layers are quantized, tune sign vectors,
//!    norms and the LM head to match the original model's logits
//!    (soft-target cross-entropy).
//!
//! Llama-architecture models only (matching the paper's evaluation; the
//! MoE / non-Llama rows of Table 9 are no-FT).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::adam::Adam;
use super::autograd::{FtLinear, Grads, LinCache};
use super::block::FtBlock;
use crate::linalg::Matrix;
use crate::model::ops::{rms_norm, rope_tables, softmax_rows};
use crate::model::{Arch, Model};
use crate::qmodel::QuantizedModel;
use crate::quant::pipeline::{quantize_matrix, Method, QuantizedLinear};

#[derive(Clone, Debug)]
pub struct FtConfig {
    /// Adam steps after each within-block layer quantization.
    pub steps_block: usize,
    /// Adam steps for the end-to-end stage.
    pub steps_e2e: usize,
    /// Token window per dev sequence.
    pub window: usize,
    pub n_train: usize,
    pub n_valid: usize,
    pub lr: f32,
    /// Sign vectors get lr × this (paper: 10× at 2 bits).
    pub sign_lr_mult: f32,
}

impl Default for FtConfig {
    fn default() -> Self {
        FtConfig {
            steps_block: 10,
            steps_e2e: 15,
            window: 64,
            n_train: 4,
            n_valid: 2,
            lr: 1e-3,
            sign_lr_mult: 10.0,
        }
    }
}

/// Assemble an FtBlock view of layer `i` of `model`, all-dense trainable.
fn block_from_model(model: &Model, i: usize) -> FtBlock {
    let cfg = &model.cfg;
    let (d, heads, hd, ff) = (cfg.d_model, cfg.n_heads, cfg.head_dim(), cfg.d_ff);
    let pre = format!("layers.{i}.");
    let mut lin = BTreeMap::new();
    for nm in ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"] {
        let t = model.p(&format!("{pre}{nm}"));
        lin.insert(
            nm.to_string(),
            FtLinear::Dense {
                w: t.data.clone(),
                m: t.shape[0],
                n: t.shape[1],
                trainable: true,
            },
        );
    }
    let (rope_cos, rope_sin) = rope_tables(cfg.ctx, hd);
    FtBlock {
        name: format!("layers.{i}"),
        d,
        heads,
        hd,
        ff,
        lin,
        attn_norm: model.p(&format!("{pre}attn_norm")).data.clone(),
        mlp_norm: model.p(&format!("{pre}mlp_norm")).data.clone(),
        rope_cos,
        rope_sin,
    }
}

/// Collect Adam-able parameter references from a set of blocks (+ extras).
fn block_param_refs<'a>(blocks: &'a mut [FtBlock]) -> BTreeMap<String, &'a mut [f32]> {
    let mut map: BTreeMap<String, &'a mut [f32]> = BTreeMap::new();
    for b in blocks.iter_mut() {
        let pfx = b.name.clone();
        map.insert(format!("{pfx}.attn_norm"), b.attn_norm.as_mut_slice());
        map.insert(format!("{pfx}.mlp_norm"), b.mlp_norm.as_mut_slice());
        for (nm, l) in b.lin.iter_mut() {
            match l {
                FtLinear::Dense { w, trainable, .. } if *trainable => {
                    map.insert(format!("{pfx}.{nm}.w"), w.as_mut_slice());
                }
                FtLinear::Quant { su, sv, .. } => {
                    map.insert(format!("{pfx}.{nm}.su"), su.as_mut_slice());
                    map.insert(format!("{pfx}.{nm}.sv"), sv.as_mut_slice());
                }
                _ => {}
            }
        }
    }
    map
}

/// MSE loss: returns (loss, dpred).
fn mse(pred: &[f32], target: &[f32]) -> (f32, Vec<f32>) {
    let n = pred.len() as f32;
    let mut d = vec![0.0f32; pred.len()];
    let mut loss = 0.0f32;
    for i in 0..pred.len() {
        let e = pred[i] - target[i];
        loss += e * e;
        d[i] = 2.0 * e / n;
    }
    (loss / n, d)
}

/// Soft-target CE: loss = mean_t KL-ish −Σ_v softmax(target)·log_softmax(pred);
/// dlogits = (softmax(pred) − softmax(target)) / tokens.
fn soft_ce(pred: &[f32], target: &[f32], rows: usize, v: usize) -> (f32, Vec<f32>) {
    let mut p = pred.to_vec();
    let mut q = target.to_vec();
    softmax_rows(&mut p, rows, v);
    softmax_rows(&mut q, rows, v);
    let mut loss = 0.0f64;
    let mut d = vec![0.0f32; pred.len()];
    for i in 0..rows {
        for j in 0..v {
            let pj = p[i * v + j];
            let qj = q[i * v + j];
            if qj > 0.0 && pj > 0.0 {
                loss -= qj as f64 * (pj as f64).ln();
            }
            d[i * v + j] = (pj - qj) / rows as f32;
        }
    }
    ((loss / rows as f64) as f32, d)
}

/// Embed a token window into (s,d) activations (llama: no pos embed).
fn embed(model: &Model, tokens: &[u8]) -> Vec<f32> {
    let d = model.cfg.d_model;
    let e = model.p("embed");
    let mut x = vec![0.0f32; tokens.len() * d];
    for (i, &t) in tokens.iter().enumerate() {
        x[i * d..(i + 1) * d].copy_from_slice(&e.data[t as usize * d..(t as usize + 1) * d]);
    }
    x
}

/// Windows sampled deterministically from the dev stream.
fn dev_windows(dev: &[u8], n: usize, w: usize) -> Vec<Vec<u8>> {
    let stride = (dev.len().saturating_sub(w + 1)) / n.max(1);
    (0..n)
        .map(|i| dev[i * stride..i * stride + w].to_vec())
        .collect()
}

/// QuIP# with fine-tuning: Algorithm 5. Returns a QuantizedModel whose
/// layers carry fine-tuned sign vectors and whose model carries
/// fine-tuned norms / head.
pub fn quantize_model_ft(
    model: &Model,
    hessians: &BTreeMap<String, Matrix>,
    bits: u8,
    seed: u64,
    dev_tokens: &[u8],
    cfg: &FtConfig,
) -> Result<QuantizedModel> {
    ensure!(
        model.cfg.arch == Arch::Llama,
        "fine-tuning supports the llama architecture"
    );
    let n_blocks = model.cfg.n_layers;
    let windows = dev_windows(dev_tokens, cfg.n_train + cfg.n_valid, cfg.window);
    let (train_w, valid_w) = windows.split_at(cfg.n_train);

    // Original-model activations: inputs to each block (Algorithm 5 keeps
    // X from the *unquantized* model) and each block's target output.
    let mut block_inputs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_blocks + 1];
    for w in windows.iter() {
        let mut x = embed(model, w);
        block_inputs[0].push(x.clone());
        for i in 0..n_blocks {
            let b = block_from_model(model, i);
            let (y, _) = b.forward(&x, w.len());
            x = y;
            block_inputs[i + 1].push(x.clone());
        }
    }

    let mut result_layers: BTreeMap<String, QuantizedLinear> = BTreeMap::new();
    let mut tuned_model = Model::new(model.cfg.clone(), model.params.clone());

    // ---- stage 1: within-block ------------------------------------------------
    let order = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];
    for bi in 0..n_blocks {
        let mut block = block_from_model(&tuned_model, bi);
        let mut qlins: BTreeMap<String, QuantizedLinear> = BTreeMap::new();
        for (oi, nm) in order.iter().enumerate() {
            let full = format!("layers.{bi}.{nm}");
            // Quantize this linear from the block's *current* (possibly
            // fine-tuned) dense weight.
            let (m, n) = match &block.lin[*nm] {
                FtLinear::Dense { m, n, .. } => (*m, *n),
                _ => unreachable!(),
            };
            let wcur = match &block.lin[*nm] {
                FtLinear::Dense { w, .. } => Matrix::from_f32(m, n, w),
                _ => unreachable!(),
            };
            let h = hessians.get(&full).cloned().unwrap_or_else(|| Matrix::eye(n));
            let layer_seed = seed ^ ((bi * 8 + oi) as u64 + 1).wrapping_mul(0x9e37_79b9);
            let ql = quantize_matrix(&Method::QuipSharp { bits, ft: true }, &wcur, &h, layer_seed)?;
            let a = ql
                .ctx
                .as_ref()
                .unwrap()
                .unprocess_w_signless(ql.w_hat_tilde.as_ref().unwrap());
            let su: Vec<f32> = ql.packed.as_ref().unwrap().su.clone();
            let sv: Vec<f32> = ql.packed.as_ref().unwrap().sv.clone();
            block.lin.insert(
                nm.to_string(),
                FtLinear::Quant { a: a.to_f32(), su, sv, m, n },
            );
            qlins.insert(full.clone(), ql);

            // Tune remaining params of the block to match the original
            // block output.
            let mut opt = Adam::new(cfg.lr).with_lr_mult(".su", cfg.sign_lr_mult).with_lr_mult(".sv", cfg.sign_lr_mult);
            // Validation of the *initial* state is a candidate too — early
            // stopping must never return something worse than no tuning.
            let valid_loss = |block: &FtBlock| -> f32 {
                let mut vloss = 0.0f32;
                for (wi, w) in valid_w.iter().enumerate() {
                    let idx = cfg.n_train + wi;
                    let x = &block_inputs[bi][idx];
                    let target = &block_inputs[bi + 1][idx];
                    let (y, _) = block.forward(x, w.len());
                    vloss += mse(&y, target).0;
                }
                vloss
            };
            let mut best_valid = valid_loss(&block);
            let mut best_state: Option<Vec<(String, Vec<f32>)>> = {
                let params = block_param_refs(std::slice::from_mut(&mut block));
                Some(params.iter().map(|(k, v)| (k.clone(), v.to_vec())).collect())
            };
            for _step in 0..cfg.steps_block {
                let mut grads = Grads::new();
                for (wi, w) in train_w.iter().enumerate() {
                    let x = &block_inputs[bi][wi];
                    let target = &block_inputs[bi + 1][wi];
                    let (y, cache) = block.forward(x, w.len());
                    let (_, dy) = mse(&y, target);
                    block.backward(&dy, &cache, &mut grads);
                }
                let mut params = block_param_refs(std::slice::from_mut(&mut block));
                opt.step(&mut params, &grads);
                // Early stopping on validation windows.
                let vloss = valid_loss(&block);
                if vloss < best_valid {
                    best_valid = vloss;
                    let params = block_param_refs(std::slice::from_mut(&mut block));
                    best_state = Some(
                        params.iter().map(|(k, v)| (k.clone(), v.to_vec())).collect(),
                    );
                }
            }
            if let Some(state) = best_state {
                let mut params = block_param_refs(std::slice::from_mut(&mut block));
                for (k, v) in state {
                    if let Some(p) = params.get_mut(&k) {
                        p.copy_from_slice(&v);
                    }
                }
            }
        }
        // Write the block back: tuned norms + fine-tuned sign vectors.
        tuned_model
            .params
            .get_mut(&format!("layers.{bi}.attn_norm"))
            .unwrap()
            .data = block.attn_norm.clone();
        tuned_model
            .params
            .get_mut(&format!("layers.{bi}.mlp_norm"))
            .unwrap()
            .data = block.mlp_norm.clone();
        for (full, mut ql) in qlins {
            let nm = full.rsplit('.').next().unwrap();
            if let FtLinear::Quant { su, sv, .. } = &block.lin[nm] {
                ql.set_signs(su, sv);
            }
            ql.refresh_w_eff();
            tuned_model.set_linear(&full, ql.w_eff.clone());
            result_layers.insert(full, ql);
        }
    }

    let mut qm = QuantizedModel {
        model: tuned_model,
        method: Method::QuipSharp { bits, ft: true },
        layers: result_layers,
        serving: std::sync::OnceLock::new(),
    };

    // ---- stage 2: end-to-end --------------------------------------------------
    finetune_e2e(&mut qm, model, train_w, valid_w, cfg)?;
    Ok(qm)
}

/// End-to-end stage: tune sign vectors, norms, and the LM head to match
/// the original model's logits.
fn finetune_e2e(
    qm: &mut QuantizedModel,
    orig: &Model,
    train_w: &[Vec<u8>],
    valid_w: &[Vec<u8>],
    cfg: &FtConfig,
) -> Result<()> {
    let mcfg = qm.model.cfg.clone();
    let (d, v) = (mcfg.d_model, mcfg.vocab);
    // Assemble FtBlocks with Quant linears from qm.
    let mut blocks: Vec<FtBlock> = (0..mcfg.n_layers)
        .map(|i| block_from_model(&qm.model, i))
        .collect();
    for (full, ql) in &qm.layers {
        let parts: Vec<&str> = full.split('.').collect();
        let bi: usize = parts[1].parse().unwrap();
        let nm = parts[2];
        let a = ql
            .ctx
            .as_ref()
            .unwrap()
            .unprocess_w_signless(ql.w_hat_tilde.as_ref().unwrap());
        let p = ql.packed.as_ref().unwrap();
        blocks[bi].lin.insert(
            nm.to_string(),
            FtLinear::Quant {
                a: a.to_f32(),
                su: p.su.clone(),
                sv: p.sv.clone(),
                m: ql.m,
                n: ql.n,
            },
        );
    }
    let mut final_norm = qm.model.p("final_norm").data.clone();
    let head_t = qm.model.p("lm_head");
    let mut lm_head = FtLinear::Dense {
        w: head_t.data.clone(),
        m: head_t.shape[0],
        n: head_t.shape[1],
        trainable: true,
    };

    // Original logits as soft targets.
    let targets: Vec<Vec<f32>> = train_w
        .iter()
        .chain(valid_w.iter())
        .map(|w| orig.forward(w, &mut crate::model::NoHook))
        .collect();

    let fwd = |blocks: &[FtBlock],
               final_norm: &[f32],
               lm_head: &FtLinear,
               toks: &[u8]|
     -> (Vec<f32>, Vec<super::block::BlockCache>, Vec<f32>, Vec<f32>, LinCache) {
        let s = toks.len();
        let mut x = embed(&qm.model, toks);
        let mut caches = Vec::new();
        for b in blocks {
            let (y, c) = b.forward(&x, s);
            x = y;
            caches.push(c);
        }
        let mut h = vec![0.0f32; s * d];
        let inv = rms_norm(&x, final_norm, s, d, &mut h);
        let mut lc = LinCache::default();
        let logits = lm_head.forward(&h, s, &mut lc);
        (logits, caches, x, inv, lc)
    };

    let mut opt = Adam::new(cfg.lr)
        .with_lr_mult(".su", cfg.sign_lr_mult)
        .with_lr_mult(".sv", cfg.sign_lr_mult);
    // Initial state is an early-stopping candidate (never regress).
    let mut best = {
        let mut vloss = 0.0f32;
        for (wi, toks) in valid_w.iter().enumerate() {
            let (logits, _, _, _, _) = fwd(&blocks, &final_norm, &lm_head, toks);
            vloss += soft_ce(&logits, &targets[train_w.len() + wi], toks.len(), v).0;
        }
        let mut params = block_param_refs(&mut blocks);
        params.insert("final_norm".into(), final_norm.as_mut_slice());
        if let FtLinear::Dense { w, .. } = &mut lm_head {
            params.insert("lm_head.w".into(), w.as_mut_slice());
        }
        let state: Vec<(String, Vec<f32>)> =
            params.iter().map(|(k, p)| (k.clone(), p.to_vec())).collect();
        (vloss, Some(state))
    };
    for _step in 0..cfg.steps_e2e {
        let mut grads = Grads::new();
        for (wi, toks) in train_w.iter().enumerate() {
            let s = toks.len();
            let (logits, caches, x_final, inv, lc) = fwd(&blocks, &final_norm, &lm_head, toks);
            let (_, dlogits) = soft_ce(&logits, &targets[wi], s, v);
            let dh = lm_head.backward("lm_head", &dlogits, s, &lc, &mut grads);
            let mut dx = super::autograd::rms_norm_backward(
                "final_norm",
                &dh,
                &x_final,
                &final_norm,
                &inv,
                s,
                d,
                &mut grads,
            );
            for (bi, b) in blocks.iter().enumerate().rev() {
                dx = b.backward(&dx, &caches[bi], &mut grads);
            }
        }
        let mut params = block_param_refs(&mut blocks);
        params.insert("final_norm".into(), final_norm.as_mut_slice());
        if let FtLinear::Dense { w, .. } = &mut lm_head {
            params.insert("lm_head.w".into(), w.as_mut_slice());
        }
        opt.step(&mut params, &grads);
        // Validation.
        let mut vloss = 0.0f32;
        for (wi, toks) in valid_w.iter().enumerate() {
            let (logits, _, _, _, _) = fwd(&blocks, &final_norm, &lm_head, toks);
            vloss += soft_ce(&logits, &targets[train_w.len() + wi], toks.len(), v).0;
        }
        if vloss < best.0 {
            let mut params = block_param_refs(&mut blocks);
            params.insert("final_norm".into(), final_norm.as_mut_slice());
            if let FtLinear::Dense { w, .. } = &mut lm_head {
                params.insert("lm_head.w".into(), w.as_mut_slice());
            }
            best = (
                vloss,
                Some(params.iter().map(|(k, p)| (k.clone(), p.to_vec())).collect()),
            );
        }
    }
    if let Some(state) = best.1 {
        let mut params = block_param_refs(&mut blocks);
        params.insert("final_norm".into(), final_norm.as_mut_slice());
        if let FtLinear::Dense { w, .. } = &mut lm_head {
            params.insert("lm_head.w".into(), w.as_mut_slice());
        }
        for (k, vv) in state {
            if let Some(p) = params.get_mut(&k) {
                p.copy_from_slice(&vv);
            }
        }
    }

    // Write everything back into the quantized model.
    for (bi, b) in blocks.iter().enumerate() {
        qm.model
            .params
            .get_mut(&format!("layers.{bi}.attn_norm"))
            .unwrap()
            .data = b.attn_norm.clone();
        qm.model
            .params
            .get_mut(&format!("layers.{bi}.mlp_norm"))
            .unwrap()
            .data = b.mlp_norm.clone();
        for (nm, l) in &b.lin {
            if let FtLinear::Quant { su, sv, .. } = l {
                let full = format!("layers.{bi}.{nm}");
                if let Some(ql) = qm.layers.get_mut(&full) {
                    ql.set_signs(su, sv);
                    ql.refresh_w_eff();
                }
            }
        }
    }
    qm.model.params.get_mut("final_norm").unwrap().data = final_norm;
    if let FtLinear::Dense { w, .. } = lm_head {
        qm.model.params.get_mut("lm_head").unwrap().data = w;
    }
    for (name, ql) in qm.layers.iter() {
        qm.model.set_linear(name, ql.w_eff.clone());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hessian::collect_hessians;
    use crate::model::tests_support::tiny_model;
    use crate::qmodel::quantize_model;

    #[test]
    fn ft_improves_over_noft_at_2bit() {
        let model = tiny_model(7);
        let dev: Vec<u8> = (0..2048).map(|i| ((i * 7 + i / 5) % 64) as u8).collect();
        let hs = collect_hessians(&model, &dev, 4, 32);
        // Baseline through the SAME code path with zero optimization steps
        // (identical per-layer seeds/transforms), so the comparison
        // isolates the effect of fine-tuning itself.
        let base_cfg = FtConfig {
            steps_block: 0,
            steps_e2e: 0,
            window: 32,
            n_train: 3,
            n_valid: 2,
            ..Default::default()
        };
        let ft_cfg = FtConfig {
            steps_block: 8,
            steps_e2e: 10,
            ..base_cfg.clone()
        };
        let noft = quantize_model_ft(&model, &hs, 2, 3, &dev, &base_cfg).unwrap();
        let ft = quantize_model_ft(&model, &hs, 2, 3, &dev, &ft_cfg).unwrap();
        // Logit MSE against the original model over the dev windows the
        // run validated on (early stopping guarantees no regression there).
        let windows = super::dev_windows(&dev, 5, 32);
        let err = |m: &crate::model::Model| -> f32 {
            let mut tot = 0.0f32;
            for w in &windows {
                let orig = model.forward(w, &mut crate::model::NoHook);
                let got = m.forward(w, &mut crate::model::NoHook);
                tot += got
                    .iter()
                    .zip(&orig)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    / orig.len() as f32;
            }
            tot
        };
        let e_noft = err(&noft.model);
        let e_ft = err(&ft.model);
        assert!(
            e_ft < e_noft,
            "fine-tuning should reduce logit error: ft {e_ft} vs noft {e_noft}"
        );
    }
}
