//! Adam optimizer over named f32 parameter buffers (paper §F.6 uses Adam
//! with lr 5e-5 for weights/norms and 5e-4 for sign vectors at 2 bits).

use std::collections::BTreeMap;

use super::autograd::Grads;

pub struct Adam {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    /// Per-name learning-rate multipliers (e.g. sign vectors ×10).
    pub lr_mult: BTreeMap<String, f32>,
    m: BTreeMap<String, Vec<f32>>,
    v: BTreeMap<String, Vec<f32>>,
    t: i32,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
            lr_mult: BTreeMap::new(),
            m: BTreeMap::new(),
            v: BTreeMap::new(),
            t: 0,
        }
    }

    /// Multiply lr for every parameter whose name contains `pattern`.
    pub fn with_lr_mult(mut self, pattern: &str, mult: f32) -> Self {
        self.lr_mult.insert(pattern.to_string(), mult);
        self
    }

    fn mult_for(&self, name: &str) -> f32 {
        for (pat, m) in &self.lr_mult {
            if name.contains(pat.as_str()) {
                return *m;
            }
        }
        1.0
    }

    /// One update. `params` maps name → mutable buffer; only names present
    /// in `grads` are touched.
    pub fn step(&mut self, params: &mut BTreeMap<String, &mut [f32]>, grads: &Grads) {
        self.t += 1;
        let bc1 = 1.0 - self.b1.powi(self.t);
        let bc2 = 1.0 - self.b2.powi(self.t);
        for (name, g) in grads {
            let Some(p) = params.get_mut(name) else {
                continue;
            };
            let lr = self.lr * self.mult_for(name);
            let (b1, b2, eps) = (self.b1, self.b2, self.eps);
            let m = self.m.entry(name.clone()).or_insert_with(|| vec![0.0; g.len()]);
            let v = self.v.entry(name.clone()).or_insert_with(|| vec![0.0; g.len()]);
            for i in 0..g.len() {
                m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                p[i] -= lr * mh / (vh.sqrt() + eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // f(x) = Σ (x - 3)², grad = 2(x - 3)
        let mut x = vec![0.0f32; 4];
        let mut opt = Adam::new(0.1);
        for _ in 0..400 {
            let mut grads = Grads::new();
            grads.insert("x".into(), x.iter().map(|v| 2.0 * (v - 3.0)).collect());
            let mut params: BTreeMap<String, &mut [f32]> = BTreeMap::new();
            params.insert("x".into(), &mut x);
            opt.step(&mut params, &grads);
        }
        for v in &x {
            assert!((v - 3.0).abs() < 0.05, "{v}");
        }
    }

    #[test]
    fn lr_mult_applies() {
        let mut a = vec![0.0f32; 1];
        let mut b = vec![0.0f32; 1];
        let mut opt = Adam::new(0.01).with_lr_mult("sv", 10.0);
        let mut grads = Grads::new();
        grads.insert("w".into(), vec![1.0]);
        grads.insert("x.sv".into(), vec![1.0]);
        let mut params: BTreeMap<String, &mut [f32]> = BTreeMap::new();
        params.insert("w".into(), &mut a);
        params.insert("x.sv".into(), &mut b);
        opt.step(&mut params, &grads);
        assert!(b[0].abs() > 5.0 * a[0].abs());
    }
}
