//! Whole-model quantization (paper Algorithm 1 applied layer-by-layer)
//! and the quantized-model container used by evaluation, fine-tuning and
//! serving.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::Result;

use crate::linalg::Matrix;
use crate::model::Model;
use crate::quant::pipeline::{quantize_matrix, Method, QuantizedLinear};

/// A model whose linear layers have been quantized: the dense effective
/// weights live inside `model` (for evaluation); per-layer quantization
/// artifacts are kept for packing, fine-tuning, and reporting.
pub struct QuantizedModel {
    pub model: Model,
    pub method: Method,
    pub layers: BTreeMap<String, QuantizedLinear>,
    /// Lazily built `Arc` view of `model` for serving
    /// ([`QuantizedModel::serving_model`]); invalidated by
    /// [`QuantizedModel::refresh`].
    pub(crate) serving: OnceLock<Arc<Model>>,
}

impl QuantizedModel {
    /// Average bits/weight over quantized layers (code bits + overheads),
    /// weighted by parameter count — the "BITS" column of every table.
    pub fn avg_bits(&self) -> f64 {
        let mut bits = 0.0;
        let mut weights = 0.0;
        for ql in self.layers.values() {
            let nw = (ql.m * ql.n) as f64;
            bits += ql.bits.total() * nw;
            weights += nw;
        }
        bits / weights.max(1.0)
    }

    /// Mean relative proxy error across layers (quality diagnostic).
    pub fn mean_proxy_rel(&self) -> f64 {
        let s: f64 = self.layers.values().map(|l| l.stats.proxy_rel).sum();
        s / self.layers.len().max(1) as f64
    }

    /// Fused-decode generator over this model's packed layers — the
    /// batch-native serving entry point. Packed codewords are shared by
    /// `Arc`, so building a generator copies no weight payload.
    pub fn generator(&self) -> crate::generation::Generator<'_> {
        crate::generation::Generator::quantized(&self.model, self)
    }

    /// The RVQ base-stage draft generator embedded in this model: packed
    /// layers decode stage 0 only (a 4-bit E8P ∘ E8P model's free 2-bit
    /// model), sharing code payloads with [`QuantizedModel::generator`].
    /// The draft side of self-speculative decoding
    /// ([`crate::generation::speculative`]); for a single-stage (2-bit)
    /// model it coincides with the full generator.
    pub fn draft_generator(&self) -> crate::generation::Generator<'_> {
        crate::generation::Generator::base_stage(&self.model, self)
    }

    /// Whether any packed layer carries more than one RVQ stage, i.e.
    /// whether [`QuantizedModel::draft_generator`] is actually cheaper
    /// than the full model.
    pub fn has_multi_stage(&self) -> bool {
        self.layers
            .values()
            .filter_map(|ql| ql.packed.as_ref())
            .any(|p| p.stage_codes.len() > 1)
    }

    /// Shared KV page pool sized at `pages` pages over this model's
    /// geometry — the serving engine's KV subsystem
    /// ([`crate::generation::paged`]). Pass
    /// `max_batch × paged::pages_per_seq(&cfg)` for worst-case
    /// (preemption-free) capacity, or less to oversubscribe.
    pub fn kv_pool(&self, pages: usize) -> crate::generation::paged::KvPagePool {
        crate::generation::paged::KvPagePool::for_model(&self.model, pages)
    }

    /// Total packed-codeword bytes across layers (the per-step weight
    /// stream of a fully batched decode; dense fallback layers excluded).
    pub fn packed_code_bytes(&self) -> u64 {
        self.layers
            .values()
            .filter_map(|ql| ql.packed.as_ref())
            .flat_map(|p| p.stage_codes.iter())
            .map(|codes| (codes.len() * 2) as u64)
            .sum()
    }

    /// The `Arc<Model>` every serving construction wants
    /// ([`crate::serve::NativeEngine::start_with_opts`],
    /// [`crate::serve::NativeEngine::start_replicas`]): built once,
    /// lazily, and shared by `Arc` clone thereafter. Cloning `Params`
    /// deep-copies every dense tensor, so the fleet path must pay that
    /// copy exactly once — N replicas share this one `Arc<Model>` (and
    /// the packed codes via `Arc<QuantizedModel>`), putting a replica's
    /// marginal footprint at its KV pool plus scheduler state.
    pub fn serving_model(&self) -> Arc<Model> {
        self.serving
            .get_or_init(|| Arc::new(Model::new(self.model.cfg.clone(), self.model.params.clone())))
            .clone()
    }

    /// Re-materialize every layer's dense effective weight into the model
    /// (after fine-tuning mutates sign vectors).
    pub fn refresh(&mut self) {
        for (name, ql) in self.layers.iter_mut() {
            ql.refresh_w_eff();
            self.model.set_linear(name, ql.w_eff.clone());
        }
        // The cached serving view predates the refresh; rebuild lazily.
        self.serving = OnceLock::new();
    }
}

/// Quantize every linear layer of `model` with `method`, given per-layer
/// Hessians (from `hessian::collect_hessians`). Layer seeds are derived
/// deterministically from `seed` and the layer name.
pub fn quantize_model(
    model: &Model,
    hessians: &BTreeMap<String, Matrix>,
    method: &Method,
    seed: u64,
) -> Result<QuantizedModel> {
    let mut qmodel = Model::new(model.cfg.clone(), model.params.clone());
    let mut layers = BTreeMap::new();
    for (idx, name) in model.cfg.linear_names().iter().enumerate() {
        let t = model.p(name);
        let (m, n) = (t.shape[0], t.shape[1]);
        let w = Matrix::from_f32(m, n, &t.data);
        let h = hessians
            .get(name)
            .cloned()
            .unwrap_or_else(|| Matrix::eye(n));
        let layer_seed = seed ^ (idx as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let ql = quantize_matrix(method, &w, &h, layer_seed)?;
        qmodel.set_linear(name, ql.w_eff.clone());
        layers.insert(name.clone(), ql);
    }
    Ok(QuantizedModel {
        model: qmodel,
        method: method.clone(),
        layers,
        serving: OnceLock::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::perplexity;
    use crate::hessian::collect_hessians;
    use crate::model::tests_support::tiny_model;

    fn calib_tokens() -> Vec<u8> {
        (0..256).map(|i| ((i * 7 + i / 3) % 64) as u8).collect()
    }

    #[test]
    fn quantize_model_2bit_runs_and_degrades_gracefully() {
        let model = tiny_model(1);
        let toks = calib_tokens();
        let hs = collect_hessians(&model, &toks, 4, 32);
        let qm = quantize_model(&model, &hs, &Method::QuipSharp { bits: 4, ft: false }, 7)
            .unwrap();
        assert_eq!(qm.layers.len(), model.cfg.linear_names().len());
        // 4-bit on a random tiny model: perplexity shouldn't explode.
        let ppl_fp = perplexity(&model, &toks, 16, 128);
        let ppl_q = perplexity(&qm.model, &toks, 16, 128);
        assert!(ppl_q < ppl_fp * 3.0, "fp {ppl_fp} vs q {ppl_q}");
        let bits = qm.avg_bits();
        assert!(bits > 4.0 && bits < 4.5, "avg bits {bits}");
        // 4-bit E8P = two 2-byte code stages per 8 weights → n_w / 2 bytes.
        let n_w: usize = qm.layers.values().map(|l| l.m * l.n).sum();
        assert_eq!(qm.packed_code_bytes(), (n_w / 2) as u64);
        // The generator convenience wires every packed layer in.
        assert_eq!(qm.generator().qlayers.len(), qm.layers.len());
        // The pool convenience matches the model geometry.
        let pool = qm.kv_pool(3);
        assert_eq!(pool.pages_total(), 3);
        let cfg = &qm.model.cfg;
        assert_eq!(
            pool.page_stride(),
            cfg.n_layers * 2 * crate::generation::paged::PAGE_ROWS * cfg.d_model
        );
    }

    #[test]
    fn method_ordering_on_tiny_model() {
        // 2-bit proxy error: QuIP# < no-E8 ablation (the Table 4 ordering).
        let model = tiny_model(2);
        let toks = calib_tokens();
        let hs = collect_hessians(&model, &toks, 4, 32);
        let qs = quantize_model(&model, &hs, &Method::QuipSharp { bits: 2, ft: false }, 7)
            .unwrap()
            .mean_proxy_rel();
        let noe8 = quantize_model(&model, &hs, &Method::QuipSharpNoE8 { bits: 2 }, 7)
            .unwrap()
            .mean_proxy_rel();
        assert!(qs < noe8, "quip# {qs} !< no-e8 {noe8}");
    }

    #[test]
    fn deterministic_given_seed() {
        let model = tiny_model(3);
        let toks = calib_tokens();
        let hs = collect_hessians(&model, &toks, 2, 32);
        let a = quantize_model(&model, &hs, &Method::QuipSharp { bits: 2, ft: false }, 42)
            .unwrap();
        let b = quantize_model(&model, &hs, &Method::QuipSharp { bits: 2, ft: false }, 42)
            .unwrap();
        for (name, la) in &a.layers {
            let lb = &b.layers[name];
            assert_eq!(la.w_eff, lb.w_eff, "layer {name} differs");
        }
    }
}
