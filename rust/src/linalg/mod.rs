//! Dense linear algebra substrate: matrices, Cholesky and g-block LDL
//! decompositions, fast Walsh–Hadamard transform, Hadamard matrix
//! constructions (Sylvester / Paley I / Paley II), a real FFT for the RFFT
//! incoherence variant, and Kronecker products.

pub mod fft;
pub mod hadamard;
pub mod ldl;
pub mod matrix;

pub use matrix::Matrix;
