//! Matrix factorizations for adaptive rounding: Cholesky, the paper's
//! g-block LDL decomposition H = 𝐋ᵀ𝐃𝐋 (Section 4.1), and a Jacobi
//! symmetric eigensolver used to verify incoherence bounds and compute
//! tr(H^{1/2}) in tests.

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor C with A = C Cᵀ. Fails on non-PD input.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut c = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= c[(i, k)] * c[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("cholesky: not positive definite at pivot {i} (s={s})");
                }
                c[(i, j)] = s.sqrt();
            } else {
                c[(i, j)] = s / c[(j, j)];
            }
        }
    }
    Ok(c)
}

/// Solve A x = b given the Cholesky factor C (A = C Cᵀ).
pub fn cholesky_solve(c: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = c.rows;
    assert_eq!(b.len(), n);
    // Forward solve C y = b.
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= c[(i, k)] * y[k];
        }
        y[i] = s / c[(i, i)];
    }
    // Back solve Cᵀ x = y.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= c[(k, i)] * x[k];
        }
        x[i] = s / c[(i, i)];
    }
    x
}

/// Inverse of a symmetric positive definite matrix via Cholesky.
pub fn spd_inverse(a: &Matrix) -> Result<Matrix> {
    let n = a.rows;
    let c = cholesky(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e.iter_mut().for_each(|v| *v = 0.0);
        e[j] = 1.0;
        let col = cholesky_solve(&c, &e);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    Ok(inv.symmetrize())
}

/// The paper's g-block LDL decomposition (Section 4.1):
/// H = 𝐋ᵀ 𝐃 𝐋 with 𝐋 unit *block lower* triangular and 𝐃 block diagonal.
///
/// We compute the equivalent U 𝐃 Uᵀ factorization with U = 𝐋ᵀ unit block
/// *upper* triangular by block elimination from the bottom-right corner.
/// BlockLDLQ's linear feedback matrix is then `U - I` (strictly block
/// upper), whose k-th block column feeds quantization of block k from the
/// rounding residual of blocks < k.
pub struct BlockLdl {
    /// Unit block-upper-triangular U (n×n), U = 𝐋ᵀ.
    pub u: Matrix,
    /// Diagonal blocks of 𝐃, each g×g.
    pub d: Vec<Matrix>,
    pub g: usize,
}

impl BlockLdl {
    /// Reconstruct H = U 𝐃 Uᵀ (tests).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.u.rows;
        let g = self.g;
        let nb = n / g;
        let mut dfull = Matrix::zeros(n, n);
        for (k, dk) in self.d.iter().enumerate() {
            dfull.set_block(k, k, g, dk);
        }
        let _ = nb;
        self.u.matmul(&dfull).matmul_transb(&self.u)
    }

    /// tr(𝐃) — the quantity Theorem 4.1 bounds.
    pub fn trace_d(&self) -> f64 {
        self.d.iter().map(|dk| dk.trace()).sum()
    }
}

/// Compute the g-block LDL decomposition of symmetric positive definite H.
/// `n` must be divisible by `g`. A tiny ridge is added automatically if a
/// diagonal block is numerically singular.
pub fn block_ldl(h: &Matrix, g: usize) -> Result<BlockLdl> {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    assert!(g >= 1 && n % g == 0, "block size {g} must divide n={n}");
    let nb = n / g;
    let mut a = h.clone();
    let mut u = Matrix::eye(n);
    let mut d = vec![Matrix::zeros(g, g); nb];

    // Scratch for the per-step U_{·k} panel (k blocks of g×g, row-major
    // per block) and its D_k-scaled copy — avoids per-block allocations
    // in the O(nb³) Schur update.
    let mut uk = vec![0.0f64; nb * g * g];
    let mut ukd = vec![0.0f64; nb * g * g];

    for k in (0..nb).rev() {
        let dk = a.block(k, k, g).symmetrize();
        // Invert D_k (with escalating ridge on numerical failure).
        let dk_inv = match spd_inverse(&dk) {
            Ok(inv) => inv,
            Err(_) => {
                let ridge = 1e-8 * (dk.trace().abs() / g as f64).max(1e-12);
                let mut dk2 = dk.clone();
                for i in 0..g {
                    dk2[(i, i)] += ridge;
                }
                spd_inverse(&dk2)?
            }
        };
        // U_{ik} = A_{ik} D_k^{-1} and (U_{ik} D_k) for i < k.
        for i in 0..k {
            for r in 0..g {
                for c in 0..g {
                    let mut acc = 0.0;
                    for t in 0..g {
                        acc += a[(i * g + r, k * g + t)] * dk_inv[(t, c)];
                    }
                    uk[(i * g + r) * g + c] = acc;
                }
            }
            // ukd_i = uk_i · D_k
            for r in 0..g {
                for c in 0..g {
                    let mut acc = 0.0;
                    for t in 0..g {
                        acc += uk[(i * g + r) * g + t] * dk[(t, c)];
                    }
                    ukd[(i * g + r) * g + c] = acc;
                }
            }
            for r in 0..g {
                for c in 0..g {
                    u[(i * g + r, k * g + c)] = uk[(i * g + r) * g + c];
                }
            }
        }
        d[k] = dk;
        if k == 0 {
            continue;
        }
        // Schur update A_{ij} -= (U_{ik} D_k) U_{jk}ᵀ for i,j < k,
        // parallel over block-rows i (disjoint row slices of `a`).
        let cols = a.cols;
        let uk_ref = &uk;
        let ukd_ref = &ukd;
        crate::util::threadpool::par_rows(&mut a.data[..k * g * cols], g * cols, |i, arows| {
            // arows = rows i·g .. (i+1)·g of A.
            let ukd_i = &ukd_ref[i * g * g..(i + 1) * g * g];
            for j in 0..k {
                let uk_j = &uk_ref[j * g * g..(j + 1) * g * g];
                for r in 0..g {
                    let arow = &mut arows[r * cols + j * g..r * cols + (j + 1) * g];
                    for (c, av) in arow.iter_mut().enumerate() {
                        let mut acc = 0.0;
                        for t in 0..g {
                            acc += ukd_i[r * g + t] * uk_j[c * g + t];
                        }
                        *av -= acc;
                    }
                }
            }
        });
    }
    Ok(BlockLdl { u, d, g })
}

/// Jacobi eigenvalue algorithm for symmetric matrices. Returns
/// (eigenvalues ascending, eigenvector matrix Q with columns = vectors),
/// A = Q diag(λ) Qᵀ. O(n³) per sweep — intended for test/verification
/// sizes (n ≲ a few hundred).
pub fn sym_eig(a: &Matrix) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.symmetrize();
    let mut q = Matrix::eye(n);
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // Off-diagonal magnitude.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..n {
            for r in p + 1..n {
                let apr = m[(p, r)];
                if apr.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let arr = m[(r, r)];
                let theta = 0.5 * (arr - app) / apr;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation G(p,r,θ) on both sides.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkr = m[(k, r)];
                    m[(k, p)] = c * mkp - s * mkr;
                    m[(k, r)] = s * mkp + c * mkr;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mrk = m[(r, k)];
                    m[(p, k)] = c * mpk - s * mrk;
                    m[(r, k)] = s * mpk + c * mrk;
                }
                for k in 0..n {
                    let qkp = q[(k, p)];
                    let qkr = q[(k, r)];
                    q[(k, p)] = c * qkp - s * qkr;
                    q[(k, r)] = s * qkp + c * qkr;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    let vals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| vals[i].partial_cmp(&vals[j]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| vals[i]).collect();
    let mut qs = Matrix::zeros(n, n);
    for (newc, &oldc) in order.iter().enumerate() {
        for i in 0..n {
            qs[(i, newc)] = q[(i, oldc)];
        }
    }
    (sorted_vals, qs)
}

/// tr(A^{1/2}) for symmetric PSD A via eigenvalues (test sizes).
pub fn trace_sqrt(a: &Matrix) -> f64 {
    let (vals, _) = sym_eig(a);
    vals.iter().map(|&v| v.max(0.0).sqrt()).sum()
}

/// Generate a random symmetric positive definite matrix (test helper):
/// B Bᵀ / n + ridge I with B gaussian.
pub fn random_spd(n: usize, ridge: f64, rng: &mut crate::util::rng::Pcg64) -> Matrix {
    let b = Matrix::gaussian(n, n, 1.0, rng);
    let mut h = b.matmul_transb(&b).scale(1.0 / n as f64);
    for i in 0..n {
        h[(i, i)] += ridge;
    }
    h.symmetrize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;
    use crate::util::rng::Pcg64;

    #[test]
    fn cholesky_reconstructs() {
        check("cholesky", 10, |rng| {
            let n = 4 + rng.below_usize(20);
            let a = random_spd(n, 0.1, rng);
            let c = cholesky(&a).map_err(|e| e.to_string())?;
            let err = c.matmul_transb(&c).max_diff(&a);
            if err > 1e-9 {
                return Err(format!("n={n} err={err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn cholesky_solve_works() {
        check("chol_solve", 10, |rng| {
            let n = 3 + rng.below_usize(12);
            let a = random_spd(n, 0.1, rng);
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let b = a.matvec(&x);
            let c = cholesky(&a).map_err(|e| e.to_string())?;
            let got = cholesky_solve(&c, &b);
            for (g, w) in got.iter().zip(&x) {
                if (g - w).abs() > 1e-7 {
                    return Err(format!("solve mismatch {g} vs {w}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spd_inverse_works() {
        let mut rng = Pcg64::new(3);
        let a = random_spd(8, 0.2, &mut rng);
        let inv = spd_inverse(&a).unwrap();
        assert!(a.matmul(&inv).max_diff(&Matrix::eye(8)) < 1e-8);
    }

    #[test]
    fn block_ldl_reconstructs() {
        check("block_ldl", 10, |rng| {
            let gs = [1usize, 2, 4, 8];
            let g = gs[rng.below_usize(gs.len())];
            let nb = 1 + rng.below_usize(6);
            let n = g * nb;
            let h = random_spd(n, 0.1, rng);
            let f = block_ldl(&h, g).map_err(|e| e.to_string())?;
            let err = f.reconstruct().max_diff(&h);
            if err > 1e-8 {
                return Err(format!("g={g} n={n} err={err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn block_ldl_u_is_unit_block_upper() {
        let mut rng = Pcg64::new(5);
        let g = 4;
        let n = 16;
        let h = random_spd(n, 0.1, &mut rng);
        let f = block_ldl(&h, g).unwrap();
        for bi in 0..n / g {
            // Diagonal blocks are exactly identity.
            let diag = f.u.block(bi, bi, g);
            assert!(diag.max_diff(&Matrix::eye(g)) == 0.0);
            // Below-diagonal blocks are exactly zero.
            for bj in 0..bi {
                let b = f.u.block(bi, bj, g);
                assert!(b.max_abs() == 0.0);
            }
        }
    }

    #[test]
    fn block_ldl_g1_matches_scalar_ldl_semantics() {
        // For g=1 the factorization must satisfy H = U diag(d) Uᵀ with unit
        // upper-triangular U.
        let mut rng = Pcg64::new(6);
        let h = random_spd(6, 0.2, &mut rng);
        let f = block_ldl(&h, 1).unwrap();
        assert!(f.reconstruct().max_diff(&h) < 1e-9);
        for dk in &f.d {
            assert!(dk[(0, 0)] > 0.0, "pivots must be positive for PD input");
        }
    }

    #[test]
    fn sym_eig_reconstructs_and_orthogonal() {
        let mut rng = Pcg64::new(7);
        let a = random_spd(12, 0.05, &mut rng);
        let (vals, q) = sym_eig(&a);
        // Q orthogonal.
        assert!(q.matmul_transb(&q).max_diff(&Matrix::eye(12)) < 1e-8);
        // Reconstruct.
        let mut lam = Matrix::zeros(12, 12);
        for i in 0..12 {
            lam[(i, i)] = vals[i];
        }
        let rec = q.matmul(&lam).matmul_transb(&q);
        assert!(rec.max_diff(&a) < 1e-8);
        // PSD input → nonnegative eigenvalues (sorted ascending).
        assert!(vals[0] > 0.0);
        assert!(vals.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn trace_sqrt_of_identity() {
        let i = Matrix::eye(9);
        assert!((trace_sqrt(&i) - 9.0).abs() < 1e-9);
    }

    #[test]
    fn trace_d_le_trace_h() {
        // tr(D) ≤ tr(H): LDL pivots are Schur complements.
        check("trace_d_bound", 10, |rng| {
            let g = 2;
            let n = 12;
            let h = random_spd(n, 0.1, rng);
            let f = block_ldl(&h, g).map_err(|e| e.to_string())?;
            if f.trace_d() > h.trace() + 1e-9 {
                return Err(format!("tr(D)={} > tr(H)={}", f.trace_d(), h.trace()));
            }
            Ok(())
        });
    }
}
