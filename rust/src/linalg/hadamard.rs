//! Hadamard machinery for incoherence processing (paper §3).
//!
//! * `fwht` — in-place fast Walsh–Hadamard transform, O(n log n), power-of-2
//!   lengths, no floating multiplies in the butterfly (paper's constant-
//!   factor argument).
//! * `hadamard_matrix` — explicit ±1 Hadamard matrices via Sylvester
//!   doubling and the two Paley constructions, covering every size this
//!   repo needs (12, 20, 28, ... and all powers of two).
//! * `HadTransform` — the paper's n = p·q scheme: V = H_q ⊗ H_p with p the
//!   largest power of 2 dividing n such that H_{n/p} exists; applies the
//!   orthogonal (scaled) transform in O(q²·p + n·log p) per vector.

use super::matrix::Matrix;

/// In-place unnormalized FWHT; `x.len()` must be a power of two.
/// After the call, x <- H_n x with H the ±1 Sylvester matrix.
pub fn fwht(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht length {n} not a power of 2");
    let mut h = 1;
    while h < n {
        let step = h * 2;
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += step;
        }
        h = step;
    }
}

/// f32 variant for the inference hot path.
pub fn fwht_f32(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht length {n} not a power of 2");
    let mut h = 1;
    while h < n {
        let step = h * 2;
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += step;
        }
        h = step;
    }
}

/// Orthogonal (1/sqrt n scaled) FWHT.
pub fn fwht_normalized(x: &mut [f64]) {
    fwht(x);
    let s = 1.0 / (x.len() as f64).sqrt();
    for v in x.iter_mut() {
        *v *= s;
    }
}

fn is_prime(n: usize) -> bool {
    if n < 2 {
        return false;
    }
    let mut d = 2;
    while d * d <= n {
        if n % d == 0 {
            return false;
        }
        d += 1;
    }
    true
}

/// Legendre symbol chi(a) over GF(p): 0 if a≡0, +1 if QR, -1 otherwise.
fn legendre(a: i64, p: i64) -> i64 {
    let a = a.rem_euclid(p);
    if a == 0 {
        return 0;
    }
    // Euler's criterion by fast modular exponentiation.
    let mut base = a as u128;
    let mut exp = ((p - 1) / 2) as u128;
    let m = p as u128;
    let mut acc: u128 = 1;
    base %= m;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = acc * base % m;
        }
        base = base * base % m;
        exp >>= 1;
    }
    if acc == 1 {
        1
    } else {
        -1
    }
}

/// Paley construction I: for prime p ≡ 3 (mod 4), returns H_{p+1}.
fn paley1(p: usize) -> Matrix {
    let n = p + 1;
    // Jacobsthal matrix Q_{ij} = chi(i - j).
    let mut h = Matrix::zeros(n, n);
    // Border row/col of +1, then I + Q inside with sign conventions:
    // H = [[1, 1^T], [-1, Q + I]] gives a Hadamard matrix for p≡3 mod 4
    // (one of the standard normalizations).
    for j in 0..n {
        h[(0, j)] = 1.0;
    }
    for i in 1..n {
        h[(i, 0)] = -1.0;
    }
    for i in 1..n {
        for j in 1..n {
            let q = legendre(i as i64 - j as i64, p as i64) as f64;
            h[(i, j)] = if i == j { 1.0 } else { q };
        }
    }
    h
}

/// Paley construction II: for prime p ≡ 1 (mod 4), returns H_{2(p+1)}.
fn paley2(p: usize) -> Matrix {
    let m = p + 1;
    // Symmetric conference matrix C of order p+1 (C^T C = p I, zero diag).
    let mut c = Matrix::zeros(m, m);
    for j in 1..m {
        c[(0, j)] = 1.0;
        c[(j, 0)] = 1.0;
    }
    for i in 1..m {
        for j in 1..m {
            if i != j {
                c[(i, j)] = legendre(i as i64 - j as i64, p as i64) as f64;
            }
        }
    }
    // Replace entries: 0 -> [[1,-1],[-1,-1]], +1 -> [[1,1],[1,-1]],
    // -1 -> -[[1,1],[1,-1]].
    let n = 2 * m;
    let mut h = Matrix::zeros(n, n);
    for i in 0..m {
        for j in 0..m {
            let (a, b, cc, d) = match c[(i, j)] as i64 {
                0 => (1.0, -1.0, -1.0, -1.0),
                1 => (1.0, 1.0, 1.0, -1.0),
                -1 => (-1.0, -1.0, -1.0, 1.0),
                _ => unreachable!(),
            };
            h[(2 * i, 2 * j)] = a;
            h[(2 * i, 2 * j + 1)] = b;
            h[(2 * i + 1, 2 * j)] = cc;
            h[(2 * i + 1, 2 * j + 1)] = d;
        }
    }
    h
}

/// Construct a ±1 Hadamard matrix of order `n`, if this library knows how:
/// n = 1, 2, or any n ≡ 0 (mod 4) reachable by Sylvester doubling over a
/// Paley I/II base. Returns None otherwise (the RFFT path is the fallback,
/// as in the paper).
pub fn hadamard_matrix(n: usize) -> Option<Matrix> {
    match n {
        0 => None,
        1 => Some(Matrix::from_vec(1, 1, vec![1.0])),
        2 => Some(Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, -1.0])),
        n if n % 4 != 0 => None,
        n => {
            // Powers of two take the Sylvester construction so the dense
            // matrix agrees with the FWHT butterfly ordering.
            if n.is_power_of_two() {
                let h2 = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, -1.0]);
                return Some(h2.kron(&hadamard_matrix(n / 2).unwrap()));
            }
            if n - 1 > 2 && is_prime(n - 1) && (n - 1) % 4 == 3 {
                return Some(paley1(n - 1));
            }
            if n % 2 == 0 {
                let half = n / 2;
                if half >= 2 && is_prime(half - 1) && (half - 1) % 4 == 1 {
                    return Some(paley2(half - 1));
                }
                if let Some(h) = hadamard_matrix(half) {
                    let h2 = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, -1.0]);
                    return Some(h2.kron(&h));
                }
            }
            None
        }
    }
}

/// Check H H^T = n I exactly (entries are ±1 so the products are integers).
pub fn is_hadamard(h: &Matrix) -> bool {
    if h.rows != h.cols {
        return false;
    }
    if h.data.iter().any(|&v| v != 1.0 && v != -1.0) {
        return false;
    }
    let n = h.rows;
    let prod = h.matmul_transb(h);
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { n as f64 } else { 0.0 };
            if (prod[(i, j)] - want).abs() > 1e-9 {
                return false;
            }
        }
    }
    true
}

/// The orthogonal structured transform used by incoherence processing:
/// V = (H_q ⊗ H_p) / sqrt(n), where p = 2^a is the power-of-2 part of n
/// (reduced until H_{n/p} is constructible) and H_q is an explicit
/// Hadamard matrix. For power-of-2 n this degenerates to the pure FWHT.
#[derive(Clone, Debug)]
pub struct HadTransform {
    pub n: usize,
    /// power-of-2 factor (FWHT part)
    pub p: usize,
    /// explicit-matrix factor; `hq` is None when q == 1
    pub q: usize,
    hq: Option<Matrix>,
}

impl HadTransform {
    /// Build the transform for dimension n, or None when n has no
    /// factorization n = q·2^a with H_q constructible.
    pub fn new(n: usize) -> Option<Self> {
        assert!(n > 0);
        // Largest power of two dividing n.
        let mut p = 1usize << n.trailing_zeros();
        let mut q = n / p;
        // Grow q by powers of two until H_q is constructible (paper: "p is
        // the largest power of 2 such that there exists a known Hadamard
        // matrix of size q").
        loop {
            if q == 1 {
                return Some(HadTransform { n, p, q, hq: None });
            }
            if let Some(hq) = hadamard_matrix(q) {
                return Some(HadTransform { n, p, q, hq: Some(hq) });
            }
            if p == 1 {
                return None;
            }
            p /= 2;
            q *= 2;
        }
    }

    /// Apply the orthogonal transform in place: x <- (H_q ⊗ H_p) x / sqrt(n).
    ///
    /// With x viewed row-major as a (q, p) matrix X, (H_q ⊗ H_p) x equals
    /// H_q · X · H_p^T flattened; H_p is symmetric so the second factor is a
    /// row-wise FWHT, and H_q is applied densely across the q rows
    /// (O(q²·p)). Total O(q²·p + n·log p), matching the paper's cost model.
    pub fn apply(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        // Row-wise FWHT over the p-sized rows.
        if self.p > 1 {
            for row in x.chunks_mut(self.p) {
                fwht(row);
            }
        }
        // Dense H_q across rows (column mixing), skipped when q == 1.
        if let Some(hq) = &self.hq {
            let p = self.p;
            let q = self.q;
            let mut col = vec![0.0f64; q];
            let mut out = vec![0.0f64; q];
            for c in 0..p {
                for r in 0..q {
                    col[r] = x[r * p + c];
                }
                for r in 0..q {
                    let hrow = hq.row(r);
                    let mut acc = 0.0;
                    for k in 0..q {
                        acc += hrow[k] * col[k];
                    }
                    out[r] = acc;
                }
                for r in 0..q {
                    x[r * p + c] = out[r];
                }
            }
        }
        let s = 1.0 / (self.n as f64).sqrt();
        for v in x.iter_mut() {
            *v *= s;
        }
    }

    /// Inverse transform. The scaled transform is orthogonal and symmetric
    /// only in the pure power-of-2 case; in general the inverse is the
    /// transpose, applied here explicitly.
    pub fn apply_inverse(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        // Inverse of H_q ⊗ H_p (scaled orthogonal) is its transpose:
        // (H_q^T ⊗ H_p^T)/sqrt(n). H_p (Sylvester) is symmetric; H_q from
        // Paley II is symmetric but Paley I is not, so use hq^T.
        if self.p > 1 {
            for row in x.chunks_mut(self.p) {
                fwht(row); // H_p^T = H_p
            }
        }
        if let Some(hq) = &self.hq {
            let p = self.p;
            let q = self.q;
            let mut col = vec![0.0f64; q];
            let mut out = vec![0.0f64; q];
            for c in 0..p {
                for r in 0..q {
                    col[r] = x[r * p + c];
                }
                for r in 0..q {
                    let mut acc = 0.0;
                    for k in 0..q {
                        acc += hq[(k, r)] * col[k]; // hq^T
                    }
                    out[r] = acc;
                }
                for r in 0..q {
                    x[r * p + c] = out[r];
                }
            }
        }
        let s = 1.0 / (self.n as f64).sqrt();
        for v in x.iter_mut() {
            *v *= s;
        }
    }

    /// Materialize the scaled orthogonal matrix (tests / small dims only).
    pub fn dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for j in 0..self.n {
            let mut e = vec![0.0; self.n];
            e[j] = 1.0;
            self.apply(&mut e);
            for i in 0..self.n {
                m[(i, j)] = e[i];
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;
    use crate::util::rng::Pcg64;

    #[test]
    fn fwht_matches_dense_hadamard() {
        let n = 16;
        let h = hadamard_matrix(n).unwrap();
        let mut rng = Pcg64::new(1);
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        let want = h.matvec(&x);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn fwht_selfinverse_scaled() {
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..64).map(|_| rng.gaussian()).collect();
        let mut y = x.clone();
        fwht_normalized(&mut y);
        fwht_normalized(&mut y);
        for (a, b) in y.iter().zip(&x) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn paley1_sizes_are_hadamard() {
        for n in [4, 12, 20, 24, 28, 44] {
            let h = hadamard_matrix(n).unwrap_or_else(|| panic!("no H_{n}"));
            assert!(is_hadamard(&h), "H_{n} failed orthogonality");
        }
    }

    #[test]
    fn sylvester_powers_are_hadamard() {
        for n in [1, 2, 4, 8, 16, 32, 64, 128] {
            let h = hadamard_matrix(n).unwrap();
            assert!(is_hadamard(&h), "H_{n} failed");
        }
    }

    #[test]
    fn paley2_from_p13_gives_h28() {
        let h = hadamard_matrix(28).unwrap();
        assert!(is_hadamard(&h));
    }

    #[test]
    fn no_hadamard_for_non_multiple_of_4() {
        assert!(hadamard_matrix(6).is_none());
        assert!(hadamard_matrix(10).is_none());
    }

    #[test]
    fn had_transform_orthogonal_for_model_dims() {
        // Every dimension the model family uses, incl. non-powers of 2.
        for n in [128usize, 256, 384, 512, 1024, 1536, 96, 12, 24] {
            let t = HadTransform::new(n).unwrap_or_else(|| panic!("no transform for {n}"));
            let d = t.dense();
            let prod = d.matmul_transb(&d);
            let err = prod.max_diff(&Matrix::eye(n));
            assert!(err < 1e-9, "n={n} not orthogonal, err={err}");
        }
    }

    #[test]
    fn had_transform_inverse_roundtrip() {
        check("had_inverse", 20, |rng| {
            let dims = [12usize, 32, 48, 96, 128, 384];
            let n = dims[rng.below_usize(dims.len())];
            let t = HadTransform::new(n).unwrap();
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let mut y = x.clone();
            t.apply(&mut y);
            t.apply_inverse(&mut y);
            for (i, (a, b)) in y.iter().zip(&x).enumerate() {
                if (a - b).abs() > 1e-9 {
                    return Err(format!("n={n} idx={i}: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn had_transform_preserves_norm() {
        check("had_norm", 20, |rng| {
            let dims = [20usize, 28, 64, 384, 1536];
            let n = dims[rng.below_usize(dims.len())];
            let t = HadTransform::new(n).unwrap();
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let norm0: f64 = x.iter().map(|v| v * v).sum();
            let mut y = x;
            t.apply(&mut y);
            let norm1: f64 = y.iter().map(|v| v * v).sum();
            if (norm0 - norm1).abs() > 1e-6 * norm0.max(1.0) {
                return Err(format!("n={n}: {norm0} vs {norm1}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pure_pow2_uses_fwht_only() {
        let t = HadTransform::new(256).unwrap();
        assert_eq!(t.q, 1);
        assert_eq!(t.p, 256);
    }

    #[test]
    fn dim_384_factors_as_12_times_32() {
        let t = HadTransform::new(384).unwrap();
        assert_eq!(t.q, 12);
        assert_eq!(t.p, 32);
    }

    #[test]
    fn fwht_f32_matches_f64() {
        let mut rng = Pcg64::new(7);
        let x64: Vec<f64> = (0..128).map(|_| rng.gaussian()).collect();
        let mut a: Vec<f64> = x64.clone();
        let mut b: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        fwht(&mut a);
        fwht_f32(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - *y as f64).abs() < 1e-3);
        }
    }
}
