//! Complex FFT substrate for the Randomized FFT incoherence variant
//! (paper Algorithm 4 / §A.2). Radix-2 iterative Cooley–Tukey for
//! power-of-two lengths plus Bluestein's chirp-z algorithm for arbitrary
//! lengths (needed because e.g. n = 384 reals → 192 complex points).

use std::f64::consts::PI;

/// In-place radix-2 FFT. `inverse` applies the conjugate transform
/// (unnormalized in both directions; see [`fft_unitary`]).
fn fft_pow2(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr0, vi0) = (re[i + k + len / 2], im[i + k + len / 2]);
                let vr = vr0 * cr - vi0 * ci;
                let vi = vr0 * ci + vi0 * cr;
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Arbitrary-length DFT via Bluestein: x_k = sum_j x_j e^{-2πi jk/n}
/// expressed as a convolution, evaluated with a power-of-2 FFT.
fn fft_bluestein(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    let m = (2 * n - 1).next_power_of_two();
    let sign = if inverse { 1.0 } else { -1.0 };
    // Chirp: w_j = e^{sign * πi j² / n}
    let chirp: Vec<(f64, f64)> = (0..n)
        .map(|j| {
            // j² mod 2n avoids precision loss for large j.
            let jj = (j * j) % (2 * n);
            let ang = sign * PI * jj as f64 / n as f64;
            (ang.cos(), ang.sin())
        })
        .collect();
    // a_j = x_j * w_j
    let mut are = vec![0.0; m];
    let mut aim = vec![0.0; m];
    for j in 0..n {
        let (cr, ci) = chirp[j];
        are[j] = re[j] * cr - im[j] * ci;
        aim[j] = re[j] * ci + im[j] * cr;
    }
    // b_j = conj(w_j) with wraparound symmetry b_{m-j} = b_j
    let mut bre = vec![0.0; m];
    let mut bim = vec![0.0; m];
    for j in 0..n {
        let (cr, ci) = chirp[j];
        bre[j] = cr;
        bim[j] = -ci;
        if j > 0 {
            bre[m - j] = cr;
            bim[m - j] = -ci;
        }
    }
    // Convolution via pow2 FFT.
    fft_pow2(&mut are, &mut aim, false);
    fft_pow2(&mut bre, &mut bim, false);
    for j in 0..m {
        let r = are[j] * bre[j] - aim[j] * bim[j];
        let i = are[j] * bim[j] + aim[j] * bre[j];
        are[j] = r;
        aim[j] = i;
    }
    fft_pow2(&mut are, &mut aim, true);
    let scale = 1.0 / m as f64;
    for k in 0..n {
        let (cr, ci) = chirp[k];
        let (r, i) = (are[k] * scale, aim[k] * scale);
        re[k] = r * cr - i * ci;
        im[k] = r * ci + i * cr;
    }
}

/// Unnormalized DFT of any length (pow2 fast path, Bluestein otherwise).
pub fn fft(re: &mut [f64], im: &mut [f64], inverse: bool) {
    assert_eq!(re.len(), im.len());
    let n = re.len();
    if n <= 1 {
        return;
    }
    if n.is_power_of_two() {
        fft_pow2(re, im, inverse);
    } else {
        fft_bluestein(re, im, inverse);
    }
}

/// Unitary DFT: scaled by 1/sqrt(n) so that as an operator on R^{2n} it is
/// orthogonal — the property incoherence processing needs (Lemma A.3).
pub fn fft_unitary(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    fft(re, im, inverse);
    let s = 1.0 / (n as f64).sqrt();
    for v in re.iter_mut() {
        *v *= s;
    }
    for v in im.iter_mut() {
        *v *= s;
    }
}

/// Naive O(n²) DFT (test oracle).
pub fn dft_naive(re: &[f64], im: &[f64], inverse: bool) -> (Vec<f64>, Vec<f64>) {
    let n = re.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut ore = vec![0.0; n];
    let mut oim = vec![0.0; n];
    for k in 0..n {
        for j in 0..n {
            let ang = sign * 2.0 * PI * (j * k % n) as f64 / n as f64;
            let (c, s) = (ang.cos(), ang.sin());
            ore[k] += re[j] * c - im[j] * s;
            oim[k] += re[j] * s + im[j] * c;
        }
    }
    (ore, oim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;

    fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() < tol)
    }

    #[test]
    fn pow2_matches_naive() {
        check("fft_pow2_naive", 10, |rng| {
            let n = 1usize << (1 + rng.below_usize(6));
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (wr, wi) = dft_naive(&re, &im, false);
            let (mut gr, mut gi) = (re, im);
            fft(&mut gr, &mut gi, false);
            if !close(&gr, &wr, 1e-8) || !close(&gi, &wi, 1e-8) {
                return Err(format!("n={n} mismatch"));
            }
            Ok(())
        });
    }

    #[test]
    fn bluestein_matches_naive() {
        check("fft_bluestein_naive", 10, |rng| {
            let sizes = [3usize, 5, 6, 7, 12, 96, 192, 100];
            let n = sizes[rng.below_usize(sizes.len())];
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (wr, wi) = dft_naive(&re, &im, false);
            let (mut gr, mut gi) = (re, im);
            fft(&mut gr, &mut gi, false);
            if !close(&gr, &wr, 1e-7) || !close(&gi, &wi, 1e-7) {
                return Err(format!("n={n} mismatch"));
            }
            Ok(())
        });
    }

    #[test]
    fn unitary_roundtrip() {
        check("fft_unitary_roundtrip", 10, |rng| {
            let sizes = [8usize, 192, 64, 100, 768];
            let n = sizes[rng.below_usize(sizes.len())];
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (mut gr, mut gi) = (re.clone(), im.clone());
            fft_unitary(&mut gr, &mut gi, false);
            fft_unitary(&mut gr, &mut gi, true);
            if !close(&gr, &re, 1e-8) || !close(&gi, &im, 1e-8) {
                return Err(format!("n={n} roundtrip failed"));
            }
            Ok(())
        });
    }

    #[test]
    fn parseval_energy_preserved() {
        check("fft_parseval", 10, |rng| {
            let n = 192;
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im = vec![0.0; n];
            let e0: f64 = re.iter().map(|x| x * x).sum();
            let (mut gr, mut gi) = (re, im);
            fft_unitary(&mut gr, &mut gi, false);
            let e1: f64 = gr.iter().zip(&gi).map(|(r, i)| r * r + i * i).sum();
            if (e0 - e1).abs() > 1e-8 * e0.max(1.0) {
                return Err(format!("{e0} vs {e1}"));
            }
            Ok(())
        });
    }
}
