//! Dense row-major f64 matrix. Small-model scale (dims ≤ a few thousand),
//! so clarity over BLAS: straightforward loops with cache-friendly order
//! and thread-pool parallelism on the heavy products.

use crate::util::rng::Pcg64;
use crate::util::threadpool;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// iid N(0, sigma^2) entries.
    pub fn gaussian(rows: usize, cols: usize, sigma: f64, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.gaussian() * sigma).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other, parallel over rows of self. ikj loop order keeps the
    /// inner loop streaming over contiguous rows of `other`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        let cols = other.cols;
        let a = &self.data;
        let b = &other.data;
        let kdim = self.cols;
        threadpool::par_rows(&mut out.data, cols, |i, orow| {
            let arow = &a[i * kdim..(i + 1) * kdim];
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * cols..(kk + 1) * cols];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv;
                }
            }
        });
        out
    }

    /// self * other^T.
    pub fn matmul_transb(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_transb shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.rows);
        let a = &self.data;
        let b = &other.data;
        let kdim = self.cols;
        let cols = other.rows;
        threadpool::par_rows(&mut out.data, cols, |i, orow| {
            let arow = &a[i * kdim..(i + 1) * kdim];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b[j * kdim..(j + 1) * kdim];
                let mut acc = 0.0;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                *o = acc;
            }
        });
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Scale row i by `d[i]` (`diag(d) * self`).
    pub fn scale_rows(&self, d: &[f64]) -> Matrix {
        assert_eq!(d.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            for v in out.row_mut(i) {
                *v *= d[i];
            }
        }
        out
    }

    /// Scale col j by `d[j]` (`self * diag(d)`).
    pub fn scale_cols(&self, d: &[f64]) -> Matrix {
        assert_eq!(d.len(), self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            for (v, s) in out.row_mut(i).iter_mut().zip(d) {
                *v *= s;
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// (A + A^T) / 2 — clean up symmetric matrices drifting from roundoff.
    pub fn symmetrize(&self) -> Matrix {
        assert_eq!(self.rows, self.cols);
        Matrix::from_fn(self.rows, self.cols, |i, j| {
            0.5 * (self[(i, j)] + self[(j, i)])
        })
    }

    /// Kronecker product self ⊗ other.
    pub fn kron(&self, other: &Matrix) -> Matrix {
        let (r1, c1, r2, c2) = (self.rows, self.cols, other.rows, other.cols);
        let mut out = Matrix::zeros(r1 * r2, c1 * c2);
        for i in 0..r1 {
            for j in 0..c1 {
                let s = self[(i, j)];
                if s == 0.0 {
                    continue;
                }
                for k in 0..r2 {
                    for l in 0..c2 {
                        out[(i * r2 + k, j * c2 + l)] = s * other[(k, l)];
                    }
                }
            }
        }
        out
    }

    /// Extract the g×g block at block coordinates (bi, bj).
    pub fn block(&self, bi: usize, bj: usize, g: usize) -> Matrix {
        let mut b = Matrix::zeros(g, g);
        for i in 0..g {
            for j in 0..g {
                b[(i, j)] = self[(bi * g + i, bj * g + j)];
            }
        }
        b
    }

    pub fn set_block(&mut self, bi: usize, bj: usize, g: usize, b: &Matrix) {
        for i in 0..g {
            for j in 0..g {
                self[(bi * g + i, bj * g + j)] = b[(i, j)];
            }
        }
    }

    /// Max |self - other| entry.
    pub fn max_diff(&self, other: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(1);
        let a = Matrix::gaussian(5, 7, 1.0, &mut rng);
        let i = Matrix::eye(7);
        assert!(a.matmul(&i).max_diff(&a) < 1e-12);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_transb_matches() {
        let mut rng = Pcg64::new(2);
        let a = Matrix::gaussian(6, 4, 1.0, &mut rng);
        let b = Matrix::gaussian(5, 4, 1.0, &mut rng);
        let got = a.matmul_transb(&b);
        let want = a.matmul(&b.transpose());
        assert!(got.max_diff(&want) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(3);
        let a = Matrix::gaussian(4, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn kron_shape_and_values() {
        let a = Matrix::from_vec(1, 2, vec![2.0, 3.0]);
        let b = Matrix::eye(2);
        let k = a.kron(&b);
        assert_eq!((k.rows, k.cols), (2, 4));
        assert_eq!(k.data, vec![2.0, 0.0, 3.0, 0.0, 0.0, 2.0, 0.0, 3.0]);
    }

    #[test]
    fn kron_mixed_product_property() {
        // (A ⊗ B)(C ⊗ D) = (AC) ⊗ (BD)
        let mut rng = Pcg64::new(4);
        let a = Matrix::gaussian(2, 3, 1.0, &mut rng);
        let b = Matrix::gaussian(2, 2, 1.0, &mut rng);
        let c = Matrix::gaussian(3, 2, 1.0, &mut rng);
        let d = Matrix::gaussian(2, 2, 1.0, &mut rng);
        let lhs = a.kron(&b).matmul(&c.kron(&d));
        let rhs = a.matmul(&c).kron(&b.matmul(&d));
        assert!(lhs.max_diff(&rhs) < 1e-10);
    }

    #[test]
    fn block_roundtrip() {
        let mut rng = Pcg64::new(5);
        let a = Matrix::gaussian(6, 6, 1.0, &mut rng);
        let b = a.block(1, 2, 2);
        let mut a2 = a.clone();
        a2.set_block(1, 2, 2, &b);
        assert_eq!(a2, a);
    }

    #[test]
    fn scale_rows_cols() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.scale_rows(&[2.0, 3.0]).data, vec![2.0, 2.0, 3.0, 3.0]);
        assert_eq!(a.scale_cols(&[2.0, 3.0]).data, vec![2.0, 3.0, 2.0, 3.0]);
    }

    #[test]
    fn trace_and_norms() {
        let a = Matrix::from_vec(2, 2, vec![3.0, -4.0, 0.0, 1.0]);
        assert_eq!(a.trace(), 4.0);
        assert!((a.frob_norm() - (26.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
    }
}
