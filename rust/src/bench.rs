//! Benchmark harness — criterion substitute for the offline crate set.
//!
//! Provides warmup + repeated timed runs, robust statistics (median, p10,
//! p99), and throughput reporting (items/s, GB/s, % of a measured memcpy
//! roofline). Every `benches/*.rs` target (`harness = false`) and the
//! paper-table drivers use this.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark: per-iteration wall times.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<u64>,
    /// Bytes of memory traffic one iteration performs (for GB/s), if set.
    pub bytes_per_iter: Option<u64>,
    /// Logical items one iteration processes (for items/s), if set.
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> u64 {
        percentile(&self.samples_ns, 0.5)
    }

    pub fn p10_ns(&self) -> u64 {
        percentile(&self.samples_ns, 0.10)
    }

    pub fn p99_ns(&self) -> u64 {
        percentile(&self.samples_ns, 0.99)
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len().max(1) as f64
    }

    /// Effective memory bandwidth at the median, GB/s (1e9 bytes).
    pub fn gbps(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.median_ns() as f64)
    }

    pub fn items_per_sec(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n as f64 * 1e9 / self.median_ns() as f64)
    }

    /// One-line human report.
    pub fn report(&self) -> String {
        let med = self.median_ns();
        let mut s = format!(
            "{:<44} median {:>12}  p10 {:>12}  p99 {:>12}",
            self.name,
            fmt_ns(med),
            fmt_ns(self.p10_ns()),
            fmt_ns(self.p99_ns()),
        );
        if let Some(g) = self.gbps() {
            s.push_str(&format!("  {g:8.2} GB/s"));
        }
        if let Some(i) = self.items_per_sec() {
            s.push_str(&format!("  {i:12.1} items/s"));
        }
        s
    }
}

fn percentile(samples: &[u64], q: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let idx = ((v.len() - 1) as f64 * q).round() as usize;
    v[idx]
}

fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Benchmark runner with warmup and a time budget.
pub struct Bench {
    name: String,
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    bytes_per_iter: Option<u64>,
    items_per_iter: Option<u64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(700),
            min_iters: 5,
            max_iters: 10_000,
            bytes_per_iter: None,
            items_per_iter: None,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn budget(mut self, d: Duration) -> Self {
        self.budget = d;
        self
    }

    pub fn min_iters(mut self, n: usize) -> Self {
        self.min_iters = n;
        self
    }

    pub fn bytes(mut self, b: u64) -> Self {
        self.bytes_per_iter = Some(b);
        self
    }

    pub fn items(mut self, n: u64) -> Self {
        self.items_per_iter = Some(n);
        self
    }

    /// Run `f` repeatedly; each invocation is one sample. `f`'s return value
    /// is black-boxed so the computation is not optimized away.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed samples until budget exhausted (respecting min/max iters).
        let mut samples = Vec::new();
        let budget_start = Instant::now();
        while (samples.len() < self.min_iters)
            || (budget_start.elapsed() < self.budget && samples.len() < self.max_iters)
        {
            let s = Instant::now();
            black_box(f());
            samples.push(s.elapsed().as_nanos() as u64);
        }
        BenchResult {
            name: self.name,
            samples_ns: samples,
            bytes_per_iter: self.bytes_per_iter,
            items_per_iter: self.items_per_iter,
        }
    }
}

/// Measure the machine's practical single-thread memcpy bandwidth in GB/s —
/// the CPU analog of the paper's "peak memory bandwidth" (Table 5 reports
/// % of 1 TB/s on an RTX 4090). `size` should exceed LLC to measure DRAM.
pub fn memcpy_roofline_gbps(size: usize) -> f64 {
    let src = vec![1u8; size];
    let mut dst = vec![0u8; size];
    let res = Bench::new("memcpy")
        .bytes(2 * size as u64) // read + write
        .budget(Duration::from_millis(300))
        .run(|| {
            dst.copy_from_slice(black_box(&src));
            black_box(dst[size / 2])
        });
    res.gbps().unwrap()
}

/// Multi-threaded memcpy roofline (saturates the memory controller the way
/// the parallel matvec hot path does). Runs on the persistent worker pool,
/// so it measures the same dispatch machinery — and honors the same
/// `QUIPSHARP_THREADS` budget — as the decode kernels it is a ceiling for.
pub fn memcpy_roofline_mt_gbps(size: usize) -> f64 {
    use crate::util::threadpool;
    let src = vec![1u8; size];
    let mut dst = vec![0u8; size];
    let res = Bench::new("memcpy-mt")
        .bytes(2 * size as u64)
        .budget(Duration::from_millis(300))
        .run(|| {
            let dst_addr = dst.as_mut_ptr() as usize;
            threadpool::par_chunks(size, |start, end| {
                // SAFETY: par_chunks hands out disjoint [start, end)
                // ranges and blocks until every chunk completes, so each
                // byte of `dst` has exactly one writer and the borrow
                // outlives the dispatch barrier.
                let d = unsafe {
                    std::slice::from_raw_parts_mut((dst_addr as *mut u8).add(start), end - start)
                };
                d.copy_from_slice(black_box(&src[start..end]));
            });
            black_box(dst[size / 2])
        });
    res.gbps().unwrap()
}

/// Best-of-N timing: run `f` N times (each returning elapsed seconds)
/// and keep the minimum — the noise floor shared by the serving and
/// attention bench drivers.
pub fn best_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    (0..reps).map(|_| f()).fold(f64::INFINITY, f64::min)
}

/// Simple aligned table printer shared by the paper-table drivers.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = width[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", "-".repeat(width.iter().sum::<usize>() + 2 * ncol));
        for row in &self.rows {
            line(row);
        }
    }

    /// Write as CSV into `results/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("results")?;
        let mut s = String::new();
        s.push_str(&self.header.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        std::fs::write(format!("results/{name}.csv"), s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .budget(Duration::from_millis(10))
            .run(|| 1 + 1);
        assert!(r.samples_ns.len() >= 5);
        assert!(r.median_ns() < 1_000_000);
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples_ns: vec![5, 1, 9, 3, 7, 2, 8, 4, 6, 10],
            bytes_per_iter: Some(1000),
            items_per_iter: Some(10),
        };
        assert!(r.p10_ns() <= r.median_ns());
        assert!(r.median_ns() <= r.p99_ns());
        assert!(r.gbps().unwrap() > 0.0);
        assert!(r.items_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn table_prints_and_csv() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
        // CSV write goes to results/ of the CWD; use temp dir by chdir-free check:
        // just exercise the string path building via print above.
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500).contains("ns"));
        assert!(fmt_ns(5_000).contains("us"));
        assert!(fmt_ns(5_000_000).contains("ms"));
        assert!(fmt_ns(5_000_000_000).contains("s"));
    }
}
