//! Workload loading: token corpora and zeroshot tasks produced by
//! `python/compile/datagen.py` at build time (`.qtz` containers).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::tensorio::TensorFile;

/// A byte-token stream.
pub fn load_corpus(art: impl AsRef<Path>, name: &str) -> Result<Vec<u8>> {
    let tf = TensorFile::load(art.as_ref().join(format!("{name}.qtz")))
        .with_context(|| format!("loading corpus {name}"))?;
    let toks = tf.get("tokens")?.to_i32()?;
    Ok(toks.into_iter().map(|t| t as u8).collect())
}

/// One two-option likelihood-comparison example.
#[derive(Clone, Debug)]
pub struct ZeroshotExample {
    pub prefix: Vec<u8>,
    pub opt_a: Vec<u8>,
    pub opt_b: Vec<u8>,
    /// 0 if option A is correct, 1 if option B.
    pub label: usize,
}

/// A zeroshot task (our ArcE/ArcC/PiQA/Wino analogs).
pub struct ZeroshotTask {
    pub name: String,
    pub examples: Vec<ZeroshotExample>,
}

pub fn load_zeroshot(art: impl AsRef<Path>, task: &str) -> Result<ZeroshotTask> {
    let tf = TensorFile::load(art.as_ref().join(format!("zeroshot_{task}.qtz")))
        .with_context(|| format!("loading zeroshot task {task}"))?;
    let prefix = tf.get("prefix")?.to_i32()?;
    let opt_a = tf.get("opt_a")?.to_i32()?;
    let opt_b = tf.get("opt_b")?.to_i32()?;
    let p_len = tf.get("prefix_len")?.to_i32()?;
    let a_len = tf.get("a_len")?.to_i32()?;
    let b_len = tf.get("b_len")?.to_i32()?;
    let label = tf.get("label")?.to_i32()?;

    let mut examples = Vec::with_capacity(label.len());
    let (mut po, mut ao, mut bo) = (0usize, 0usize, 0usize);
    for i in 0..label.len() {
        let (pl, al, bl) = (p_len[i] as usize, a_len[i] as usize, b_len[i] as usize);
        examples.push(ZeroshotExample {
            prefix: prefix[po..po + pl].iter().map(|&t| t as u8).collect(),
            opt_a: opt_a[ao..ao + al].iter().map(|&t| t as u8).collect(),
            opt_b: opt_b[bo..bo + bl].iter().map(|&t| t as u8).collect(),
            label: label[i] as usize,
        });
        po += pl;
        ao += al;
        bo += bl;
    }
    Ok(ZeroshotTask {
        name: task.to_string(),
        examples,
    })
}

pub const ZEROSHOT_TASKS: [&str; 4] = ["arce", "arcc", "piqa", "wino"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensorio::{TensorData, TensorFile};

    #[test]
    fn zeroshot_roundtrip() {
        let dir = std::env::temp_dir();
        let mut tf = TensorFile::new();
        tf.insert("prefix", TensorData::from_i32(vec![5], &[1, 2, 3, 4, 5]));
        tf.insert("opt_a", TensorData::from_i32(vec![3], &[10, 11, 12]));
        tf.insert("opt_b", TensorData::from_i32(vec![2], &[20, 21]));
        tf.insert("prefix_len", TensorData::from_i32(vec![2], &[2, 3]));
        tf.insert("a_len", TensorData::from_i32(vec![2], &[1, 2]));
        tf.insert("b_len", TensorData::from_i32(vec![2], &[1, 1]));
        tf.insert("label", TensorData::from_i32(vec![2], &[0, 1]));
        tf.save(dir.join("zeroshot_fake.qtz")).unwrap();
        let task = load_zeroshot(&dir, "fake").unwrap();
        assert_eq!(task.examples.len(), 2);
        assert_eq!(task.examples[0].prefix, vec![1, 2]);
        assert_eq!(task.examples[1].prefix, vec![3, 4, 5]);
        assert_eq!(task.examples[1].opt_a, vec![11, 12]);
        assert_eq!(task.examples[1].label, 1);
        std::fs::remove_file(dir.join("zeroshot_fake.qtz")).ok();
    }
}
