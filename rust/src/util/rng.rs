//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction must be seed-deterministic (quantization sign
//! vectors, synthetic workloads, property tests), and the offline crate set
//! has no `rand`, so we carry our own generator: PCG-XSL-RR 128/64
//! ("pcg64"), O'Neill 2014. State is 128-bit LCG, output is a 64-bit
//! xorshift-rotate permutation of the state.

/// PCG64 generator. Cheap to construct, `Clone` to fork deterministic
/// sub-streams.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams for practical purposes.
    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator on an explicit stream (odd-ified internally), so
    /// one logical seed can fan out into independent substreams.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        // A few extra steps decorrelate small seeds.
        for _ in 0..4 {
            rng.step();
        }
        rng
    }

    /// Fork a child generator whose stream depends on `tag`; the parent
    /// advances by one draw. Used to give each layer / matrix / test case
    /// its own stream.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::new_stream(s ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Random sign in `{-1.0, +1.0}` (the paper's S_U / S_V entries).
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Random sign vector of length `n` — the RHT diagonal.
    pub fn sign_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.sign()).collect()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        // Draw until u1 is nonzero to keep ln finite.
        let mut u1 = self.f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.f64();
        }
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of iid N(0, sigma^2) f32 samples.
    pub fn gaussian_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| (self.gaussian() as f32) * sigma).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg64::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sign_vec_balanced() {
        let mut rng = Pcg64::new(5);
        let s = rng.sign_vec(10_000);
        let pos = s.iter().filter(|&&x| x > 0.0).count();
        assert!((pos as i64 - 5000).abs() < 300, "pos={pos}");
        assert!(s.iter().all(|&x| x == 1.0 || x == -1.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg64::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(1234);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
