//! Minimal JSON parser + emitter (serde/serde_json are not in the offline
//! crate set). Used for the AOT artifact manifest and the serving wire
//! protocol. Supports the full JSON data model; numbers are f64 (with an
//! integer fast path on emit).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`, or Null when missing / not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Builder: JSON object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Compact serialization.
    pub fn emit(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.emit()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": -1.5e2}"#).unwrap();
        assert_eq!(v.get("c").as_f64(), Some(-150.0));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
    }

    #[test]
    fn emit_deterministic_ordering() {
        let a = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(a.emit(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&v.emit()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ≤ wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ≤ wörld"));
    }

    #[test]
    fn get_missing_is_null() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert_eq!(*v.get("zz"), Json::Null);
        assert_eq!(v.get("zz").as_f64(), None);
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
    }
}
