//! Engine phase profiler: lightweight scoped wall-clock timers that
//! attribute decode time to the hot-path phases (quantized matmul, fused
//! attention, sampling, speculative draft/verify, KV cold-compress and
//! cold-decode), surfaced as the `phases` block of the serving `stats`
//! snapshot ([`crate::serve::Metrics`]).
//!
//! # Design: thread-local sink, outermost-wins
//!
//! A scheduler thread that wants attribution calls [`install`] once with
//! its metrics' [`PhaseAccum`]; every [`scope`] entered on that thread
//! then records its elapsed nanoseconds into the accumulator on drop.
//! Threads that never install a sink (worker-pool threads, library
//! callers, benches) pay only a thread-local depth bump and an
//! `Option::is_some` check per scope — no clock is read — which is what
//! keeps the instrumented kernels unmeasurable when profiling is off.
//!
//! **Outermost-wins**: only a depth-1 scope records. A speculative
//! draft/verify scope wraps whole batched decode calls, so the matmul /
//! attention / sampling scopes inside it stay inert and their time is
//! attributed to `spec_draft` / `spec_verify` inclusively. Every
//! recorded interval is therefore disjoint wall time of one thread,
//! which gives the invariant the stats snapshot relies on:
//! `Σ phase time ≤ scheduler-thread wall time ≤ uptime`, so
//! share-of-wall figures always sum to ≤ 100%.
//!
//! Timers are wall-clock (`Instant`), deliberately: the phases bound
//! kernels that dispatch onto the worker pool, and the scheduler-thread
//! wall time of a parallel section *is* its cost to the serving loop.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Number of [`Phase`] variants (the `PhaseAccum` slot count).
pub const PHASE_COUNT: usize = 7;

/// A hot-path phase of the serving decode loop. Wire names (snake_case,
/// via [`Phase::name`]) are pinned by the docs-drift test against the
/// `#### Phases` table in `rust/src/serve/README.md`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Quantized (or dense-fallback) linear layers, lm_head included.
    QuantMatmul,
    /// The blocked / fused batch attention pass, inline cold-page
    /// decode inside the walk included.
    Attention,
    /// Stochastic next-token selection (distribution build + draw;
    /// greedy argmax is not counted — it draws nothing).
    Sampling,
    /// Speculative draft rounds, inclusive of the draft model's matmul
    /// and attention time.
    SpecDraft,
    /// Speculative verify steps, inclusive of the target model's chunked
    /// decode.
    SpecVerify,
    /// KV cold-tier compression (`quantize_page`: E8P/RVQ re-encode).
    KvCompress,
    /// KV cold-tier re-heat (`reheat_page`: decode back to fp32 rows).
    KvDecode,
}

impl Phase {
    /// Every phase, in `PhaseAccum` slot order.
    pub const ALL: [Phase; PHASE_COUNT] = [
        Phase::QuantMatmul,
        Phase::Attention,
        Phase::Sampling,
        Phase::SpecDraft,
        Phase::SpecVerify,
        Phase::KvCompress,
        Phase::KvDecode,
    ];

    /// The snake_case wire name used in the stats `phases` block.
    pub fn name(self) -> &'static str {
        match self {
            Phase::QuantMatmul => "matmul",
            Phase::Attention => "attention",
            Phase::Sampling => "sampling",
            Phase::SpecDraft => "spec_draft",
            Phase::SpecVerify => "spec_verify",
            Phase::KvCompress => "kv_compress",
            Phase::KvDecode => "kv_decode",
        }
    }
}

/// Per-phase cumulative nanosecond counters. Lock-free: the owning
/// scheduler thread adds, any number of stats threads read.
#[derive(Debug)]
pub struct PhaseAccum {
    nanos: [AtomicU64; PHASE_COUNT],
}

impl Default for PhaseAccum {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseAccum {
    pub fn new() -> Self {
        PhaseAccum {
            nanos: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Add `nanos` of wall time to `phase` (called from guard drops).
    pub fn add(&self, phase: Phase, nanos: u64) {
        self.nanos[phase as usize].fetch_add(nanos, Ordering::Relaxed);
    }

    /// Cumulative nanoseconds recorded for `phase`.
    pub fn nanos(&self, phase: Phase) -> u64 {
        self.nanos[phase as usize].load(Ordering::Relaxed)
    }

    /// Cumulative nanoseconds over all phases. Because only depth-1
    /// scopes record, this never exceeds the recording thread's wall
    /// time.
    pub fn total_nanos(&self) -> u64 {
        Phase::ALL.iter().map(|&p| self.nanos(p)).sum()
    }
}

thread_local! {
    static SINK: RefCell<Option<Arc<PhaseAccum>>> = const { RefCell::new(None) };
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// Route this thread's depth-1 [`scope`] timings into `accum` (the
/// engine scheduler calls this once at thread start when profiling is
/// on). Replaces any previously installed sink.
pub fn install(accum: Arc<PhaseAccum>) {
    SINK.with(|s| *s.borrow_mut() = Some(accum));
}

/// Remove this thread's sink; later scopes stop recording.
pub fn uninstall() {
    SINK.with(|s| *s.borrow_mut() = None);
}

/// Open a scoped timer for `phase`. Hold the guard for the duration of
/// the phase (`let _scope = phase::scope(...)`); it records on drop if
/// and only if this thread has a sink installed **and** this is the
/// outermost scope on the thread.
#[must_use]
pub fn scope(phase: Phase) -> PhaseGuard {
    let depth = DEPTH.with(|d| {
        let v = d.get() + 1;
        d.set(v);
        v
    });
    let start = if depth == 1 && SINK.with(|s| s.borrow().is_some()) {
        Some(Instant::now())
    } else {
        None
    };
    PhaseGuard { phase, start }
}

/// RAII guard from [`scope`]; records elapsed wall time on drop.
#[derive(Debug)]
pub struct PhaseGuard {
    phase: Phase,
    start: Option<Instant>,
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        DEPTH.with(|d| d.set(d.get() - 1));
        if let Some(t0) = self.start.take() {
            let ns = t0.elapsed().as_nanos() as u64;
            SINK.with(|s| {
                if let Some(a) = s.borrow().as_ref() {
                    a.add(self.phase, ns);
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn no_sink_records_nothing() {
        let a = Arc::new(PhaseAccum::new());
        {
            let _s = scope(Phase::QuantMatmul);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(a.total_nanos(), 0);
    }

    #[test]
    fn outermost_scope_wins() {
        // Run on a dedicated thread so install() cannot leak into other
        // tests sharing this test thread.
        let a = Arc::new(PhaseAccum::new());
        let acc = a.clone();
        std::thread::spawn(move || {
            install(acc);
            {
                let _outer = scope(Phase::SpecDraft);
                {
                    // Inner scopes are inert: their time lands on the
                    // enclosing phase.
                    let _inner = scope(Phase::QuantMatmul);
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            {
                let _solo = scope(Phase::Attention);
                std::thread::sleep(Duration::from_millis(1));
            }
            uninstall();
        })
        .join()
        .unwrap();
        assert_eq!(a.nanos(Phase::QuantMatmul), 0);
        assert!(a.nanos(Phase::SpecDraft) >= 1_000_000);
        assert!(a.nanos(Phase::Attention) >= 500_000);
        assert_eq!(
            a.total_nanos(),
            a.nanos(Phase::SpecDraft) + a.nanos(Phase::Attention)
        );
    }

    #[test]
    fn names_are_distinct_and_ordered() {
        let names: Vec<&str> = Phase::ALL.iter().map(|&p| p.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), PHASE_COUNT);
        for (i, &p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p as usize, i, "ALL order must match slot order");
        }
    }
}
