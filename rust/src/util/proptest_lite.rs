//! Seeded property-testing helper (proptest is not in the offline crate
//! set). Each case gets a deterministic RNG derived from the case index; a
//! failing property reports the case index and message so the exact case
//! replays by construction.

use super::rng::Pcg64;

/// Run `prop` over `cases` deterministic random cases. `prop` returns
/// `Err(msg)` (or panics) to fail; the harness re-raises with the replay
/// seed in the message.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg64::new_stream(0xC0FFEE ^ case, case.wrapping_mul(2) + 1);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case}: {msg}");
        }
    }
}

/// Assert helper producing `Result<(), String>` bodies for `check`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate-equality helper for floating point slices.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 16, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'failer'")]
    fn check_reports_failure() {
        check("failer", 8, |rng| {
            let x = rng.below(4);
            if x == 3 {
                Err("hit 3".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_behaviour() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
