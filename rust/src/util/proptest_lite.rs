//! Seeded property-testing helper (proptest is not in the offline crate
//! set). Each case gets a deterministic RNG derived from the case index; a
//! failing property reports the case index and message so the exact case
//! replays by construction.
//!
//! Also home to the statistical assertion helpers behind the stochastic
//! decode tests ([`tv_distance`] / [`chi_square_stat`] /
//! [`assert_histogram_close`]): empirical token histograms against their
//! expected distributions, with bounds *derived* from the sample count
//! and support size rather than hand-tuned — and every caller draws from
//! a fixed-seed RNG, so the checks are deterministic, never flaky.

use super::rng::Pcg64;

/// Run `prop` over `cases` deterministic random cases. `prop` returns
/// `Err(msg)` (or panics) to fail; the harness re-raises with the replay
/// seed in the message.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg64::new_stream(0xC0FFEE ^ case, case.wrapping_mul(2) + 1);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case}: {msg}");
        }
    }
}

/// Assert helper producing `Result<(), String>` bodies for `check`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate-equality helper for floating point slices.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Total-variation distance `½ Σ |p_i − q_i|` between two normalized
/// distributions of equal support.
pub fn tv_distance(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len(), "TV over mismatched supports");
    0.5 * p.iter().zip(q).map(|(&a, &b)| (a - b).abs()).sum::<f64>()
}

/// Normalize a count histogram into an empirical distribution.
pub fn empirical_dist(counts: &[u64]) -> Vec<f64> {
    let n: u64 = counts.iter().sum();
    counts.iter().map(|&c| c as f64 / n.max(1) as f64).collect()
}

/// Derived TV budget for `n` iid draws from a `k`-outcome distribution:
/// `E[TV] ≤ √(k / 4n)` (Cauchy–Schwarz over per-bin binomial standard
/// deviations) plus a `√(ln(1/δ) / 2n)` McDiarmid concentration term at
/// `δ = 10⁻⁶`. A correct sampler stays under this for all but a ~1e-6
/// sliver of seeds — and the callers' seeds are fixed, so a pass is a
/// pass forever.
pub fn tv_bound(k: usize, n: u64) -> f64 {
    let n = n as f64;
    (k as f64 / (4.0 * n)).sqrt() + (1e6f64.ln() / (2.0 * n)).sqrt()
}

/// Pearson chi-square statistic of `counts` against `expected`
/// (normalized probabilities). Bins whose expected count falls below 5
/// are pooled into one tail bin (the classic validity rule for the
/// chi-square approximation); returns `(statistic, degrees of freedom)`.
/// A positive count on a zero-probability bin returns `(f64::INFINITY, dof)`
/// — an impossible token was emitted.
pub fn chi_square_stat(counts: &[u64], expected: &[f64]) -> (f64, usize) {
    assert_eq!(counts.len(), expected.len(), "chi-square over mismatched supports");
    let n: u64 = counts.iter().sum();
    let mut stat = 0.0f64;
    let mut bins = 0usize;
    let (mut tail_c, mut tail_e) = (0.0f64, 0.0f64);
    for (&c, &p) in counts.iter().zip(expected) {
        if p <= 0.0 {
            if c > 0 {
                return (f64::INFINITY, 1);
            }
            continue;
        }
        let e = p * n as f64;
        if e < 5.0 {
            tail_c += c as f64;
            tail_e += e;
        } else {
            stat += (c as f64 - e) * (c as f64 - e) / e;
            bins += 1;
        }
    }
    if tail_e > 0.0 {
        stat += (tail_c - tail_e) * (tail_c - tail_e) / tail_e;
        bins += 1;
    }
    (stat, bins.saturating_sub(1).max(1))
}

/// Chi-square critical value at tail probability ~1e-6 via the
/// Wilson–Hilferty cube-root normal approximation:
/// `χ²_crit ≈ dof · (1 − 2/9dof + z √(2/9dof))³` with `z = Φ⁻¹(1 − 10⁻⁶)
/// ≈ 4.7534`. Same contract as [`tv_bound`]: a correct sampler at a
/// fixed seed essentially never crosses it.
pub fn chi_square_crit(dof: usize) -> f64 {
    let k = dof.max(1) as f64;
    let z = 4.7534f64;
    let t = 2.0 / (9.0 * k);
    k * (1.0 - t + z * t.sqrt()).powi(3)
}

/// Assert an empirical token histogram matches its expected distribution
/// under *both* derived checks — TV distance under [`tv_bound`] and the
/// Pearson statistic under [`chi_square_crit`] — returning `Err` with
/// the realized values for [`check`]-style replay.
pub fn assert_histogram_close(counts: &[u64], expected: &[f64]) -> Result<(), String> {
    let n: u64 = counts.iter().sum();
    if n == 0 {
        return Err("empty histogram".to_string());
    }
    let support = expected.iter().filter(|&&p| p > 0.0).count();
    let tv = tv_distance(&empirical_dist(counts), expected);
    let bound = tv_bound(support, n);
    if tv > bound {
        return Err(format!("TV distance {tv:.5} exceeds derived bound {bound:.5} (n={n}, support={support})"));
    }
    let (stat, dof) = chi_square_stat(counts, expected);
    let crit = chi_square_crit(dof);
    if stat > crit {
        return Err(format!("chi-square {stat:.3} exceeds critical {crit:.3} at dof={dof} (n={n})"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 16, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'failer'")]
    fn check_reports_failure() {
        check("failer", 8, |rng| {
            let x = rng.below(4);
            if x == 3 {
                Err("hit 3".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_behaviour() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }

    #[test]
    fn tv_distance_basics() {
        assert_eq!(tv_distance(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        assert!((tv_distance(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-15);
        assert!((tv_distance(&[0.5, 0.5], &[0.75, 0.25]) - 0.25).abs() < 1e-15);
    }

    #[test]
    fn histogram_checks_pass_for_true_dist_and_catch_wrong_dist() {
        let dist = [0.5f64, 0.25, 0.125, 0.125];
        let mut rng = Pcg64::new(99);
        let mut counts = [0u64; 4];
        for _ in 0..20_000 {
            counts[rng.weighted(&dist)] += 1;
        }
        assert_histogram_close(&counts, &dist).unwrap();
        // The same counts against a materially different distribution
        // must fail both derived bounds.
        let wrong = [0.25f64, 0.25, 0.25, 0.25];
        assert!(assert_histogram_close(&counts, &wrong).is_err());
        let (stat, dof) = chi_square_stat(&counts, &wrong);
        assert!(stat > chi_square_crit(dof));
    }

    #[test]
    fn chi_square_flags_impossible_tokens_and_pools_thin_bins() {
        // A count on a zero-probability bin is an immediate fail.
        let (stat, _) = chi_square_stat(&[10, 1], &[1.0, 0.0]);
        assert!(stat.is_infinite());
        // Thin bins pool: with n=100 the last two bins (expected 0.3
        // each) merge into one tail bin rather than destabilizing the
        // statistic.
        let counts = [60u64, 34, 3, 3];
        let expected = [0.6f64, 0.34, 0.03, 0.03];
        let (stat, dof) = chi_square_stat(&counts, &expected);
        assert!(stat.is_finite());
        assert_eq!(dof, 2); // 2 fat bins + 1 pooled tail − 1
        assert!(assert_histogram_close(&counts, &expected).is_ok());
    }

    #[test]
    fn derived_bounds_scale_with_samples() {
        // More samples → tighter TV budget; more dof → larger critical.
        assert!(tv_bound(8, 40_000) < tv_bound(8, 4_000));
        assert!(tv_bound(64, 4_000) > tv_bound(8, 4_000));
        assert!(chi_square_crit(63) > chi_square_crit(7));
    }
}
