//! Persistent data-parallel worker pool (rayon is not in the offline crate
//! set, so this is hand-rolled on `std::sync`).
//!
//! # Why a persistent pool
//!
//! Earlier revisions spawned fresh OS threads via `std::thread::scope` on
//! every parallel call. A decode step issues dozens of matvecs per layer per
//! token, so spawn cost (~10–50 µs each) dominated the small kernels and
//! forced a high [`PAR_MIN_WORK`] threshold that kept B=1 decode serial.
//! This module instead keeps N long-lived workers parked on a condvar and
//! hands them jobs by bumping an epoch counter: dispatch costs one mutex
//! round-trip plus a condvar wakeup (~1 µs), so even small decode matvecs
//! are worth sharding.
//!
//! # Execution model
//!
//! A *job* is a closure over chunk indices `0..n_chunks` plus an atomic
//! cursor. Every participant — the parked workers *and the calling thread* —
//! claims chunks with `fetch_add` (work stealing) until the cursor runs off
//! the end, then the caller blocks on a per-job condvar until the completed
//! count reaches `n_chunks` (caller-participates barrier). Workers never
//! exit; after a job they re-park on the pool condvar.
//!
//! Jobs may nest: a chunk body may itself dispatch a job. The inner caller
//! participates in and fully drains its own job, so progress never depends
//! on workers that are busy with the outer job.
//!
//! # Determinism / bit-exactness
//!
//! Chunk *claiming* is racy, but every chunk index is claimed by exactly one
//! participant and the helpers below map chunks to disjoint output regions
//! (one writer per row). Each row's value depends only on its row index,
//! never on which thread ran it or on the thread count — so results are
//! bitwise identical at any `QUIPSHARP_THREADS`, including 1.
//!
//! # Thread-count semantics
//!
//! `QUIPSHARP_THREADS` is read **once**, when the pool is first touched;
//! later changes to the environment variable are ignored (the old
//! implementation silently memoized it in an `AtomicUsize`, which made
//! tests that set the variable after startup no-ops — that one-shot
//! behaviour is now explicit and documented here). To change the thread
//! budget at runtime use [`set_num_threads`]; tests should prefer
//! [`with_threads`], which serializes on a global lock and restores the
//! previous value.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Job: one data-parallel dispatch.
// ---------------------------------------------------------------------------

/// One dispatched job. `task` is a lifetime-erased pointer into the calling
/// frame; it is only dereferenced for *claimed* chunk indices, and the caller
/// blocks inside [`run_job`] until `completed == n_chunks`, so the pointee
/// outlives every dereference. Late-waking workers that find the cursor
/// exhausted touch only the atomics, never `task`.
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    n_chunks: usize,
    /// Work-stealing cursor: next unclaimed chunk index.
    next: AtomicUsize,
    /// Chunks fully executed. The last finisher flips `done`.
    completed: AtomicUsize,
    done: Mutex<bool>,
    done_cv: Condvar,
    /// First panic payload from any chunk, rethrown by the caller.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// SAFETY: `task` is `Sync` (shared-call safe) and the barrier in `run_job`
// guarantees it is not dereferenced after the caller's frame unwinds.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and execute chunks until the cursor is exhausted. Panics in the
    /// task are caught so the completion count always reaches `n_chunks`
    /// (otherwise the caller would block forever); the first payload is
    /// stashed and rethrown by the dispatching thread.
    fn run(&self) {
        loop {
            let c = self.next.fetch_add(1, Ordering::Relaxed);
            if c >= self.n_chunks {
                return;
            }
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // SAFETY: chunk `c` was claimed exactly once and the caller
                // keeps the pointee alive until the completion barrier.
                unsafe { (*self.task)(c) }
            }));
            if let Err(p) = r {
                let mut slot = self.panic.lock().unwrap_or_else(|e| e.into_inner());
                slot.get_or_insert(p);
            }
            // AcqRel: chains every participant's row writes into a release
            // sequence, so the final count (and the mutex handoff below)
            // publishes all output writes to the caller.
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n_chunks {
                let mut g = self.done.lock().unwrap_or_else(|e| e.into_inner());
                *g = true;
                self.done_cv.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut g = self.done.lock().unwrap_or_else(|e| e.into_inner());
        while !*g {
            g = self.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

// ---------------------------------------------------------------------------
// Pool: long-lived workers parked on a condvar.
// ---------------------------------------------------------------------------

struct PoolState {
    /// Current (or most recent) job; cleared by its caller after the barrier.
    job: Option<Arc<Job>>,
    /// Bumped on every dispatch; workers compare against their last-seen
    /// value, so notify-while-busy can never lose a wakeup.
    epoch: u64,
    /// Workers with `id < participants` join the current epoch's job.
    participants: usize,
    /// Worker threads spawned so far (grown lazily, never shrunk).
    spawned: usize,
}

struct PoolInner {
    state: Mutex<PoolState>,
    wake: Condvar,
    /// Current thread budget (callers + workers); see [`set_num_threads`].
    active: AtomicUsize,
    /// Stats: parallel jobs dispatched to the pool (serial fallbacks do not
    /// count). Used by regression tests to prove a path went parallel.
    jobs: AtomicUsize,
    /// Stats: mirrors `PoolState::spawned` for lock-free reads. The stress
    /// test pins this flat across thousands of jobs — the property the old
    /// spawn-per-call helpers lacked.
    spawned: AtomicUsize,
}

fn env_threads() -> usize {
    std::env::var("QUIPSHARP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

fn pool() -> &'static Arc<PoolInner> {
    static POOL: OnceLock<Arc<PoolInner>> = OnceLock::new();
    POOL.get_or_init(|| {
        Arc::new(PoolInner {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                participants: 0,
                spawned: 0,
            }),
            wake: Condvar::new(),
            active: AtomicUsize::new(env_threads()),
            jobs: AtomicUsize::new(0),
            spawned: AtomicUsize::new(0),
        })
    })
}

fn worker_loop(inner: Arc<PoolInner>, id: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if id < st.participants {
                        break st.job.clone();
                    }
                    break None;
                }
                st = inner.wake.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        if let Some(job) = job {
            job.run();
        }
    }
}

/// Spawn workers up to `want` (callers hold the state lock). Workers park
/// immediately and live for the rest of the process.
fn ensure_spawned(st: &mut PoolState, inner: &Arc<PoolInner>, want: usize) {
    while st.spawned < want {
        let id = st.spawned;
        let inner2 = Arc::clone(inner);
        std::thread::Builder::new()
            .name(format!("quipsharp-pool-{id}"))
            .spawn(move || worker_loop(inner2, id))
            .expect("failed to spawn pool worker");
        st.spawned += 1;
    }
    inner.spawned.store(st.spawned, Ordering::Relaxed);
}

/// Dispatch `f` over chunk indices `0..n_chunks` across the pool, with the
/// calling thread participating, and block until every chunk has executed.
/// Runs serially inline when the thread budget or chunk count is 1.
fn run_job(n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if n_chunks == 0 {
        return;
    }
    let budget = num_threads();
    let workers = budget.saturating_sub(1).min(n_chunks - 1);
    if workers == 0 {
        for c in 0..n_chunks {
            f(c);
        }
        return;
    }
    let inner = pool();
    let job = Arc::new(Job {
        task: f as *const (dyn Fn(usize) + Sync),
        n_chunks,
        next: AtomicUsize::new(0),
        completed: AtomicUsize::new(0),
        done: Mutex::new(false),
        done_cv: Condvar::new(),
        panic: Mutex::new(None),
    });
    {
        let mut st = inner.state.lock().unwrap_or_else(|e| e.into_inner());
        ensure_spawned(&mut st, inner, workers);
        st.job = Some(Arc::clone(&job));
        st.participants = workers;
        st.epoch = st.epoch.wrapping_add(1);
        inner.jobs.fetch_add(1, Ordering::Relaxed);
    }
    inner.wake.notify_all();
    job.run(); // caller participates in its own job
    job.wait();
    {
        // Detach so parked workers drop their reference promptly. A nested
        // or subsequent dispatch may already have replaced it — only clear
        // our own job.
        let mut st = inner.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(cur) = &st.job {
            if Arc::ptr_eq(cur, &job) {
                st.job = None;
            }
        }
    }
    let payload = job.panic.lock().unwrap_or_else(|e| e.into_inner()).take();
    if let Some(p) = payload {
        std::panic::resume_unwind(p);
    }
}

// ---------------------------------------------------------------------------
// Thread-budget control.
// ---------------------------------------------------------------------------

/// Current thread budget (calling thread + pool workers). Initialized from
/// `QUIPSHARP_THREADS` (else available parallelism) the first time the pool
/// is touched; the environment variable is **not** re-read after that — use
/// [`set_num_threads`] / [`with_threads`] to change it at runtime.
pub fn num_threads() -> usize {
    pool().active.load(Ordering::Relaxed).max(1)
}

/// Set the thread budget for subsequent parallel calls. Values are clamped
/// to at least 1; values above the hardware core count are allowed (workers
/// are spawned on demand), which tests use to exercise oversubscribed
/// chunking. Existing workers are never torn down — a smaller budget just
/// leaves the extras parked.
pub fn set_num_threads(n: usize) {
    pool().active.store(n.max(1), Ordering::Relaxed);
}

/// Run `f` with the thread budget temporarily set to `n`, restoring the
/// previous value afterwards (even on panic). Serialized on a global lock so
/// concurrent tests cannot interleave budget changes.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    static GUARD: Mutex<()> = Mutex::new(());
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_num_threads(self.0);
        }
    }
    let _restore = Restore(num_threads());
    set_num_threads(n);
    f()
}

/// Pool observability counters, for benches and regression tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Parallel jobs dispatched to the pool since process start (serial
    /// fallbacks excluded).
    pub pool_jobs: usize,
    /// Worker threads spawned since process start. Flat across steady-state
    /// load — the whole point of the persistent pool.
    pub workers_spawned: usize,
}

/// Snapshot the pool counters.
pub fn stats() -> PoolStats {
    let inner = pool();
    PoolStats {
        pool_jobs: inner.jobs.load(Ordering::Relaxed),
        workers_spawned: inner.spawned.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------------
// Data-parallel helpers (public API unchanged from the scoped-thread era).
// ---------------------------------------------------------------------------

/// Raw-pointer courier for handing disjoint output regions to workers.
struct SendPtr<T>(*mut T);
// SAFETY: every helper below hands each index/row/tile to exactly one chunk,
// and chunks are claimed exactly once — no aliased &mut ever exists.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Oversubscription factor: chunks per participant, so work stealing can
/// rebalance when chunk costs are uneven.
const CHUNKS_PER_THREAD: usize = 4;

/// Run `f(start, end)` over disjoint contiguous chunks of `0..len`. Blocks
/// until all chunks finish. `f` must be `Sync` because it is shared by
/// reference across threads. Chunk boundaries depend on the thread budget —
/// callers must not encode semantics in them.
pub fn par_chunks<F>(len: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nt = num_threads().min(len.max(1));
    if nt <= 1 || len == 0 {
        f(0, len);
        return;
    }
    let n_chunks = (nt * CHUNKS_PER_THREAD).min(len);
    let chunk = len.div_ceil(n_chunks);
    let body = |c: usize| {
        let start = c * chunk;
        let end = ((c + 1) * chunk).min(len);
        if start < end {
            f(start, end);
        }
    };
    run_job(len.div_ceil(chunk), &body);
}

/// Run `f(i)` exactly once for every `i` in `0..n`, one task per stolen
/// chunk. For few, coarse, pre-balanced tasks (e.g. attention lane groups)
/// where the caller owns the partitioning.
pub fn par_tasks<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let body = |c: usize| f(c);
    run_job(n, &body);
}

/// Parallel map over indices `0..len`, preserving order. Each output slot
/// has exactly one writer, so results are identical at any thread count.
pub fn par_map<T, F>(len: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); len];
    let nt = num_threads().min(len.max(1));
    if nt <= 1 || len == 0 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let n_chunks = (nt * CHUNKS_PER_THREAD).min(len);
    let chunk = len.div_ceil(n_chunks);
    let ptr = SendPtr(out.as_mut_ptr());
    let body = |c: usize| {
        let start = c * chunk;
        let end = ((c + 1) * chunk).min(len);
        for i in start..end {
            // SAFETY: slot `i` belongs to chunk `c` alone; `out` outlives
            // the dispatch barrier.
            unsafe { *ptr.0.add(i) = f(i) };
        }
    };
    run_job(len.div_ceil(chunk), &body);
    out
}

/// Minimum useful work (in rough flop units) before going parallel is worth
/// it. Dispatch on the persistent pool costs ~1 µs of wakeup latency
/// (vs ~10–50 µs per spawned thread before), i.e. a few thousand flops —
/// `1 << 15` keeps a healthy margin while letting realistic B=1 decode
/// matvecs (d·d ≥ 64²·8 work units) shard across cores.
pub const PAR_MIN_WORK: usize = 1 << 15;

/// [`par_rows`] with an explicit per-row work hint: runs serially when
/// rows·work_per_row is below [`PAR_MIN_WORK`]. The threshold decision
/// depends only on the shape, never on the thread count, so serial/parallel
/// selection cannot introduce thread-count-dependent results.
pub fn par_rows_work<T, F>(data: &mut [T], cols: usize, work_per_row: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(cols > 0 && data.len() % cols == 0);
    let rows = data.len() / cols;
    if rows.saturating_mul(work_per_row) < PAR_MIN_WORK {
        for (r, row) in data.chunks_mut(cols).enumerate() {
            f(r, row);
        }
        return;
    }
    par_rows(data, cols, f);
}

/// Parallel-for over rows of a mutable row-major matrix:
/// `f(row_index, row_slice)`. One writer per row — bit-exact by
/// construction at any thread count.
pub fn par_rows<T, F>(data: &mut [T], cols: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(cols > 0 && data.len() % cols == 0);
    let rows = data.len() / cols;
    let nt = num_threads().min(rows.max(1));
    let tile = rows.div_ceil((nt * CHUNKS_PER_THREAD).max(1)).max(1);
    par_row_tiles(data, cols, tile, f);
}

/// [`par_row_tiles`] with a per-row work hint: serial below
/// [`PAR_MIN_WORK`], like [`par_rows_work`].
pub fn par_row_tiles_work<T, F>(data: &mut [T], cols: usize, tile_rows: usize, work: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(cols > 0 && data.len() % cols == 0);
    let rows = data.len() / cols;
    if rows.saturating_mul(work) < PAR_MIN_WORK {
        for (r, row) in data.chunks_mut(cols).enumerate() {
            f(r, row);
        }
        return;
    }
    par_row_tiles(data, cols, tile_rows, f);
}

/// [`par_rows`] with an explicit tile height: workers claim `tile_rows`-row
/// tiles off the stealing cursor. Kernels with per-row payloads (e.g. packed
/// code rows) pick a tile so one tile's payload fits in L2.
pub fn par_row_tiles<T, F>(data: &mut [T], cols: usize, tile_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(cols > 0 && data.len() % cols == 0);
    assert!(tile_rows > 0);
    let rows = data.len() / cols;
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || rows <= 1 {
        for (r, row) in data.chunks_mut(cols).enumerate() {
            f(r, row);
        }
        return;
    }
    let n_tiles = rows.div_ceil(tile_rows);
    let ptr = SendPtr(data.as_mut_ptr());
    let body = |t: usize| {
        let start = t * tile_rows;
        let end = ((t + 1) * tile_rows).min(rows);
        for r in start..end {
            // SAFETY: row `r` lies in tile `t` alone; disjoint from every
            // other chunk's rows, and `data` outlives the barrier.
            let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(r * cols), cols) };
            f(r, row);
        }
    };
    run_job(n_tiles, &body);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_chunks_covers_every_index_once() {
        with_threads(4, || {
            let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
            par_chunks(1000, |a, b| {
                for i in a..b {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        });
    }

    #[test]
    fn par_chunks_empty_ok() {
        par_chunks(0, |_, _| {});
    }

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(257, |i| i * i);
        let want: Vec<usize> = (0..257).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_rows_touches_each_row() {
        let mut m = vec![0.0f32; 7 * 13];
        par_rows(&mut m, 13, |r, row| {
            for v in row.iter_mut() {
                *v = r as f32;
            }
        });
        for (r, row) in m.chunks(13).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32));
        }
    }

    /// Results must be bitwise identical at every thread count, including
    /// oversubscribed non-power-of-two counts that stress tile edges.
    #[test]
    fn helpers_invariant_across_thread_counts() {
        let reference: Vec<u64> = (0..311).map(|i| (i as u64).wrapping_mul(0x9e37)).collect();
        for nt in [1usize, 2, 3, 7] {
            with_threads(nt, || {
                let got = par_map(311, |i| (i as u64).wrapping_mul(0x9e37));
                assert_eq!(got, reference, "par_map diverged at {nt} threads");

                let mut m = vec![0u64; 311];
                par_rows(&mut m, 1, |r, row| row[0] = (r as u64).wrapping_mul(0x9e37));
                assert_eq!(m, reference, "par_rows diverged at {nt} threads");

                let mut t = vec![0u64; 311];
                par_row_tiles(&mut t, 1, 5, |r, row| {
                    row[0] = (r as u64).wrapping_mul(0x9e37);
                });
                assert_eq!(t, reference, "par_row_tiles diverged at {nt} threads");
            });
        }
    }

    #[test]
    fn set_num_threads_takes_effect() {
        with_threads(3, || {
            assert_eq!(num_threads(), 3);
            set_num_threads(5);
            assert_eq!(num_threads(), 5);
        });
    }

    /// Many tiny jobs back-to-back must not spawn any new threads once the
    /// pool is warm — this is the regression test that would have caught the
    /// old spawn-per-call helpers (which spawned nt threads per job).
    #[test]
    fn stress_many_tiny_jobs_no_respawn() {
        with_threads(4, || {
            // Warm: first parallel job grows the pool to the budget.
            let mut warm = vec![0.0f32; 64];
            par_rows(&mut warm, 1, |r, row| row[0] = r as f32);
            let before = stats();
            assert!(before.workers_spawned >= 3);
            let jobs = 5000usize;
            let mut m = vec![0.0f32; 64 * 4];
            for it in 0..jobs {
                par_rows(&mut m, 4, |r, row| {
                    for v in row.iter_mut() {
                        *v = (r + it) as f32;
                    }
                });
            }
            let after = stats();
            assert_eq!(
                after.workers_spawned, before.workers_spawned,
                "persistent pool must not respawn workers per job"
            );
            assert!(
                after.pool_jobs >= before.pool_jobs + jobs,
                "tiny jobs should still dispatch to the pool"
            );
        });
    }

    /// A panicking chunk must propagate to the caller without wedging the
    /// pool for subsequent jobs.
    #[test]
    fn panic_in_chunk_propagates_and_pool_survives() {
        with_threads(4, || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut m = vec![0u32; 64];
                par_rows(&mut m, 1, |r, row| {
                    if r == 33 {
                        panic!("boom");
                    }
                    row[0] = r as u32;
                });
            }));
            assert!(r.is_err(), "worker panic must reach the caller");
            // Pool still serviceable afterwards.
            let got = par_map(100, |i| i + 1);
            assert_eq!(got, (1..=100).collect::<Vec<_>>());
        });
    }

    /// Nested dispatch (a chunk body issuing its own parallel job) must not
    /// deadlock: the inner caller drains its own cursor.
    #[test]
    fn nested_jobs_complete() {
        with_threads(4, || {
            let outer = par_map(8, |i| {
                let inner = par_map(16, move |j| i * 16 + j);
                inner.iter().sum::<usize>()
            });
            let want: Vec<usize> = (0..8).map(|i| (0..16).map(|j| i * 16 + j).sum()).collect();
            assert_eq!(outer, want);
        });
    }
}
