//! Data-parallel helpers over `std::thread::scope` (rayon is not in the
//! offline crate set). Quantization parallelizes over weight-matrix rows /
//! layers; the serving hot path parallelizes matvec rows.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `QUIPSHARP_THREADS` env override, else
/// available parallelism, clamped to at least 1.
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("QUIPSHARP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `f(start, end)` over disjoint contiguous chunks of `0..len` on up to
/// `num_threads()` scoped threads. Blocks until all chunks finish. `f` must
/// be `Sync` because it is shared by reference across threads.
pub fn par_chunks<F>(len: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nt = num_threads().min(len.max(1));
    if nt <= 1 || len == 0 {
        f(0, len);
        return;
    }
    let chunk = len.div_ceil(nt);
    std::thread::scope(|s| {
        for t in 0..nt {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

/// Parallel map over indices `0..len`, preserving order. Each worker owns a
/// disjoint slice of the output vector.
pub fn par_map<T, F>(len: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); len];
    let nt = num_threads().min(len.max(1));
    if nt <= 1 || len == 0 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let chunk = len.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, block) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (off, slot) in block.iter_mut().enumerate() {
                    *slot = f(t * chunk + off);
                }
            });
        }
    });
    out
}

/// Minimum useful work (in rough flop units) before spawning threads is
/// worth it: scoped-thread spawn costs ~10–50 µs, i.e. ~10⁵ flops.
pub const PAR_MIN_WORK: usize = 1 << 19;

/// [`par_rows`] with an explicit per-row work hint: runs serially when
/// rows·work_per_row is below [`PAR_MIN_WORK`] — the generation hot path
/// calls matvecs small enough that thread spawn would dominate.
pub fn par_rows_work<T, F>(data: &mut [T], cols: usize, work_per_row: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(cols > 0 && data.len() % cols == 0);
    let rows = data.len() / cols;
    if rows.saturating_mul(work_per_row) < PAR_MIN_WORK {
        for (r, row) in data.chunks_mut(cols).enumerate() {
            f(r, row);
        }
        return;
    }
    par_rows(data, cols, f);
}

/// Parallel-for over rows of a mutable row-major matrix:
/// `f(row_index, row_slice)`. This is the hot-path shape (matvec rows,
/// per-row quantization).
pub fn par_rows<T, F>(data: &mut [T], cols: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(cols > 0 && data.len() % cols == 0);
    let rows = data.len() / cols;
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 {
        for (r, row) in data.chunks_mut(cols).enumerate() {
            f(r, row);
        }
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, block) in data.chunks_mut(rows_per * cols).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, row) in block.chunks_mut(cols).enumerate() {
                    f(t * rows_per + i, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_chunks_covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_chunks(1000, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_chunks_empty_ok() {
        par_chunks(0, |_, _| {});
    }

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(257, |i| i * i);
        let want: Vec<usize> = (0..257).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_rows_touches_each_row() {
        let mut m = vec![0.0f32; 7 * 13];
        par_rows(&mut m, 13, |r, row| {
            for v in row.iter_mut() {
                *v = r as f32;
            }
        });
        for (r, row) in m.chunks(13).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32));
        }
    }
}
