//! Tiny command-line argument parser (clap is not in the offline crate
//! set). Supports `--key value`, `--flag`, and positionals; subcommands are
//! handled by the caller peeling the first positional.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    /// A `--key` followed by a value that does not start with `--` binds the
    /// value; a `--key` followed by another option or end-of-args is a flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(key.to_string(), v);
                        }
                        _ => out.flags.push(key.to_string()),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    /// First positional (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--size=L", "extra"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("size"), Some("L"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--ft"]);
        assert!(a.has_flag("ft"));
    }

    #[test]
    fn numeric_helpers() {
        let a = parse(&["--n", "12", "--rho", "0.9"]);
        assert_eq!(a.get_usize("n", 0), 12);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert!((a.get_f64("rho", 0.0) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["--ft", "--bits", "2"]);
        assert!(a.has_flag("ft"));
        assert_eq!(a.get("bits"), Some("2"));
    }
}
