//! Substrate utilities built from scratch for the offline environment:
//! deterministic RNG, minimal JSON, scoped thread-pool helpers, the shared
//! `.qtz` tensor container, a tiny CLI parser, a seeded property-test
//! harness, and the scoped phase profiler behind the serving telemetry.

pub mod cli;
pub mod json;
pub mod phase;
pub mod proptest_lite;
pub mod rng;
pub mod tensorio;
pub mod threadpool;
