//! Substrate utilities built from scratch for the offline environment:
//! deterministic RNG, minimal JSON, scoped thread-pool helpers, the shared
//! `.qtz` tensor container, a tiny CLI parser, and a seeded property-test
//! harness.

pub mod cli;
pub mod json;
pub mod proptest_lite;
pub mod rng;
pub mod tensorio;
pub mod threadpool;
