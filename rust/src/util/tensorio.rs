//! `.qtz` — the binary tensor container shared between the python build
//! path (`python/compile/tensorio.py`) and the rust runtime. Little-endian
//! throughout.
//!
//! Layout:
//! ```text
//! magic   b"QTZ1"
//! u32     tensor count
//! repeat:
//!   u16   name length, then name bytes (utf-8)
//!   u8    dtype  (0=f32, 1=i32, 2=u16, 3=u8, 4=i64)
//!   u8    ndim
//!   u32*  dims
//!   u64   payload byte length
//!   raw   payload
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I32 = 1,
    U16 = 2,
    U8 = 3,
    I64 = 4,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U16 => 2,
            DType::U8 => 1,
            DType::I64 => 8,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U16,
            3 => DType::U8,
            4 => DType::I64,
            _ => bail!("unknown dtype tag {v}"),
        })
    }
}

/// One named tensor: dtype + shape + raw little-endian payload.
#[derive(Clone, Debug)]
pub struct TensorData {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl TensorData {
    pub fn from_f32(shape: Vec<usize>, data: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        TensorData {
            dtype: DType::F32,
            shape,
            bytes,
        }
    }

    pub fn from_u16(shape: Vec<usize>, data: &[u16]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 2);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        TensorData {
            dtype: DType::U16,
            shape,
            bytes,
        }
    }

    pub fn from_i32(shape: Vec<usize>, data: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        TensorData {
            dtype: DType::I32,
            shape,
            bytes,
        }
    }

    pub fn from_u8(shape: Vec<usize>, data: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorData {
            dtype: DType::U8,
            shape,
            bytes: data,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, expected F32", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_u16(&self) -> Result<Vec<u16>> {
        if self.dtype != DType::U16 {
            bail!("tensor is {:?}, expected U16", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }

    pub fn to_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, expected I32", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// An ordered collection of named tensors (a checkpoint / corpus / packed
/// quantized model).
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, TensorData>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: TensorData) {
        self.tensors.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&TensorData> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor '{name}' not found"))
    }

    pub fn f32(&self, name: &str) -> Result<Vec<f32>> {
        self.get(name)?.to_f32()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(path.as_ref())
                .with_context(|| format!("create {:?}", path.as_ref()))?,
        );
        w.write_all(b"QTZ1")?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u16).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&[t.dtype as u8, t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            w.write_all(&(t.bytes.len() as u64).to_le_bytes())?;
            w.write_all(&t.bytes)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("open {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"QTZ1" {
            bail!("bad magic {:?} in {:?}", magic, path.as_ref());
        }
        let count = read_u32(&mut r)? as usize;
        let mut tf = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u16(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf8")?;
            let mut hdr = [0u8; 2];
            r.read_exact(&mut hdr)?;
            let dtype = DType::from_u8(hdr[0])?;
            let ndim = hdr[1] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut r)? as usize);
            }
            let nbytes = read_u64(&mut r)? as usize;
            let expect = shape.iter().product::<usize>() * dtype.size();
            if nbytes != expect {
                bail!("tensor '{name}': payload {nbytes} != shape-implied {expect}");
            }
            let mut bytes = vec![0u8; nbytes];
            r.read_exact(&mut bytes)?;
            tf.insert(name, TensorData { dtype, shape, bytes });
        }
        Ok(tf)
    }
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("qtz_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_mixed_dtypes() {
        let mut tf = TensorFile::new();
        tf.insert("w", TensorData::from_f32(vec![2, 3], &[1.0, -2.5, 3.0, 0.0, 1e-9, 7.5]));
        tf.insert("codes", TensorData::from_u16(vec![4], &[0, 65535, 12345, 1]));
        tf.insert("ids", TensorData::from_i32(vec![2], &[-5, 123456]));
        tf.insert("bytes", TensorData::from_u8(vec![3], vec![0, 128, 255]));
        let p = tmpfile("roundtrip");
        tf.save(&p).unwrap();
        let tf2 = TensorFile::load(&p).unwrap();
        assert_eq!(tf2.tensors.len(), 4);
        assert_eq!(tf2.f32("w").unwrap(), vec![1.0, -2.5, 3.0, 0.0, 1e-9, 7.5]);
        assert_eq!(tf2.get("codes").unwrap().to_u16().unwrap(), vec![0, 65535, 12345, 1]);
        assert_eq!(tf2.get("ids").unwrap().to_i32().unwrap(), vec![-5, 123456]);
        assert_eq!(tf2.get("bytes").unwrap().bytes, vec![0, 128, 255]);
        assert_eq!(tf2.get("w").unwrap().shape, vec![2, 3]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let tf = TensorFile::new();
        assert!(tf.get("nope").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmpfile("badmagic");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(TensorFile::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = TensorData::from_u16(vec![1], &[3]);
        assert!(t.to_f32().is_err());
    }
}
