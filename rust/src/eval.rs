//! Evaluation harness: perplexity (Wikitext2/C4 protocol analog) and
//! zeroshot likelihood-comparison accuracy (LM-Eval `acc` analog).

use crate::data::ZeroshotTask;
use crate::model::{Model, NoHook};

/// Log-softmax normalizer for one logits row.
fn log_z(row: &[f32]) -> f32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln()
}

/// Perplexity over a token stream with non-overlapping windows of length
/// `window` (the paper's "context length" protocol: 2048 vs 4096 ↔ our
/// 128 vs 256). `max_tokens` bounds the evaluation cost.
pub fn perplexity(model: &Model, tokens: &[u8], window: usize, max_tokens: usize) -> f64 {
    let usable = tokens.len().min(max_tokens);
    let mut total_nll = 0.0f64;
    let mut count = 0usize;
    let mut start = 0usize;
    while start + window + 1 <= usable {
        let seq = &tokens[start..start + window + 1];
        let logits = model.forward(&seq[..window], &mut NoHook);
        let v = model.cfg.vocab;
        for i in 0..window {
            let row = &logits[i * v..(i + 1) * v];
            let target = seq[i + 1] as usize;
            let nll = log_z(row) - row[target];
            total_nll += nll as f64;
            count += 1;
        }
        start += window;
    }
    (total_nll / count.max(1) as f64).exp()
}

/// Sum log-probability of `cont` given `prefix` (LM-Eval style scoring).
pub fn continuation_logprob(model: &Model, prefix: &[u8], cont: &[u8]) -> f64 {
    let mut seq = Vec::with_capacity(prefix.len() + cont.len());
    seq.extend_from_slice(prefix);
    seq.extend_from_slice(cont);
    let ctx = model.cfg.ctx;
    // Clip from the left if too long (keep the continuation).
    let clipped: &[u8] = if seq.len() > ctx { &seq[seq.len() - ctx..] } else { &seq };
    let p_len = clipped.len() - cont.len();
    let logits = model.forward(&clipped[..clipped.len() - 1], &mut NoHook);
    let v = model.cfg.vocab;
    let mut lp = 0.0f64;
    for (j, &tok) in cont.iter().enumerate() {
        let pos = p_len + j - 1; // logits index predicting this token
        let row = &logits[pos * v..(pos + 1) * v];
        lp += (row[tok as usize] - log_z(row)) as f64;
    }
    lp
}

/// Accuracy on a two-option task: pick the higher-likelihood option.
pub fn zeroshot_accuracy(model: &Model, task: &ZeroshotTask, max_examples: usize) -> f64 {
    let n = task.examples.len().min(max_examples);
    let mut correct = 0usize;
    for ex in task.examples.iter().take(n) {
        let la = continuation_logprob(model, &ex.prefix, &ex.opt_a);
        let lb = continuation_logprob(model, &ex.prefix, &ex.opt_b);
        let pick = if la >= lb { 0 } else { 1 };
        if pick == ex.label {
            correct += 1;
        }
    }
    correct as f64 / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ZeroshotExample;
    use crate::model::tests_support::tiny_model;

    #[test]
    fn perplexity_bounded_by_vocab() {
        let m = tiny_model(1);
        let tokens: Vec<u8> = (0..200).map(|i| (i * 13 % 64) as u8).collect();
        let ppl = perplexity(&m, &tokens, 16, 128);
        assert!(ppl > 1.0, "ppl={ppl}");
        // A random-ish model can't be much worse than uniform over 64.
        assert!(ppl < 1000.0, "ppl={ppl}");
    }

    #[test]
    fn perplexity_uniform_logits_equals_vocab() {
        // Zeroed lm_head → uniform distribution → ppl == vocab.
        let mut m = tiny_model(2);
        let v = m.cfg.vocab;
        let d = m.cfg.d_model;
        m.set_linear("lm_head", vec![0.0; v * d]);
        let tokens: Vec<u8> = (0..100).map(|i| (i % 64) as u8).collect();
        let ppl = perplexity(&m, &tokens, 16, 64);
        assert!((ppl - v as f64).abs() < 0.5, "ppl={ppl} want {v}");
    }

    #[test]
    fn continuation_logprob_is_negative_and_additive() {
        let m = tiny_model(3);
        let lp1 = continuation_logprob(&m, &[1, 2, 3], &[4]);
        assert!(lp1 < 0.0);
        let lp2 = continuation_logprob(&m, &[1, 2, 3], &[4, 5]);
        // Longer continuation ⇒ not higher probability.
        assert!(lp2 <= lp1 + 1e-6);
    }

    #[test]
    fn zeroshot_on_rigged_task() {
        // Option equal to argmax continuation should win vs an unlikely one.
        let m = tiny_model(4);
        let prefix = vec![1u8, 2, 3, 4];
        let logits = m.forward(&prefix, &mut crate::model::NoHook);
        let v = m.cfg.vocab;
        let last = &logits[(prefix.len() - 1) * v..prefix.len() * v];
        let best = (0..v).max_by(|&a, &b| last[a].partial_cmp(&last[b]).unwrap()).unwrap() as u8;
        let worst = (0..v).min_by(|&a, &b| last[a].partial_cmp(&last[b]).unwrap()).unwrap() as u8;
        let task = ZeroshotTask {
            name: "rigged".into(),
            examples: vec![
                ZeroshotExample {
                    prefix: prefix.clone(),
                    opt_a: vec![best],
                    opt_b: vec![worst],
                    label: 0,
                },
                ZeroshotExample {
                    prefix,
                    opt_a: vec![worst],
                    opt_b: vec![best],
                    label: 1,
                },
            ],
        };
        assert_eq!(zeroshot_accuracy(&m, &task, 10), 1.0);
    }
}
