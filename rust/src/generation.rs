//! Autoregressive generation with a KV cache — the workload behind
//! Tables 5/6 (generation throughput) and the serving engine's native
//! fallback path. Supports dense (fp) weights and the fused E8P decode
//! hot path per linear layer.
//!
//! The decode path is batch-native: [`Generator::decode_batch`] advances
//! B sequences one token in lockstep, routing every linear layer through
//! the decode-once/multiply-many batched kernel in
//! [`crate::model::qlinear`] and running one cross-sequence fused
//! attention pass over the batch ([`paged::fused_batch_attention`]): a
//! single walk over K/V block indices per step services every sequence
//! and head attending to each block, so packed codewords *and* shared
//! K/V blocks are streamed once per step instead of once per sequence.
//! [`Generator::decode_one`] is the batch-1 special case, and
//! [`AttnMode::PerSeq`] keeps the per-sequence block walk
//! ([`paged::blocked_attention`]) as a bit-exact baseline.
//!
//! KV storage comes in two layouts behind one decode implementation:
//! per-sequence contiguous slabs ([`KvCache`], the parity baseline) and
//! page tables over a shared [`paged::KvPagePool`]
//! ([`Generator::decode_batch_paged`], the serving path). Both walk
//! their rows through the same [`paged::PAGE_ROWS`]-blocked attention
//! kernels, so the two layouts produce bit-identical logits.
//!
//! Lanes and sequences are decoupled: a decode step may advance several
//! *consecutive* tokens of one sequence as separate lanes
//! ([`Generator::decode_chunks`] / [`Generator::decode_chunks_paged`] —
//! prefill-style chunked decode, bitwise identical to one-token-at-a-
//! time decode), which is what the self-speculative verify step in
//! [`speculative`] builds on, together with the KV rollback primitives
//! ([`KvCache::truncate`], [`paged::PagedKv::truncate`]).
//!
//! `rust/src/generation/README.md` tours the decode/attention data flow
//! end to end.

use std::collections::BTreeMap;

pub mod paged;
pub mod sampling;
pub mod speculative;

use crate::linalg::hadamard::{fwht_f32, HadTransform};
use crate::model::ops::*;
use crate::model::qlinear::{dense_matmul, QuantMatvec};
use crate::model::{Arch, Model};
use crate::util::phase::{self, Phase};
use paged::{
    blocked_attention, blocked_attention_kv, fused_batch_attention, fused_batch_attention_kv,
    AttnLane, KvPagePool, PagedKv, PAGE_ROWS,
};

/// Apply a scaled orthogonal Hadamard transform to an f32 vector
/// (pure-FWHT fast path; f64 round-trip for the H_q ⊗ H_p case).
pub fn had_apply_f32(t: &HadTransform, x: &mut [f32]) {
    if t.q == 1 {
        fwht_f32(x);
        let s = 1.0 / (t.n as f32).sqrt();
        for v in x.iter_mut() {
            *v *= s;
        }
    } else {
        let mut buf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        t.apply(&mut buf);
        for (o, v) in x.iter_mut().zip(buf) {
            *o = v as f32;
        }
    }
}

pub fn had_apply_inverse_f32(t: &HadTransform, x: &mut [f32]) {
    if t.q == 1 {
        fwht_f32(x); // Sylvester H is symmetric
        let s = 1.0 / (t.n as f32).sqrt();
        for v in x.iter_mut() {
            *v *= s;
        }
    } else {
        let mut buf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        t.apply_inverse(&mut buf);
        for (o, v) in x.iter_mut().zip(buf) {
            *o = v as f32;
        }
    }
}

/// Per-sequence contiguous KV cache — the parity baseline for the paged
/// layout. Storage grows lazily in [`KvCache::GROW_ROWS`] slabs as the
/// sequence lengthens, so admitting a short request never pays the full
/// `ctx × d_model` per-layer allocation up front.
pub struct KvCache {
    /// per layer: (grown_len, d) k and v rows.
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub len: usize,
    d: usize,
    ctx: usize,
}

impl KvCache {
    /// Token rows added per growth step — equal to the paged layout's
    /// page size so blocked attention covers identical row ranges in
    /// both layouts.
    pub const GROW_ROWS: usize = PAGE_ROWS;

    pub fn new(model: &Model) -> Self {
        let l = model.cfg.n_layers;
        KvCache {
            k: vec![Vec::new(); l],
            v: vec![Vec::new(); l],
            len: 0,
            d: model.cfg.d_model,
            ctx: model.cfg.ctx,
        }
    }

    /// f32 slots currently allocated across layers (diagnostic hook for
    /// the lazy-growth tests and admission accounting).
    pub fn allocated_f32(&self) -> usize {
        let ks: usize = self.k.iter().map(|r| r.len()).sum();
        let vs: usize = self.v.iter().map(|r| r.len()).sum();
        ks + vs
    }

    /// Store the k/v rows for position `pos` in `layer`, growing storage
    /// on demand.
    pub fn store(&mut self, layer: usize, pos: usize, kx: &[f32], vx: &[f32]) {
        let need = (pos + 1) * self.d;
        if self.k[layer].len() < need {
            let rows = ((pos + 1).div_ceil(Self::GROW_ROWS) * Self::GROW_ROWS).min(self.ctx);
            self.k[layer].resize(rows * self.d, 0.0);
            self.v[layer].resize(rows * self.d, 0.0);
        }
        self.k[layer][pos * self.d..need].copy_from_slice(kx);
        self.v[layer][pos * self.d..need].copy_from_slice(vx);
    }

    /// Roll the cache back to `new_len` rows — the contiguous analogue of
    /// [`PagedKv::truncate`] (speculative-decode rejection path). Storage
    /// is kept; rows `[new_len, old_len)` become stale but are never read
    /// (attention reads rows `< len` only) and are fully overwritten by
    /// [`KvCache::store`] before the length covers them again.
    pub fn truncate(&mut self, new_len: usize) {
        assert!(
            new_len <= self.len,
            "truncate to {new_len} rows but the cache holds {}",
            self.len
        );
        self.len = new_len;
    }
}

/// KV storage backing one batched decode step: per-sequence contiguous
/// slabs (the baseline) or page tables over a shared pool (the serving
/// layout). One decode implementation serves both.
enum KvBatch<'a, 'b> {
    Contig(&'a mut [&'b mut KvCache]),
    Paged {
        pool: &'a mut KvPagePool,
        seqs: &'a mut [&'b mut PagedKv],
    },
}

impl KvBatch<'_, '_> {
    fn seq_count(&self) -> usize {
        match self {
            KvBatch::Contig(caches) => caches.len(),
            KvBatch::Paged { seqs, .. } => seqs.len(),
        }
    }

    /// KV row each lane of `lane_seq` writes and attends up to: a
    /// sequence's lanes take consecutive positions starting at its
    /// current length, in lane order (chunked decode maps several
    /// consecutive lanes onto one sequence; plain batched decode is the
    /// identity mapping with one lane per sequence).
    fn lane_positions(&self, lane_seq: &[usize]) -> Vec<usize> {
        let base: Vec<usize> = match self {
            KvBatch::Contig(caches) => caches.iter().map(|c| c.len).collect(),
            KvBatch::Paged { seqs, .. } => seqs.iter().map(|s| s.len).collect(),
        };
        let mut taken = vec![0usize; base.len()];
        lane_seq
            .iter()
            .map(|&s| {
                let pos = base[s] + taken[s];
                taken[s] += 1;
                pos
            })
            .collect()
    }

    fn store(&mut self, seq: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        match self {
            KvBatch::Contig(caches) => caches[seq].store(layer, pos, k, v),
            KvBatch::Paged { pool, seqs } => seqs[seq].store(pool, layer, pos, k, v),
        }
    }

    fn advance(&mut self, lane_seq: &[usize]) {
        for &s in lane_seq {
            match self {
                KvBatch::Contig(caches) => caches[s].len += 1,
                KvBatch::Paged { pool, seqs } => {
                    seqs[s].len += 1;
                    // Quantize pages that just aged out of the hot tail
                    // (no-op on fp32 pools — see PagedKv::compress_cold).
                    seqs[s].compress_cold(pool);
                }
            }
        }
    }
}

/// How each linear layer is applied at decode time.
pub enum DecodeLinear<'a> {
    Dense,
    /// Fused E8P decode path (with RHT around it).
    Quant(&'a QuantMatvec),
}

/// Which attention kernel a [`Generator`] runs per decode step.
///
/// Both kernels execute identical per-sequence floating-point ops (see
/// the bit-exactness notes on [`paged::fused_batch_attention`]), so
/// the mode changes performance, never logits — pinned by bitwise
/// parity tests. [`AttnMode::Fused`] is the default;
/// [`AttnMode::PerSeq`] remains as the parity oracle and the
/// micro-bench baseline (`benches/bench_attention.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnMode {
    /// Walk each sequence's K/V blocks separately (the pre-fusion hot
    /// path): simple, but a K-block aliased by B forked siblings is
    /// re-streamed B times per step.
    PerSeq,
    /// One cross-sequence block walk per step: every sequence and head
    /// attending to a physical block is serviced while the block is
    /// cache-hot ([`paged::fused_batch_attention`]).
    Fused,
}

/// Generator with per-layer quantized matvec overrides.
pub struct Generator<'a> {
    pub model: &'a Model,
    pub qlayers: BTreeMap<String, QuantMatvec>,
    /// Attention kernel selection — [`AttnMode::Fused`] by default;
    /// swap to [`AttnMode::PerSeq`] for the per-sequence baseline
    /// walk (bit-exact either way).
    pub attn_mode: AttnMode,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl<'a> Generator<'a> {
    pub fn dense(model: &'a Model) -> Self {
        Generator {
            model,
            qlayers: BTreeMap::new(),
            attn_mode: AttnMode::Fused,
            _marker: Default::default(),
        }
    }

    /// Build from a quantized model's packed layers (E8P methods only).
    pub fn quantized(model: &'a Model, qm: &crate::qmodel::QuantizedModel) -> Self {
        let mut qlayers = BTreeMap::new();
        for (name, ql) in &qm.layers {
            if let Some(p) = &ql.packed {
                qlayers.insert(name.clone(), QuantMatvec::from_packed(ql.m, ql.n, p));
            }
        }
        Generator {
            model,
            qlayers,
            attn_mode: AttnMode::Fused,
            _marker: Default::default(),
        }
    }

    /// Build the RVQ *base-stage* generator over a quantized model: every
    /// packed layer decodes only its stage-0 codes
    /// ([`QuantMatvec::base_stage`]), so a 4-bit (E8P ∘ E8P) model yields
    /// its embedded 2-bit model — the self-speculative draft
    /// ([`crate::generation::speculative`]). Codes stay `Arc`-shared
    /// with the full generator; unpacked (dense-fallback) layers and the
    /// embed/norm/lm_head tensors are identical to the target's.
    pub fn base_stage(model: &'a Model, qm: &crate::qmodel::QuantizedModel) -> Self {
        let mut gen = Self::quantized(model, qm);
        for q in gen.qlayers.values_mut() {
            *q = q.base_stage();
        }
        gen
    }

    /// Apply a linear layer to B sequence-major inputs through the
    /// batched kernel (fused E8P decode when packed, dense otherwise).
    fn apply_linear_batch(&self, name: &str, xs: &[f32], batch: usize, ys: &mut [f32]) {
        let _scope = phase::scope(Phase::QuantMatmul);
        if let Some(qm) = self.qlayers.get(name) {
            if qm.n.is_power_of_two() && qm.m.is_power_of_two() {
                qm.matmul(xs, batch, ys);
                return;
            }
        }
        let w = self.model.p(name);
        let (m, n) = (w.shape[0], w.shape[1]);
        dense_matmul(&w.data, xs, m, n, batch, ys);
    }

    /// Per-step weight-stream components, in bytes:
    /// `(packed, dense_linear, per_lane)`. Packed codes and dense linear
    /// weights amortize across a batched step (codes are re-read once per
    /// [`crate::model::qlinear::BATCH_TILE`] lanes); the fp32 lm_head is
    /// streamed once per sequence (`matmul_nt` walks the full head matrix
    /// per output row).
    pub fn weight_bytes_split(&self) -> (u64, u64, u64) {
        let mut packed = 0u64;
        let mut dense_linear = 0u64;
        for name in self.model.cfg.linear_names() {
            if let Some(qm) = self.qlayers.get(&name) {
                packed += qm.bytes_per_matvec();
            } else {
                let w = self.model.p(&name);
                dense_linear += (w.data.len() * 4) as u64;
            }
        }
        let per_lane = (self.model.p("lm_head").data.len() * 4) as u64;
        (packed, dense_linear, per_lane)
    }

    /// Bytes of weights streamed per decoded token (the B = 1 stream).
    pub fn weight_bytes_per_token(&self) -> u64 {
        let (packed, dense_linear, per_lane) = self.weight_bytes_split();
        packed + dense_linear + per_lane
    }

    /// Bytes of weights one batched decode step actually streams at batch
    /// size `batch` — the honest numerator for decode-bytes-amortization
    /// metrics (a sequence-at-a-time loop would stream
    /// `batch × weight_bytes_per_token()`).
    pub fn weight_bytes_streamed_per_step(&self, batch: usize) -> u64 {
        streamed_bytes_for_batch(self.weight_bytes_split(), batch)
    }

    /// Advance one token, returning the logits row — the batch-1 special
    /// case of [`Generator::decode_batch`].
    pub fn decode_one(&self, token: u8, cache: &mut KvCache) -> Vec<f32> {
        self.decode_batch(&[token], &mut [cache]).pop().unwrap()
    }

    /// Advance every sequence one token in lockstep against per-sequence
    /// contiguous caches — the parity baseline layout. See
    /// [`Generator::decode_batch_paged`] for the pooled layout; both run
    /// the identical decode implementation.
    pub fn decode_batch(&self, tokens: &[u8], caches: &mut [&mut KvCache]) -> Vec<Vec<f32>> {
        let lane_seq: Vec<usize> = (0..tokens.len()).collect();
        self.decode_batch_kv(tokens, &mut KvBatch::Contig(caches), &lane_seq)
    }

    /// Advance one sequence by a *chunk* of consecutive tokens in one
    /// prefill-style batched step — the contiguous-KV form of
    /// [`Generator::decode_chunk_paged`]. Returns the logits row after
    /// every chunk position. Bit-exact with feeding the same tokens one
    /// [`Generator::decode_one`] call at a time (see
    /// [`Generator::decode_chunks`] for why).
    pub fn decode_chunk(&self, tokens: &[u8], cache: &mut KvCache) -> Vec<Vec<f32>> {
        self.decode_chunks(&[tokens], &mut [cache]).pop().unwrap()
    }

    /// Advance several sequences by per-sequence token chunks in one
    /// batched step: every chunk position of every sequence is a lane of
    /// the same underlying decode call, so each packed codeword is
    /// decoded once for *all* positions (the speculative-verify hot
    /// path). Returns, per sequence, the logits row after each of its
    /// chunk positions.
    ///
    /// Bit-exactness: a lane's linear-layer accumulation order is
    /// batch-invariant (the decode-once tiling invariant pinned in
    /// [`crate::model::qlinear`]), per-lane RoPE/norm ops are
    /// independent, and attention for the lane at position `p` walks
    /// rows `0..=p` through the same blocked kernels a one-token step
    /// at `p` would — rows `< p` written by earlier lanes of the same
    /// chunk hold exactly the values sequential decode would have
    /// stored (every KV write for a layer lands before any lane's
    /// attention in that layer). Chunked decode is therefore bitwise
    /// identical to sequential decode, which is what makes speculative
    /// verification exact ([`crate::generation::speculative`]).
    pub fn decode_chunks(
        &self,
        chunks: &[&[u8]],
        caches: &mut [&mut KvCache],
    ) -> Vec<Vec<Vec<f32>>> {
        assert_eq!(chunks.len(), caches.len());
        let (tokens, lane_seq) = flatten_chunks(chunks);
        let flat = self.decode_batch_kv(&tokens, &mut KvBatch::Contig(caches), &lane_seq);
        unflatten_rows(flat, chunks)
    }

    /// Advance every sequence one token in lockstep against page tables
    /// over a shared [`KvPagePool`] — the serving layout. Pages are
    /// reserved up front for this step; the call panics if the pool is
    /// exhausted, so schedulers must preempt (release a sequence's pages
    /// via [`PagedKv::release`]) or size the pool before stepping.
    /// Bit-exact with [`Generator::decode_batch`] and with sequential
    /// [`Generator::decode_one`]: every layout runs the same blocked
    /// attention and decode-once linear kernels in the same order.
    ///
    /// Sequences may alias each other's pages: after
    /// [`PagedKv::fork_prefix`], several page tables (in the same batch
    /// or across batches) can point at the same physical prefix pages.
    /// Attention only *reads* through the table, so aliased rows are
    /// indistinguishable from owned rows and the logits stay bit-exact
    /// against unshared decode; the per-step reserve clones any shared
    /// page before this step's KV rows are written into it
    /// (copy-on-write), so no write ever lands in a page another
    /// sequence still reads.
    pub fn decode_batch_paged(
        &self,
        tokens: &[u8],
        pool: &mut KvPagePool,
        seqs: &mut [&mut PagedKv],
    ) -> Vec<Vec<f32>> {
        assert_eq!(tokens.len(), seqs.len());
        for s in seqs.iter_mut() {
            let new_len = s.len + 1;
            assert!(
                s.reserve(pool, new_len),
                "KV page pool exhausted ({} pages): preempt a sequence or enlarge the pool",
                pool.pages_total()
            );
        }
        let lane_seq: Vec<usize> = (0..tokens.len()).collect();
        self.decode_batch_kv(tokens, &mut KvBatch::Paged { pool, seqs }, &lane_seq)
    }

    /// [`Generator::decode_chunks`] over page tables — one sequence per
    /// chunk, all chunk positions decoded as lanes of a single batched
    /// step. Reserves each sequence's pages up front (panicking on
    /// exhaustion like [`Generator::decode_batch_paged`]); bit-exact
    /// with one-token-at-a-time paged decode.
    pub fn decode_chunks_paged(
        &self,
        chunks: &[&[u8]],
        pool: &mut KvPagePool,
        seqs: &mut [&mut PagedKv],
    ) -> Vec<Vec<Vec<f32>>> {
        assert_eq!(chunks.len(), seqs.len());
        for (s, chunk) in seqs.iter_mut().zip(chunks) {
            let new_len = s.len + chunk.len();
            assert!(
                s.reserve(pool, new_len),
                "KV page pool exhausted ({} pages): preempt a sequence or enlarge the pool",
                pool.pages_total()
            );
        }
        let (tokens, lane_seq) = flatten_chunks(chunks);
        let flat = self.decode_batch_kv(&tokens, &mut KvBatch::Paged { pool, seqs }, &lane_seq);
        unflatten_rows(flat, chunks)
    }

    /// The one-sequence special case of [`Generator::decode_chunks_paged`].
    pub fn decode_chunk_paged(
        &self,
        tokens: &[u8],
        pool: &mut KvPagePool,
        kv: &mut PagedKv,
    ) -> Vec<Vec<f32>> {
        self.decode_chunks_paged(&[tokens], pool, &mut [kv]).pop().unwrap()
    }

    /// The shared decode step over either KV layout. Each *lane* advances
    /// one token; `lane_seq` maps lanes onto sequences (identity for
    /// plain batched decode; several consecutive lanes per sequence for
    /// chunked decode, which assigns them consecutive positions). RoPE
    /// and KV writes run per lane, every linear layer is applied once for
    /// the whole batch (each packed codeword decoded exactly once per
    /// step), and attention runs as one cross-sequence fused block walk
    /// over the batch (see [`Generator::attn_mode`]), so K/V blocks
    /// aliased across forked sequences are loaded once per step. Within a
    /// layer every lane's K/V row is stored before any lane attends, so a
    /// chunk lane at position `p` reads its same-chunk predecessors'
    /// rows exactly as sequential decode would.
    fn decode_batch_kv(
        &self,
        tokens: &[u8],
        kvb: &mut KvBatch,
        lane_seq: &[usize],
    ) -> Vec<Vec<f32>> {
        let bsz = tokens.len();
        assert!(bsz > 0, "empty decode batch");
        assert_eq!(bsz, lane_seq.len());
        debug_assert!(lane_seq.iter().all(|&s| s < kvb.seq_count()));
        let cfg = &self.model.cfg;
        let (d, heads, hd, ff) = (cfg.d_model, cfg.n_heads, cfg.head_dim(), cfg.d_ff);
        let model = self.model;
        let positions = kvb.lane_positions(lane_seq);
        for &pos in &positions {
            assert!(pos < cfg.ctx, "KV cache full");
        }
        let (rope_cos, rope_sin) = {
            // RoPE tables are owned by Model (private); recompute lazily:
            // cheap at hd ≤ 64, but cache anyway via thread_local.
            thread_local! {
                static TABLES: std::cell::RefCell<Option<(usize, usize, Vec<f32>, Vec<f32>)>> =
                    const { std::cell::RefCell::new(None) };
            }
            TABLES.with(|t| {
                let mut t = t.borrow_mut();
                let need = match &*t {
                    Some((c, h, _, _)) => *c != cfg.ctx || *h != hd,
                    None => true,
                };
                if need {
                    let (c, s) = rope_tables(cfg.ctx, hd);
                    *t = Some((cfg.ctx, hd, c, s));
                }
                let (_, _, c, s) = t.as_ref().unwrap();
                (c.clone(), s.clone())
            })
        };

        let embed = model.p("embed");
        let mut xs = vec![0.0f32; bsz * d];
        for (b, &tok) in tokens.iter().enumerate() {
            let row = &embed.data[tok as usize * d..(tok as usize + 1) * d];
            xs[b * d..(b + 1) * d].copy_from_slice(row);
            if cfg.arch == Arch::NonLlama {
                let pe = model.p("pos_embed");
                let pos = positions[b];
                for j in 0..d {
                    xs[b * d + j] += pe.data[pos * d + j];
                }
            }
        }

        let mut h = vec![0.0f32; bsz * d];
        let mut q = vec![0.0f32; bsz * d];
        let mut kx = vec![0.0f32; bsz * d];
        let mut vx = vec![0.0f32; bsz * d];
        let mut att = vec![0.0f32; bsz * d];
        let mut tmp_d = vec![0.0f32; bsz * d];
        let mut ffg = vec![0.0f32; bsz * ff];
        let mut ffu = vec![0.0f32; bsz * ff];

        for layer in 0..cfg.n_layers {
            let pre = format!("layers.{layer}.");
            for b in 0..bsz {
                let xb = &xs[b * d..(b + 1) * d];
                self.norm_one(&format!("{pre}attn_norm"), xb, d, &mut h[b * d..(b + 1) * d]);
            }
            self.apply_linear_batch(&format!("{pre}wq"), &h, bsz, &mut q);
            self.apply_linear_batch(&format!("{pre}wk"), &h, bsz, &mut kx);
            self.apply_linear_batch(&format!("{pre}wv"), &h, bsz, &mut vx);
            // RoPE + KV write per sequence (each against its own page
            // table or slab).
            for b in 0..bsz {
                let pos = positions[b];
                let qb = &mut q[b * d..(b + 1) * d];
                let kb = &mut kx[b * d..(b + 1) * d];
                if cfg.arch != Arch::NonLlama {
                    rope_apply(qb, heads, hd, pos, &rope_cos, &rope_sin);
                    rope_apply(kb, heads, hd, pos, &rope_cos, &rope_sin);
                }
                kvb.store(lane_seq[b], layer, pos, kb, &vx[b * d..(b + 1) * d]);
            }
            // Fused batched attention: one blocked (flash-style) pass
            // over every sequence's KV blocks, sharing the Q/K/V
            // projections computed above (cross-sequence block walk by
            // default — see [`AttnMode`]).
            self.attend_batch(kvb, layer, lane_seq, &positions, &q, &mut att);
            self.apply_linear_batch(&format!("{pre}wo"), &att, bsz, &mut tmp_d);
            for (xv, &o) in xs.iter_mut().zip(&tmp_d) {
                *xv += o;
            }
            // MLP.
            for b in 0..bsz {
                let xb = &xs[b * d..(b + 1) * d];
                self.norm_one(&format!("{pre}mlp_norm"), xb, d, &mut h[b * d..(b + 1) * d]);
            }
            match cfg.arch {
                Arch::Moe => {
                    let router = model.p(&format!("{pre}router"));
                    let ne = cfg.n_experts;
                    let mut gl = vec![0.0f32; bsz * ne];
                    {
                        let _scope = phase::scope(Phase::QuantMatmul);
                        matmul_nt(&h, &router.data, bsz, d, ne, &mut gl);
                    }
                    softmax_rows(&mut gl, bsz, ne);
                    let mut acc = vec![0.0f32; bsz * d];
                    for e in 0..ne {
                        self.apply_linear_batch(&format!("{pre}w_gate.{e}"), &h, bsz, &mut ffg);
                        self.apply_linear_batch(&format!("{pre}w_up.{e}"), &h, bsz, &mut ffu);
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = silu(*g) * u;
                        }
                        self.apply_linear_batch(&format!("{pre}w_down.{e}"), &ffg, bsz, &mut tmp_d);
                        for b in 0..bsz {
                            let gw = gl[b * ne + e];
                            for j in 0..d {
                                acc[b * d + j] += gw * tmp_d[b * d + j];
                            }
                        }
                    }
                    for (xv, &o) in xs.iter_mut().zip(&acc) {
                        *xv += o;
                    }
                }
                _ => {
                    self.apply_linear_batch(&format!("{pre}w_gate"), &h, bsz, &mut ffg);
                    self.apply_linear_batch(&format!("{pre}w_up"), &h, bsz, &mut ffu);
                    if cfg.arch == Arch::NonLlama {
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = gelu(*g) * u;
                        }
                    } else {
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = silu(*g) * u;
                        }
                    }
                    self.apply_linear_batch(&format!("{pre}w_down"), &ffg, bsz, &mut tmp_d);
                    for (xv, &o) in xs.iter_mut().zip(&tmp_d) {
                        *xv += o;
                    }
                }
            }
        }
        for b in 0..bsz {
            let xb = &xs[b * d..(b + 1) * d];
            self.norm_one("final_norm", xb, d, &mut h[b * d..(b + 1) * d]);
        }
        let head = model.p("lm_head");
        let mut logits = vec![0.0f32; bsz * cfg.vocab];
        {
            let _scope = phase::scope(Phase::QuantMatmul);
            matmul_nt(&h, &head.data, bsz, d, cfg.vocab, &mut logits);
        }
        kvb.advance(lane_seq);
        logits.chunks(cfg.vocab).map(|r| r.to_vec()).collect()
    }

    /// One attention pass over the batch for `layer`, dispatching on
    /// [`Generator::attn_mode`]. Both arms feed identical row ranges
    /// through the same chunked inner loops, so they are bit-exact; the
    /// fused arm additionally groups sequences by *physical* K/V block,
    /// so page tables aliased by [`PagedKv::fork_prefix`] load each
    /// shared block once per step instead of once per sequence.
    fn attend_batch(
        &self,
        kvb: &KvBatch,
        layer: usize,
        lane_seq: &[usize],
        positions: &[usize],
        q: &[f32],
        att: &mut [f32],
    ) {
        let (heads, hd) = (self.model.cfg.n_heads, self.model.cfg.head_dim());
        let d = heads * hd;
        // Inline cold-page decode inside the walk is attributed here,
        // not to `kv_decode` (which times explicit page re-heats).
        let _scope = phase::scope(Phase::Attention);
        match self.attn_mode {
            AttnMode::PerSeq => {
                for (b, &pos) in positions.iter().enumerate() {
                    let qb = &q[b * d..(b + 1) * d];
                    let attb = &mut att[b * d..(b + 1) * d];
                    match kvb {
                        KvBatch::Contig(caches) => {
                            let kc = &caches[lane_seq[b]].k[layer];
                            let vc = &caches[lane_seq[b]].v[layer];
                            blocked_attention(qb, attb, pos, heads, hd, |blk| {
                                let lo = blk * PAGE_ROWS * d;
                                let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
                                (&kc[lo..lo + rows * d], &vc[lo..lo + rows * d])
                            });
                        }
                        KvBatch::Paged { pool, seqs } => {
                            let pages = &seqs[lane_seq[b]].pages;
                            // KvBlock-typed blocks: hot pages pass their
                            // fp32 slices through unchanged (bit-exact
                            // with the slice closure this replaces), cold
                            // pages decode inline in the kernel.
                            blocked_attention_kv(qb, attb, pos, heads, hd, |blk| {
                                pool.kv_block(pages[blk], layer)
                            });
                        }
                    }
                }
            }
            AttnMode::Fused => {
                let mut lanes: Vec<AttnLane> = att
                    .chunks_exact_mut(d)
                    .enumerate()
                    .map(|(b, outb)| AttnLane {
                        q: &q[b * d..(b + 1) * d],
                        out: outb,
                        pos: positions[b],
                    })
                    .collect();
                match kvb {
                    KvBatch::Contig(caches) => {
                        fused_batch_attention(&mut lanes, heads, hd, |b, blk| {
                            let pos = positions[b];
                            let rows = (pos + 1 - blk * PAGE_ROWS).min(PAGE_ROWS);
                            let lo = blk * PAGE_ROWS * d;
                            let kc = &caches[lane_seq[b]].k[layer];
                            let vc = &caches[lane_seq[b]].v[layer];
                            // Contiguous slabs alias only across chunk
                            // lanes of the same sequence: keying by
                            // (sequence, block) groups exactly those.
                            let key = ((lane_seq[b] as u64) << 32) | blk as u64;
                            (key, &kc[lo..lo + rows * d], &vc[lo..lo + rows * d])
                        });
                    }
                    KvBatch::Paged { pool, seqs } => {
                        fused_batch_attention_kv(&mut lanes, heads, hd, |b, blk| {
                            // Physical page id as the grouping key:
                            // forked siblings aliasing a prefix page
                            // process it back to back, loading (or
                            // decoding) it once per group per step.
                            let page = seqs[lane_seq[b]].pages[blk];
                            (page as u64, pool.kv_block(page, layer))
                        });
                    }
                }
            }
        }
    }

    fn norm_one(&self, name: &str, x: &[f32], d: usize, y: &mut [f32]) {
        match self.model.cfg.arch {
            Arch::NonLlama => {
                let w = self.model.p(name);
                let b = self.model.p(&format!("{name}_bias"));
                layer_norm(x, &w.data, &b.data, 1, d, y);
            }
            _ => {
                let w = self.model.p(name);
                rms_norm(x, &w.data, 1, d, y);
            }
        }
    }

    /// Greedy generation: prefill the prompt token-by-token, then sample
    /// argmax until `max_new` tokens or ctx is full. Returns new tokens.
    pub fn generate(&self, prompt: &[u8], max_new: usize) -> Vec<u8> {
        let mut cache = KvCache::new(self.model);
        let mut logits = vec![0.0f32; self.model.cfg.vocab];
        for &t in prompt {
            logits = self.decode_one(t, &mut cache);
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            if cache.len >= self.model.cfg.ctx {
                break;
            }
            let next = argmax(&logits) as u8;
            out.push(next);
            logits = self.decode_one(next, &mut cache);
        }
        out
    }
}

/// Flatten per-sequence token chunks into one lane-major token stream
/// plus its lane → sequence map (chunk lanes stay consecutive and in
/// token order, which is what assigns them consecutive KV positions).
fn flatten_chunks(chunks: &[&[u8]]) -> (Vec<u8>, Vec<usize>) {
    let mut tokens = Vec::new();
    let mut lane_seq = Vec::new();
    for (s, chunk) in chunks.iter().enumerate() {
        assert!(!chunk.is_empty(), "empty chunk for sequence {s}");
        tokens.extend_from_slice(chunk);
        lane_seq.extend(std::iter::repeat(s).take(chunk.len()));
    }
    (tokens, lane_seq)
}

/// Regroup flat per-lane logits rows back into per-sequence chunks.
fn unflatten_rows(flat: Vec<Vec<f32>>, chunks: &[&[u8]]) -> Vec<Vec<Vec<f32>>> {
    let mut it = flat.into_iter();
    chunks
        .iter()
        .map(|chunk| (0..chunk.len()).map(|_| it.next().unwrap()).collect())
        .collect()
}

/// Streamed bytes for one batched decode step given a precomputed
/// [`Generator::weight_bytes_split`] — the single owner of the
/// amortization formula (the engine hot loop precomputes the split once
/// and calls this per round).
pub fn streamed_bytes_for_batch(split: (u64, u64, u64), batch: usize) -> u64 {
    let (packed, dense_linear, per_lane) = split;
    let tiles = batch.max(1).div_ceil(crate::model::qlinear::BATCH_TILE) as u64;
    packed * tiles + dense_linear + per_lane * batch as u64
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::tiny_model;
    use crate::model::NoHook;

    #[test]
    fn decode_matches_full_forward() {
        let m = tiny_model(1);
        let gen = Generator::dense(&m);
        let tokens: Vec<u8> = vec![5, 9, 1, 33, 7];
        let full = m.forward(&tokens, &mut NoHook);
        let v = m.cfg.vocab;
        let mut cache = KvCache::new(&m);
        let mut last = vec![];
        for &t in &tokens {
            last = gen.decode_one(t, &mut cache);
        }
        let want = &full[(tokens.len() - 1) * v..tokens.len() * v];
        for (a, b) in last.iter().zip(want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn generate_emits_tokens_below_vocab() {
        let m = tiny_model(2);
        let gen = Generator::dense(&m);
        let out = gen.generate(&[1, 2, 3], 10);
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|&t| (t as usize) < m.cfg.vocab));
    }

    #[test]
    fn generation_is_deterministic() {
        let m = tiny_model(3);
        let gen = Generator::dense(&m);
        assert_eq!(gen.generate(&[4, 5], 8), gen.generate(&[4, 5], 8));
    }

    #[test]
    fn quantized_generator_close_to_dense_at_4bit() {
        use crate::hessian::collect_hessians;
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = tiny_model(4);
        let calib: Vec<u8> = (0..128).map(|i| (i * 5 % 64) as u8).collect();
        let hs = collect_hessians(&m, &calib, 4, 32);
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        let gen_q = Generator::quantized(&qm.model, &qm);
        assert!(!gen_q.qlayers.is_empty());
        // The fused path must agree with the dense effective weights.
        let gen_dense = Generator::dense(&qm.model);
        let a = gen_q.generate(&[1, 2, 3, 4], 6);
        let b = gen_dense.generate(&[1, 2, 3, 4], 6);
        assert_eq!(a, b, "fused decode path diverged from dense w_eff");
    }

    #[test]
    fn weight_bytes_smaller_when_quantized() {
        use crate::hessian::collect_hessians;
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = tiny_model(5);
        let calib: Vec<u8> = (0..128).map(|i| (i % 64) as u8).collect();
        let hs = collect_hessians(&m, &calib, 2, 32);
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
        let gq = Generator::quantized(&qm.model, &qm);
        let gd = Generator::dense(&m);
        assert!(gq.weight_bytes_per_token() < gd.weight_bytes_per_token() / 4);
        // Batched streaming: B = 1 equals the per-token stream, and a
        // batched step streams strictly less than B sequential decodes
        // (only the fp32 lm_head scales with the batch).
        assert_eq!(gq.weight_bytes_streamed_per_step(1), gq.weight_bytes_per_token());
        assert!(gq.weight_bytes_streamed_per_step(8) < 8 * gq.weight_bytes_per_token());
        let (packed, dense_linear, per_lane) = gq.weight_bytes_split();
        assert!(packed > 0 && dense_linear == 0 && per_lane > 0);
    }

    /// Drive B sequences through `decode_batch` and, in parallel, B
    /// independent `decode_one` runs; the logits must agree at every step
    /// (prefill and greedy continuation).
    fn batch_parity(gen: &Generator, bsz: usize, tol: Option<f32>) {
        let m = gen.model;
        let plen = 3usize;
        let prompts: Vec<Vec<u8>> = (0..bsz)
            .map(|b| (0..plen).map(|i| ((i * 7 + b * 13 + 1) % 60) as u8).collect())
            .collect();
        let mut c_ref: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(m)).collect();
        let mut c_bat: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(m)).collect();
        let mut l_ref: Vec<Vec<f32>> = vec![Vec::new(); bsz];
        let mut l_bat: Vec<Vec<f32>> = Vec::new();
        let mut toks: Vec<u8> = vec![0; bsz];
        for step in 0..plen + 5 {
            for b in 0..bsz {
                toks[b] = if step < plen {
                    prompts[b][step]
                } else {
                    argmax(&l_ref[b]) as u8
                };
            }
            for b in 0..bsz {
                l_ref[b] = gen.decode_one(toks[b], &mut c_ref[b]);
            }
            let mut refs: Vec<&mut KvCache> = c_bat.iter_mut().collect();
            l_bat = gen.decode_batch(&toks, &mut refs);
            for b in 0..bsz {
                for (i, (x, y)) in l_bat[b].iter().zip(&l_ref[b]).enumerate() {
                    match tol {
                        Some(t) => assert!(
                            (x - y).abs() < t,
                            "step {step} lane {b} logit {i}: {x} vs {y}"
                        ),
                        None => assert!(
                            x.to_bits() == y.to_bits(),
                            "step {step} lane {b} logit {i}: {x} vs {y}"
                        ),
                    }
                }
            }
        }
        let _ = l_bat;
    }

    #[test]
    fn decode_batch_matches_sequential_dense() {
        let m = tiny_model(6);
        let gen = Generator::dense(&m);
        batch_parity(&gen, 4, Some(1e-5));
    }

    #[test]
    fn decode_batch_matches_sequential_quantized_exactly() {
        use crate::hessian::collect_hessians;
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = tiny_model(7);
        let calib: Vec<u8> = (0..128).map(|i| (i * 3 % 64) as u8).collect();
        let hs = collect_hessians(&m, &calib, 4, 32);
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
        let gen = Generator::quantized(&qm.model, &qm);
        assert!(!gen.qlayers.is_empty());
        // The fused E8P path must be bit-exact between batched and
        // sequential decode: every lane accumulates in the same order.
        batch_parity(&gen, 3, None);
    }

    /// Drive B paged sequences of *unequal* lengths against B sequential
    /// contiguous `decode_one` runs. Prompts are prefilled per sequence
    /// (so positions diverge), then the batch advances jointly; logits
    /// must agree at every joint step.
    fn paged_parity(gen: &Generator, bsz: usize, tol: Option<f32>) {
        let m = gen.model;
        let mut pool = KvPagePool::for_model(m, bsz * paged::pages_per_seq(&m.cfg));
        let prompts: Vec<Vec<u8>> = (0..bsz)
            .map(|b| {
                let plen = 2 + (b % 3); // unequal prompt lengths
                (0..plen).map(|i| ((i * 11 + b * 17 + 3) % 60) as u8).collect()
            })
            .collect();
        let mut c_ref: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(m)).collect();
        let mut kvs: Vec<PagedKv> = (0..bsz).map(|_| PagedKv::new()).collect();
        let mut l_ref: Vec<Vec<f32>> = vec![Vec::new(); bsz];
        // Per-sequence prefill: sequences end at different positions
        // (prefill logits parity is implied by the first joint step).
        for b in 0..bsz {
            for &t in &prompts[b] {
                l_ref[b] = gen.decode_one(t, &mut c_ref[b]);
                gen.decode_batch_paged(&[t], &mut pool, &mut [&mut kvs[b]]);
            }
        }
        // Joint batched decode over unequal positions.
        for step in 0..6 {
            let toks: Vec<u8> = (0..bsz).map(|b| argmax(&l_ref[b]) as u8).collect();
            for b in 0..bsz {
                l_ref[b] = gen.decode_one(toks[b], &mut c_ref[b]);
            }
            let batched = {
                let mut refs: Vec<&mut PagedKv> = kvs.iter_mut().collect();
                gen.decode_batch_paged(&toks, &mut pool, &mut refs)
            };
            for (b, row) in batched.into_iter().enumerate() {
                for (i, (x, y)) in row.iter().zip(&l_ref[b]).enumerate() {
                    match tol {
                        Some(t) => assert!(
                            (x - y).abs() < t,
                            "step {step} lane {b} logit {i}: {x} vs {y}"
                        ),
                        None => assert!(
                            x.to_bits() == y.to_bits(),
                            "step {step} lane {b} logit {i}: {x} vs {y}"
                        ),
                    }
                }
            }
        }
        // Everything allocated goes back to the pool on release.
        for kv in kvs.iter_mut() {
            kv.release(&mut pool);
        }
        assert_eq!(pool.pages_free(), pool.pages_total());
    }

    #[test]
    fn paged_decode_matches_contiguous_dense() {
        let m = tiny_model(9);
        let gen = Generator::dense(&m);
        for &bsz in &[1usize, 4] {
            paged_parity(&gen, bsz, Some(1e-5));
        }
    }

    #[test]
    fn paged_decode_matches_contiguous_quantized_exactly() {
        use crate::hessian::collect_hessians;
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = tiny_model(10);
        let calib: Vec<u8> = (0..128).map(|i| (i * 3 % 64) as u8).collect();
        let hs = collect_hessians(&m, &calib, 4, 32);
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
        let gen = Generator::quantized(&qm.model, &qm);
        assert!(!gen.qlayers.is_empty());
        // The paged layout must be bit-exact against sequential contiguous
        // decode for the fused E8P path, across batch sizes and unequal
        // sequence lengths.
        for &bsz in &[1usize, 4, 8] {
            paged_parity(&gen, bsz, None);
        }
    }

    /// Multi-page context so prompt prefixes span several KV pages, with
    /// power-of-two linear shapes so the fused E8P path applies.
    fn prefix_model(seed: u64) -> Model {
        let cfg = crate::model::ModelConfig {
            name: "tinypfx".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            vocab: 64,
            ctx: 4 * PAGE_ROWS,
            arch: Arch::Llama,
            n_experts: 2,
        };
        Model::random(cfg, seed)
    }

    /// Fork `bsz` sequences off one shared prompt prefix and decode them
    /// batched; an unshared control group prefills the identical tokens
    /// from scratch. Logits must match bit-for-bit at every step: the
    /// children's early page-table entries alias the parent's pages, and
    /// attention reads them through the same indirection the control
    /// group uses for its own pages.
    fn shared_prefix_parity(gen: &Generator, bsz: usize) {
        let m = gen.model;
        let prefix_len = PAGE_ROWS + 7; // one full page + a partial tail
        let prefix: Vec<u8> = (0..prefix_len).map(|i| ((i * 13 + 2) % 60) as u8).collect();
        let mut pool = KvPagePool::for_model(m, 2 * bsz * paged::pages_per_seq(&m.cfg));
        // Parent: prefill the shared prefix once.
        let mut parent = PagedKv::new();
        for &t in &prefix {
            gen.decode_batch_paged(&[t], &mut pool, &mut [&mut parent]);
        }
        let parent_pages = PagedKv::pages_needed(prefix_len);
        // Children fork the prefix; controls prefill it from scratch.
        let mut shared: Vec<PagedKv> = (0..bsz).map(|_| PagedKv::new()).collect();
        let mut control: Vec<PagedKv> = (0..bsz).map(|_| PagedKv::new()).collect();
        for b in 0..bsz {
            shared[b].fork_prefix(&mut pool, &parent, prefix_len);
            for &t in &prefix {
                gen.decode_batch_paged(&[t], &mut pool, &mut [&mut control[b]]);
            }
        }
        assert_eq!(pool.shared_pages(), parent_pages, "fork must share the prefix pages");
        // Unique per-lane suffix tokens diverge the sequences, then the
        // greedy continuation advances both groups through the same
        // batched call over a page boundary.
        let mut l_control: Vec<Vec<f32>> = vec![Vec::new(); bsz];
        for step in 0..PAGE_ROWS + 4 {
            let toks: Vec<u8> = (0..bsz)
                .map(|b| {
                    if step == 0 {
                        ((7 * b + 5) % 60) as u8
                    } else {
                        argmax(&l_control[b]) as u8
                    }
                })
                .collect();
            let l_shared = {
                let mut refs: Vec<&mut PagedKv> = shared.iter_mut().collect();
                gen.decode_batch_paged(&toks, &mut pool, &mut refs)
            };
            l_control = {
                let mut refs: Vec<&mut PagedKv> = control.iter_mut().collect();
                gen.decode_batch_paged(&toks, &mut pool, &mut refs)
            };
            for b in 0..bsz {
                for (i, (x, y)) in l_shared[b].iter().zip(&l_control[b]).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "step {step} lane {b} logit {i}: shared {x} vs unshared {y}"
                    );
                }
            }
        }
        // The fully occupied prefix page is still shared (only partial
        // tails are ever cloned), and releases return every page.
        assert!(pool.shared_pages() > 0, "full prefix pages should stay shared");
        for kv in shared.iter_mut().chain(control.iter_mut()) {
            kv.release(&mut pool);
        }
        parent.release(&mut pool);
        assert_eq!(pool.pages_free(), pool.pages_total());
    }

    #[test]
    fn shared_prefix_decode_matches_unshared_dense() {
        let m = prefix_model(12);
        let gen = Generator::dense(&m);
        for &bsz in &[2usize, 4, 8] {
            shared_prefix_parity(&gen, bsz);
        }
    }

    #[test]
    fn shared_prefix_decode_matches_unshared_quantized() {
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = prefix_model(13);
        // Identity Hessians: decode parity is independent of quantization
        // quality, and skipping calibration keeps the test fast.
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
        let gen = Generator::quantized(&qm.model, &qm);
        assert!(!gen.qlayers.is_empty());
        for &bsz in &[2usize, 4, 8] {
            shared_prefix_parity(&gen, bsz);
        }
    }

    /// Assert two runs' logits (steps × lanes × vocab) agree bit-for-bit.
    fn assert_logits_bitwise_eq(a: &[Vec<Vec<f32>>], b: &[Vec<Vec<f32>>], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: step count");
        for (step, (rows_a, rows_b)) in a.iter().zip(b).enumerate() {
            assert_eq!(rows_a.len(), rows_b.len(), "{what}: lane count at step {step}");
            for (lane, (ra, rb)) in rows_a.iter().zip(rows_b).enumerate() {
                for (i, (x, y)) in ra.iter().zip(rb).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "{what}: step {step} lane {lane} logit {i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    /// Drive an identical forked + unshared paged workload through two
    /// generators that differ only in [`AttnMode`]; every logits row
    /// (per-lane prefill and joint batched steps alike) must agree
    /// bitwise. Half the lanes fork the parent prefix (aliased page
    /// tables), half prefill it privately, and per-lane extras leave
    /// the batch at unequal positions.
    fn attn_mode_parity(gen_a: &Generator, gen_b: &Generator, bsz: usize) {
        let m = gen_a.model;
        let prefix_len = PAGE_ROWS + 7;
        let prefix: Vec<u8> = (0..prefix_len).map(|i| ((i * 13 + 2) % 60) as u8).collect();
        let run = |gen: &Generator| -> Vec<Vec<Vec<f32>>> {
            let mut pool = KvPagePool::for_model(m, 2 * bsz * paged::pages_per_seq(&m.cfg) + 4);
            let mut parent = PagedKv::new();
            let mut steps_out = Vec::new();
            for &t in &prefix {
                steps_out.push(gen.decode_batch_paged(&[t], &mut pool, &mut [&mut parent]));
            }
            let mut kvs: Vec<PagedKv> = (0..bsz).map(|_| PagedKv::new()).collect();
            for b in 0..bsz {
                if b % 2 == 0 {
                    kvs[b].fork_prefix(&mut pool, &parent, prefix_len);
                } else {
                    for &t in &prefix {
                        let l = gen.decode_batch_paged(&[t], &mut pool, &mut [&mut kvs[b]]);
                        steps_out.push(l);
                    }
                }
                // Unequal positions: up to two private extra tokens.
                for j in 0..b % 3 {
                    let t = (j * 9 + b + 1) as u8;
                    steps_out.push(gen.decode_batch_paged(&[t], &mut pool, &mut [&mut kvs[b]]));
                }
            }
            for step in 0..PAGE_ROWS + 2 {
                let toks: Vec<u8> =
                    (0..bsz).map(|b| ((step * 7 + b * 11 + 1) % 60) as u8).collect();
                let mut refs: Vec<&mut PagedKv> = kvs.iter_mut().collect();
                steps_out.push(gen.decode_batch_paged(&toks, &mut pool, &mut refs));
            }
            steps_out
        };
        let outs_a = run(gen_a);
        let outs_b = run(gen_b);
        assert_logits_bitwise_eq(&outs_a, &outs_b, "fused vs per-seq paged decode");
    }

    #[test]
    fn fused_attention_matches_per_seq_walk_dense() {
        let m = prefix_model(14);
        let gen_fused = Generator::dense(&m);
        assert_eq!(gen_fused.attn_mode, AttnMode::Fused, "fused must be the default");
        let mut gen_perseq = Generator::dense(&m);
        gen_perseq.attn_mode = AttnMode::PerSeq;
        for &bsz in &[1usize, 4, 8, 16] {
            attn_mode_parity(&gen_fused, &gen_perseq, bsz);
        }
    }

    #[test]
    fn fused_attention_matches_per_seq_walk_quantized() {
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = prefix_model(15);
        // Identity Hessians: kernel parity is independent of
        // quantization quality (see the shared-prefix tests).
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
        let gen_fused = Generator::quantized(&qm.model, &qm);
        assert!(!gen_fused.qlayers.is_empty());
        let mut gen_perseq = Generator::quantized(&qm.model, &qm);
        gen_perseq.attn_mode = AttnMode::PerSeq;
        for &bsz in &[4usize, 8] {
            attn_mode_parity(&gen_fused, &gen_perseq, bsz);
        }
    }

    #[test]
    fn fused_attention_contiguous_matches_per_seq_walk() {
        // The contiguous backend takes the unique-key path through the
        // fused kernel (no aliasing); logits must still match the
        // per-sequence walk bitwise.
        let m = tiny_model(16);
        let gen_fused = Generator::dense(&m);
        let mut gen_perseq = Generator::dense(&m);
        gen_perseq.attn_mode = AttnMode::PerSeq;
        for &bsz in &[1usize, 4, 8] {
            let run = |gen: &Generator| -> Vec<Vec<Vec<f32>>> {
                let mut caches: Vec<KvCache> = (0..bsz).map(|_| KvCache::new(&m)).collect();
                let mut out = Vec::new();
                for step in 0..10 {
                    let toks: Vec<u8> =
                        (0..bsz).map(|b| ((step * 5 + b * 3 + 2) % 60) as u8).collect();
                    let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                    out.push(gen.decode_batch(&toks, &mut refs));
                }
                out
            };
            let outs_a = run(&gen_fused);
            let outs_b = run(&gen_perseq);
            assert_logits_bitwise_eq(&outs_a, &outs_b, "fused vs per-seq contiguous decode");
        }
    }

    #[test]
    fn kv_cache_grows_lazily() {
        let m = tiny_model(8);
        let gen = Generator::dense(&m);
        let mut cache = KvCache::new(&m);
        assert_eq!(cache.allocated_f32(), 0, "admission should allocate nothing");
        gen.decode_one(3, &mut cache);
        let after_one = cache.allocated_f32();
        let full = 2 * m.cfg.n_layers * m.cfg.ctx * m.cfg.d_model;
        assert!(after_one > 0 && after_one <= full);
        // tiny_model has ctx = GROW_ROWS, so one slab is the full cache;
        // the invariant that matters: growth is bounded by ctx and the
        // decoded prefix stays intact.
        for t in 0..8 {
            gen.decode_one(t as u8, &mut cache);
        }
        assert!(cache.allocated_f32() <= full);
        assert_eq!(cache.len, 9);
    }

    #[test]
    fn kv_cache_truncate_replays_bitwise() {
        // Decode, roll back, re-decode the same tokens: the replayed
        // logits must be bit-identical to the first pass (stale rows
        // past the truncation point are never read and are fully
        // overwritten) — the contiguous rollback the speculative
        // verify/reject path relies on.
        let m = tiny_model(17);
        let gen = Generator::dense(&m);
        let tokens: Vec<u8> = vec![5, 9, 1, 33, 7, 12];
        let mut cache = KvCache::new(&m);
        let mut first = Vec::new();
        for &t in &tokens {
            first.push(gen.decode_one(t, &mut cache));
        }
        cache.truncate(3);
        assert_eq!(cache.len, 3);
        for (step, &t) in tokens.iter().enumerate().skip(3) {
            let replay = gen.decode_one(t, &mut cache);
            for (i, (x, y)) in replay.iter().zip(&first[step]).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "replayed step {step} logit {i}: {x} vs {y}"
                );
            }
        }
        assert_eq!(cache.len, tokens.len());
    }

    #[test]
    fn paged_decode_allocates_on_demand() {
        let m = tiny_model(11);
        let gen = Generator::dense(&m);
        let mut pool = KvPagePool::for_model(&m, 4);
        let mut kv = PagedKv::new();
        assert_eq!(kv.allocated_f32(&pool), 0, "admission pins no pages");
        gen.decode_batch_paged(&[3], &mut pool, &mut [&mut kv]);
        // tiny_model ctx = PAGE_ROWS: one page covers the whole context.
        assert_eq!(kv.pages.len(), 1);
        assert_eq!(pool.pages_in_use(), 1);
        kv.release(&mut pool);
        assert_eq!(pool.pages_in_use(), 0);
    }

    /// Decode with the 2-bit compressed KV tier engaged. The numeric
    /// *values* drift (cold pages hold E8P reconstructions — the tight
    /// per-kernel parity is pinned by the offline-decode oracle in
    /// `paged::tests`), but every structural invariant must hold
    /// exactly: batched decode is bit-identical to running each
    /// sequence alone at B ∈ {1, 4, 8}, CoW forks sharing cold pages
    /// stay bit-identical to each other, and the drift against an
    /// fp32-KV run stays finite and bounded.
    #[test]
    fn paged_decode_with_quantized_kv_is_batch_invariant_and_bounded() {
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        use paged::KvQuantSpec;
        let m = prefix_model(21);
        // Identity Hessians: the invariants under test are independent
        // of weight-quantization quality, and skipping calibration
        // keeps the test fast.
        let hs = BTreeMap::new();
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
        let gen = Generator::quantized(&qm.model, &qm);
        assert!(!gen.qlayers.is_empty());
        let quant = Some(KvQuantSpec { bits: 2, hot_pages: 0 });
        let steps = 2 * PAGE_ROWS + 6; // spans three pages; two go cold
        // Fixed token schedule so every run sees identical inputs
        // regardless of numeric drift.
        let tok = |step: usize, lane: usize| ((step * 7 + lane * 13 + 3) % 60) as u8;
        let run = |lane_ids: &[usize], q: Option<KvQuantSpec>| -> Vec<Vec<Vec<f32>>> {
            let bsz = lane_ids.len();
            let mut pool = KvPagePool::for_model_quant(
                &m,
                2 * bsz * paged::pages_per_seq(&m.cfg),
                q,
            );
            let mut kvs: Vec<PagedKv> = (0..bsz).map(|_| PagedKv::new()).collect();
            let mut out = Vec::new();
            for step in 0..steps {
                let toks: Vec<u8> = lane_ids.iter().map(|&l| tok(step, l)).collect();
                let mut refs: Vec<&mut PagedKv> = kvs.iter_mut().collect();
                out.push(gen.decode_batch_paged(&toks, &mut pool, &mut refs));
            }
            if q.is_some() {
                assert!(pool.pages_quantized_total() > 0, "compression never engaged");
            }
            for kv in kvs.iter_mut() {
                kv.release(&mut pool);
            }
            assert_eq!(pool.pages_free(), pool.pages_total());
            out
        };
        // Batch invariance: lane b of the batched run is bit-identical
        // to the same token schedule run alone in its own pool.
        let solo: Vec<_> = (0..8).map(|b| run(&[b], quant)).collect();
        for &bsz in &[1usize, 4, 8] {
            let ids: Vec<usize> = (0..bsz).collect();
            let batched = run(&ids, quant);
            for b in 0..bsz {
                for step in 0..steps {
                    for (i, (x, y)) in batched[step][b].iter().zip(&solo[b][step][0]).enumerate() {
                        assert!(
                            x.to_bits() == y.to_bits(),
                            "B={bsz} step {step} lane {b} logit {i}: {x} vs {y}"
                        );
                    }
                }
            }
        }
        // Bounded drift vs fp32 KV: a smoke bound — it catches NaN
        // scales and garbage decodes, while exact numeric parity is
        // the paged oracle tests' job.
        let fp32 = run(&[0], None);
        let (last_q, last_f) = (&solo[0][steps - 1][0], &fp32[steps - 1][0]);
        let (mut d2, mut r2) = (0.0f64, 0.0f64);
        for (x, y) in last_q.iter().zip(last_f) {
            assert!(x.is_finite(), "quantized-KV logit not finite: {x}");
            d2 += f64::from(x - y).powi(2);
            r2 += f64::from(*y).powi(2);
        }
        assert!(
            d2.sqrt() <= 5.0 * r2.sqrt() + 1e-3,
            "quantized-KV drift unbounded: |Δ|={} vs |ref|={}",
            d2.sqrt(),
            r2.sqrt()
        );
        // CoW forks over a *cold* shared prefix: children forked off a
        // quantized parent page decode the same continuation
        // bit-identically in one batch.
        let mut pool =
            KvPagePool::for_model_quant(&m, 4 * paged::pages_per_seq(&m.cfg), quant);
        let mut parent = PagedKv::new();
        for step in 0..PAGE_ROWS + 2 {
            gen.decode_batch_paged(&[tok(step, 0)], &mut pool, &mut [&mut parent]);
        }
        assert!(pool.cold_pages() > 0, "parent prefix page should be cold");
        let mut f1 = PagedKv::new();
        f1.fork_prefix(&mut pool, &parent, parent.len);
        let mut f2 = PagedKv::new();
        f2.fork_prefix(&mut pool, &parent, parent.len);
        for step in 0..6 {
            let t = tok(step, 1);
            let rows = gen.decode_batch_paged(&[t, t], &mut pool, &mut [&mut f1, &mut f2]);
            for (i, (x, y)) in rows[0].iter().zip(&rows[1]).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "forked lanes diverged at step {step} logit {i}: {x} vs {y}"
                );
            }
        }
        for kv in [&mut f1, &mut f2, &mut parent] {
            kv.release(&mut pool);
        }
        assert_eq!(pool.pages_free(), pool.pages_total());
    }
}
