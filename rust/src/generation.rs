//! Autoregressive generation with a KV cache — the workload behind
//! Tables 5/6 (generation throughput) and the serving engine's native
//! fallback path. Supports dense (fp) weights and the fused E8P decode
//! hot path per linear layer.

use std::collections::BTreeMap;

use crate::linalg::hadamard::{fwht_f32, HadTransform};
use crate::model::ops::*;
use crate::model::qlinear::QuantMatvec;
use crate::model::{Arch, Model};

/// Apply a scaled orthogonal Hadamard transform to an f32 vector
/// (pure-FWHT fast path; f64 round-trip for the H_q ⊗ H_p case).
pub fn had_apply_f32(t: &HadTransform, x: &mut [f32]) {
    if t.q == 1 {
        fwht_f32(x);
        let s = 1.0 / (t.n as f32).sqrt();
        for v in x.iter_mut() {
            *v *= s;
        }
    } else {
        let mut buf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        t.apply(&mut buf);
        for (o, v) in x.iter_mut().zip(buf) {
            *o = v as f32;
        }
    }
}

pub fn had_apply_inverse_f32(t: &HadTransform, x: &mut [f32]) {
    if t.q == 1 {
        fwht_f32(x); // Sylvester H is symmetric
        let s = 1.0 / (t.n as f32).sqrt();
        for v in x.iter_mut() {
            *v *= s;
        }
    } else {
        let mut buf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        t.apply_inverse(&mut buf);
        for (o, v) in x.iter_mut().zip(buf) {
            *o = v as f32;
        }
    }
}

/// Per-sequence KV cache.
pub struct KvCache {
    /// per layer: (ctx, d) k and v rows.
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub len: usize,
}

impl KvCache {
    pub fn new(model: &Model) -> Self {
        let (l, ctx, d) = (model.cfg.n_layers, model.cfg.ctx, model.cfg.d_model);
        KvCache {
            k: vec![vec![0.0; ctx * d]; l],
            v: vec![vec![0.0; ctx * d]; l],
            len: 0,
        }
    }
}

/// How each linear layer is applied at decode time.
pub enum DecodeLinear<'a> {
    Dense,
    /// Fused E8P decode path (with RHT around it).
    Quant(&'a QuantMatvec),
}

/// Generator with per-layer quantized matvec overrides.
pub struct Generator<'a> {
    pub model: &'a Model,
    pub qlayers: BTreeMap<String, QuantMatvec>,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl<'a> Generator<'a> {
    pub fn dense(model: &'a Model) -> Self {
        Generator {
            model,
            qlayers: BTreeMap::new(),
            _marker: Default::default(),
        }
    }

    /// Build from a quantized model's packed layers (E8P methods only).
    pub fn quantized(model: &'a Model, qm: &crate::qmodel::QuantizedModel) -> Self {
        let mut qlayers = BTreeMap::new();
        for (name, ql) in &qm.layers {
            if let Some(p) = &ql.packed {
                qlayers.insert(name.clone(), QuantMatvec::from_packed(ql.m, ql.n, p));
            }
        }
        Generator {
            model,
            qlayers,
            _marker: Default::default(),
        }
    }

    fn apply_linear(&self, name: &str, x: &[f32], y: &mut [f32]) {
        if let Some(qm) = self.qlayers.get(name) {
            if qm.n.is_power_of_two() && qm.m.is_power_of_two() {
                qm.matvec(x, y);
                return;
            }
        }
        let w = self.model.p(name);
        let (m, n) = (w.shape[0], w.shape[1]);
        crate::model::qlinear::dense_matvec(&w.data, x, m, n, y);
    }

    /// Bytes of weights streamed per decoded token.
    pub fn weight_bytes_per_token(&self) -> u64 {
        let mut total = 0u64;
        for name in self.model.cfg.linear_names() {
            if let Some(qm) = self.qlayers.get(&name) {
                total += qm.bytes_per_matvec();
            } else {
                let w = self.model.p(&name);
                total += (w.data.len() * 4) as u64;
            }
        }
        // embed row + head also stream (fp32).
        total += (self.model.p("lm_head").data.len() * 4) as u64;
        total
    }

    /// Advance one token, returning the logits row.
    pub fn decode_one(&self, token: u8, cache: &mut KvCache) -> Vec<f32> {
        let cfg = &self.model.cfg;
        let (d, heads, hd, ff) = (cfg.d_model, cfg.n_heads, cfg.head_dim(), cfg.d_ff);
        let pos = cache.len;
        assert!(pos < cfg.ctx, "KV cache full");
        let model = self.model;
        let (rope_cos, rope_sin) = {
            // RoPE tables are owned by Model (private); recompute lazily:
            // cheap at hd ≤ 64, but cache anyway via thread_local.
            thread_local! {
                static TABLES: std::cell::RefCell<Option<(usize, usize, Vec<f32>, Vec<f32>)>> =
                    const { std::cell::RefCell::new(None) };
            }
            TABLES.with(|t| {
                let mut t = t.borrow_mut();
                let need = match &*t {
                    Some((c, h, _, _)) => *c != cfg.ctx || *h != hd,
                    None => true,
                };
                if need {
                    let (c, s) = rope_tables(cfg.ctx, hd);
                    *t = Some((cfg.ctx, hd, c, s));
                }
                let (_, _, c, s) = t.as_ref().unwrap();
                (c.clone(), s.clone())
            })
        };

        let embed = model.p("embed");
        let mut x: Vec<f32> = embed.data[token as usize * d..(token as usize + 1) * d].to_vec();
        if cfg.arch == Arch::NonLlama {
            let pe = model.p("pos_embed");
            for j in 0..d {
                x[j] += pe.data[pos * d + j];
            }
        }

        let mut h = vec![0.0f32; d];
        let mut q = vec![0.0f32; d];
        let mut kx = vec![0.0f32; d];
        let mut vx = vec![0.0f32; d];
        let mut att = vec![0.0f32; d];
        let mut tmp_d = vec![0.0f32; d];
        let mut ffg = vec![0.0f32; ff];
        let mut ffu = vec![0.0f32; ff];

        for layer in 0..cfg.n_layers {
            let pre = format!("layers.{layer}.");
            self.norm_one(&format!("{pre}attn_norm"), &x, d, &mut h);
            self.apply_linear(&format!("{pre}wq"), &h, &mut q);
            self.apply_linear(&format!("{pre}wk"), &h, &mut kx);
            self.apply_linear(&format!("{pre}wv"), &h, &mut vx);
            if cfg.arch != Arch::NonLlama {
                rope_apply(&mut q, heads, hd, pos, &rope_cos, &rope_sin);
                rope_apply(&mut kx, heads, hd, pos, &rope_cos, &rope_sin);
            }
            cache.k[layer][pos * d..(pos + 1) * d].copy_from_slice(&kx);
            cache.v[layer][pos * d..(pos + 1) * d].copy_from_slice(&vx);
            // Attention over cache[0..=pos].
            let kc = &cache.k[layer];
            let vc = &cache.v[layer];
            let scale = 1.0 / (hd as f32).sqrt();
            for hh in 0..heads {
                let qh = &q[hh * hd..(hh + 1) * hd];
                let mut scores = vec![0.0f32; pos + 1];
                for t in 0..=pos {
                    let kt = &kc[t * d + hh * hd..t * d + (hh + 1) * hd];
                    let mut s = 0.0f32;
                    for j in 0..hd {
                        s += qh[j] * kt[j];
                    }
                    scores[t] = s * scale;
                }
                softmax_rows(&mut scores, 1, pos + 1);
                let out = &mut att[hh * hd..(hh + 1) * hd];
                out.iter_mut().for_each(|v| *v = 0.0);
                for (t, &sc) in scores.iter().enumerate() {
                    let vt = &vc[t * d + hh * hd..t * d + (hh + 1) * hd];
                    for j in 0..hd {
                        out[j] += sc * vt[j];
                    }
                }
            }
            self.apply_linear(&format!("{pre}wo"), &att, &mut tmp_d);
            for (xv, &o) in x.iter_mut().zip(&tmp_d) {
                *xv += o;
            }
            // MLP.
            self.norm_one(&format!("{pre}mlp_norm"), &x, d, &mut h);
            match cfg.arch {
                Arch::Moe => {
                    let router = model.p(&format!("{pre}router"));
                    let ne = cfg.n_experts;
                    let mut gl = vec![0.0f32; ne];
                    matmul_nt(&h, &router.data, 1, d, ne, &mut gl);
                    softmax_rows(&mut gl, 1, ne);
                    let mut acc = vec![0.0f32; d];
                    for e in 0..ne {
                        self.apply_linear(&format!("{pre}w_gate.{e}"), &h, &mut ffg);
                        self.apply_linear(&format!("{pre}w_up.{e}"), &h, &mut ffu);
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = silu(*g) * u;
                        }
                        self.apply_linear(&format!("{pre}w_down.{e}"), &ffg, &mut tmp_d);
                        for j in 0..d {
                            acc[j] += gl[e] * tmp_d[j];
                        }
                    }
                    for (xv, &o) in x.iter_mut().zip(&acc) {
                        *xv += o;
                    }
                }
                _ => {
                    self.apply_linear(&format!("{pre}w_gate"), &h, &mut ffg);
                    self.apply_linear(&format!("{pre}w_up"), &h, &mut ffu);
                    if cfg.arch == Arch::NonLlama {
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = gelu(*g) * u;
                        }
                    } else {
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = silu(*g) * u;
                        }
                    }
                    self.apply_linear(&format!("{pre}w_down"), &ffg, &mut tmp_d);
                    for (xv, &o) in x.iter_mut().zip(&tmp_d) {
                        *xv += o;
                    }
                }
            }
        }
        self.norm_one("final_norm", &x, d, &mut h);
        let head = model.p("lm_head");
        let mut logits = vec![0.0f32; cfg.vocab];
        matmul_nt(&h, &head.data, 1, d, cfg.vocab, &mut logits);
        cache.len += 1;
        logits
    }

    fn norm_one(&self, name: &str, x: &[f32], d: usize, y: &mut [f32]) {
        match self.model.cfg.arch {
            Arch::NonLlama => {
                let w = self.model.p(name);
                let b = self.model.p(&format!("{name}_bias"));
                layer_norm(x, &w.data, &b.data, 1, d, y);
            }
            _ => {
                let w = self.model.p(name);
                rms_norm(x, &w.data, 1, d, y);
            }
        }
    }

    /// Greedy generation: prefill the prompt token-by-token, then sample
    /// argmax until `max_new` tokens or ctx is full. Returns new tokens.
    pub fn generate(&self, prompt: &[u8], max_new: usize) -> Vec<u8> {
        let mut cache = KvCache::new(self.model);
        let mut logits = vec![0.0f32; self.model.cfg.vocab];
        for &t in prompt {
            logits = self.decode_one(t, &mut cache);
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            if cache.len >= self.model.cfg.ctx {
                break;
            }
            let next = argmax(&logits) as u8;
            out.push(next);
            logits = self.decode_one(next, &mut cache);
        }
        out
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::tiny_model;
    use crate::model::NoHook;

    #[test]
    fn decode_matches_full_forward() {
        let m = tiny_model(1);
        let gen = Generator::dense(&m);
        let tokens: Vec<u8> = vec![5, 9, 1, 33, 7];
        let full = m.forward(&tokens, &mut NoHook);
        let v = m.cfg.vocab;
        let mut cache = KvCache::new(&m);
        let mut last = vec![];
        for &t in &tokens {
            last = gen.decode_one(t, &mut cache);
        }
        let want = &full[(tokens.len() - 1) * v..tokens.len() * v];
        for (a, b) in last.iter().zip(want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn generate_emits_tokens_below_vocab() {
        let m = tiny_model(2);
        let gen = Generator::dense(&m);
        let out = gen.generate(&[1, 2, 3], 10);
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|&t| (t as usize) < m.cfg.vocab));
    }

    #[test]
    fn generation_is_deterministic() {
        let m = tiny_model(3);
        let gen = Generator::dense(&m);
        assert_eq!(gen.generate(&[4, 5], 8), gen.generate(&[4, 5], 8));
    }

    #[test]
    fn quantized_generator_close_to_dense_at_4bit() {
        use crate::hessian::collect_hessians;
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = tiny_model(4);
        let calib: Vec<u8> = (0..128).map(|i| (i * 5 % 64) as u8).collect();
        let hs = collect_hessians(&m, &calib, 4, 32);
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 4, ft: false }, 1).unwrap();
        let gen_q = Generator::quantized(&qm.model, &qm);
        assert!(!gen_q.qlayers.is_empty());
        // The fused path must agree with the dense effective weights.
        let gen_dense = Generator::dense(&qm.model);
        let a = gen_q.generate(&[1, 2, 3, 4], 6);
        let b = gen_dense.generate(&[1, 2, 3, 4], 6);
        assert_eq!(a, b, "fused decode path diverged from dense w_eff");
    }

    #[test]
    fn weight_bytes_smaller_when_quantized() {
        use crate::hessian::collect_hessians;
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        let m = tiny_model(5);
        let calib: Vec<u8> = (0..128).map(|i| (i % 64) as u8).collect();
        let hs = collect_hessians(&m, &calib, 2, 32);
        let qm = quantize_model(&m, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
        let gq = Generator::quantized(&qm.model, &qm);
        let gd = Generator::dense(&m);
        assert!(gq.weight_bytes_per_token() < gd.weight_bytes_per_token() / 4);
    }
}
