//! Incoherence processing (paper §3, Algorithms 3–4, Appendix A).
//!
//! Conjugates W and H by structured random orthogonal transforms so that
//! the result is μ-incoherent with high probability:
//!
//! * **RHT** (QuIP#): x → H·(s ⊙ x) with H a (scaled) Hadamard transform
//!   and s a random ±1 vector — Algorithm 3.
//! * **RFFT** (fallback for awkward dimensions): x → F·(φ ⊙ x) with F the
//!   unitary FFT over pairs and φ random unit phases — Algorithm 4.
//! * **Kron** (QuIP baseline, Chee et al. 2023): x → (A ⊗ B)·x with A, B
//!   dense random orthogonal factors of size ≈ √n.
//!
//! The proxy objective is preserved exactly:
//! tr((UWVᵀ)(VHVᵀ)(VWᵀUᵀ)) = tr(WHWᵀ).

use crate::linalg::fft::fft_unitary;
use crate::linalg::hadamard::HadTransform;
use crate::linalg::ldl::sym_eig;
use crate::linalg::Matrix;
use crate::util::rng::Pcg64;
use crate::util::threadpool;

/// Which structured transform family to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IncoherenceKind {
    Rht,
    Rfft,
    Kron2,
}

/// One side's structured random orthogonal transform.
pub enum Transform {
    /// x → Had(s ⊙ x), s ∈ {±1}^n (the paper stores s as the "sign
    /// vector" S_U/S_V; fine-tuning later relaxes it to reals).
    Rht { t: HadTransform, s: Vec<f64> },
    /// x → unpack(F(φ ⊙ pack(x))) over n/2 complex pairs.
    Rfft { cos: Vec<f64>, sin: Vec<f64> },
    /// x → (A ⊗ B) x with dense orthogonal A (a×a), B (b×b), n = a·b.
    Kron { a: Matrix, b: Matrix },
}

/// Random orthogonal matrix via modified Gram–Schmidt on a Gaussian
/// matrix (Haar for our purposes).
pub fn random_orthogonal(n: usize, rng: &mut Pcg64) -> Matrix {
    let g = Matrix::gaussian(n, n, 1.0, rng);
    let mut q = Matrix::zeros(n, n);
    for j in 0..n {
        let mut v: Vec<f64> = (0..n).map(|i| g[(i, j)]).collect();
        for k in 0..j {
            let mut dot = 0.0;
            for i in 0..n {
                dot += q[(i, k)] * v[i];
            }
            for i in 0..n {
                v[i] -= dot * q[(i, k)];
            }
        }
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
        for i in 0..n {
            q[(i, j)] = v[i] / norm;
        }
    }
    q
}

/// Split n = a·b with a, b as close to √n as possible (QuIP's 2-factor
/// Kronecker shapes).
pub fn balanced_factor(n: usize) -> (usize, usize) {
    let mut best = (1, n);
    let mut a = 1;
    while a * a <= n {
        if n % a == 0 {
            best = (a, n / a);
        }
        a += 1;
    }
    best
}

impl Transform {
    pub fn new(kind: IncoherenceKind, n: usize, rng: &mut Pcg64) -> Transform {
        match kind {
            IncoherenceKind::Rht => {
                let t = HadTransform::new(n)
                    .unwrap_or_else(|| panic!("no Hadamard factorization for n={n}"));
                let s = rng.sign_vec(n).into_iter().map(|v| v as f64).collect();
                Transform::Rht { t, s }
            }
            IncoherenceKind::Rfft => {
                assert!(n % 2 == 0, "RFFT needs even n, got {n}");
                let half = n / 2;
                let theta: Vec<f64> = (0..half)
                    .map(|_| rng.f64() * 2.0 * std::f64::consts::PI)
                    .collect();
                Transform::Rfft {
                    cos: theta.iter().map(|t| t.cos()).collect(),
                    sin: theta.iter().map(|t| t.sin()).collect(),
                }
            }
            IncoherenceKind::Kron2 => {
                let (a, b) = balanced_factor(n);
                Transform::Kron {
                    a: random_orthogonal(a, rng),
                    b: random_orthogonal(b, rng),
                }
            }
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            Transform::Rht { t, .. } => t.n,
            Transform::Rfft { cos, .. } => cos.len() * 2,
            Transform::Kron { a, b } => a.rows * b.rows,
        }
    }

    /// The stored randomization vector, for fine-tuning (RHT signs). The
    /// RFFT/Kron variants have no sign vector to tune.
    pub fn sign_vec(&self) -> Option<&[f64]> {
        match self {
            Transform::Rht { s, .. } => Some(s),
            _ => None,
        }
    }

    pub fn sign_vec_mut(&mut self) -> Option<&mut Vec<f64>> {
        match self {
            Transform::Rht { s, .. } => Some(s),
            _ => None,
        }
    }

    /// y = T x.
    pub fn apply(&self, x: &mut [f64]) {
        match self {
            Transform::Rht { t, s } => {
                for (v, si) in x.iter_mut().zip(s) {
                    *v *= si;
                }
                t.apply(x);
            }
            Transform::Rfft { cos, sin } => {
                let half = cos.len();
                let mut re = vec![0.0; half];
                let mut im = vec![0.0; half];
                for j in 0..half {
                    // phase multiply: (x0 + i x1) * e^{iθ}
                    let (x0, x1) = (x[2 * j], x[2 * j + 1]);
                    re[j] = x0 * cos[j] - x1 * sin[j];
                    im[j] = x0 * sin[j] + x1 * cos[j];
                }
                fft_unitary(&mut re, &mut im, false);
                for j in 0..half {
                    x[2 * j] = re[j];
                    x[2 * j + 1] = im[j];
                }
            }
            Transform::Kron { a, b } => {
                // (A ⊗ B) x : view x as (a.rows × b.rows) row-major X,
                // result = A X Bᵀ.
                let (ar, br) = (a.rows, b.rows);
                let xm = Matrix::from_vec(ar, br, x.to_vec());
                let y = a.matmul(&xm).matmul_transb(b);
                x.copy_from_slice(&y.data);
            }
        }
    }

    /// y = Tᵀ x (inverse, since T is orthogonal).
    pub fn apply_inverse(&self, x: &mut [f64]) {
        match self {
            Transform::Rht { t, s } => {
                t.apply_inverse(x);
                for (v, si) in x.iter_mut().zip(s) {
                    *v *= si; // signs are ±1 ⇒ s⁻¹ = s (exact before FT)
                }
            }
            Transform::Rfft { cos, sin } => {
                let half = cos.len();
                let mut re = vec![0.0; half];
                let mut im = vec![0.0; half];
                for j in 0..half {
                    re[j] = x[2 * j];
                    im[j] = x[2 * j + 1];
                }
                fft_unitary(&mut re, &mut im, true);
                for j in 0..half {
                    // conj phase multiply
                    let (r, i) = (re[j], im[j]);
                    x[2 * j] = r * cos[j] + i * sin[j];
                    x[2 * j + 1] = -r * sin[j] + i * cos[j];
                }
            }
            Transform::Kron { a, b } => {
                let (ar, br) = (a.rows, b.rows);
                let xm = Matrix::from_vec(ar, br, x.to_vec());
                // (A ⊗ B)ᵀ x = Aᵀ X B
                let y = a.transpose().matmul(&xm).matmul(b);
                x.copy_from_slice(&y.data);
            }
        }
    }

    /// Core inverse *without* the sign multiplication: x → Hᵀx for RHT
    /// (full inverse for RFFT/Kron, which have no separable sign vector).
    /// Lets fine-tuning split W_eff = diag(s_u)·A·diag(s_v) with A frozen.
    pub fn apply_core_inverse(&self, x: &mut [f64]) {
        match self {
            Transform::Rht { t, .. } => t.apply_inverse(x),
            _ => self.apply_inverse(x),
        }
    }

    /// Materialize as a dense matrix (tests only).
    pub fn dense(&self) -> Matrix {
        let n = self.dim();
        let mut m = Matrix::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            self.apply(&mut e);
            for i in 0..n {
                m[(i, j)] = e[i];
            }
        }
        m
    }
}

/// Both sides of the conjugation for one weight matrix:
/// W̃ = T_U W T_Vᵀ, H̃ = T_V H T_Vᵀ.
pub struct IncoherenceCtx {
    pub u: Transform,
    pub v: Transform,
    pub kind: IncoherenceKind,
}

impl IncoherenceCtx {
    /// Fresh random context for an m×n weight matrix.
    pub fn new(kind: IncoherenceKind, m: usize, n: usize, rng: &mut Pcg64) -> Self {
        let mut ru = rng.fork(1);
        let mut rv = rng.fork(2);
        IncoherenceCtx {
            u: Transform::new(kind, m, &mut ru),
            v: Transform::new(kind, n, &mut rv),
            kind,
        }
    }

    /// W̃ = T_U W T_Vᵀ (Algorithm 3 line 2). Parallel over rows/cols.
    pub fn process_w(&self, w: &Matrix) -> Matrix {
        let mut out = w.clone();
        // Right side: each row r ← T_V r  (since (W T_Vᵀ)ᵢ. = T_V(Wᵢ.)).
        let v = &self.v;
        threadpool::par_rows(&mut out.data, out.cols, |_, row| {
            v.apply(row);
        });
        // Left side: transform columns via transpose.
        let mut t = out.transpose();
        let u = &self.u;
        threadpool::par_rows(&mut t.data, t.cols, |_, row| {
            u.apply(row);
        });
        t.transpose()
    }

    /// Invert the conjugation: W = T_Uᵀ W̃ T_V.
    pub fn unprocess_w(&self, wt: &Matrix) -> Matrix {
        let mut out = wt.clone();
        let v = &self.v;
        threadpool::par_rows(&mut out.data, out.cols, |_, row| {
            v.apply_inverse(row);
        });
        let mut t = out.transpose();
        let u = &self.u;
        threadpool::par_rows(&mut t.data, t.cols, |_, row| {
            u.apply_inverse(row);
        });
        t.transpose()
    }

    /// Sign-free inverse conjugation: A = H_mᵀ W̃ H_n, so that
    /// W_eff = diag(s_u) · A · diag(s_v) (the fine-tuning parametrization).
    pub fn unprocess_w_signless(&self, wt: &Matrix) -> Matrix {
        let mut out = wt.clone();
        let v = &self.v;
        threadpool::par_rows(&mut out.data, out.cols, |_, row| {
            v.apply_core_inverse(row);
        });
        let mut t = out.transpose();
        let u = &self.u;
        threadpool::par_rows(&mut t.data, t.cols, |_, row| {
            u.apply_core_inverse(row);
        });
        t.transpose()
    }

    /// H̃ = T_V H T_Vᵀ (Algorithm 3 line 3).
    pub fn process_h(&self, h: &Matrix) -> Matrix {
        let mut out = h.clone();
        let v = &self.v;
        threadpool::par_rows(&mut out.data, out.cols, |_, row| {
            v.apply(row);
        });
        let mut t = out.transpose();
        threadpool::par_rows(&mut t.data, t.cols, |_, row| {
            v.apply(row);
        });
        t.transpose().symmetrize()
    }
}

/// Weight incoherence μ_W = max|W_ij|·√(mn)/‖W‖_F (Definition 2.1).
pub fn mu_w(w: &Matrix) -> f64 {
    let f = w.frob_norm();
    if f == 0.0 {
        return 0.0;
    }
    w.max_abs() * ((w.rows * w.cols) as f64).sqrt() / f
}

/// Hessian incoherence μ_H = max|Q_ij|·√n over the eigenvector matrix Q
/// (Definition 2.1). O(n³) eigensolve — test/verification sizes.
pub fn mu_h(h: &Matrix) -> f64 {
    let (_, q) = sym_eig(h);
    q.max_abs() * (h.rows as f64).sqrt()
}

/// The paper's Lemma 3.1 bounds for failure probability δ.
pub fn lemma31_mu_h(n: usize, delta: f64) -> f64 {
    (2.0 * (2.0 * (n * n) as f64 / delta).ln()).sqrt()
}

pub fn lemma31_mu_w(m: usize, n: usize, delta: f64) -> f64 {
    2.0 * (4.0 * (m * n) as f64 / delta).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ldl::random_spd;
    use crate::util::proptest_lite::check;

    fn transform_kinds() -> Vec<IncoherenceKind> {
        vec![
            IncoherenceKind::Rht,
            IncoherenceKind::Rfft,
            IncoherenceKind::Kron2,
        ]
    }

    #[test]
    fn transforms_are_orthogonal() {
        let mut rng = Pcg64::new(1);
        for kind in transform_kinds() {
            for n in [16usize, 24, 48] {
                let t = Transform::new(kind, n, &mut rng);
                let d = t.dense();
                let err = d.matmul_transb(&d).max_diff(&Matrix::eye(n));
                assert!(err < 1e-8, "{kind:?} n={n} err={err}");
            }
        }
    }

    #[test]
    fn apply_inverse_roundtrip() {
        check("transform_roundtrip", 12, |rng| {
            for kind in transform_kinds() {
                let n = 32;
                let t = Transform::new(kind, n, rng);
                let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                let mut y = x.clone();
                t.apply(&mut y);
                t.apply_inverse(&mut y);
                for (a, b) in y.iter().zip(&x) {
                    if (a - b).abs() > 1e-8 {
                        return Err(format!("{kind:?} roundtrip failed"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn proxy_objective_preserved() {
        // tr(W̃ H̃ W̃ᵀ) == tr(W H Wᵀ) for every transform family.
        check("proxy_preserved", 6, |rng| {
            for kind in transform_kinds() {
                let (m, n) = (16, 24);
                let w = Matrix::gaussian(m, n, 1.0, rng);
                let h = random_spd(n, 0.1, rng);
                let ctx = IncoherenceCtx::new(kind, m, n, rng);
                let wt = ctx.process_w(&w);
                let ht = ctx.process_h(&h);
                let before = w.matmul(&h).matmul_transb(&w).trace();
                let after = wt.matmul(&ht).matmul_transb(&wt).trace();
                if (before - after).abs() > 1e-6 * before.abs().max(1.0) {
                    return Err(format!("{kind:?}: {before} vs {after}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn unprocess_inverts_process() {
        check("unprocess", 6, |rng| {
            for kind in transform_kinds() {
                let (m, n) = (12, 16);
                let w = Matrix::gaussian(m, n, 1.0, rng);
                let ctx = IncoherenceCtx::new(kind, m, n, rng);
                let roundtrip = ctx.unprocess_w(&ctx.process_w(&w));
                if roundtrip.max_diff(&w) > 1e-8 {
                    return Err(format!("{kind:?} unprocess failed"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rht_achieves_lemma31_weight_incoherence() {
        // Spiky matrix (one huge entry) becomes incoherent under RHT with
        // μ_W below the Lemma 3.1 bound at δ = 0.01.
        check("rht_mu_w", 10, |rng| {
            let (m, n) = (64, 128);
            let mut w = Matrix::gaussian(m, n, 0.01, rng);
            w[(3, 5)] = 100.0; // massive outlier
            let ctx = IncoherenceCtx::new(IncoherenceKind::Rht, m, n, rng);
            let wt = ctx.process_w(&w);
            let mu = mu_w(&wt);
            let bound = lemma31_mu_w(m, n, 0.01);
            if mu > bound {
                return Err(format!("mu_W={mu} exceeds bound {bound}"));
            }
            // And it must actually help: the original is far above 1.
            if mu_w(&w) < mu {
                return Err("incoherence processing made things worse".into());
            }
            Ok(())
        });
    }

    #[test]
    fn rht_achieves_lemma31_hessian_incoherence() {
        check("rht_mu_h", 5, |rng| {
            let n = 32;
            // Spiky Hessian: near rank-1 in a coordinate direction.
            let mut h = random_spd(n, 0.01, rng);
            h[(2, 2)] += 50.0;
            let ctx = IncoherenceCtx::new(IncoherenceKind::Rht, n, n, rng);
            let ht = ctx.process_h(&h);
            let mu = mu_h(&ht);
            let bound = lemma31_mu_h(n, 0.01);
            if mu > bound {
                return Err(format!("mu_H={mu} exceeds bound {bound}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rfft_also_reduces_mu() {
        let mut rng = Pcg64::new(5);
        let (m, n) = (32, 64);
        let mut w = Matrix::gaussian(m, n, 0.01, &mut rng);
        w[(0, 0)] = 10.0;
        let before = mu_w(&w);
        let ctx = IncoherenceCtx::new(IncoherenceKind::Rfft, m, n, &mut rng);
        let after = mu_w(&ctx.process_w(&w));
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn kron_reduces_mu_but_weaker_shape() {
        let mut rng = Pcg64::new(6);
        let (m, n) = (36, 64);
        let mut w = Matrix::gaussian(m, n, 0.01, &mut rng);
        w[(1, 1)] = 10.0;
        let before = mu_w(&w);
        let ctx = IncoherenceCtx::new(IncoherenceKind::Kron2, m, n, &mut rng);
        let after = mu_w(&ctx.process_w(&w));
        assert!(after < before);
    }

    #[test]
    fn balanced_factor_examples() {
        assert_eq!(balanced_factor(64), (8, 8));
        assert_eq!(balanced_factor(384), (16, 24));
        assert_eq!(balanced_factor(24), (4, 6));
    }

    #[test]
    fn rht_processed_weights_look_gaussian() {
        // Kurtosis of RHT(W) entries ≈ 3 (CLT shaping — §4 premise).
        let mut rng = Pcg64::new(8);
        let (m, n) = (64, 128);
        // Heavy-tailed input: cubed gaussians.
        let w = Matrix::from_fn(m, n, |_, _| {
            let g = rng.gaussian();
            g * g * g
        });
        let ctx = IncoherenceCtx::new(IncoherenceKind::Rht, m, n, &mut rng);
        let wt = ctx.process_w(&w);
        let mean = wt.data.iter().sum::<f64>() / wt.data.len() as f64;
        let var = wt.data.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / wt.data.len() as f64;
        let kurt = wt.data.iter().map(|x| (x - mean).powi(4)).sum::<f64>()
            / (wt.data.len() as f64 * var * var);
        let raw_kurt = {
            let mean = w.data.iter().sum::<f64>() / w.data.len() as f64;
            let var = w.data.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / w.data.len() as f64;
            w.data.iter().map(|x| (x - mean).powi(4)).sum::<f64>()
                / (w.data.len() as f64 * var * var)
        };
        assert!(raw_kurt > 10.0, "input should be heavy-tailed: {raw_kurt}");
        assert!(kurt < 4.5, "RHT output kurtosis {kurt} should approach 3");
    }
}
