//! BlockLDLQ — adaptive rounding with linear feedback, generalized to
//! vector quantization (paper §4.1, Theorem 4.1).
//!
//! Given the g-block LDL decomposition H = 𝐋ᵀ𝐃𝐋 (computed as U𝐃Uᵀ with
//! U = 𝐋ᵀ unit block-upper, see `linalg::ldl`), blocks are rounded left to
//! right with feedback from the running rounding error:
//!
//!   Ŵ_k = Q(W_k + (W_{:k−1} − Ŵ_{:k−1}) A_k),   A = U − I.
//!
//! Scalar LDLQ (QuIP / OPTQ) is the g = 1 special case.

use super::codebook::VectorQuantizer;
use crate::linalg::ldl::block_ldl;
use crate::linalg::Matrix;
use crate::util::threadpool;
use anyhow::Result;

/// Output of a BlockLDLQ run.
pub struct LdlqResult {
    /// Quantized (decoded) weights in the processed domain, m×n.
    pub w_hat: Matrix,
    /// Codes, row-major: m rows × (n/g) blocks × num_codes per block.
    pub codes: Vec<u32>,
    /// Proxy loss tr((Ŵ−W) H (Ŵ−W)ᵀ) actually achieved.
    pub proxy_err: f64,
}

/// Quantize `w` (m×n) against Hessian `h` (n×n, SPD) with quantizer `q`
/// at input scale `scale` (weights are divided by `scale` before `q` and
/// multiplied back after).
pub fn block_ldlq(
    w: &Matrix,
    h: &Matrix,
    q: &dyn VectorQuantizer,
    scale: f64,
) -> Result<LdlqResult> {
    let (m, n) = (w.rows, w.cols);
    let g = q.dim();
    anyhow::ensure!(n % g == 0, "quantizer dim {g} must divide n={n}");
    let nb = n / g;
    let nc = q.num_codes();
    let ldl = block_ldl(h, g)?;
    let u = &ldl.u; // unit block upper triangular

    // Per-row state lives in disjoint slices → parallel over rows.
    let mut w_hat = Matrix::zeros(m, n);
    let mut err = vec![0.0f64; m * n]; // E = W − Ŵ (valid for processed cols)
    let mut codes = vec![0u32; m * nb * nc];

    // Feedback blocks A_k = U[0..k·g, k·g..(k+1)·g] are shared across rows;
    // precompute column-major slices for locality.
    // We process block-by-block so the feedback only reads finished columns.
    for k in 0..nb {
        let col0 = k * g;
        // Views that let each row thread work independently.
        let u_ref = u;
        let w_ref = w;
        struct RowTask<'a> {
            err: &'a mut [f64],
            w_hat: &'a mut [f64],
            codes: &'a mut [u32],
        }
        // Split mutable state into per-row tasks.
        let mut tasks: Vec<RowTask> = {
            let mut out = Vec::with_capacity(m);
            let mut err_rest: &mut [f64] = &mut err;
            let mut what_rest: &mut [f64] = &mut w_hat.data;
            let mut codes_rest: &mut [u32] = &mut codes;
            for _ in 0..m {
                let (e, er) = err_rest.split_at_mut(n);
                let (wh, wr) = what_rest.split_at_mut(n);
                let (c, cr) = codes_rest.split_at_mut(nb * nc);
                err_rest = er;
                what_rest = wr;
                codes_rest = cr;
                out.push(RowTask {
                    err: e,
                    w_hat: wh,
                    codes: c,
                });
            }
            out
        };
        threadpool::par_rows(&mut tasks, 1, |i, task| {
            let task = &mut task[0];
            let wrow = w_ref.row(i);
            // t = W_k + E_{:,<k} · A_k   (A_k rows only 0..col0 are nonzero)
            let mut t = [0.0f64; 64];
            assert!(g <= 64);
            for (jj, tv) in t[..g].iter_mut().enumerate() {
                let mut acc = wrow[col0 + jj];
                for c in 0..col0 {
                    // u[(c, col0+jj)] is A's entry (U − I has zero diag here
                    // since c < col0).
                    acc += task.err[c] * u_ref[(c, col0 + jj)];
                }
                *tv = acc;
            }
            // Quantize at scale.
            let scaled: Vec<f64> = t[..g].iter().map(|v| v / scale).collect();
            let code_slice = &mut task.codes[k * nc..(k + 1) * nc];
            let dec = q.quantize(&scaled, code_slice);
            for jj in 0..g {
                let wq = dec[jj] * scale;
                task.w_hat[col0 + jj] = wq;
                task.err[col0 + jj] = t[jj] - wq;
            }
        });
    }

    // Proxy error tr((Ŵ−W) H (Ŵ−W)ᵀ).
    let diff = w_hat.sub(w);
    let proxy_err = diff.matmul(h).matmul_transb(&diff).trace();
    Ok(LdlqResult {
        w_hat,
        codes,
        proxy_err,
    })
}

/// Direct (no-feedback) rounding baseline: Ŵ_k = Q(W_k) blockwise.
pub fn round_direct(w: &Matrix, h: &Matrix, q: &dyn VectorQuantizer, scale: f64) -> LdlqResult {
    let (m, n) = (w.rows, w.cols);
    let g = q.dim();
    assert!(n % g == 0);
    let nb = n / g;
    let nc = q.num_codes();
    let mut w_hat = Matrix::zeros(m, n);
    // Parallel over rows: each row's (w_hat, codes) computed independently,
    // codes gathered afterwards to keep the closure free of shared writes.
    let w_ref = w;
    let row_codes: Vec<Vec<u32>> = {
        let results = threadpool::par_map(m, |i| {
            let wrow = w_ref.row(i);
            let mut rc = vec![0u32; nb * nc];
            let mut dec_row = vec![0.0f64; n];
            for k in 0..nb {
                let scaled: Vec<f64> =
                    wrow[k * g..(k + 1) * g].iter().map(|v| v / scale).collect();
                let dec = q.quantize(&scaled, &mut rc[k * nc..(k + 1) * nc]);
                for jj in 0..g {
                    dec_row[k * g + jj] = dec[jj] * scale;
                }
            }
            (rc, dec_row)
        });
        let mut codes_rows = Vec::with_capacity(m);
        for (i, (rc, dec_row)) in results.into_iter().enumerate() {
            w_hat.row_mut(i).copy_from_slice(&dec_row);
            codes_rows.push(rc);
        }
        codes_rows
    };
    let codes: Vec<u32> = row_codes.into_iter().flatten().collect();
    let diff = w_hat.sub(w);
    let proxy_err = diff.matmul(h).matmul_transb(&diff).trace();
    LdlqResult {
        w_hat,
        codes,
        proxy_err,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ldl::random_spd;
    use crate::quant::codebook::e8p::E8P;
    use crate::quant::codebook::scalar::HalfIntGrid;
    use crate::util::proptest_lite::check;
    use crate::util::rng::Pcg64;

    #[test]
    fn identity_hessian_equals_direct_rounding() {
        // With H = I the LDL feedback is zero, so LDLQ == direct.
        let mut rng = Pcg64::new(1);
        let w = Matrix::gaussian(4, 16, 1.0, &mut rng);
        let h = Matrix::eye(16);
        let q = E8P::new();
        let a = block_ldlq(&w, &h, &q, 1.0).unwrap();
        let b = round_direct(&w, &h, &q, 1.0);
        assert!(a.w_hat.max_diff(&b.w_hat) < 1e-12);
        assert_eq!(a.codes, b.codes);
    }

    #[test]
    fn ldlq_beats_direct_on_correlated_hessians() {
        // Theorem 4.1's point: feedback exploits off-diagonal H structure.
        // Compare average proxy error over several draws.
        let q = E8P::new();
        let mut tot_ldlq = 0.0;
        let mut tot_direct = 0.0;
        let mut rng = Pcg64::new(2);
        for _ in 0..6 {
            let w = Matrix::gaussian(8, 32, 1.0, &mut rng);
            let h = random_spd(32, 0.05, &mut rng);
            tot_ldlq += block_ldlq(&w, &h, &q, 1.0).unwrap().proxy_err;
            tot_direct += round_direct(&w, &h, &q, 1.0).proxy_err;
        }
        assert!(
            tot_ldlq < tot_direct,
            "LDLQ {tot_ldlq} should beat direct {tot_direct}"
        );
    }

    #[test]
    fn scalar_g1_ldlq_works() {
        let mut rng = Pcg64::new(3);
        let w = Matrix::gaussian(4, 12, 1.0, &mut rng);
        let h = random_spd(12, 0.1, &mut rng);
        let q = HalfIntGrid::new(4);
        let r = block_ldlq(&w, &h, &q, 0.5).unwrap();
        assert!(r.proxy_err.is_finite());
        assert!(r.proxy_err >= -1e-9);
        // 4-bit at sensible scale should have small error.
        let rel = r.w_hat.sub(&w).frob_norm() / w.frob_norm();
        assert!(rel < 0.2, "relative error {rel}");
    }

    #[test]
    fn eta_d_eta_identity() {
        // tr((Ŵ−W)H(Ŵ−W)ᵀ) == tr(η 𝐃 ηᵀ) with η = (W−Ŵ)U — the identity at
        // the heart of Theorem 4.1's proof.
        check("eta_identity", 6, |rng| {
            let (m, n, g) = (4usize, 16usize, 8usize);
            let w = Matrix::gaussian(m, n, 1.0, rng);
            let h = random_spd(n, 0.1, rng);
            let q = E8P::new();
            let r = block_ldlq(&w, &h, &q, 1.0).map_err(|e| e.to_string())?;
            let ldl = crate::linalg::ldl::block_ldl(&h, g).map_err(|e| e.to_string())?;
            let eta = w.sub(&r.w_hat).matmul(&ldl.u);
            // tr(η 𝐃 ηᵀ) = Σ_k tr(η_k D_k η_kᵀ)
            let mut tr = 0.0;
            for k in 0..n / g {
                for i in 0..m {
                    for a in 0..g {
                        for b in 0..g {
                            tr += eta[(i, k * g + a)] * ldl.d[k][(a, b)] * eta[(i, k * g + b)];
                        }
                    }
                }
            }
            if (tr - r.proxy_err).abs() > 1e-6 * tr.abs().max(1.0) {
                return Err(format!("identity violated: {tr} vs {}", r.proxy_err));
            }
            Ok(())
        });
    }

    #[test]
    fn codes_decode_back_to_w_hat() {
        let mut rng = Pcg64::new(5);
        let w = Matrix::gaussian(3, 16, 1.0, &mut rng);
        let h = random_spd(16, 0.1, &mut rng);
        let q = E8P::new();
        let scale = 0.7;
        let r = block_ldlq(&w, &h, &q, scale).unwrap();
        use crate::quant::codebook::VectorQuantizer;
        for i in 0..3 {
            for k in 0..2 {
                let code = &r.codes[i * 2 + k..i * 2 + k + 1];
                let dec = VectorQuantizer::decode(&q, code);
                for jj in 0..8 {
                    let want = r.w_hat[(i, k * 8 + jj)];
                    assert!((dec[jj] * scale - want).abs() < 1e-12);
                }
            }
        }
    }
}
