//! Scalar (1-D) codebooks.
//!
//! * [`HalfIntGrid`] — the paper's "no-E8" ablation: round each weight to
//!   the k-bit half-integer grid {±1/2, ±3/2, ...}. Also the d=1 series in
//!   Figure 3.
//! * [`HalfIntCube`] — d-dimensional product of half-integer grids
//!   (Figure 3's "half-int d=2/4/8" curves), showing the dimension effect
//!   without lattice shaping.

use super::Codebook;

/// k-bit half-integer grid: 2^k points {-(2^{k-1} - 1/2), ..., -1/2, 1/2,
/// ..., 2^{k-1} - 1/2}. Code = index into the sorted grid.
pub struct HalfIntGrid {
    bits: u32,
    levels: Vec<f64>,
}

impl HalfIntGrid {
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 16);
        let half = 1i64 << (bits - 1);
        let levels = (-half..half).map(|i| i as f64 + 0.5).collect();
        HalfIntGrid { bits, levels }
    }

    #[inline]
    pub fn quantize_scalar(&self, x: f64) -> (u32, f64) {
        // Nearest grid point = clamp(round(x - 0.5) + 0.5).
        let half = 1i64 << (self.bits - 1);
        let idx = (x - 0.5).round() as i64 + half;
        let idx = idx.clamp(0, 2 * half - 1) as u32;
        (idx, self.levels[idx as usize])
    }
}

impl Codebook for HalfIntGrid {
    fn dim(&self) -> usize {
        1
    }

    fn size(&self) -> usize {
        1 << self.bits
    }

    fn decode_one(&self, code: u32) -> Vec<f64> {
        vec![self.levels[code as usize]]
    }

    fn encode_one(&self, x: &[f64]) -> u32 {
        self.quantize_scalar(x[0]).0
    }

    fn cb_name(&self) -> String {
        format!("halfint-{}bit", self.bits)
    }
}

/// d-dimensional half-integer product grid with a ball constraint to reach
/// a non-power-of-two size when requested; used only for the Figure 3
/// dimension sweep. Codes pack per-coordinate indices.
pub struct HalfIntCube {
    bits: u32,
    d: usize,
    grid: HalfIntGrid,
}

impl HalfIntCube {
    pub fn new(bits: u32, d: usize) -> Self {
        assert!(d * (bits as usize) <= 31, "code must fit u32");
        HalfIntCube {
            bits,
            d,
            grid: HalfIntGrid::new(bits),
        }
    }
}

impl Codebook for HalfIntCube {
    fn dim(&self) -> usize {
        self.d
    }

    fn size(&self) -> usize {
        1usize << (self.bits as usize * self.d)
    }

    fn decode_one(&self, code: u32) -> Vec<f64> {
        let mask = (1u32 << self.bits) - 1;
        (0..self.d)
            .map(|i| self.grid.levels[((code >> (i as u32 * self.bits)) & mask) as usize])
            .collect()
    }

    fn encode_one(&self, x: &[f64]) -> u32 {
        let mut code = 0u32;
        for (i, &v) in x.iter().enumerate() {
            let (c, _) = self.grid.quantize_scalar(v);
            code |= c << (i as u32 * self.bits);
        }
        code
    }

    fn cb_name(&self) -> String {
        format!("halfint-{}bit-d{}", self.bits, self.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;

    #[test]
    fn grid_levels_2bit() {
        let g = HalfIntGrid::new(2);
        assert_eq!(g.levels, vec![-1.5, -0.5, 0.5, 1.5]);
    }

    #[test]
    fn quantize_rounds_to_nearest() {
        let g = HalfIntGrid::new(2);
        assert_eq!(g.quantize_scalar(0.1).1, 0.5);
        assert_eq!(g.quantize_scalar(-0.1).1, -0.5);
        assert_eq!(g.quantize_scalar(0.9).1, 0.5);
        assert_eq!(g.quantize_scalar(1.01).1, 1.5);
        assert_eq!(g.quantize_scalar(100.0).1, 1.5); // clamp
        assert_eq!(g.quantize_scalar(-100.0).1, -1.5);
    }

    #[test]
    fn encode_exact_nearest_property() {
        let g = HalfIntGrid::new(3);
        check("halfint_nearest", 100, |rng| {
            let x = rng.gaussian() * 3.0;
            let (_, v) = g.quantize_scalar(x);
            for &l in &g.levels {
                if (l - x).abs() < (v - x).abs() - 1e-12 {
                    return Err(format!("{l} beats {v} for {x}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cube_roundtrip() {
        let c = HalfIntCube::new(2, 8);
        check("cube_roundtrip", 50, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
            let code = c.encode_one(&x);
            let v = c.decode_one(code);
            let code2 = c.encode_one(&v);
            if code != code2 {
                return Err(format!("not idempotent: {code} vs {code2}"));
            }
            Ok(())
        });
    }

    #[test]
    fn cube_equals_product_of_grids() {
        let c = HalfIntCube::new(2, 4);
        let g = HalfIntGrid::new(2);
        let x = [0.3, -1.2, 2.7, -0.6];
        let v = c.decode_one(c.encode_one(&x));
        for (i, &xi) in x.iter().enumerate() {
            assert_eq!(v[i], g.quantize_scalar(xi).1);
        }
    }
}
