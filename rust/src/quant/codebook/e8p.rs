//! E8P — the paper's 2-bit "E8 Padded" codebook (§4.2, §C).
//!
//! The codebook is the 2^16-point subset of E8 + 1/4 generated from a
//! 256-entry table S ⊂ |D̂8| of elementwise-absolute half-integer vectors:
//!
//! * 8 bits — index into S (227 entries of ‖s‖² ≤ 10 plus 29 padding
//!   entries of ‖s‖² = 12),
//! * 7 bits — explicit sign flips for coordinates 0..6; the sign of
//!   coordinate 7 is *inferred* from parity (each s needs an odd or even
//!   number of flips to land in D̂8, determined by the parity of the sum
//!   of its entries),
//! * 1 bit — global shift of ±1/4, using (D̂8 − 1/4) ∪ (D̂8 + 1/4) = E8 + 1/4.
//!
//! Decoding therefore needs a 256×8 lookup plus a handful of bit
//! operations — the property that lets the inference kernel keep the whole
//! table in L1/VMEM (the paper's "1KiB codebook").

use super::Codebook;

/// Shift magnitude applied by the final codeword bit.
pub const SHIFT: f64 = 0.25;

/// The E8P codebook: 2^16 entries, dimension 8, 2 bits/weight.
pub struct E8P {
    /// 256×8 table of |D̂8| absolute vectors (all entries positive
    /// half-integers).
    pub abs: Vec<[f64; 8]>,
    /// Parity of the integer sum of each abs entry: true if the number of
    /// sign flips needed to reach D̂8 (even integer sum) is odd.
    pub flip_parity_odd: Vec<bool>,
}

/// Enumerate all-positive half-integer 8-vectors with squared norm equal to
/// `target_sq` (units: actual value; entries in {0.5, 1.5, 2.5, 3.5}).
/// Deterministic lexicographic order (in half-units).
fn enumerate_abs_by_norm(target_sq: f64) -> Vec<[f64; 8]> {
    // Work in half-units h = 2v (odd positive integers 1,3,5,7);
    // ‖v‖² = Σ h²/4, so Σh² = 4·target_sq.
    let target_h: i64 = (4.0 * target_sq).round() as i64;
    let mut out = Vec::new();
    let mut cur = [0i64; 8];
    fn rec(pos: usize, remaining: i64, cur: &mut [i64; 8], out: &mut Vec<[f64; 8]>) {
        if pos == 8 {
            if remaining == 0 {
                let mut v = [0.0f64; 8];
                for i in 0..8 {
                    v[i] = cur[i] as f64 / 2.0;
                }
                out.push(v);
            }
            return;
        }
        // Odd h with h² ≤ remaining; also prune: minimum for the rest is
        // (8-pos-1) * 1.
        let rest_min = (8 - pos as i64 - 1) * 1;
        let mut h = 1i64;
        while h * h + rest_min <= remaining {
            cur[pos] = h;
            rec(pos + 1, remaining - h * h, cur, out);
            h += 2;
        }
    }
    rec(0, target_h, &mut cur, &mut out);
    out
}

impl E8P {
    /// Build the canonical E8P table: all 227 |D̂8| vectors with ‖s‖² ≤ 10,
    /// padded to 256 with 29 vectors of ‖s‖² = 12.
    ///
    /// The paper's Appendix C.1 lists a specific set of 29 padding
    /// vectors; the extraction of that list is unreliable, so we take the
    /// first 29 norm-12 candidates in deterministic lexicographic order
    /// (documented in DESIGN.md; any norm-12 padding set gives the same
    /// ball shaping up to symmetry).
    pub fn new() -> Self {
        let mut abs: Vec<[f64; 8]> = Vec::with_capacity(256);
        // Shells with ‖s‖² ∈ {2, 4, 6, 8, 10} (all-positive half-integer
        // vectors have even integer squared norm ≥ 2).
        for ns in [2.0, 4.0, 6.0, 8.0, 10.0] {
            abs.extend(enumerate_abs_by_norm(ns));
        }
        assert_eq!(abs.len(), 227, "expected 227 entries with norm^2 <= 10");
        let pad = enumerate_abs_by_norm(12.0);
        assert!(pad.len() >= 29);
        abs.extend(pad.into_iter().take(29));
        assert_eq!(abs.len(), 256);

        // Parity: sum of entries is an integer; if it is odd, an odd number
        // of sign flips is required to reach even-sum D̂8.
        let flip_parity_odd = abs
            .iter()
            .map(|s| {
                let sum: f64 = s.iter().sum();
                (sum.round() as i64).rem_euclid(2) == 1
            })
            .collect();
        E8P {
            abs,
            flip_parity_odd,
        }
    }

    /// Decode a 16-bit codeword: [abs index: bits 0..8][sign flips for
    /// coords 0..6: bits 8..15][shift bit: bit 15].
    #[inline]
    pub fn decode_u16(&self, code: u16) -> [f64; 8] {
        let s_idx = (code & 0xff) as usize;
        let sign_bits = ((code >> 8) & 0x7f) as u32;
        let shift_bit = code >> 15;
        let s = &self.abs[s_idx];
        let explicit_flips = sign_bits.count_ones();
        // Coord 7 flip inferred from parity.
        let need_odd = self.flip_parity_odd[s_idx];
        let flip7 = (explicit_flips % 2 == 1) != need_odd;
        let shift = if shift_bit == 1 { SHIFT } else { -SHIFT };
        let mut v = [0.0f64; 8];
        for i in 0..7 {
            let sgn = if (sign_bits >> i) & 1 == 1 { -1.0 } else { 1.0 };
            v[i] = s[i] * sgn + shift;
        }
        let sgn7 = if flip7 { -1.0 } else { 1.0 };
        v[7] = s[7] * sgn7 + shift;
        v
    }

    /// Exact nearest-codeword search. For each shift and abs entry, the
    /// optimal sign assignment is sign(y_i) per coordinate; the parity
    /// constraint is repaired by flipping the coordinate with the smallest
    /// penalty 4·|y_i|·s_i. O(2 · 256 · 8).
    pub fn encode_u16(&self, x: &[f64]) -> u16 {
        debug_assert_eq!(x.len(), 8);
        let mut best_code = 0u16;
        let mut best_d = f64::INFINITY;
        for shift_bit in 0..2u16 {
            let shift = if shift_bit == 1 { SHIFT } else { -SHIFT };
            // y = x - shift: distance to (signed s) is ‖y‖² - 2⟨y, v⟩ + ‖s‖².
            let mut y = [0.0f64; 8];
            for i in 0..8 {
                y[i] = x[i] - shift;
            }
            for (s_idx, s) in self.abs.iter().enumerate() {
                // Unconstrained optimum: v_i = sign(y_i)·s_i.
                // cost = Σ (|y_i| - s_i)²; flips where y_i < 0.
                let mut cost = 0.0f64;
                let mut nflips = 0u32;
                let mut min_pen = f64::INFINITY;
                let mut min_pen_i = 0usize;
                for i in 0..8 {
                    let ay = y[i].abs();
                    let diff = ay - s[i];
                    cost += diff * diff;
                    if y[i] < 0.0 {
                        nflips += 1;
                    }
                    let pen = 4.0 * ay * s[i];
                    if pen < min_pen {
                        min_pen = pen;
                        min_pen_i = i;
                    }
                }
                let parity_ok = (nflips % 2 == 1) == self.flip_parity_odd[s_idx];
                let mut flips_mask = 0u32;
                for i in 0..8 {
                    if y[i] < 0.0 {
                        flips_mask |= 1 << i;
                    }
                }
                let total_cost = if parity_ok {
                    cost
                } else {
                    flips_mask ^= 1 << min_pen_i;
                    cost + min_pen
                };
                if total_cost < best_d {
                    best_d = total_cost;
                    // Encode: only bits 0..6 explicit; bit for coord 7 is
                    // implied, and the decoder reconstructs it from parity,
                    // so just drop it.
                    let sign_bits = (flips_mask & 0x7f) as u16;
                    best_code = (shift_bit << 15) | (sign_bits << 8) | s_idx as u16;
                }
            }
        }
        best_code
    }

    /// Flat 256×8 f32 table (exported to artifacts for the Pallas kernel
    /// and the fused decode hot path).
    pub fn abs_table_f32(&self) -> Vec<f32> {
        self.abs
            .iter()
            .flat_map(|s| s.iter().map(|&v| v as f32))
            .collect()
    }

    /// Parity bits as u8 (exported alongside the table).
    pub fn parity_table(&self) -> Vec<u8> {
        self.flip_parity_odd.iter().map(|&b| b as u8).collect()
    }
}

impl Default for E8P {
    fn default() -> Self {
        Self::new()
    }
}

impl Codebook for E8P {
    fn dim(&self) -> usize {
        8
    }

    fn size(&self) -> usize {
        1 << 16
    }

    fn decode_one(&self, code: u32) -> Vec<f64> {
        self.decode_u16(code as u16).to_vec()
    }

    fn encode_one(&self, x: &[f64]) -> u32 {
        self.encode_u16(x) as u32
    }

    fn cb_name(&self) -> String {
        "e8p".to_string()
    }
}

/// Check whether v ∈ E8 + 1/4 (test helper): v ∓ 1/4 must be half-integer
/// with even integer sum or integer with even sum.
pub fn in_e8_plus_quarter(v: &[f64]) -> bool {
    for &shift in &[SHIFT, -SHIFT] {
        let w: Vec<f64> = v.iter().map(|x| x - shift).collect();
        if in_e8(&w) {
            return true;
        }
    }
    false
}

/// Check whether w ∈ E8 = D8 ∪ (D8 + 1/2·1), where
/// D8 = {x ∈ Z^8 : Σx even}.
pub fn in_e8(w: &[f64]) -> bool {
    let all_int = w.iter().all(|x| (x - x.round()).abs() < 1e-9);
    let all_half = w
        .iter()
        .all(|x| ((x - 0.5) - (x - 0.5).round()).abs() < 1e-9);
    if !all_int && !all_half {
        return false;
    }
    let sum: f64 = w.iter().sum();
    let sum_r = sum.round();
    (sum - sum_r).abs() < 1e-9 && (sum_r as i64).rem_euclid(2) == 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;
    use std::collections::HashSet;

    #[test]
    fn table_has_227_plus_29() {
        let cb = E8P::new();
        let n_le10 = cb
            .abs
            .iter()
            .filter(|s| s.iter().map(|v| v * v).sum::<f64>() <= 10.0 + 1e-9)
            .count();
        assert_eq!(n_le10, 227);
        assert_eq!(cb.abs.len(), 256);
        for s in &cb.abs[227..] {
            let ns: f64 = s.iter().map(|v| v * v).sum();
            assert!((ns - 12.0).abs() < 1e-9);
        }
    }

    #[test]
    fn every_abs_entry_is_positive_half_integer() {
        let cb = E8P::new();
        for s in &cb.abs {
            for &v in s {
                assert!(v > 0.0);
                assert!(((v * 2.0).round() as i64) % 2 == 1, "entry {v} not half-odd");
            }
        }
    }

    #[test]
    fn all_decoded_points_lie_in_e8_plus_quarter() {
        let cb = E8P::new();
        // Sample a spread of codes incl. all abs indices and sign patterns.
        for s_idx in 0..256u32 {
            for &extra in &[0u32, 0x7f00, 0x2a00, 0x8000, 0xff00] {
                let code = (s_idx | extra) as u16;
                let v = cb.decode_u16(code);
                assert!(
                    in_e8_plus_quarter(&v),
                    "code {code:#06x} decodes outside E8+1/4: {v:?}"
                );
            }
        }
    }

    #[test]
    fn distinct_codes_decode_distinct_points() {
        let cb = E8P::new();
        let mut seen = HashSet::new();
        // Full 2^16 enumeration: entries must be unique (it's a codebook).
        for code in 0..=u16::MAX {
            let v = cb.decode_u16(code);
            let key: Vec<i64> = v.iter().map(|x| (x * 4.0).round() as i64).collect();
            assert!(seen.insert(key), "duplicate decode at {code:#06x}");
        }
        assert_eq!(seen.len(), 1 << 16);
    }

    #[test]
    fn paper_worked_example_c2() {
        // Appendix C.2: s = [1/2,1/2,1/2,3/2,1/2,1/2,1/2,1/2], flips on
        // coords {0,1,3,6} (1st, 2nd, 4th, 7th "from right"), parity forces
        // an 8th flip, shift bit adds +1/4 →
        // [-1/4,-3/4, 3/4, 7/4, -1/4, 3/4, -1/4, -1/4] reading their list
        // right-to-left. We verify via direct construction.
        let cb = E8P::new();
        // Find the abs index of s.
        let s_want = [0.5, 0.5, 0.5, 1.5, 0.5, 0.5, 0.5, 0.5];
        // (their printed s has the 3/2 in position 3 of the set notation)
        let s_idx = cb
            .abs
            .iter()
            .position(|s| s.iter().zip(&s_want).all(|(a, b)| (a - b).abs() < 1e-9));
        let s_idx = s_idx.expect("example abs vector must be in S") as u16;
        // sum(s) = 5.0 odd → odd number of flips required.
        assert!(cb.flip_parity_odd[s_idx as usize]);
        // Flip bits for coords 0,1,3,6 → mask 0b1001011.
        let mask = 0b100_1011u16;
        let code = (1u16 << 15) | (mask << 8) | s_idx;
        let v = cb.decode_u16(code);
        // Explicit flips: 4 (even) but parity needs odd → coord 7 flips too.
        let want = [-0.25, -0.25, 0.75, -1.25, 0.75, 0.75, -0.25, -0.25];
        for (i, (&got, &w)) in v.iter().zip(&want).enumerate() {
            assert!(
                (got - w).abs() < 1e-9,
                "coord {i}: got {got}, want {w} (full {v:?})"
            );
        }
        assert!(in_e8_plus_quarter(&v));
    }

    #[test]
    fn encode_decode_fixpoint() {
        // decode(encode(p)) == p for every codebook point p (sampled).
        let cb = E8P::new();
        check("e8p_fixpoint", 200, |rng| {
            let code = (rng.next_u64() & 0xffff) as u16;
            let v = cb.decode_u16(code);
            let code2 = cb.encode_u16(&v);
            let v2 = cb.decode_u16(code2);
            for (a, b) in v.iter().zip(&v2) {
                if (a - b).abs() > 1e-9 {
                    return Err(format!("{code:#06x} -> {code2:#06x}: {v:?} vs {v2:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn encode_is_exact_nearest() {
        // Against brute force over all 2^16 decoded points.
        let cb = E8P::new();
        let all: Vec<[f64; 8]> = (0..=u16::MAX).map(|c| cb.decode_u16(c)).collect();
        check("e8p_nearest", 30, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian() * 1.2).collect();
            let got = cb.encode_u16(&x);
            let got_d: f64 = cb
                .decode_u16(got)
                .iter()
                .zip(&x)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let mut best_d = f64::INFINITY;
            for v in &all {
                let d: f64 = v.iter().zip(&x).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best_d {
                    best_d = d;
                }
            }
            if got_d > best_d + 1e-9 {
                return Err(format!("not nearest: {got_d} vs {best_d} for {x:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn bits_per_weight_is_two() {
        let cb = E8P::new();
        use super::super::VectorQuantizer;
        assert!((cb.bits_per_weight() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantizer_error_bounded() {
        // On moderate inputs the nearest point is within the covering
        // radius; per-coordinate error stays bounded.
        let cb = E8P::new();
        check("e8p_err_bound", 100, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
            let code = cb.encode_u16(&x);
            let v = cb.decode_u16(code);
            let err: f64 = v.iter().zip(&x).map(|(a, b)| (a - b) * (a - b)).sum();
            if err > 8.0 {
                return Err(format!("error {err} too large for {x:?}"));
            }
            Ok(())
        });
    }
}
