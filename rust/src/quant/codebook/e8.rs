//! E8 lattice codebooks beyond E8P:
//!
//! * exact nearest-point search in the infinite E8 lattice (via the
//!   classic D8 ∪ (D8 + ½·1) decomposition),
//! * the paper's 1-bit E8 codebook used as the RVQ residual stage for
//!   3-bit quantization (§4.3): the 241 points of norm² ≤ 2 plus 15
//!   points from the norm² = 4 shell,
//! * `E8Ball`: E8 ∩ ball codebooks of arbitrary size (the "E8 lattice
//!   2.37 bit" row of Table 7 and the Figure 3 sweep).

use super::{nearest_bruteforce, Codebook};

/// Nearest point in D_n = {x ∈ Z^n : Σx even}: round every coordinate;
/// if the sum is odd, re-round the coordinate whose rounding error was
/// largest in the other direction (Conway & Sloane, SPLAG ch. 4).
pub fn nearest_dn(x: &[f64]) -> Vec<f64> {
    let mut r: Vec<f64> = x.iter().map(|v| v.round()).collect();
    let sum: i64 = r.iter().map(|&v| v as i64).sum();
    if sum.rem_euclid(2) != 0 {
        // Index with the largest |x - round(x)|.
        let (mut worst, mut worst_e) = (0usize, -1.0f64);
        for (i, (&xi, &ri)) in x.iter().zip(&r).enumerate() {
            let e = (xi - ri).abs();
            if e > worst_e {
                worst_e = e;
                worst = i;
            }
        }
        let xi = x[worst];
        let ri = r[worst];
        // Move to the second-nearest integer.
        r[worst] = if xi >= ri { ri + 1.0 } else { ri - 1.0 };
    }
    r
}

/// Nearest point in E8 = D8 ∪ (D8 + ½·1): the better of the two coset
/// decodings. Exact.
pub fn nearest_e8(x: &[f64]) -> Vec<f64> {
    debug_assert_eq!(x.len(), 8);
    let a = nearest_dn(x);
    let shifted: Vec<f64> = x.iter().map(|v| v - 0.5).collect();
    let mut b = nearest_dn(&shifted);
    for v in b.iter_mut() {
        *v += 0.5;
    }
    let da: f64 = a.iter().zip(x).map(|(p, q)| (p - q) * (p - q)).sum();
    let db: f64 = b.iter().zip(x).map(|(p, q)| (p - q) * (p - q)).sum();
    if da <= db {
        a
    } else {
        b
    }
}

/// Enumerate all E8 points with squared norm ≤ `max_sq`, deterministic
/// order (shell by shell, lexicographic within shell).
pub fn e8_points_up_to(max_sq: f64) -> Vec<[f64; 8]> {
    // Integer coset D8: coords in [-L, L]; half coset: odd half-integers.
    let limit = (max_sq.sqrt().ceil() as i64) + 1;
    let mut pts: Vec<[f64; 8]> = Vec::new();
    // D8 part.
    let mut cur = [0i64; 8];
    fn rec_int(
        pos: usize,
        rem: f64,
        limit: i64,
        cur: &mut [i64; 8],
        pts: &mut Vec<[f64; 8]>,
    ) {
        if pos == 8 {
            let s: i64 = cur.iter().sum();
            if s.rem_euclid(2) == 0 {
                let mut v = [0.0; 8];
                for i in 0..8 {
                    v[i] = cur[i] as f64;
                }
                pts.push(v);
            }
            return;
        }
        let mut c = -limit;
        while c <= limit {
            let cc = (c * c) as f64;
            if cc <= rem + 1e-9 {
                cur[pos] = c;
                rec_int(pos + 1, rem - cc, limit, cur, pts);
            }
            c += 1;
        }
    }
    rec_int(0, max_sq, limit, &mut cur, &mut pts);
    // D8 + 1/2 part: coords are odd multiples of 1/2.
    let mut curh = [0i64; 8]; // value = curh/2, curh odd
    fn rec_half(pos: usize, rem4: i64, limit2: i64, cur: &mut [i64; 8], pts: &mut Vec<[f64; 8]>) {
        // rem4 = remaining squared norm in quarter units.
        if pos == 8 {
            if rem4 >= 0 {
                // Sum must be even: Σ(h/2) with h odd → Σh ≡ 0 (mod 4)
                // for integer-even sum. Σ h/2 even ⇔ Σh ≡ 0 mod 4.
                let s: i64 = cur.iter().sum();
                if s.rem_euclid(4) == 0 {
                    let mut v = [0.0; 8];
                    for i in 0..8 {
                        v[i] = cur[i] as f64 / 2.0;
                    }
                    pts.push(v);
                }
            }
            return;
        }
        let mut h = -limit2;
        while h <= limit2 {
            if h.rem_euclid(2) != 0 {
                let hh = h * h;
                if hh <= rem4 {
                    cur[pos] = h;
                    rec_half(pos + 1, rem4 - hh, limit2, cur, pts);
                }
            }
            h += 1;
        }
    }
    rec_half(
        0,
        (4.0 * max_sq).round() as i64,
        2 * limit,
        &mut curh,
        &mut pts,
    );
    // Sort by (norm², lexicographic) for deterministic shells.
    pts.sort_by(|a, b| {
        let na: f64 = a.iter().map(|v| v * v).sum();
        let nb: f64 = b.iter().map(|v| v * v).sum();
        na.partial_cmp(&nb)
            .unwrap()
            .then_with(|| a.partial_cmp(b).unwrap())
    });
    pts
}

/// The paper's 1-bit E8 codebook: 256 entries = {0} ∪ 240 roots (norm²=2)
/// ∪ 15 chosen norm²=4 points. Used as RVQ stage 2 for 3-bit QuIP#.
pub struct E8OneBit {
    entries: Vec<f64>, // 256 × 8 row-major
}

impl E8OneBit {
    pub fn new() -> Self {
        let small = e8_points_up_to(2.0);
        assert_eq!(small.len(), 241, "origin + 240 roots");
        let shell4 = e8_points_up_to(4.0)
            .into_iter()
            .filter(|p| {
                let n: f64 = p.iter().map(|v| v * v).sum();
                (n - 4.0).abs() < 1e-9
            })
            .collect::<Vec<_>>();
        assert!(shell4.len() >= 15);
        let mut entries = Vec::with_capacity(256 * 8);
        for p in small.iter().chain(shell4.iter().take(15)) {
            entries.extend_from_slice(p);
        }
        assert_eq!(entries.len(), 256 * 8);
        E8OneBit { entries }
    }
}

impl Default for E8OneBit {
    fn default() -> Self {
        Self::new()
    }
}

impl Codebook for E8OneBit {
    fn dim(&self) -> usize {
        8
    }

    fn size(&self) -> usize {
        256
    }

    fn decode_one(&self, code: u32) -> Vec<f64> {
        let i = code as usize;
        self.entries[i * 8..(i + 1) * 8].to_vec()
    }

    fn encode_one(&self, x: &[f64]) -> u32 {
        nearest_bruteforce(&self.entries, 8, x)
    }

    fn cb_name(&self) -> String {
        "e8-1bit".to_string()
    }
}

/// E8 ∩ ball codebook of a given target size (e.g. 2^19 ≈ the paper's
/// "2.37 bit" row in Table 7; small sizes for the Figure 3 sweep).
/// Encoding uses the exact infinite-lattice decoder and falls back to a
/// shrink-toward-origin loop when the lattice point lands outside the
/// ball, then a local brute force over the outermost shell.
pub struct E8Ball {
    entries: Vec<f64>, // size × 8
    max_norm_sq: f64,
    name: String,
    /// lattice point (in quarter units) → code, for O(1) encode.
    index: std::collections::HashMap<[i64; 8], u32>,
}

impl E8Ball {
    /// Build with the smallest shell radius reaching at least
    /// `target_size` points, then truncate to exactly `target_size`
    /// (deterministic shell order).
    pub fn with_size(target_size: usize) -> Self {
        let mut max_sq = 2.0;
        let mut pts = e8_points_up_to(max_sq);
        while pts.len() < target_size {
            max_sq += 2.0;
            pts = e8_points_up_to(max_sq);
        }
        pts.truncate(target_size);
        let max_norm_sq = pts
            .iter()
            .map(|p| p.iter().map(|v| v * v).sum::<f64>())
            .fold(0.0f64, f64::max);
        let mut entries = Vec::with_capacity(pts.len() * 8);
        let mut index = std::collections::HashMap::with_capacity(pts.len());
        for (i, p) in pts.iter().enumerate() {
            entries.extend_from_slice(p);
            index.insert(Self::key(p), i as u32);
        }
        E8Ball {
            entries,
            max_norm_sq,
            name: format!("e8-ball-{target_size}"),
            index,
        }
    }

    fn key(p: &[f64]) -> [i64; 8] {
        let mut k = [0i64; 8];
        for i in 0..8 {
            k[i] = (p[i] * 4.0).round() as i64;
        }
        k
    }

    fn find_index(&self, p: &[f64]) -> Option<u32> {
        self.index.get(&Self::key(p)).copied()
    }
}

impl Codebook for E8Ball {
    fn dim(&self) -> usize {
        8
    }

    fn size(&self) -> usize {
        self.entries.len() / 8
    }

    fn decode_one(&self, code: u32) -> Vec<f64> {
        let i = code as usize;
        self.entries[i * 8..(i + 1) * 8].to_vec()
    }

    fn encode_one(&self, x: &[f64]) -> u32 {
        // Exact lattice point first.
        let p = nearest_e8(x);
        let norm: f64 = p.iter().map(|v| v * v).sum();
        if norm <= self.max_norm_sq + 1e-9 {
            if let Some(idx) = self.find_index(&p) {
                return idx;
            }
        }
        // Outside the ball (or truncated outer shell): shrink x toward the
        // origin until the decoded point is inside, then refine with a
        // brute-force pass for exactness near the boundary.
        let mut scale = (self.max_norm_sq / norm.max(1e-12)).sqrt();
        for _ in 0..8 {
            let xs: Vec<f64> = x.iter().map(|v| v * scale).collect();
            let p = nearest_e8(&xs);
            let n: f64 = p.iter().map(|v| v * v).sum();
            if n <= self.max_norm_sq + 1e-9 {
                if let Some(idx) = self.find_index(&p) {
                    if Codebook::size(self) <= 4096 {
                        // Small codebooks: brute-force guarantees exact
                        // nearest near the truncated boundary.
                        let bf = nearest_bruteforce(&self.entries, 8, x);
                        let d_idx = dist_sq(&self.decode_one(idx), x);
                        let d_bf = dist_sq(&self.decode_one(bf), x);
                        return if d_bf < d_idx { bf } else { idx };
                    }
                    return idx;
                }
            }
            scale *= 0.9;
        }
        nearest_bruteforce(&self.entries, 8, x)
    }

    fn cb_name(&self) -> String {
        self.name.clone()
    }
}

fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;

    #[test]
    fn nearest_dn_is_in_dn_and_nearest() {
        check("nearest_dn", 100, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian() * 2.0).collect();
            let p = nearest_dn(&x);
            let s: i64 = p.iter().map(|&v| v as i64).sum();
            if s.rem_euclid(2) != 0 {
                return Err(format!("sum odd: {p:?}"));
            }
            // Verify optimality within D8 by local search: any single-coord
            // ±1 plus parity-restoring move can't improve (spot check via
            // brute force over offsets in {-1,0,1}^2 on two random coords).
            let d0 = dist_sq(&p, &x);
            for _ in 0..20 {
                let i = rng.below_usize(8);
                let j = rng.below_usize(8);
                if i == j {
                    continue;
                }
                for di in [-1.0, 1.0] {
                    for dj in [-1.0, 1.0] {
                        let mut q = p.clone();
                        q[i] += di;
                        q[j] += dj;
                        let s: i64 = q.iter().map(|&v| v as i64).sum();
                        if s.rem_euclid(2) == 0 && dist_sq(&q, &x) < d0 - 1e-9 {
                            return Err(format!("improvable: {p:?} -> {q:?} for {x:?}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nearest_e8_in_lattice() {
        check("nearest_e8", 100, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian() * 2.0).collect();
            let p = nearest_e8(&x);
            if !super::super::e8p::in_e8(&p) {
                return Err(format!("not in E8: {p:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn nearest_e8_covering_radius() {
        // E8 covering radius is 1 → squared distance ≤ 1 for any point.
        check("e8_covering", 200, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian() * 3.0).collect();
            let p = nearest_e8(&x);
            let d = dist_sq(&p, &x);
            if d > 1.0 + 1e-9 {
                return Err(format!("covering radius violated: d²={d} at {x:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn shell_counts_match_theta_series() {
        // E8 theta series: 1, 240 (norm² 2), 2160 (norm² 4).
        let pts2 = e8_points_up_to(2.0);
        assert_eq!(pts2.len(), 1 + 240);
        let pts4 = e8_points_up_to(4.0);
        assert_eq!(pts4.len(), 1 + 240 + 2160);
    }

    #[test]
    fn one_bit_codebook_size_and_membership() {
        let cb = E8OneBit::new();
        assert_eq!(Codebook::size(&cb), 256);
        for c in 0..256u32 {
            let p = cb.decode_one(c);
            assert!(super::super::e8p::in_e8(&p), "{p:?} not in E8");
        }
    }

    #[test]
    fn one_bit_encode_is_nearest() {
        let cb = E8OneBit::new();
        check("e8_1bit_nearest", 50, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian() * 0.7).collect();
            let got = cb.encode_one(&x);
            let d_got = dist_sq(&cb.decode_one(got), &x);
            for c in 0..256u32 {
                let d = dist_sq(&cb.decode_one(c), &x);
                if d < d_got - 1e-9 {
                    return Err(format!("code {c} beats {got}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ball_codebook_exact_small() {
        let cb = E8Ball::with_size(241);
        check("e8ball_nearest", 40, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian() * 1.0).collect();
            let got = cb.encode_one(&x);
            let d_got = dist_sq(&cb.decode_one(got), &x);
            for c in 0..Codebook::size(&cb) as u32 {
                let d = dist_sq(&cb.decode_one(c), &x);
                if d < d_got - 1e-9 {
                    return Err(format!("code {c} beats {got} (d {d} vs {d_got})"));
                }
            }
            Ok(())
        });
    }
}
