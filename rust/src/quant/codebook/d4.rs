//! D4 lattice codebooks (Table 7 / Figure 3 comparison): D4 = even-parity
//! integer vectors in Z⁴, the densest 4-D packing. Codebooks are D4 ∩ ball
//! truncated to a target size in deterministic shell order.

use super::{nearest_bruteforce, Codebook};
use crate::quant::codebook::e8::nearest_dn;

/// Enumerate D4 points with squared norm ≤ max_sq, sorted (norm², lex).
pub fn d4_points_up_to(max_sq: f64) -> Vec<[f64; 4]> {
    let limit = (max_sq.sqrt().ceil() as i64) + 1;
    let mut pts = Vec::new();
    for a in -limit..=limit {
        for b in -limit..=limit {
            for c in -limit..=limit {
                for d in -limit..=limit {
                    let n = (a * a + b * b + c * c + d * d) as f64;
                    if n <= max_sq + 1e-9 && (a + b + c + d).rem_euclid(2) == 0 {
                        pts.push([a as f64, b as f64, c as f64, d as f64]);
                    }
                }
            }
        }
    }
    pts.sort_by(|x, y| {
        let nx: f64 = x.iter().map(|v| v * v).sum();
        let ny: f64 = y.iter().map(|v| v * v).sum();
        nx.partial_cmp(&ny)
            .unwrap()
            .then_with(|| x.partial_cmp(y).unwrap())
    });
    pts
}

/// D4 ∩ ball codebook with exactly `target_size` entries.
/// 256 entries ↔ the paper's "D4 2 bit"; ~460 ↔ "D4 2.21 bit".
pub struct D4Ball {
    entries: Vec<f64>, // size × 4
    max_norm_sq: f64,
    index: std::collections::HashMap<[i64; 4], u32>,
    name: String,
}

impl D4Ball {
    pub fn with_size(target_size: usize) -> Self {
        let mut max_sq = 2.0;
        let mut pts = d4_points_up_to(max_sq);
        while pts.len() < target_size {
            max_sq += 2.0;
            pts = d4_points_up_to(max_sq);
        }
        pts.truncate(target_size);
        let max_norm_sq = pts
            .iter()
            .map(|p| p.iter().map(|v| v * v).sum::<f64>())
            .fold(0.0f64, f64::max);
        let mut entries = Vec::with_capacity(pts.len() * 4);
        let mut index = std::collections::HashMap::new();
        for (i, p) in pts.iter().enumerate() {
            entries.extend_from_slice(p);
            index.insert(Self::key(p), i as u32);
        }
        D4Ball {
            entries,
            max_norm_sq,
            index,
            name: format!("d4-ball-{target_size}"),
        }
    }

    fn key(p: &[f64]) -> [i64; 4] {
        let mut k = [0i64; 4];
        for i in 0..4 {
            k[i] = p[i].round() as i64;
        }
        k
    }
}

impl Codebook for D4Ball {
    fn dim(&self) -> usize {
        4
    }

    fn size(&self) -> usize {
        self.entries.len() / 4
    }

    fn decode_one(&self, code: u32) -> Vec<f64> {
        let i = code as usize;
        self.entries[i * 4..(i + 1) * 4].to_vec()
    }

    fn encode_one(&self, x: &[f64]) -> u32 {
        // Exact D4 decode, fall back to brute force near/outside the ball
        // (codebooks here are ≤ a few hundred entries).
        let p = nearest_dn(x);
        let n: f64 = p.iter().map(|v| v * v).sum();
        if n <= self.max_norm_sq + 1e-9 {
            if let Some(&idx) = self.index.get(&Self::key(&p)) {
                return idx;
            }
        }
        nearest_bruteforce(&self.entries, 4, x)
    }

    fn cb_name(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;

    #[test]
    fn d4_shell_counts() {
        // D4 theta series: 1, 24 (norm² 2), 24 (norm² 4), 96 (norm² 6)...
        let p2 = d4_points_up_to(2.0);
        assert_eq!(p2.len(), 25);
        let p4 = d4_points_up_to(4.0);
        assert_eq!(p4.len(), 49);
        let p6 = d4_points_up_to(6.0);
        assert_eq!(p6.len(), 145);
    }

    #[test]
    fn d4_256_codebook_valid() {
        let cb = D4Ball::with_size(256);
        assert_eq!(Codebook::size(&cb), 256);
        for c in 0..256u32 {
            let p = cb.decode_one(c);
            let s: i64 = p.iter().map(|&v| v as i64).sum();
            assert_eq!(s.rem_euclid(2), 0, "{p:?} not even parity");
            assert!(p.iter().all(|v| (v - v.round()).abs() < 1e-9));
        }
    }

    #[test]
    fn encode_is_nearest() {
        let cb = D4Ball::with_size(256);
        check("d4_nearest", 60, |rng| {
            let x: Vec<f64> = (0..4).map(|_| rng.gaussian() * 1.5).collect();
            let got = cb.encode_one(&x);
            let dg: f64 = cb
                .decode_one(got)
                .iter()
                .zip(&x)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            for c in 0..256u32 {
                let d: f64 = cb
                    .decode_one(c)
                    .iter()
                    .zip(&x)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < dg - 1e-9 {
                    return Err(format!("code {c} beats {got}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bits_accounting() {
        use super::super::VectorQuantizer;
        let cb = D4Ball::with_size(256);
        assert!((cb.bits_per_weight() - 2.0).abs() < 1e-9);
        let cb221 = D4Ball::with_size(460);
        assert!((cb221.bits_per_weight() - 2.21).abs() < 0.01);
    }
}
