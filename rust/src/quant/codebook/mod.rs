//! Vector-quantization codebooks (paper §4.2–4.3, §C).
//!
//! Everything rounds through the [`VectorQuantizer`] trait so BlockLDLQ is
//! agnostic to the codebook: the 2-bit E8P lattice codebook (the paper's
//! contribution), the 1-bit E8 residual codebook, E8/D4 ball codebooks,
//! k-means ("AQLM-like" and the Table 7 comparison), the 1-D half-integer
//! grid (the "no-E8" ablation), and multi-stage RVQ composition.
//!
//! Convention: quantizers operate in *codebook units*. The pipeline
//! rescales weights by `sigma_w * rho` first, where `rho` is the
//! codebook's optimal Gaussian scale found by [`crate::quant::scales`].

pub mod d4;
pub mod e8;
pub mod e8p;
pub mod kmeans;
pub mod rowq;
pub mod scalar;

use crate::util::rng::Pcg64;

/// A (possibly multi-stage) vector quantizer: maps a `dim()`-vector to
/// `num_codes()` integer codes and back.
pub trait VectorQuantizer: Send + Sync {
    /// Vector dimension d (the paper's g when used inside BlockLDLQ).
    fn dim(&self) -> usize;

    /// Total bits per *weight* spent on codes: sum(log2 sizes)/dim.
    fn bits_per_weight(&self) -> f64;

    /// Number of codes emitted per vector (1 for plain codebooks,
    /// #stages for RVQ).
    fn num_codes(&self) -> usize;

    /// Quantize `x` (len = dim) writing codes into `codes` (len =
    /// num_codes) and returning the decoded vector.
    fn quantize(&self, x: &[f64], codes: &mut [u32]) -> Vec<f64>;

    /// Decode codes back to the vector.
    fn decode(&self, codes: &[u32]) -> Vec<f64>;

    /// Short identifier used in artifacts and reports.
    fn name(&self) -> String;

    /// Per-stage scale multipliers (RVQ overrides; single codebooks are
    /// `[1.0]`). Used to reconstruct per-stage total scales when packing.
    fn stage_scales(&self) -> Vec<f64> {
        vec![1.0]
    }
}

/// A single-table codebook: `size()` entries of dimension `dim()`.
/// Blanket-implements [`VectorQuantizer`].
pub trait Codebook: Send + Sync {
    fn dim(&self) -> usize;
    fn size(&self) -> usize;
    fn decode_one(&self, code: u32) -> Vec<f64>;
    /// Exact nearest codebook entry (Euclidean).
    fn encode_one(&self, x: &[f64]) -> u32;
    fn cb_name(&self) -> String;
}

impl<T: Codebook> VectorQuantizer for T {
    fn dim(&self) -> usize {
        Codebook::dim(self)
    }

    fn bits_per_weight(&self) -> f64 {
        (self.size() as f64).log2() / Codebook::dim(self) as f64
    }

    fn num_codes(&self) -> usize {
        1
    }

    fn quantize(&self, x: &[f64], codes: &mut [u32]) -> Vec<f64> {
        debug_assert_eq!(x.len(), Codebook::dim(self));
        let c = self.encode_one(x);
        codes[0] = c;
        self.decode_one(c)
    }

    fn decode(&self, codes: &[u32]) -> Vec<f64> {
        self.decode_one(codes[0])
    }

    fn name(&self) -> String {
        self.cb_name()
    }
}

/// Brute-force nearest neighbour over an explicit entry table
/// (row-major `entries`: size × dim). Shared by the smaller codebooks.
pub(crate) fn nearest_bruteforce(entries: &[f64], dim: usize, x: &[f64]) -> u32 {
    debug_assert_eq!(x.len(), dim);
    let mut best = 0u32;
    let mut best_d = f64::INFINITY;
    for (idx, e) in entries.chunks_exact(dim).enumerate() {
        let mut d = 0.0;
        for (a, b) in e.iter().zip(x) {
            let t = a - b;
            d += t * t;
            if d >= best_d {
                break;
            }
        }
        if d < best_d {
            best_d = d;
            best = idx as u32;
        }
    }
    best
}

/// Monte-Carlo elementwise MSE of quantizing N(0,1)^d with a quantizer at
/// input scale `rho` (decode(quantize(x/rho))*rho vs x). This is the
/// quantity plotted in the paper's Figure 3.
pub fn gaussian_mse(q: &dyn VectorQuantizer, rho: f64, samples: usize, rng: &mut Pcg64) -> f64 {
    let d = q.dim();
    let mut codes = vec![0u32; q.num_codes()];
    let mut se = 0.0;
    let mut count = 0usize;
    let inv = 1.0 / rho;
    while count < samples {
        let x: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
        let xs: Vec<f64> = x.iter().map(|v| v * inv).collect();
        let dec = q.quantize(&xs, &mut codes);
        for (orig, d) in x.iter().zip(&dec) {
            let err = orig - d * rho;
            se += err * err;
        }
        count += d;
    }
    se / count as f64
}

#[cfg(test)]
mod tests {
    use super::scalar::HalfIntGrid;
    use super::*;

    #[test]
    fn blanket_impl_roundtrip() {
        let g = HalfIntGrid::new(2);
        let mut codes = [0u32];
        let dec = VectorQuantizer::quantize(&g, &[0.4], &mut codes);
        assert_eq!(dec, VectorQuantizer::decode(&g, &codes));
        assert!((g.bits_per_weight() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gaussian_mse_decreases_with_bits() {
        let mut rng = Pcg64::new(1);
        let g2 = HalfIntGrid::new(2);
        let g4 = HalfIntGrid::new(4);
        let m2 = gaussian_mse(&g2, 1.0, 4000, &mut rng);
        let m4 = gaussian_mse(&g4, 1.0, 4000, &mut rng);
        assert!(m4 < m2, "4-bit MSE {m4} should beat 2-bit {m2}");
    }
}
