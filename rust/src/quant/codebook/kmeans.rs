//! Learned (k-means) vector codebooks.
//!
//! Two uses, both from the paper:
//! * Table 7 / §C.3 — "K-Means" 8-D codebook trained on a Gaussian source,
//!   compared against E8P (the paper finds E8P *beats* k-means).
//! * The "AQLM-like" baseline — a per-layer unstructured codebook with
//!   fp16-class entries, learned on the layer's own weight blocks
//!   (Egiazarian et al. 2024 use a 2^16×8 codebook per linear layer; at
//!   our model scale the codebook-size overhead is reported explicitly).

use super::Codebook;
use crate::util::rng::Pcg64;
use crate::util::threadpool;

/// A learned flat codebook of `k` entries in d dimensions.
pub struct KMeansCodebook {
    pub d: usize,
    /// k × d row-major entries.
    pub entries: Vec<f64>,
    name: String,
}

impl KMeansCodebook {
    /// Lloyd's algorithm. `data` is n × d row-major. k-means++ -lite
    /// seeding (random distinct samples), `iters` full Lloyd iterations.
    /// Assignment is parallel over samples.
    pub fn train(d: usize, k: usize, data: &[f64], iters: usize, rng: &mut Pcg64) -> Self {
        let n = data.len() / d;
        assert!(n >= 1 && data.len() == n * d);
        let k = k.min(n);
        // Seed with k distinct random samples.
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let mut entries = vec![0.0f64; k * d];
        for (c, &s) in perm.iter().take(k).enumerate() {
            entries[c * d..(c + 1) * d].copy_from_slice(&data[s * d..(s + 1) * d]);
        }
        let mut assign = vec![0u32; n];
        for _ in 0..iters {
            // Assignment step (parallel).
            let ent = &entries;
            let new_assign: Vec<u32> = threadpool::par_map(n, |i| {
                nearest_batched(ent, d, &data[i * d..(i + 1) * d])
            });
            assign = new_assign;
            // Update step.
            let mut sums = vec![0.0f64; k * d];
            let mut counts = vec![0usize; k];
            for (i, &a) in assign.iter().enumerate() {
                let a = a as usize;
                counts[a] += 1;
                for j in 0..d {
                    sums[a * d + j] += data[i * d + j];
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for j in 0..d {
                        entries[c * d + j] = sums[c * d + j] / counts[c] as f64;
                    }
                }
                // Empty clusters keep their old center.
            }
        }
        let _ = assign;
        KMeansCodebook {
            d,
            entries,
            name: format!("kmeans-{k}x{d}"),
        }
    }

    /// Train on iid N(0,1)^d samples (the Table 7 / §C.3 variant).
    pub fn train_gaussian(d: usize, k: usize, n_samples: usize, iters: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let data: Vec<f64> = (0..n_samples * d).map(|_| rng.gaussian()).collect();
        let mut cb = Self::train(d, k, &data, iters, &mut rng);
        cb.name = format!("kmeans-gauss-{k}x{d}");
        cb
    }

    /// Total storage the codebook itself needs at inference time, in bits,
    /// assuming fp16 entries (the AQLM convention the paper criticizes).
    pub fn codebook_storage_bits(&self) -> usize {
        self.entries.len() * 16
    }
}

/// Nearest entry by partial-distance brute force with norm precompute.
fn nearest_batched(entries: &[f64], d: usize, x: &[f64]) -> u32 {
    let mut best = 0u32;
    let mut best_d = f64::INFINITY;
    for (idx, e) in entries.chunks_exact(d).enumerate() {
        let mut dist = 0.0;
        for (a, b) in e.iter().zip(x) {
            let t = a - b;
            dist += t * t;
            if dist >= best_d {
                break;
            }
        }
        if dist < best_d {
            best_d = dist;
            best = idx as u32;
        }
    }
    best
}

impl Codebook for KMeansCodebook {
    fn dim(&self) -> usize {
        self.d
    }

    fn size(&self) -> usize {
        self.entries.len() / self.d
    }

    fn decode_one(&self, code: u32) -> Vec<f64> {
        let i = code as usize;
        self.entries[i * self.d..(i + 1) * self.d].to_vec()
    }

    fn encode_one(&self, x: &[f64]) -> u32 {
        nearest_batched(&self.entries, self.d, x)
    }

    fn cb_name(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::{gaussian_mse, VectorQuantizer};

    #[test]
    fn kmeans_reduces_distortion_vs_random_init() {
        let mut rng = Pcg64::new(1);
        let data: Vec<f64> = (0..2000 * 2).map(|_| rng.gaussian()).collect();
        let cb0 = KMeansCodebook::train(2, 16, &data, 0, &mut Pcg64::new(2));
        let cb5 = KMeansCodebook::train(2, 16, &data, 8, &mut Pcg64::new(2));
        let mse = |cb: &KMeansCodebook| {
            let mut s = 0.0;
            for v in data.chunks_exact(2) {
                let dec = cb.decode_one(cb.encode_one(v));
                s += (dec[0] - v[0]).powi(2) + (dec[1] - v[1]).powi(2);
            }
            s / data.len() as f64
        };
        assert!(mse(&cb5) < mse(&cb0), "{} !< {}", mse(&cb5), mse(&cb0));
    }

    #[test]
    fn memorizes_when_k_equals_n() {
        let mut rng = Pcg64::new(3);
        let data: Vec<f64> = (0..32 * 4).map(|_| rng.gaussian()).collect();
        let cb = KMeansCodebook::train(4, 32, &data, 3, &mut rng);
        for v in data.chunks_exact(4) {
            let dec = cb.decode_one(cb.encode_one(v));
            let err: f64 = dec.iter().zip(v).map(|(a, b)| (a - b).abs()).sum();
            assert!(err < 1e-9, "should memorize exactly, err={err}");
        }
    }

    #[test]
    fn gaussian_kmeans_beats_trivial_grid_at_low_rate() {
        // 16 entries in 2-D ≈ 2 bits/weight; k-means must beat the 2-bit
        // scalar grid MSE on Gaussian data (shaping advantage).
        let cb = KMeansCodebook::train_gaussian(2, 16, 4000, 12, 7);
        let grid = super::super::scalar::HalfIntGrid::new(2);
        let mut rng = Pcg64::new(9);
        let m_k = gaussian_mse(&cb, 1.0, 6000, &mut rng);
        // Grid at its optimal scale (coarse sweep).
        let mut best_grid = f64::INFINITY;
        for s in [0.6, 0.8, 1.0, 1.2, 1.4] {
            let m = gaussian_mse(&grid, s, 6000, &mut rng);
            best_grid = best_grid.min(m);
        }
        assert!(m_k < best_grid, "kmeans {m_k} !< grid {best_grid}");
    }

    #[test]
    fn storage_accounting() {
        let cb = KMeansCodebook::train_gaussian(8, 64, 512, 2, 1);
        assert_eq!(cb.codebook_storage_bits(), 64 * 8 * 16);
        assert_eq!(VectorQuantizer::num_codes(&cb), 1);
    }
}
