//! Fixed-rate E8P/RVQ codec for f32 slabs (KV-cache pages).
//!
//! Weight quantization in this repo goes through an offline pipeline
//! (Hadamard incoherence, per-matrix scale search). KV rows are produced at
//! decode time and must be compressed in nanoseconds-per-element, so this
//! codec is deliberately minimal: one RMS scale per slab, then each
//! 8-element group is quantized with the same E8P (+ residual E8P stage at
//! 4 bits) machinery the weights use.
//!
//! The contract that matters for correctness elsewhere:
//!
//! * **Decode is pure f32 and deterministic.** `decode_slab` uses the
//!   process-wide [`E8PTables`] through [`decode8_fast`] (the same AVX2
//!   sign-LUT path as the weight matmuls, bit-exact with its scalar
//!   oracle), so two lanes decoding the same codes — e.g. CoW forks
//!   sharing a cold page — see bit-identical f32 values, on any thread.
//! * **Encode minimizes *f32 reconstruction* error.** Residuals for the
//!   second stage are computed against the f32 decode of the first stage
//!   (not the f64 lattice point), so what `decode_slab` reproduces is
//!   exactly what encode optimized.
//!
//! Rates: `bits = 2` is a single E8P stage (16 bits / 8 coords);
//! `bits = 4` adds a residual E8P stage at scale 0.3, matching the RVQ
//! stage scales used for 4-bit weights (`quant/rvq.rs`).

use crate::model::qlinear::{decode8_fast, E8PTables};
use crate::quant::codebook::e8p::E8P;

/// Smallest slab RMS treated as a real signal; all-zero (or denormal)
/// slabs fall back to scale 1.0 so decode stays finite.
const MIN_SCALE: f32 = 1e-20;

/// Fixed-rate f32 slab encoder/decoder built on E8P residual stages.
pub struct RowCodec {
    e8p: E8P,
    tables: &'static E8PTables,
    /// Per-stage scales (f32 so encode's residual arithmetic mirrors the
    /// f32 decode exactly).
    stage_scales: Vec<f32>,
    bits: usize,
}

impl RowCodec {
    /// `bits` must be 2 (one E8P stage) or 4 (E8P + 0.3-scaled residual
    /// E8P stage, the `rvq_4bit` recipe).
    pub fn new(bits: usize) -> Self {
        let stage_scales = match bits {
            2 => vec![1.0f32],
            4 => vec![1.0f32, 0.3f32],
            _ => panic!("RowCodec supports 2 or 4 bits per weight, got {bits}"),
        };
        RowCodec {
            e8p: E8P::new(),
            tables: E8PTables::shared(),
            stage_scales,
            bits,
        }
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    pub fn stages(&self) -> usize {
        self.stage_scales.len()
    }

    /// Number of u16 codes `encode_slab` emits for a slab of `len` f32s.
    pub fn codes_per_slab(&self, len: usize) -> usize {
        assert_eq!(len % 8, 0, "slab length must be a multiple of 8");
        self.stage_scales.len() * (len / 8)
    }

    /// Quantize `x` into `codes` (stage-major: all stage-0 group codes,
    /// then all stage-1), returning the slab scale used. `codes.len()`
    /// must equal `codes_per_slab(x.len())`.
    pub fn encode_slab(&self, x: &[f32], codes: &mut [u16]) -> f32 {
        let ng = x.len() / 8;
        assert_eq!(x.len(), ng * 8, "slab length must be a multiple of 8");
        assert_eq!(codes.len(), self.stage_scales.len() * ng);
        let scale = slab_scale(x);
        let inv = 1.0f32 / scale;
        let mut dec = [0.0f32; 8];
        for g in 0..ng {
            // Residual chain in f32, mirroring decode_slab's arithmetic.
            // Non-finite inputs are encoded as 0 (see [`finite_or_zero`]):
            // a poisoned element must not poison its whole group with NaN
            // residuals, and the scale already ignored it.
            let mut resid = [0.0f32; 8];
            for i in 0..8 {
                resid[i] = finite_or_zero(x[g * 8 + i]) * inv;
            }
            for (si, &ss) in self.stage_scales.iter().enumerate() {
                let mut target = [0.0f64; 8];
                for i in 0..8 {
                    target[i] = (resid[i] / ss) as f64;
                }
                let code = self.e8p.encode_u16(&target);
                codes[si * ng + g] = code;
                decode8_fast(self.tables, code, &mut dec);
                for i in 0..8 {
                    resid[i] -= dec[i] * ss;
                }
            }
        }
        scale
    }

    /// Reconstruct a slab previously produced by [`encode_slab`]. Pure
    /// f32; bit-deterministic for fixed codes + scale.
    pub fn decode_slab(&self, codes: &[u16], scale: f32, out: &mut [f32]) {
        let ng = out.len() / 8;
        assert_eq!(out.len(), ng * 8, "slab length must be a multiple of 8");
        assert_eq!(codes.len(), self.stage_scales.len() * ng);
        let mut dec = [0.0f32; 8];
        for (si, &ss) in self.stage_scales.iter().enumerate() {
            let stage = &codes[si * ng..(si + 1) * ng];
            if si == 0 {
                for g in 0..ng {
                    decode8_fast(self.tables, stage[g], &mut dec);
                    for i in 0..8 {
                        out[g * 8 + i] = dec[i] * ss;
                    }
                }
            } else {
                for g in 0..ng {
                    decode8_fast(self.tables, stage[g], &mut dec);
                    for i in 0..8 {
                        out[g * 8 + i] += dec[i] * ss;
                    }
                }
            }
        }
        for v in out.iter_mut() {
            *v *= scale;
        }
    }
}

/// A value the codec can actually represent: NaN and ±inf map to 0.
/// A non-finite element carries no information a fixed-rate lattice
/// code could recover, and letting it through would turn the whole
/// slab's decode into NaN (`inf × 1/inf`, NaN residuals feeding
/// `encode_u16`). KV rows should never contain such values; if one
/// sneaks in, it must not poison the page.
#[inline]
fn finite_or_zero(v: f32) -> f32 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// RMS of the slab, clamped away from zero so `x / scale` is always
/// finite. RMS (rather than abs-max) keeps the scaled distribution close
/// to the unit Gaussian ball E8P is shaped for. Non-finite elements are
/// excluded (as zeros) so one poisoned value cannot drive the scale to
/// inf/NaN; the guard also rejects a non-finite RMS outright, so the
/// returned scale is always finite and positive.
fn slab_scale(x: &[f32]) -> f32 {
    let mut sumsq = 0.0f64;
    for &v in x {
        let v = finite_or_zero(v);
        sumsq += (v as f64) * (v as f64);
    }
    let rms = (sumsq / x.len().max(1) as f64).sqrt() as f32;
    if rms.is_finite() && rms > MIN_SCALE {
        rms
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;

    fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            num += ((x - y) as f64).powi(2);
            den += (*x as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt()
    }

    #[test]
    fn roundtrip_error_bounded() {
        // Generous bounds: E8P on unit-Gaussian data has per-coord MSE well
        // under 0.1 (see quant::codebook tests), so relative L2 lands near
        // 0.28 at 2 bits and well under that with the residual stage. The
        // thresholds below only catch gross breakage, not regressions.
        for (bits, bound) in [(2usize, 0.7f64), (4usize, 0.35f64)] {
            let codec = RowCodec::new(bits);
            check(&format!("rowq_roundtrip_{bits}b"), 20, |rng| {
                let x = rng.gaussian_vec(256, 1.7);
                let mut codes = vec![0u16; codec.codes_per_slab(x.len())];
                let scale = codec.encode_slab(&x, &mut codes);
                let mut out = vec![0.0f32; x.len()];
                codec.decode_slab(&codes, scale, &mut out);
                let err = rel_l2(&x, &out);
                if err > bound {
                    return Err(format!("{bits}-bit rel L2 {err} > {bound}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn decode_is_bit_deterministic() {
        let codec = RowCodec::new(4);
        check("rowq_deterministic", 10, |rng| {
            let x = rng.gaussian_vec(64, 1.0);
            let mut codes = vec![0u16; codec.codes_per_slab(x.len())];
            let scale = codec.encode_slab(&x, &mut codes);
            let mut a = vec![0.0f32; x.len()];
            let mut b = vec![7.0f32; x.len()];
            codec.decode_slab(&codes, scale, &mut a);
            codec.decode_slab(&codes, scale, &mut b);
            for (u, v) in a.iter().zip(&b) {
                if u.to_bits() != v.to_bits() {
                    return Err(format!("decode not deterministic: {u} vs {v}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_slab_stays_finite() {
        let codec = RowCodec::new(2);
        let x = vec![0.0f32; 32];
        let mut codes = vec![0u16; codec.codes_per_slab(32)];
        let scale = codec.encode_slab(&x, &mut codes);
        assert_eq!(scale, 1.0);
        let mut out = vec![f32::NAN; 32];
        codec.decode_slab(&codes, scale, &mut out);
        for v in &out {
            assert!(v.is_finite());
            // Nearest lattice point to 0 is within the shifted codebook's
            // minimum radius; just sanity-bound it.
            assert!(v.abs() < 2.0, "zero slab decoded to {v}");
        }
    }

    /// Adversarial rows must never panic and must round-trip to finite
    /// values: a poisoned KV element (NaN/±inf from an upstream overflow)
    /// or a degenerate-scale row (denormals, one huge spike in zeros) is
    /// exactly the input a serving engine cannot afford to crash on.
    #[test]
    fn adversarial_rows_never_panic_and_decode_finite() {
        let spike = {
            let mut v = vec![0.0f32; 32];
            v[13] = f32::MAX;
            v
        };
        let mixed = {
            let mut v = vec![1.0f32; 32];
            v[0] = f32::NAN;
            v[7] = f32::INFINITY;
            v[8] = f32::NEG_INFINITY;
            v[20] = -3.5;
            v
        };
        let cases: Vec<(&str, Vec<f32>)> = vec![
            ("all_zero", vec![0.0f32; 32]),
            ("all_nan", vec![f32::NAN; 32]),
            ("all_pos_inf", vec![f32::INFINITY; 32]),
            ("all_neg_inf", vec![f32::NEG_INFINITY; 32]),
            ("denormal", vec![1e-40f32; 32]),
            ("single_spike", spike),
            ("mixed_poison", mixed),
            ("neg_zero", vec![-0.0f32; 32]),
            ("f32_min_positive", vec![f32::MIN_POSITIVE; 32]),
        ];
        for bits in [2usize, 4] {
            let codec = RowCodec::new(bits);
            for (name, x) in &cases {
                let mut codes = vec![0u16; codec.codes_per_slab(x.len())];
                let scale = codec.encode_slab(x, &mut codes);
                assert!(
                    scale.is_finite() && scale > 0.0,
                    "{bits}-bit {name}: scale {scale} not finite-positive"
                );
                let mut out = vec![f32::NAN; x.len()];
                codec.decode_slab(&codes, scale, &mut out);
                for (i, v) in out.iter().enumerate() {
                    assert!(
                        v.is_finite(),
                        "{bits}-bit {name}: decoded[{i}] = {v} not finite"
                    );
                }
            }
        }
    }

    /// Non-finite elements decode as (near) zero and do not disturb the
    /// finite elements around them: the mixed-poison slab reconstructs
    /// its finite values about as well as the same slab without poison.
    #[test]
    fn poisoned_elements_do_not_poison_neighbors() {
        let codec = RowCodec::new(4);
        let clean: Vec<f32> = (0..64).map(|i| ((i * 37 % 23) as f32 - 11.0) * 0.1).collect();
        let mut poisoned = clean.clone();
        poisoned[5] = f32::NAN;
        poisoned[17] = f32::INFINITY;
        poisoned[40] = f32::NEG_INFINITY;

        let decode = |x: &[f32]| {
            let mut codes = vec![0u16; codec.codes_per_slab(x.len())];
            let scale = codec.encode_slab(x, &mut codes);
            let mut out = vec![0.0f32; x.len()];
            codec.decode_slab(&codes, scale, &mut out);
            out
        };
        let out_clean = decode(&clean);
        let out_poison = decode(&poisoned);
        for (i, (&c, &p)) in out_clean.iter().zip(&out_poison).enumerate() {
            if matches!(i, 5 | 17 | 40) {
                // Poisoned slots behave as zeros.
                assert!(p.is_finite() && p.abs() < 1.0, "slot {i} decoded to {p}");
            } else {
                // The neighbors' reconstruction stays in the same ballpark
                // (scales differ slightly since poison drops three terms
                // from the RMS; bound loosely).
                assert!(
                    (c - p).abs() < 0.5,
                    "slot {i}: clean {c} vs poisoned {p} diverged"
                );
            }
        }
    }

    #[test]
    fn four_bit_beats_two_bit() {
        let c2 = RowCodec::new(2);
        let c4 = RowCodec::new(4);
        check("rowq_4_beats_2", 10, |rng| {
            let x = rng.gaussian_vec(512, 1.0);
            let mut e = [0.0f64; 2];
            for (slot, codec) in [&c2, &c4].iter().enumerate() {
                let mut codes = vec![0u16; codec.codes_per_slab(x.len())];
                let scale = codec.encode_slab(&x, &mut codes);
                let mut out = vec![0.0f32; x.len()];
                codec.decode_slab(&codes, scale, &mut out);
                e[slot] = rel_l2(&x, &out);
            }
            if e[1] >= e[0] {
                return Err(format!("4-bit err {} not below 2-bit err {}", e[1], e[0]));
            }
            Ok(())
        });
    }

    /// The 4-bit < 2-bit error ordering must hold across input scales,
    /// not just unit-variance data: the slab RMS normalization is what
    /// makes the codebook scale-free, so a regression here usually means
    /// `slab_scale` broke.
    #[test]
    fn rate_monotone_across_scales() {
        let c2 = RowCodec::new(2);
        let c4 = RowCodec::new(4);
        for std in [0.01f32, 0.3, 1.0, 4.0, 50.0] {
            check(&format!("rowq_monotone_std_{std}"), 8, |rng| {
                let x = rng.gaussian_vec(256, std);
                let mut e = [0.0f64; 2];
                for (slot, codec) in [&c2, &c4].iter().enumerate() {
                    let mut codes = vec![0u16; codec.codes_per_slab(x.len())];
                    let scale = codec.encode_slab(&x, &mut codes);
                    let mut out = vec![0.0f32; x.len()];
                    codec.decode_slab(&codes, scale, &mut out);
                    e[slot] = rel_l2(&x, &out);
                }
                if e[1] >= e[0] {
                    return Err(format!(
                        "std {std}: 4-bit err {} not below 2-bit err {}",
                        e[1], e[0]
                    ));
                }
                Ok(())
            });
        }
    }
}
