//! End-to-end per-layer quantization pipeline (paper Algorithm 1) and the
//! method registry backing every experiment table.
//!
//! `quantize_matrix` takes a trained weight matrix and its proxy Hessian
//! and produces a [`QuantizedLinear`]: the dense effective weight (for
//! native evaluation), packed E8P codes + sign vectors (for the inference
//! hot path and the AOT artifacts), and quality/bit statistics.

use super::codebook::d4::D4Ball;
use super::codebook::e8::{E8Ball, E8OneBit};
use super::codebook::e8p::E8P;
use super::codebook::kmeans::KMeansCodebook;
use super::codebook::scalar::HalfIntGrid;
use super::codebook::VectorQuantizer;
use super::incoherence::{mu_w, IncoherenceCtx, IncoherenceKind};
use super::ldlq::block_ldlq;
use super::packing::BitAccounting;
use super::rvq::Rvq;
use super::scales::{optimal_rho, rvq_stage_scales};
use crate::linalg::Matrix;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::sync::Arc;

/// Every quantization method the experiment tables exercise.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// No quantization (FP16/FP32 reference rows).
    Fp16,
    /// QuIP#: RHT incoherence + BlockLDLQ + E8P (+ RVQ stages at 3/4 bits).
    /// Fine-tuning is applied afterwards by `ft::finetune` when `ft`.
    QuipSharp { bits: u8, ft: bool },
    /// Ablation "no E8": RHT + scalar LDLQ on the half-integer grid.
    QuipSharpNoE8 { bits: u8 },
    /// Table 1: RFFT instead of RHT.
    QuipSharpRfft { bits: u8 },
    /// QuIP baseline (Chee et al. 2023): Kronecker incoherence + scalar
    /// LDLQ on the half-integer grid.
    QuipKron { bits: u8 },
    /// OmniQuant-like: per-channel (optionally per-group) learned
    /// clipping grid quantization, Hessian-diagonal weighted.
    OmniquantLike { bits: u8, group: Option<usize> },
    /// AWQ-like: activation-magnitude channel scaling + clipped RTN grid.
    AwqLike { bits: u8 },
    /// AQLM-like: per-layer k-means 8-D codebook (fp16 entries) with
    /// BlockLDLQ feedback; codebook storage reported in bit accounting.
    AqlmLike { bits: u8 },
    /// Table 7 codebook swaps (all with RHT + BlockLDLQ, no FT).
    CodebookSwap { cb: SwapCodebook },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapCodebook {
    /// D4 ∩ ball, 256 entries (2 bits).
    D4Two,
    /// D4 ∩ ball, 460 entries (≈2.21 bits).
    D4TwoTwentyOne,
    /// E8 ∩ ball, 2^19 entries (≈2.37 bits).
    E8TwoThirtySeven,
    /// K-means on Gaussian, 2^16 × 8 (2 bits).
    KMeansTwo,
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Fp16 => "fp16".into(),
            Method::QuipSharp { bits, ft } => {
                format!("quip#-{bits}bit{}", if *ft { "" } else { "-noft" })
            }
            Method::QuipSharpNoE8 { bits } => format!("quip#-{bits}bit-noe8"),
            Method::QuipSharpRfft { bits } => format!("quip#-{bits}bit-rfft"),
            Method::QuipKron { bits } => format!("quip-kron-{bits}bit"),
            Method::OmniquantLike { bits, group } => match group {
                Some(g) => format!("omniq-{bits}bit-g{g}"),
                None => format!("omniq-{bits}bit"),
            },
            Method::AwqLike { bits } => format!("awq-{bits}bit"),
            Method::AqlmLike { bits } => format!("aqlm-{bits}bit"),
            Method::CodebookSwap { cb } => match cb {
                SwapCodebook::D4Two => "d4-2bit".into(),
                SwapCodebook::D4TwoTwentyOne => "d4-2.21bit".into(),
                SwapCodebook::E8TwoThirtySeven => "e8-2.37bit".into(),
                SwapCodebook::KMeansTwo => "kmeans-2bit".into(),
            },
        }
    }
}

/// Quality statistics recorded for every quantized layer.
#[derive(Clone, Debug, Default)]
pub struct QuantStats {
    /// tr((Ŵ−W)H(Ŵ−W)ᵀ) in the processed domain.
    pub proxy_err: f64,
    /// proxy error relative to tr(W H Wᵀ).
    pub proxy_rel: f64,
    /// ‖Ŵ−W‖_F / ‖W‖_F in the original domain.
    pub frob_rel: f64,
    /// μ_W before and after incoherence processing.
    pub mu_before: f64,
    pub mu_after: f64,
}

/// Packed representation for the E8P family (what the inference hot path
/// and the AOT artifacts consume).
#[derive(Clone, Debug)]
pub struct PackedE8P {
    /// Per-stage 16-bit codewords, each stage m×(n/8) row-major. Held by
    /// `Arc` so the serving hot path (`QuantMatvec::from_packed`) shares
    /// the payload instead of deep-cloning it per layer.
    pub stage_codes: Arc<Vec<Vec<u16>>>,
    /// Per-stage global scale (σ_w · ρ · stage multiplier).
    pub stage_scales: Vec<f32>,
    /// RHT sign vectors (±1, or real after fine-tuning).
    pub su: Vec<f32>,
    pub sv: Vec<f32>,
}

/// A quantized linear layer.
pub struct QuantizedLinear {
    pub method: Method,
    pub m: usize,
    pub n: usize,
    /// Effective dense weight in the *original* domain (Ŵ_eff ≈ W),
    /// row-major f32 — used by native evaluation and fine-tuning.
    pub w_eff: Vec<f32>,
    /// Fast-path payload when the method is E8P-based.
    pub packed: Option<PackedE8P>,
    /// The incoherence context (needed to re-assemble w_eff after sign
    /// vectors are fine-tuned). None for grid/AQLM methods.
    pub ctx: Option<IncoherenceCtx>,
    /// Quantized weights in the processed domain (None for grid methods).
    pub w_hat_tilde: Option<Matrix>,
    pub bits: BitAccounting,
    pub stats: QuantStats,
}

impl QuantizedLinear {
    /// Recompute `w_eff` from the processed-domain Ŵ and the (possibly
    /// fine-tuned) sign vectors.
    pub fn refresh_w_eff(&mut self) {
        if let (Some(ctx), Some(wht)) = (&self.ctx, &self.w_hat_tilde) {
            let w = ctx.unprocess_w(wht);
            self.w_eff = w.to_f32();
        }
    }

    /// Install fine-tuned (real-valued) sign vectors — paper §5: "we must
    /// store the sign vectors in FP16 instead of as bitvectors".
    pub fn set_signs(&mut self, su: &[f32], sv: &[f32]) {
        if let Some(ctx) = &mut self.ctx {
            if let Some(s) = ctx.u.sign_vec_mut() {
                s.clear();
                s.extend(su.iter().map(|&v| v as f64));
            }
            if let Some(s) = ctx.v.sign_vec_mut() {
                s.clear();
                s.extend(sv.iter().map(|&v| v as f64));
            }
        }
        if let Some(p) = &mut self.packed {
            p.su = su.to_vec();
            p.sv = sv.to_vec();
        }
    }
}

/// Build the paper's quantizer for a bit width: 2 → E8P, 3 → E8P + 1-bit
/// E8 residual, 4 → E8P + E8P residual (§4.3).
pub fn build_e8p_quantizer(bits: u8) -> Box<dyn VectorQuantizer> {
    match bits {
        2 => Box::new(E8P::new()),
        3 => {
            let (s1, s2) = rvq_stage_scales(&E8P::new(), &E8OneBit::new());
            Box::new(Rvq::new(vec![
                (Box::new(E8P::new()) as Box<dyn VectorQuantizer>, s1),
                (Box::new(E8OneBit::new()), s2),
            ]))
        }
        4 => {
            let (s1, s2) = rvq_stage_scales(&E8P::new(), &E8P::new());
            Box::new(Rvq::new(vec![
                (Box::new(E8P::new()) as Box<dyn VectorQuantizer>, s1),
                (Box::new(E8P::new()), s2),
            ]))
        }
        b => panic!("unsupported E8P bit width {b}"),
    }
}

fn sigma_w(w: &Matrix) -> f64 {
    (w.frob_norm().powi(2) / (w.rows * w.cols) as f64).sqrt()
}

/// Incoherence + BlockLDLQ + codebook path shared by every lattice/VQ
/// method. `kind` selects RHT/RFFT/Kron; `q` is the (possibly RVQ)
/// quantizer operating at unit-Gaussian scale.
fn quantize_incoherent(
    method: &Method,
    w: &Matrix,
    h: &Matrix,
    kind: IncoherenceKind,
    q: &dyn VectorQuantizer,
    seed: u64,
    ft_signs: bool,
    codebook_storage_bits: usize,
) -> Result<QuantizedLinear> {
    let (m, n) = (w.rows, w.cols);
    let mut rng = Pcg64::new(seed);
    let ctx = IncoherenceCtx::new(kind, m, n, &mut rng);
    let wt = ctx.process_w(w);
    let ht = ctx.process_h(h);

    let (rho, _) = optimal_rho(q, 20_000, 17);
    // Convention must match `gaussian_mse`: the quantizer sees x/ρ for
    // x ~ N(0,1), i.e. W̃/(σ_W·ρ). (A σ/ρ slip here is nearly invisible
    // for E8P, whose ρ* ≈ 0.95, but breaks scalar grids with ρ* ≈ 0.3 —
    // caught by the Table 2 driver.)
    let scale = sigma_w(&wt) * rho.max(1e-9);

    let res = block_ldlq(&wt, &ht, q, scale)?;

    // Effective weight back in the original domain.
    let w_eff = ctx.unprocess_w(&res.w_hat);

    // Stats.
    let base = wt.matmul(&ht).matmul_transb(&wt).trace();
    let diff_f = res.w_hat.sub(&wt).frob_norm();
    let stats = QuantStats {
        proxy_err: res.proxy_err,
        proxy_rel: res.proxy_err / base.max(1e-30),
        frob_rel: diff_f / wt.frob_norm().max(1e-30),
        mu_before: mu_w(w),
        mu_after: mu_w(&wt),
    };

    // Pack the E8P fast path when applicable (8-dim quantizers).
    let packed = if q.dim() == 8 {
        let stages = q.num_codes();
        let nb = n / 8;
        let mut stage_codes: Vec<Vec<u16>> = vec![Vec::with_capacity(m * nb); stages];
        for i in 0..m {
            for k in 0..nb {
                for s in 0..stages {
                    stage_codes[s].push(res.codes[(i * nb + k) * stages + s] as u16);
                }
            }
        }
        // Per-stage total scale: global scale × RVQ stage multiplier.
        let muls: Vec<f64> = q.stage_scales();
        let su = ctx
            .u
            .sign_vec()
            .map(|s| s.iter().map(|&v| v as f32).collect())
            .unwrap_or_default();
        let sv = ctx
            .v
            .sign_vec()
            .map(|s| s.iter().map(|&v| v as f32).collect())
            .unwrap_or_default();
        Some(PackedE8P {
            stage_codes: Arc::new(stage_codes),
            stage_scales: muls.iter().map(|&s| (s * scale) as f32).collect(),
            su,
            sv,
        })
    } else {
        None
    };

    let bits = BitAccounting::new(
        m,
        n,
        q.bits_per_weight(),
        ft_signs,
        q.num_codes(),
        codebook_storage_bits,
    );

    Ok(QuantizedLinear {
        method: method.clone(),
        m,
        n,
        w_eff: w_eff.to_f32(),
        packed,
        ctx: Some(ctx),
        w_hat_tilde: Some(res.w_hat),
        bits,
        stats,
    })
}

/// Symmetric k-bit RTN grid quantization of one channel group with clip
/// search: pick the scale minimizing Σ d_j (w_j − ŵ_j)² over a grid of
/// clip ratios, where d_j are importance weights (Hessian diagonal).
fn grid_quantize_group(w: &[f64], d: &[f64], bits: u8, out: &mut [f64]) -> f64 {
    let qmax = ((1i64 << (bits - 1)) - 1).max(1) as f64; // symmetric int grid
    let wmax = w.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1e-12);
    let mut best_scale = wmax / qmax;
    let mut best_err = f64::INFINITY;
    for clip in [0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 1.0] {
        let scale = (wmax * clip / qmax).max(1e-12);
        let mut err = 0.0;
        for (j, &v) in w.iter().enumerate() {
            let q = (v / scale).round().clamp(-qmax - 1.0, qmax);
            let e = v - q * scale;
            err += d[j] * e * e;
        }
        if err < best_err {
            best_err = err;
            best_scale = scale;
        }
    }
    for (j, &v) in w.iter().enumerate() {
        let q = (v / best_scale).round().clamp(-qmax - 1.0, qmax);
        out[j] = q * best_scale;
    }
    best_err
}

/// OmniQuant-like: per-output-channel (optionally per-group along input
/// dim) clipped RTN, weighted by the Hessian diagonal (their learnable
/// equivalent transformation, realized as a direct search).
fn quantize_omniquant(
    method: &Method,
    w: &Matrix,
    h: &Matrix,
    bits: u8,
    group: Option<usize>,
) -> QuantizedLinear {
    let (m, n) = (w.rows, w.cols);
    let diag: Vec<f64> = (0..n).map(|j| h[(j, j)].max(1e-12)).collect();
    let gsize = group.unwrap_or(n);
    assert!(n % gsize == 0);
    let mut w_eff = Matrix::zeros(m, n);
    let mut proxy = 0.0;
    for i in 0..m {
        for g0 in (0..n).step_by(gsize) {
            let mut out = vec![0.0; gsize];
            proxy += grid_quantize_group(
                &w.row(i)[g0..g0 + gsize],
                &diag[g0..g0 + gsize],
                bits,
                &mut out,
            );
            w_eff.row_mut(i)[g0..g0 + gsize].copy_from_slice(&out);
        }
    }
    let diff = w_eff.sub(w);
    let base = w.matmul(h).matmul_transb(w).trace();
    let true_proxy = diff.matmul(h).matmul_transb(&diff).trace();
    let n_scales = m * (n / gsize);
    let _ = proxy;
    QuantizedLinear {
        method: method.clone(),
        m,
        n,
        w_eff: w_eff.to_f32(),
        packed: None,
        ctx: None,
        w_hat_tilde: None,
        bits: BitAccounting::new(m, n, bits as f64, false, n_scales, 0),
        stats: QuantStats {
            proxy_err: true_proxy,
            proxy_rel: true_proxy / base.max(1e-30),
            frob_rel: diff.frob_norm() / w.frob_norm().max(1e-30),
            mu_before: mu_w(w),
            mu_after: mu_w(w),
        },
    }
}

/// AWQ-like: scale input channels by activation magnitude^α (α = 0.5,
/// E[x_j²] ≈ H_jj), then per-channel clipped RTN; the inverse scaling is
/// model-preserving and folded back into the effective weight.
fn quantize_awq(method: &Method, w: &Matrix, h: &Matrix, bits: u8) -> QuantizedLinear {
    let (m, n) = (w.rows, w.cols);
    let alpha = 0.5;
    let act: Vec<f64> = (0..n).map(|j| h[(j, j)].max(1e-12).sqrt()).collect();
    let act_mean = act.iter().sum::<f64>() / n as f64;
    let s: Vec<f64> = act.iter().map(|a| (a / act_mean).powf(alpha).max(1e-6)).collect();
    // w' = w ⊙ s (per input channel), quantize w', then fold s back.
    let ws = w.scale_cols(&s);
    let diag: Vec<f64> = (0..n).map(|j| h[(j, j)].max(1e-12) / (s[j] * s[j])).collect();
    let mut w_q = Matrix::zeros(m, n);
    for i in 0..m {
        let mut out = vec![0.0; n];
        grid_quantize_group(ws.row(i), &diag, bits, &mut out);
        w_q.row_mut(i).copy_from_slice(&out);
    }
    let inv_s: Vec<f64> = s.iter().map(|v| 1.0 / v).collect();
    let w_eff = w_q.scale_cols(&inv_s);
    let diff = w_eff.sub(w);
    let base = w.matmul(h).matmul_transb(w).trace();
    let true_proxy = diff.matmul(h).matmul_transb(&diff).trace();
    QuantizedLinear {
        method: method.clone(),
        m,
        n,
        w_eff: w_eff.to_f32(),
        packed: None,
        ctx: None,
        w_hat_tilde: None,
        // per-output-channel scale + n per-input-channel fp16 scales
        bits: BitAccounting::new(m, n, bits as f64, false, m + n, 0),
        stats: QuantStats {
            proxy_err: true_proxy,
            proxy_rel: true_proxy / base.max(1e-30),
            frob_rel: diff.frob_norm() / w.frob_norm().max(1e-30),
            mu_before: mu_w(w),
            mu_after: mu_w(w),
        },
    }
}

/// AQLM-like: per-layer k-means codebook (k capped by the layer's block
/// count) learned on the layer's own 8-D weight blocks, then BlockLDLQ.
/// Codebook storage (fp16) is charged to the bit accounting — the
/// paper's Table 6 point.
fn quantize_aqlm(
    method: &Method,
    w: &Matrix,
    h: &Matrix,
    bits: u8,
    seed: u64,
) -> Result<QuantizedLinear> {
    let (m, n) = (w.rows, w.cols);
    let d = 8usize;
    anyhow::ensure!(n % d == 0);
    let n_vec = m * n / d;
    let k_target = 1usize << (bits as usize * d); // 2^{8·bits}
    let k = k_target.min(n_vec / 2).max(16);
    // Train on the layer's blocks, normalized.
    let sigma = sigma_w(w).max(1e-12);
    let data: Vec<f64> = w.data.iter().map(|&v| v / sigma).collect();
    let mut rng = Pcg64::new(seed ^ 0x41514c4d); // "AQLM"
    let cb = KMeansCodebook::train(d, k, &data, 6, &mut rng);
    let storage = cb.codebook_storage_bits();
    let res = block_ldlq(w, h, &cb, sigma)?;
    let diff = res.w_hat.sub(w);
    let base = w.matmul(h).matmul_transb(w).trace();
    let code_bits = (k as f64).log2() / d as f64;
    Ok(QuantizedLinear {
        method: method.clone(),
        m,
        n,
        w_eff: res.w_hat.to_f32(),
        packed: None,
        ctx: None,
        w_hat_tilde: None,
        bits: BitAccounting::new(m, n, code_bits, false, 1, storage),
        stats: QuantStats {
            proxy_err: res.proxy_err,
            proxy_rel: res.proxy_err / base.max(1e-30),
            frob_rel: diff.frob_norm() / w.frob_norm().max(1e-30),
            mu_before: mu_w(w),
            mu_after: mu_w(w),
        },
    })
}

/// Quantize one linear layer with any method. `seed` controls the random
/// transforms (stored in the result for inference).
pub fn quantize_matrix(
    method: &Method,
    w: &Matrix,
    h: &Matrix,
    seed: u64,
) -> Result<QuantizedLinear> {
    match method {
        Method::Fp16 => {
            let (m, n) = (w.rows, w.cols);
            Ok(QuantizedLinear {
                method: method.clone(),
                m,
                n,
                w_eff: w.to_f32(),
                packed: None,
                ctx: None,
                w_hat_tilde: None,
                bits: BitAccounting::new(m, n, 16.0, false, 0, 0),
                stats: QuantStats {
                    mu_before: mu_w(w),
                    mu_after: mu_w(w),
                    ..Default::default()
                },
            })
        }
        Method::QuipSharp { bits, ft } => {
            let q = build_e8p_quantizer(*bits);
            quantize_incoherent(method, w, h, IncoherenceKind::Rht, q.as_ref(), seed, *ft, 0)
        }
        Method::QuipSharpNoE8 { bits } => {
            let q = HalfIntGrid::new(*bits as u32);
            quantize_incoherent(method, w, h, IncoherenceKind::Rht, &q, seed, false, 0)
        }
        Method::QuipSharpRfft { bits } => {
            let q = build_e8p_quantizer(*bits);
            quantize_incoherent(method, w, h, IncoherenceKind::Rfft, q.as_ref(), seed, false, 0)
        }
        Method::QuipKron { bits } => {
            let q = HalfIntGrid::new(*bits as u32);
            quantize_incoherent(method, w, h, IncoherenceKind::Kron2, &q, seed, false, 0)
        }
        Method::OmniquantLike { bits, group } => {
            Ok(quantize_omniquant(method, w, h, *bits, *group))
        }
        Method::AwqLike { bits } => Ok(quantize_awq(method, w, h, *bits)),
        Method::AqlmLike { bits } => quantize_aqlm(method, w, h, *bits, seed),
        Method::CodebookSwap { cb } => match cb {
            SwapCodebook::D4Two => {
                let q = D4Ball::with_size(256);
                quantize_incoherent(method, w, h, IncoherenceKind::Rht, &q, seed, false, 0)
            }
            SwapCodebook::D4TwoTwentyOne => {
                let q = D4Ball::with_size(460);
                quantize_incoherent(method, w, h, IncoherenceKind::Rht, &q, seed, false, 0)
            }
            SwapCodebook::E8TwoThirtySeven => {
                let q = E8Ball::with_size(1 << 19);
                quantize_incoherent(method, w, h, IncoherenceKind::Rht, &q, seed, false, 0)
            }
            SwapCodebook::KMeansTwo => {
                let q = KMeansCodebook::train_gaussian(8, 1 << 16, 1 << 17, 4, 99);
                let storage = q.codebook_storage_bits();
                quantize_incoherent(method, w, h, IncoherenceKind::Rht, &q, seed, false, storage)
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ldl::random_spd;

    fn setup(m: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Pcg64::new(seed);
        let w = Matrix::gaussian(m, n, 0.02, &mut rng);
        let h = random_spd(n, 0.05, &mut rng);
        (w, h)
    }

    #[test]
    fn quip_sharp_2bit_roundtrip() {
        let (w, h) = setup(16, 32, 1);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 7).unwrap();
        assert_eq!(ql.w_eff.len(), 16 * 32);
        assert!(ql.stats.frob_rel < 0.8, "frob_rel={}", ql.stats.frob_rel);
        assert!(ql.packed.is_some());
        let p = ql.packed.as_ref().unwrap();
        assert_eq!(p.stage_codes.len(), 1);
        assert_eq!(p.stage_codes[0].len(), 16 * 4);
        assert!((ql.bits.code_bits - 2.0).abs() < 1e-12);
    }

    #[test]
    fn higher_bits_lower_error() {
        let (w, h) = setup(16, 32, 2);
        let e2 = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 7)
            .unwrap()
            .stats
            .proxy_err;
        let e4 = quantize_matrix(&Method::QuipSharp { bits: 4, ft: false }, &w, &h, 7)
            .unwrap()
            .stats
            .proxy_err;
        assert!(e4 < e2, "4-bit {e4} !< 2-bit {e2}");
    }

    #[test]
    fn quip_sharp_beats_grid_baselines_at_2bit() {
        let (w, h) = setup(24, 64, 3);
        let qs = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 7)
            .unwrap()
            .stats
            .proxy_rel;
        let om = quantize_matrix(&Method::OmniquantLike { bits: 2, group: None }, &w, &h, 7)
            .unwrap()
            .stats
            .proxy_rel;
        let aw = quantize_matrix(&Method::AwqLike { bits: 2 }, &w, &h, 7)
            .unwrap()
            .stats
            .proxy_rel;
        assert!(qs < om, "quip# {qs} !< omniq {om}");
        assert!(qs < aw, "quip# {qs} !< awq {aw}");
    }

    #[test]
    fn grid_methods_work_at_4bit() {
        let (w, h) = setup(16, 32, 4);
        for m in [
            Method::OmniquantLike { bits: 4, group: Some(16) },
            Method::AwqLike { bits: 4 },
        ] {
            let ql = quantize_matrix(&m, &w, &h, 7).unwrap();
            assert!(
                ql.stats.frob_rel < 0.2,
                "{}: frob_rel={}",
                m.label(),
                ql.stats.frob_rel
            );
        }
    }

    #[test]
    fn fp16_is_exact() {
        let (w, h) = setup(8, 16, 5);
        let ql = quantize_matrix(&Method::Fp16, &w, &h, 7).unwrap();
        for (a, b) in ql.w_eff.iter().zip(&w.to_f32()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn refresh_w_eff_consistent() {
        let (w, h) = setup(8, 16, 6);
        let mut ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 7).unwrap();
        let before = ql.w_eff.clone();
        ql.refresh_w_eff();
        for (a, b) in before.iter().zip(&ql.w_eff) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn labels_unique() {
        let methods = [
            Method::Fp16,
            Method::QuipSharp { bits: 2, ft: true },
            Method::QuipSharp { bits: 2, ft: false },
            Method::QuipSharpNoE8 { bits: 2 },
            Method::QuipSharpRfft { bits: 2 },
            Method::QuipKron { bits: 2 },
            Method::OmniquantLike { bits: 2, group: None },
            Method::OmniquantLike { bits: 2, group: Some(64) },
            Method::AwqLike { bits: 2 },
            Method::AqlmLike { bits: 2 },
        ];
        let labels: std::collections::HashSet<String> =
            methods.iter().map(|m| m.label()).collect();
        assert_eq!(labels.len(), methods.len());
    }
}
