//! QuIP# — the paper's contribution (Algorithms 1–4, §3–§5):
//! incoherence processing, lattice codebooks, BlockLDLQ adaptive rounding,
//! RVQ bit scaling, scale optimization, packing, and the per-layer
//! quantization pipeline with every baseline the evaluation compares
//! against.

pub mod codebook;
pub mod incoherence;
pub mod ldlq;
pub mod packing;
pub mod pipeline;
pub mod rvq;
pub mod scales;

pub use pipeline::{quantize_matrix, Method, QuantizedLinear};
