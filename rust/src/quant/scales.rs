//! Codebook input-scale optimization (paper §F.5): find the ρ that
//! minimizes the MSE of quantizing a unit Gaussian with the codebook at
//! input scale ρ (weights are divided by ρ·σ_W before rounding and
//! multiplied back after). Results are cached per codebook name.

use super::codebook::{gaussian_mse, VectorQuantizer};
use crate::util::rng::Pcg64;
use std::collections::HashMap;
use std::sync::Mutex;

static CACHE: Mutex<Option<HashMap<String, (f64, f64)>>> = Mutex::new(None);

/// Sweep ρ over a log-ish grid and refine once; returns (ρ*, mse(ρ*)).
pub fn optimal_rho(q: &dyn VectorQuantizer, samples: usize, seed: u64) -> (f64, f64) {
    {
        let cache = CACHE.lock().unwrap();
        if let Some(map) = cache.as_ref() {
            if let Some(&hit) = map.get(&q.name()) {
                return hit;
            }
        }
    }
    let mut best = (1.0, f64::INFINITY);
    let coarse: Vec<f64> = (0..=24).map(|i| 0.3 + 0.1 * i as f64).collect();
    for rho in coarse {
        let mut rng = Pcg64::new(seed);
        let mse = gaussian_mse(q, rho, samples, &mut rng);
        if mse < best.1 {
            best = (rho, mse);
        }
    }
    // Refine around the coarse winner.
    let center = best.0;
    for i in -4i32..=4 {
        let rho = center + 0.025 * i as f64;
        if rho <= 0.05 {
            continue;
        }
        let mut rng = Pcg64::new(seed);
        let mse = gaussian_mse(q, rho, samples, &mut rng);
        if mse < best.1 {
            best = (rho, mse);
        }
    }
    let mut cache = CACHE.lock().unwrap();
    cache
        .get_or_insert_with(HashMap::new)
        .insert(q.name(), best);
    best
}

/// Default per-stage scales for the paper's RVQ configurations, expressed
/// as residual-std multipliers. Stage 1 quantizes x/σ≈N(0,1) at its own
/// ρ*; the residual of an E8P stage has std ≈ sqrt(mse), so stage 2's
/// scale is ρ*₂ · residual_std. Computed empirically once.
pub fn rvq_stage_scales(stage1: &dyn VectorQuantizer, stage2: &dyn VectorQuantizer) -> (f64, f64) {
    let (rho1, mse1) = optimal_rho(stage1, 30_000, 11);
    let resid_std = mse1.sqrt();
    let (rho2, _) = optimal_rho(stage2, 30_000, 11);
    (rho1, rho2 * resid_std)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::e8p::E8P;
    use crate::quant::codebook::scalar::HalfIntGrid;

    #[test]
    fn rho_is_cached() {
        let g = HalfIntGrid::new(2);
        let a = optimal_rho(&g, 3000, 1);
        let b = optimal_rho(&g, 3000, 1);
        assert_eq!(a, b);
    }

    #[test]
    fn grid_2bit_rho_reasonable() {
        // Optimal input scale for a 2-bit half-integer grid on N(0,1) is
        // around 1.0 (grid covers ±1.5): accept a broad sanity band.
        let g = HalfIntGrid::new(2);
        let (rho, mse) = optimal_rho(&g, 20_000, 2);
        assert!(rho > 0.4 && rho < 1.6, "rho={rho}");
        assert!(mse > 0.05 && mse < 0.3, "mse={mse}");
    }

    #[test]
    fn e8p_beats_scalar_grid_at_optimum() {
        // The paper's core claim at 2 bits (Figure 3 ordering).
        let e8p = E8P::new();
        let grid = HalfIntGrid::new(2);
        let (_, mse_e8p) = optimal_rho(&e8p, 20_000, 3);
        let (_, mse_grid) = optimal_rho(&grid, 20_000, 3);
        assert!(
            mse_e8p < mse_grid,
            "E8P {mse_e8p} must beat 2-bit grid {mse_grid}"
        );
    }
}
