//! Residual vector quantization (paper §4.3): quantize to p bits with a
//! set of q_i-bit codebooks by repeatedly quantizing the residual,
//! RVQ(x) = Σ_i s_i · Q_i((x − Σ_{j<i} δ_j)/s_i).
//!
//! QuIP# 4-bit = E8P ∘ E8P, 3-bit = E8P ∘ 1-bit-E8; the per-stage scales
//! s_i play the role of the paper's §F.5 stage scales.

use super::codebook::VectorQuantizer;

/// Multi-stage residual quantizer. All stages must share `dim()`.
pub struct Rvq {
    stages: Vec<(Box<dyn VectorQuantizer>, f64)>,
    name: String,
}

impl Rvq {
    pub fn new(stages: Vec<(Box<dyn VectorQuantizer>, f64)>) -> Self {
        assert!(!stages.is_empty());
        let d = stages[0].0.dim();
        assert!(stages.iter().all(|(q, _)| q.dim() == d));
        let name = format!(
            "rvq[{}]",
            stages
                .iter()
                .map(|(q, s)| format!("{}@{s:.3}", q.name()))
                .collect::<Vec<_>>()
                .join("+")
        );
        Rvq { stages, name }
    }

}

impl VectorQuantizer for Rvq {
    fn dim(&self) -> usize {
        self.stages[0].0.dim()
    }

    fn bits_per_weight(&self) -> f64 {
        self.stages.iter().map(|(q, _)| q.bits_per_weight()).sum()
    }

    fn num_codes(&self) -> usize {
        self.stages.iter().map(|(q, _)| q.num_codes()).sum()
    }

    fn quantize(&self, x: &[f64], codes: &mut [u32]) -> Vec<f64> {
        let d = self.dim();
        debug_assert_eq!(x.len(), d);
        let mut residual = x.to_vec();
        let mut acc = vec![0.0f64; d];
        let mut off = 0usize;
        for (q, s) in &self.stages {
            let nc = q.num_codes();
            let scaled: Vec<f64> = residual.iter().map(|v| v / s).collect();
            let dec = q.quantize(&scaled, &mut codes[off..off + nc]);
            for i in 0..d {
                let delta = dec[i] * s;
                acc[i] += delta;
                residual[i] -= delta;
            }
            off += nc;
        }
        acc
    }

    fn decode(&self, codes: &[u32]) -> Vec<f64> {
        let d = self.dim();
        let mut acc = vec![0.0f64; d];
        let mut off = 0usize;
        for (q, s) in &self.stages {
            let nc = q.num_codes();
            let dec = q.decode(&codes[off..off + nc]);
            for i in 0..d {
                acc[i] += dec[i] * s;
            }
            off += nc;
        }
        acc
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn stage_scales(&self) -> Vec<f64> {
        self.stages.iter().map(|(_, s)| *s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::e8::E8OneBit;
    use crate::quant::codebook::e8p::E8P;
    use crate::quant::codebook::gaussian_mse;
    use crate::util::proptest_lite::check;
    use crate::util::rng::Pcg64;

    fn rvq_4bit() -> Rvq {
        Rvq::new(vec![
            (Box::new(E8P::new()), 1.0),
            (Box::new(E8P::new()), 0.3),
        ])
    }

    #[test]
    fn bits_add_up() {
        let q = rvq_4bit();
        assert!((q.bits_per_weight() - 4.0).abs() < 1e-12);
        assert_eq!(q.num_codes(), 2);
        let q3 = Rvq::new(vec![
            (Box::new(E8P::new()) as Box<dyn VectorQuantizer>, 1.0),
            (Box::new(E8OneBit::new()), 0.4),
        ]);
        assert!((q3.bits_per_weight() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantize_decode_consistent() {
        let q = rvq_4bit();
        check("rvq_decode", 50, |rng| {
            let x: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
            let mut codes = vec![0u32; q.num_codes()];
            let dec = q.quantize(&x, &mut codes);
            let dec2 = q.decode(&codes);
            for (a, b) in dec.iter().zip(&dec2) {
                if (a - b).abs() > 1e-12 {
                    return Err(format!("{a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn telescoping_improves_error() {
        // 2 stages must beat stage 1 alone on the same input.
        let one = E8P::new();
        let two = rvq_4bit();
        let mut rng = Pcg64::new(4);
        let m1 = gaussian_mse(&one, 1.0, 8000, &mut rng);
        let m2 = gaussian_mse(&two, 1.0, 8000, &mut rng);
        assert!(m2 < m1 * 0.5, "RVQ {m2} should be well below single {m1}");
    }
}
