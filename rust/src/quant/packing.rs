//! Codeword bit-packing and the paper's bit accounting (§F.1).
//!
//! E8P codes are exactly 16 bits and pack into `u16` streams (the layout
//! the inference kernel consumes). Other codebooks use the generic
//! LSB-first bitstream packer.

/// Pack codes of `bits` bits each (bits ≤ 32) into a little-endian,
/// LSB-first byte stream.
pub fn pack_bits(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!(bits >= 1 && bits <= 32);
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 32 || c < (1u32 << bits));
        for b in 0..bits {
            if (c >> b) & 1 == 1 {
                out[(bitpos + b as usize) / 8] |= 1 << ((bitpos + b as usize) % 8);
            }
        }
        bitpos += bits as usize;
    }
    out
}

/// Inverse of [`pack_bits`].
pub fn unpack_bits(bytes: &[u8], bits: u32, count: usize) -> Vec<u32> {
    assert!(bits >= 1 && bits <= 32);
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let mut c = 0u32;
        for b in 0..bits {
            let idx = bitpos + b as usize;
            if (bytes[idx / 8] >> (idx % 8)) & 1 == 1 {
                c |= 1 << b;
            }
        }
        out.push(c);
        bitpos += bits as usize;
    }
    out
}

/// u16 view of 16-bit codes (the E8P fast path).
pub fn to_u16_codes(codes: &[u32]) -> Vec<u16> {
    codes.iter().map(|&c| c as u16).collect()
}

/// Bits-per-weight accounting for one quantized m×n linear layer,
/// reproducing the paper's §F.1 overhead discussion.
#[derive(Clone, Debug)]
pub struct BitAccounting {
    pub m: usize,
    pub n: usize,
    /// bits spent on codes per weight.
    pub code_bits: f64,
    /// sign-vector overhead: (m + n) bits as bitvectors, 16(m + n) after
    /// fine-tuning stores them in fp16 (§5).
    pub sign_bits: f64,
    /// per-layer scalar scales (fp16 each).
    pub scale_bits: f64,
    /// codebook storage amortized over this layer (0 for shared E8P;
    /// large for AQLM-style per-layer codebooks).
    pub codebook_bits: f64,
}

impl BitAccounting {
    pub fn new(
        m: usize,
        n: usize,
        code_bits: f64,
        ft_signs: bool,
        n_scales: usize,
        codebook_storage_bits: usize,
    ) -> Self {
        let per_sign = if ft_signs { 16.0 } else { 1.0 };
        BitAccounting {
            m,
            n,
            code_bits,
            sign_bits: per_sign * (m + n) as f64 / (m * n) as f64,
            scale_bits: 16.0 * n_scales as f64 / (m * n) as f64,
            codebook_bits: codebook_storage_bits as f64 / (m * n) as f64,
        }
    }

    /// Total effective bits per weight.
    pub fn total(&self) -> f64 {
        self.code_bits + self.sign_bits + self.scale_bits + self.codebook_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::check;

    #[test]
    fn pack_roundtrip_all_widths() {
        check("pack_roundtrip", 40, |rng| {
            let bits = 1 + (rng.below(16)) as u32;
            let count = 1 + rng.below_usize(100);
            let codes: Vec<u32> = (0..count)
                .map(|_| (rng.next_u64() as u32) & ((1u32 << bits) - 1))
                .collect();
            let packed = pack_bits(&codes, bits);
            let got = unpack_bits(&packed, bits, count);
            if got != codes {
                return Err(format!("roundtrip failed bits={bits}"));
            }
            Ok(())
        });
    }

    #[test]
    fn packed_size_is_tight() {
        let codes = vec![0u32; 100];
        assert_eq!(pack_bits(&codes, 16).len(), 200);
        assert_eq!(pack_bits(&codes, 2).len(), 25);
        assert_eq!(pack_bits(&codes, 3).len(), 38); // ceil(300/8)
    }

    #[test]
    fn u16_codes() {
        assert_eq!(to_u16_codes(&[1, 65535, 256]), vec![1u16, 65535, 256]);
    }

    #[test]
    fn paper_f1_bit_accounting() {
        // §F.1: for a 4096×4096 layer with bitvector signs, overhead is
        // (n+m)/(nm) < 0.01 bits; with fp16 signs 16(n+m)/(nm) < 0.01.
        let acc = BitAccounting::new(4096, 4096, 2.0, false, 1, 0);
        assert!(acc.sign_bits < 0.001);
        let acc_ft = BitAccounting::new(4096, 4096, 2.0, true, 1, 0);
        assert!(acc_ft.sign_bits < 0.01);
        assert!(acc_ft.total() < 2.01);
        // AQLM-style 2^16×8 fp16 codebook on the same layer: ~0.5 bits.
        let acc_aqlm = BitAccounting::new(4096, 4096, 2.0, false, 1, 65536 * 8 * 16);
        assert!(acc_aqlm.codebook_bits > 0.4, "{}", acc_aqlm.codebook_bits);
    }
}
