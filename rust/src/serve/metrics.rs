//! Serving metrics: throughput, latency, batch-occupancy,
//! decode-bytes-amortization, KV-page-pool and prefix-sharing counters,
//! exported as JSON through the `stats` API command.
//!
//! Conventions: counters (`requests_*`, `preemptions`, `prefix_hits`,
//! `pages_saved`, token/byte totals) only ever grow; gauges
//! (`pages_in_use`, `shared_pages`) are overwritten by the scheduler at
//! step boundaries, with `peak_pages_in_use` tracking the pool gauge's
//! high-water mark. Everything is atomics — latency distributions
//! included, which live in fixed-size log-scale bucket [`Histogram`]s
//! (bounded memory at any request count) — so the engine's scheduler
//! thread records without coordination and any number of API threads
//! snapshot concurrently; a snapshot is *per-field* consistent, not a
//! cross-field transaction.
//!
//! Request latency is recorded whole (`p50_ms` / `p99_ms`) and split
//! into the spans an SLO class actually controls: `queue_ms` (submit →
//! the admission that produced the surviving token stream), `ttft_ms`
//! (submit → first surviving token), and `decode_ms` (first token →
//! finish), each with its own histogram. The `phases` block breaks the
//! scheduler's decode wall time down by engine phase
//! ([`crate::util::phase`]); because only outermost scopes record,
//! per-phase shares of wall always sum to ≤ 100%.
//!
//! A multi-replica fleet ([`crate::serve::router`]) aggregates one
//! `Metrics` per replica (plus the router's own, which carries only
//! router-level counters such as `requests_rerouted`) through
//! [`Metrics::merged`] — same field set as [`Metrics::snapshot`], with
//! per-field merge rules documented there.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::phase::{Phase, PhaseAccum, PHASE_COUNT};

/// Bucket count of the latency [`Histogram`]s.
const HIST_BUCKETS: usize = 96;
/// Lower edge of the first log bucket, in ms (bucket 0 is `[0, LO)`).
const HIST_LO_MS: f64 = 0.01;
/// Log2 width of one bucket: 4 buckets per octave, so consecutive
/// bucket edges are a factor `2^0.25 ≈ 1.189` apart.
const HIST_BUCKET_LOG2: f64 = 0.25;

/// Fixed-size log-scale latency histogram: 96 atomic buckets at 4 per
/// octave from 0.01 ms, so memory stays constant under millions of
/// requests and recording is one lock-free `fetch_add`.
///
/// **Documented bucket error**: percentiles report the upper edge of
/// the rank's bucket, so they never *under*state a latency and
/// overstate it by at most one bucket ratio, `2^0.25 − 1 < 18.9%`.
/// Values beyond the top edge (≈ 141 s) saturate into the last bucket.
#[derive(Debug)]
pub struct Histogram {
    counts: Box<[AtomicU64]>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn bucket(ms: f64) -> usize {
        if ms.is_nan() || ms <= HIST_LO_MS {
            return 0;
        }
        let idx = 1 + ((ms / HIST_LO_MS).log2() / HIST_BUCKET_LOG2).floor() as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Upper edge of `bucket`, in ms — what percentiles report.
    fn upper_ms(bucket: usize) -> f64 {
        HIST_LO_MS * (bucket as f64 * HIST_BUCKET_LOG2).exp2()
    }

    pub fn record(&self, ms: f64) {
        self.counts[Self::bucket(ms)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    fn counts_vec(&self) -> Vec<u64> {
        self.counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Quantile `q ∈ [0, 1]` within the documented bucket error (0.0
    /// when empty). Rank convention matches the exact-sample percentile
    /// this replaced: the element at `round((n − 1) · q)` of the sorted
    /// samples.
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_of(&self.counts_vec(), q)
    }
}

/// [`Histogram::percentile`] over raw bucket counts (shared with the
/// fleet-merged path, which sums per-bucket counts across replicas).
fn percentile_of(counts: &[u64], q: f64) -> f64 {
    let n: u64 = counts.iter().sum();
    if n == 0 {
        return 0.0;
    }
    let rank = ((n - 1) as f64 * q).round() as u64;
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum > rank {
            return Histogram::upper_ms(i);
        }
    }
    Histogram::upper_ms(HIST_BUCKETS - 1)
}

/// The `phases` block: per-phase cumulative milliseconds plus each
/// phase's share of `wall_sec`. Outermost-wins recording guarantees
/// `Σ nanos ≤ recording thread's wall ≤ wall_sec`, so shares sum to
/// ≤ 1.
fn phases_json(nanos: &[u64; PHASE_COUNT], wall_sec: f64) -> Json {
    let wall_ns = (wall_sec * 1e9).max(1.0);
    let mut map = BTreeMap::new();
    for p in Phase::ALL {
        let ns = nanos[p as usize] as f64;
        map.insert(format!("{}_ms", p.name()), Json::num(ns / 1e6));
        map.insert(format!("{}_share", p.name()), Json::num(ns / wall_ns));
    }
    Json::Obj(map)
}

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub decode_steps: AtomicU64,
    pub batched_sequences: AtomicU64,
    /// Prompt tokens consumed by chunked prefill.
    pub prefill_tokens: AtomicU64,
    /// Largest batch observed in a single decode step.
    pub peak_batch: AtomicU64,
    /// Sequences evicted from the KV page pool (pages released, request
    /// requeued) because an allocation failed under over-subscription.
    pub preemptions: AtomicU64,
    /// Requests rejected at submit time (e.g. prompt exceeds context).
    pub requests_rejected: AtomicU64,
    /// Requests that failed mid-flight (e.g. an admitted sequence that
    /// can never fit the KV pool) — distinct from submit-time
    /// rejections so operators can tell client error from pool
    /// misconfiguration.
    pub requests_failed: AtomicU64,
    /// Requests re-dispatched to a different replica after their
    /// original replica died or stalled. Counted by the fleet router
    /// ([`crate::serve::router`]) on its own `Metrics`; always 0 on a
    /// single engine's metrics — the field exists everywhere so the
    /// stats JSON keeps one shape with or without a fleet.
    pub requests_rerouted: AtomicU64,
    /// Total pages in the shared KV pool (set once at engine start).
    pub pool_pages: AtomicU64,
    /// Pages currently allocated to live sequences (gauge).
    pub pages_in_use: AtomicU64,
    /// High-water mark of `pages_in_use`.
    pub peak_pages_in_use: AtomicU64,
    /// Pages currently referenced by more than one sequence — the
    /// copy-on-write prefix-sharing gauge.
    pub shared_pages: AtomicU64,
    /// Requests admitted by forking a registered prompt prefix instead
    /// of re-prefilling it.
    pub prefix_hits: AtomicU64,
    /// Fully occupied prefix pages a fork shared instead of allocating,
    /// summed over all prefix hits. Partial tail pages are excluded:
    /// they are shared at fork too, but the first write clones them
    /// back (copy-on-write), so they are not a lasting saving.
    pub pages_saved: AtomicU64,
    /// Cold prefix caches unpinned under pool pressure (their pages
    /// were referenced by no live sequence; a later hit rebuilds).
    pub prefix_evictions: AtomicU64,
    /// Draft tokens proposed by self-speculative rounds.
    pub tokens_drafted: AtomicU64,
    /// Draft tokens the target model accepted (the ratio to
    /// `tokens_drafted` is the acceptance rate).
    pub tokens_accepted: AtomicU64,
    /// Per-sequence speculative rounds executed.
    pub spec_rounds: AtomicU64,
    /// Sampled-mode speculative rounds whose first rejected draft was
    /// re-drawn from the target's own distribution (always 0 on greedy
    /// traffic — the greedy accept rule has no resample step).
    pub tokens_resampled: AtomicU64,
    /// KV pages quantized to their cold (E8P/RVQ) representation.
    pub kv_pages_quantized: AtomicU64,
    /// Sequences whose quantized pages were exported to the host-side
    /// spill arena instead of being discarded on preemption.
    pub kv_spills: AtomicU64,
    /// Spilled sequences re-admitted by importing their pages back into
    /// the pool (each one is a full re-prefill avoided).
    pub kv_restores: AtomicU64,
    /// Pages currently resident in cold (quantized) form (gauge).
    pub kv_cold_pages: AtomicU64,
    /// Pages currently parked in the spill arena (gauge).
    pub kv_spilled_pages: AtomicU64,
    /// Codewords decoded by the weight matmul kernels — includes the
    /// `⌈B / BATCH_TILE⌉` re-decodes per codeword a wide batch pays
    /// (gauge mirroring [`crate::model::qlinear::codewords_decoded`]).
    pub codewords_decoded: AtomicU64,
    /// Weight bytes actually streamed by the decode-once batched kernel.
    weight_bytes_streamed: AtomicU64,
    /// Weight bytes the same steps would stream decoding one sequence at
    /// a time (batch × bytes/step).
    weight_bytes_logical: AtomicU64,
    /// Whole-request latency (submit → answer), log-bucketed.
    latency_hist: Histogram,
    /// Submit → the admission that produced the surviving stream.
    queue_hist: Histogram,
    /// Submit → first surviving token (time-to-first-token).
    ttft_hist: Histogram,
    /// First surviving token → finish.
    decode_hist: Histogram,
    /// Per-phase decode wall time ([`crate::util::phase`]); the engine
    /// scheduler installs this as its thread's phase sink when tracing
    /// is enabled.
    phases: Arc<PhaseAccum>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            start: Instant::now(),
            requests_completed: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            decode_steps: AtomicU64::new(0),
            batched_sequences: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            peak_batch: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            requests_rejected: AtomicU64::new(0),
            requests_failed: AtomicU64::new(0),
            requests_rerouted: AtomicU64::new(0),
            pool_pages: AtomicU64::new(0),
            pages_in_use: AtomicU64::new(0),
            peak_pages_in_use: AtomicU64::new(0),
            shared_pages: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            pages_saved: AtomicU64::new(0),
            prefix_evictions: AtomicU64::new(0),
            tokens_drafted: AtomicU64::new(0),
            tokens_accepted: AtomicU64::new(0),
            spec_rounds: AtomicU64::new(0),
            tokens_resampled: AtomicU64::new(0),
            kv_pages_quantized: AtomicU64::new(0),
            kv_spills: AtomicU64::new(0),
            kv_restores: AtomicU64::new(0),
            kv_cold_pages: AtomicU64::new(0),
            kv_spilled_pages: AtomicU64::new(0),
            codewords_decoded: AtomicU64::new(0),
            weight_bytes_streamed: AtomicU64::new(0),
            weight_bytes_logical: AtomicU64::new(0),
            latency_hist: Histogram::new(),
            queue_hist: Histogram::new(),
            ttft_hist: Histogram::new(),
            decode_hist: Histogram::new(),
            phases: Arc::new(PhaseAccum::new()),
        }
    }

    pub fn record_request(&self, tokens: usize, latency_ms: f64) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated
            .fetch_add(tokens as u64, Ordering::Relaxed);
        self.latency_hist.record(latency_ms);
    }

    /// [`Metrics::record_request`] plus the latency split the trace
    /// events expose: queue wait, time-to-first-token, and decode span.
    /// `ttft_ms` / `decode_ms` are `None` for requests that finished
    /// without emitting a token (e.g. `max_new: 0`).
    pub fn record_request_timed(
        &self,
        tokens: usize,
        latency_ms: f64,
        queue_ms: f64,
        ttft_ms: Option<f64>,
        decode_ms: Option<f64>,
    ) {
        self.record_request(tokens, latency_ms);
        self.queue_hist.record(queue_ms);
        if let Some(t) = ttft_ms {
            self.ttft_hist.record(t);
        }
        if let Some(d) = decode_ms {
            self.decode_hist.record(d);
        }
    }

    /// The phase-time accumulator behind the snapshot's `phases` block.
    /// The engine scheduler installs it as its thread's sink
    /// ([`crate::util::phase::install`]) when tracing is on.
    pub fn phases(&self) -> Arc<PhaseAccum> {
        self.phases.clone()
    }

    pub fn record_step(&self, batch: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.batched_sequences
            .fetch_add(batch as u64, Ordering::Relaxed);
        self.peak_batch.fetch_max(batch as u64, Ordering::Relaxed);
    }

    /// Prompt tokens consumed this step by sequences still in prefill.
    pub fn record_prefill(&self, tokens: usize) {
        self.prefill_tokens
            .fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// A sequence was evicted back to the queue under pool pressure.
    pub fn record_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was rejected at submit time.
    pub fn record_rejected(&self) {
        self.requests_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// An admitted request failed mid-flight.
    pub fn record_failed(&self) {
        self.requests_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was re-dispatched to another replica after its original
    /// replica died or stalled (router-level).
    pub fn record_rerouted(&self) {
        self.requests_rerouted.fetch_add(1, Ordering::Relaxed);
    }

    /// Capacity of the shared KV page pool (once, at engine start).
    pub fn set_pool_capacity(&self, pages: usize) {
        self.pool_pages.store(pages as u64, Ordering::Relaxed);
    }

    /// Current pool occupancy gauge (also tracks the high-water mark).
    pub fn set_pages_in_use(&self, pages: usize) {
        self.pages_in_use.store(pages as u64, Ordering::Relaxed);
        self.peak_pages_in_use
            .fetch_max(pages as u64, Ordering::Relaxed);
    }

    /// Current count of pages shared by more than one sequence (gauge).
    pub fn set_shared_pages(&self, pages: usize) {
        self.shared_pages.store(pages as u64, Ordering::Relaxed);
    }

    /// A request was admitted by forking a cached prefix: `pages_shared`
    /// pages were referenced instead of allocated (and that many rows of
    /// prefill compute skipped).
    pub fn record_prefix_hit(&self, pages_shared: usize) {
        self.prefix_hits.fetch_add(1, Ordering::Relaxed);
        self.pages_saved
            .fetch_add(pages_shared as u64, Ordering::Relaxed);
    }

    /// A cold prefix cache was unpinned under pool pressure.
    pub fn record_prefix_eviction(&self) {
        self.prefix_evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// One batch of self-speculative lane-rounds completed: `drafted`
    /// tokens proposed, `accepted` of them confirmed by the target
    /// across `rounds` lanes, `resampled` of those lanes re-drawing
    /// their first rejected position from the target distribution
    /// (sampled mode only; always 0 for greedy traffic).
    pub fn record_spec(&self, drafted: u64, accepted: u64, rounds: u64, resampled: u64) {
        self.tokens_drafted.fetch_add(drafted, Ordering::Relaxed);
        self.tokens_accepted.fetch_add(accepted, Ordering::Relaxed);
        self.spec_rounds.fetch_add(rounds, Ordering::Relaxed);
        self.tokens_resampled.fetch_add(resampled, Ordering::Relaxed);
    }

    /// Fraction of drafted tokens the target accepted (0 when nothing
    /// was drafted yet).
    pub fn acceptance_rate(&self) -> f64 {
        let d = self.tokens_drafted.load(Ordering::Relaxed);
        if d == 0 {
            return 0.0;
        }
        self.tokens_accepted.load(Ordering::Relaxed) as f64 / d as f64
    }

    /// A sequence's pages were exported to the spill arena instead of
    /// discarded on preemption.
    pub fn record_kv_spill(&self) {
        self.kv_spills.fetch_add(1, Ordering::Relaxed);
    }

    /// A spilled sequence was restored by importing its pages back,
    /// skipping a full re-prefill.
    pub fn record_kv_restore(&self) {
        self.kv_restores.fetch_add(1, Ordering::Relaxed);
    }

    /// Pool/arena KV quantization gauges, refreshed at step boundaries:
    /// cumulative pages quantized, current cold-resident pages, and pages
    /// currently parked in the spill arena.
    pub fn set_kv_quant_state(&self, pages_quantized: u64, cold_pages: usize, spilled_pages: usize) {
        self.kv_pages_quantized
            .store(pages_quantized, Ordering::Relaxed);
        self.kv_cold_pages
            .store(cold_pages as u64, Ordering::Relaxed);
        self.kv_spilled_pages
            .store(spilled_pages as u64, Ordering::Relaxed);
    }

    /// Refresh the codeword-decode gauge from the process-wide kernel
    /// counter ([`crate::model::qlinear::codewords_decoded`]).
    pub fn set_codewords_decoded(&self, total: u64) {
        self.codewords_decoded.store(total, Ordering::Relaxed);
    }

    /// Weight-traffic accounting for one batched decode step: `streamed`
    /// is what the decode-once kernel read, `logical` what B independent
    /// sequence decodes would have read.
    pub fn record_decode_bytes(&self, streamed: u64, logical: u64) {
        self.weight_bytes_streamed
            .fetch_add(streamed, Ordering::Relaxed);
        self.weight_bytes_logical
            .fetch_add(logical, Ordering::Relaxed);
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let toks = self.tokens_generated.load(Ordering::Relaxed) as f64;
        toks / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    /// Mean batch occupancy per decode step.
    pub fn mean_batch(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed).max(1) as f64;
        self.batched_sequences.load(Ordering::Relaxed) as f64 / steps
    }

    /// Decode-bytes amortization ratio: logical bytes over streamed bytes.
    /// Equals the mean batch size when every step is fully batch-native;
    /// 1.0 for a sequence-at-a-time decode loop.
    pub fn bytes_amortization(&self) -> f64 {
        let s = self.weight_bytes_streamed.load(Ordering::Relaxed);
        if s == 0 {
            return 1.0;
        }
        self.weight_bytes_logical.load(Ordering::Relaxed) as f64 / s as f64
    }

    pub fn snapshot(&self) -> Json {
        let uptime = self.start.elapsed().as_secs_f64();
        let phase_nanos: [u64; PHASE_COUNT] =
            std::array::from_fn(|i| self.phases.nanos(Phase::ALL[i]));
        Json::obj(vec![
            (
                "requests",
                Json::num(self.requests_completed.load(Ordering::Relaxed) as f64),
            ),
            (
                "tokens",
                Json::num(self.tokens_generated.load(Ordering::Relaxed) as f64),
            ),
            ("tok_per_sec", Json::num(self.tokens_per_sec())),
            ("mean_batch", Json::num(self.mean_batch())),
            (
                "peak_batch",
                Json::num(self.peak_batch.load(Ordering::Relaxed) as f64),
            ),
            (
                "prefill_tokens",
                Json::num(self.prefill_tokens.load(Ordering::Relaxed) as f64),
            ),
            ("bytes_amortization", Json::num(self.bytes_amortization())),
            (
                "pool_pages",
                Json::num(self.pool_pages.load(Ordering::Relaxed) as f64),
            ),
            (
                "pages_in_use",
                Json::num(self.pages_in_use.load(Ordering::Relaxed) as f64),
            ),
            (
                "peak_pages_in_use",
                Json::num(self.peak_pages_in_use.load(Ordering::Relaxed) as f64),
            ),
            (
                "shared_pages",
                Json::num(self.shared_pages.load(Ordering::Relaxed) as f64),
            ),
            (
                "prefix_hits",
                Json::num(self.prefix_hits.load(Ordering::Relaxed) as f64),
            ),
            (
                "pages_saved",
                Json::num(self.pages_saved.load(Ordering::Relaxed) as f64),
            ),
            (
                "prefix_evictions",
                Json::num(self.prefix_evictions.load(Ordering::Relaxed) as f64),
            ),
            (
                "tokens_drafted",
                Json::num(self.tokens_drafted.load(Ordering::Relaxed) as f64),
            ),
            (
                "tokens_accepted",
                Json::num(self.tokens_accepted.load(Ordering::Relaxed) as f64),
            ),
            (
                "spec_rounds",
                Json::num(self.spec_rounds.load(Ordering::Relaxed) as f64),
            ),
            (
                "tokens_resampled",
                Json::num(self.tokens_resampled.load(Ordering::Relaxed) as f64),
            ),
            ("acceptance_rate", Json::num(self.acceptance_rate())),
            (
                "kv_pages_quantized",
                Json::num(self.kv_pages_quantized.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_cold_pages",
                Json::num(self.kv_cold_pages.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_spills",
                Json::num(self.kv_spills.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_restores",
                Json::num(self.kv_restores.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_spilled_pages",
                Json::num(self.kv_spilled_pages.load(Ordering::Relaxed) as f64),
            ),
            (
                "codewords_decoded",
                Json::num(self.codewords_decoded.load(Ordering::Relaxed) as f64),
            ),
            (
                "preemptions",
                Json::num(self.preemptions.load(Ordering::Relaxed) as f64),
            ),
            (
                "requests_rejected",
                Json::num(self.requests_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "requests_failed",
                Json::num(self.requests_failed.load(Ordering::Relaxed) as f64),
            ),
            (
                "requests_rerouted",
                Json::num(self.requests_rerouted.load(Ordering::Relaxed) as f64),
            ),
            ("p50_ms", Json::num(self.latency_hist.percentile(0.5))),
            ("p99_ms", Json::num(self.latency_hist.percentile(0.99))),
            ("queue_p50_ms", Json::num(self.queue_hist.percentile(0.5))),
            ("queue_p99_ms", Json::num(self.queue_hist.percentile(0.99))),
            ("ttft_p50_ms", Json::num(self.ttft_hist.percentile(0.5))),
            ("ttft_p99_ms", Json::num(self.ttft_hist.percentile(0.99))),
            ("decode_p50_ms", Json::num(self.decode_hist.percentile(0.5))),
            (
                "decode_p99_ms",
                Json::num(self.decode_hist.percentile(0.99)),
            ),
            ("phases", phases_json(&phase_nanos, uptime)),
            ("uptime_sec", Json::num(uptime)),
        ])
    }

    /// Fleet-merged snapshot over several `Metrics` — the same field set
    /// as [`Metrics::snapshot`], so one parser serves both shapes (the
    /// docs-drift test pins this).
    ///
    /// Per-field merge rules:
    /// * counters and occupancy/capacity gauges **sum** across parts
    ///   (`requests`, `tokens`, `prefill_tokens`, `pool_pages`,
    ///   `pages_in_use`, preemption/prefix/spec/kv counters, …);
    /// * `peak_batch` / `peak_pages_in_use` also sum — an upper bound on
    ///   the simultaneous fleet peak, since per-replica peaks need not
    ///   co-occur;
    /// * `codewords_decoded` takes the **max**: every replica mirrors
    ///   the same process-wide kernel counter
    ///   ([`crate::model::qlinear::codewords_decoded`]), so summing
    ///   would multiply-count it;
    /// * `uptime_sec` takes the max (fleet age);
    /// * derived rates (`tok_per_sec`, `mean_batch`,
    ///   `bytes_amortization`, `acceptance_rate`) are recomputed from
    ///   the summed numerators/denominators, never averaged;
    /// * latency percentiles (whole-request, queue, ttft, decode) come
    ///   from the per-bucket **sum** of every part's histogram — the
    ///   exact fleet distribution at the documented bucket error, with
    ///   no per-sample memory;
    /// * `phases` sums per-phase time across parts; shares are taken
    ///   against the summed uptime of the parts that recorded any phase
    ///   time (replicas — the router's own `Metrics` never does), so
    ///   fleet shares still sum to ≤ 100%.
    pub fn merged(parts: &[Arc<Metrics>]) -> Json {
        macro_rules! summed {
            ($field:ident) => {
                parts
                    .iter()
                    .map(|m| m.$field.load(Ordering::Relaxed))
                    .sum::<u64>()
            };
        }
        macro_rules! maxed {
            ($field:ident) => {
                parts
                    .iter()
                    .map(|m| m.$field.load(Ordering::Relaxed))
                    .max()
                    .unwrap_or(0)
            };
        }
        let uptime = parts
            .iter()
            .map(|m| m.start.elapsed().as_secs_f64())
            .fold(0.0f64, f64::max);
        let tokens = summed!(tokens_generated);
        let steps = summed!(decode_steps);
        let batched = summed!(batched_sequences);
        let streamed = summed!(weight_bytes_streamed);
        let logical = summed!(weight_bytes_logical);
        let drafted = summed!(tokens_drafted);
        let accepted = summed!(tokens_accepted);
        let merge_hist = |pick: fn(&Metrics) -> &Histogram| -> Vec<u64> {
            let mut acc = vec![0u64; HIST_BUCKETS];
            for m in parts {
                for (a, c) in acc.iter_mut().zip(pick(m).counts_vec()) {
                    *a += c;
                }
            }
            acc
        };
        let latency = merge_hist(|m| &m.latency_hist);
        let queue = merge_hist(|m| &m.queue_hist);
        let ttft = merge_hist(|m| &m.ttft_hist);
        let decode = merge_hist(|m| &m.decode_hist);
        let mut phase_nanos = [0u64; PHASE_COUNT];
        let mut phase_wall = 0.0f64;
        for m in parts {
            if m.phases.total_nanos() > 0 {
                phase_wall += m.start.elapsed().as_secs_f64();
            }
            for p in Phase::ALL {
                phase_nanos[p as usize] += m.phases.nanos(p);
            }
        }
        if phase_wall == 0.0 {
            phase_wall = uptime;
        }
        Json::obj(vec![
            ("requests", Json::num(summed!(requests_completed) as f64)),
            ("tokens", Json::num(tokens as f64)),
            ("tok_per_sec", Json::num(tokens as f64 / uptime.max(1e-9))),
            (
                "mean_batch",
                Json::num(batched as f64 / steps.max(1) as f64),
            ),
            ("peak_batch", Json::num(summed!(peak_batch) as f64)),
            ("prefill_tokens", Json::num(summed!(prefill_tokens) as f64)),
            (
                "bytes_amortization",
                Json::num(if streamed == 0 {
                    1.0
                } else {
                    logical as f64 / streamed as f64
                }),
            ),
            ("pool_pages", Json::num(summed!(pool_pages) as f64)),
            ("pages_in_use", Json::num(summed!(pages_in_use) as f64)),
            (
                "peak_pages_in_use",
                Json::num(summed!(peak_pages_in_use) as f64),
            ),
            ("shared_pages", Json::num(summed!(shared_pages) as f64)),
            ("prefix_hits", Json::num(summed!(prefix_hits) as f64)),
            ("pages_saved", Json::num(summed!(pages_saved) as f64)),
            (
                "prefix_evictions",
                Json::num(summed!(prefix_evictions) as f64),
            ),
            ("tokens_drafted", Json::num(drafted as f64)),
            ("tokens_accepted", Json::num(accepted as f64)),
            ("spec_rounds", Json::num(summed!(spec_rounds) as f64)),
            (
                "tokens_resampled",
                Json::num(summed!(tokens_resampled) as f64),
            ),
            (
                "acceptance_rate",
                Json::num(if drafted == 0 {
                    0.0
                } else {
                    accepted as f64 / drafted as f64
                }),
            ),
            (
                "kv_pages_quantized",
                Json::num(summed!(kv_pages_quantized) as f64),
            ),
            ("kv_cold_pages", Json::num(summed!(kv_cold_pages) as f64)),
            ("kv_spills", Json::num(summed!(kv_spills) as f64)),
            ("kv_restores", Json::num(summed!(kv_restores) as f64)),
            (
                "kv_spilled_pages",
                Json::num(summed!(kv_spilled_pages) as f64),
            ),
            (
                "codewords_decoded",
                Json::num(maxed!(codewords_decoded) as f64),
            ),
            ("preemptions", Json::num(summed!(preemptions) as f64)),
            (
                "requests_rejected",
                Json::num(summed!(requests_rejected) as f64),
            ),
            (
                "requests_failed",
                Json::num(summed!(requests_failed) as f64),
            ),
            (
                "requests_rerouted",
                Json::num(summed!(requests_rerouted) as f64),
            ),
            ("p50_ms", Json::num(percentile_of(&latency, 0.5))),
            ("p99_ms", Json::num(percentile_of(&latency, 0.99))),
            ("queue_p50_ms", Json::num(percentile_of(&queue, 0.5))),
            ("queue_p99_ms", Json::num(percentile_of(&queue, 0.99))),
            ("ttft_p50_ms", Json::num(percentile_of(&ttft, 0.5))),
            ("ttft_p99_ms", Json::num(percentile_of(&ttft, 0.99))),
            ("decode_p50_ms", Json::num(percentile_of(&decode, 0.5))),
            ("decode_p99_ms", Json::num(percentile_of(&decode, 0.99))),
            ("phases", phases_json(&phase_nanos, phase_wall)),
            ("uptime_sec", Json::num(uptime)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(10, 5.0);
        m.record_request(20, 15.0);
        m.record_step(2);
        m.record_step(4);
        let s = m.snapshot();
        assert_eq!(s.get("requests").as_f64(), Some(2.0));
        assert_eq!(s.get("tokens").as_f64(), Some(30.0));
        assert_eq!(s.get("mean_batch").as_f64(), Some(3.0));
        assert_eq!(s.get("peak_batch").as_f64(), Some(4.0));
        assert!(s.get("p50_ms").as_f64().unwrap() >= 5.0);
    }

    #[test]
    fn amortization_tracks_batch() {
        let m = Metrics::new();
        // No traffic recorded yet → neutral ratio.
        assert_eq!(m.bytes_amortization(), 1.0);
        // Two steps at batch 4 and 2 over the same 100-byte weights.
        m.record_decode_bytes(100, 400);
        m.record_decode_bytes(100, 200);
        assert!((m.bytes_amortization() - 3.0).abs() < 1e-12);
        m.record_prefill(5);
        assert_eq!(m.prefill_tokens.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn pool_counters_and_peaks() {
        let m = Metrics::new();
        m.set_pool_capacity(16);
        m.set_pages_in_use(9);
        m.set_pages_in_use(4);
        m.record_preemption();
        m.record_preemption();
        m.record_rejected();
        m.record_failed();
        let s = m.snapshot();
        assert_eq!(s.get("pool_pages").as_f64(), Some(16.0));
        assert_eq!(s.get("pages_in_use").as_f64(), Some(4.0));
        assert_eq!(s.get("peak_pages_in_use").as_f64(), Some(9.0));
        assert_eq!(s.get("preemptions").as_f64(), Some(2.0));
        assert_eq!(s.get("requests_rejected").as_f64(), Some(1.0));
        assert_eq!(s.get("requests_failed").as_f64(), Some(1.0));
    }

    #[test]
    fn speculative_and_eviction_counters() {
        let m = Metrics::new();
        assert_eq!(m.acceptance_rate(), 0.0);
        // Two batched rounds: 8 drafted / 5 accepted with one sampled
        // resample, then 4 / 4 (all accepted, nothing re-drawn).
        m.record_spec(8, 5, 2, 1);
        m.record_spec(4, 4, 1, 0);
        m.record_prefix_eviction();
        let s = m.snapshot();
        assert_eq!(s.get("tokens_drafted").as_f64(), Some(12.0));
        assert_eq!(s.get("tokens_accepted").as_f64(), Some(9.0));
        assert_eq!(s.get("spec_rounds").as_f64(), Some(3.0));
        assert_eq!(s.get("tokens_resampled").as_f64(), Some(1.0));
        assert_eq!(s.get("prefix_evictions").as_f64(), Some(1.0));
        assert!((m.acceptance_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn kv_quant_counters() {
        let m = Metrics::new();
        m.record_kv_spill();
        m.record_kv_spill();
        m.record_kv_restore();
        // Gauges overwrite: second refresh wins.
        m.set_kv_quant_state(5, 3, 8);
        m.set_kv_quant_state(7, 2, 4);
        m.set_codewords_decoded(1234);
        let s = m.snapshot();
        assert_eq!(s.get("kv_spills").as_f64(), Some(2.0));
        assert_eq!(s.get("kv_restores").as_f64(), Some(1.0));
        assert_eq!(s.get("kv_pages_quantized").as_f64(), Some(7.0));
        assert_eq!(s.get("kv_cold_pages").as_f64(), Some(2.0));
        assert_eq!(s.get("kv_spilled_pages").as_f64(), Some(4.0));
        assert_eq!(s.get("codewords_decoded").as_f64(), Some(1234.0));
    }

    #[test]
    fn prefix_sharing_counters() {
        let m = Metrics::new();
        // Two forks off a 3-page prefix, one off a 1-page prefix.
        m.record_prefix_hit(3);
        m.record_prefix_hit(3);
        m.record_prefix_hit(1);
        // shared_pages is a gauge: overwritten, not accumulated.
        m.set_shared_pages(4);
        m.set_shared_pages(3);
        let s = m.snapshot();
        assert_eq!(s.get("prefix_hits").as_f64(), Some(3.0));
        assert_eq!(s.get("pages_saved").as_f64(), Some(7.0));
        assert_eq!(s.get("shared_pages").as_f64(), Some(3.0));
    }

    #[test]
    fn merged_sums_counters_and_recomputes_rates() {
        let a = Arc::new(Metrics::new());
        let b = Arc::new(Metrics::new());
        a.record_request(10, 5.0);
        a.record_step(2);
        a.record_step(2);
        a.set_pool_capacity(8);
        a.set_pages_in_use(6);
        a.record_spec(8, 4, 1, 2);
        a.set_codewords_decoded(100);
        b.record_request(20, 50.0);
        b.record_request(30, 100.0);
        b.record_step(4);
        b.set_pool_capacity(8);
        b.set_pages_in_use(3);
        b.record_spec(4, 4, 1, 1);
        // Both replicas mirror the same process-wide kernel counter,
        // b's refresh ran later:
        b.set_codewords_decoded(120);
        b.record_rerouted();
        let s = Metrics::merged(&[a, b]);
        assert_eq!(s.get("requests").as_f64(), Some(3.0));
        assert_eq!(s.get("tokens").as_f64(), Some(60.0));
        // mean_batch = (2 + 2 + 4) / 3 steps, recomputed — not the
        // average of per-part means (2.0 and 4.0 → 3.0 would be wrong).
        assert!((s.get("mean_batch").as_f64().unwrap() - 8.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.get("pool_pages").as_f64(), Some(16.0));
        assert_eq!(s.get("pages_in_use").as_f64(), Some(9.0));
        // Mirrored process-wide counter takes the max, not the sum.
        assert_eq!(s.get("codewords_decoded").as_f64(), Some(120.0));
        // acceptance_rate = (4 + 4) / (8 + 4).
        assert!((s.get("acceptance_rate").as_f64().unwrap() - 8.0 / 12.0).abs() < 1e-12);
        // Resample counter sums across replicas like any other counter.
        assert_eq!(s.get("tokens_resampled").as_f64(), Some(3.0));
        assert_eq!(s.get("requests_rerouted").as_f64(), Some(1.0));
        // Percentiles come from the per-bucket sum of both histograms;
        // the bucket upper edge never understates the true sample.
        assert!(s.get("p99_ms").as_f64().unwrap() >= 100.0);
    }

    #[test]
    fn merged_field_set_matches_snapshot() {
        // One parser must serve both shapes: the fleet-merged view
        // exposes exactly the per-engine snapshot's fields.
        let m = Arc::new(Metrics::new());
        let single = m.snapshot();
        let fleet = Metrics::merged(&[m]);
        let keys = |j: &Json| -> Vec<String> {
            j.as_obj()
                .expect("snapshot is an object")
                .keys()
                .cloned()
                .collect()
        };
        assert_eq!(keys(&single), keys(&fleet));
    }

    #[test]
    fn histogram_percentile_within_documented_error() {
        // Any value in (LO, top] reports in [v, v·2^0.25): never
        // understated, overstated by less than one bucket ratio.
        let err = HIST_BUCKET_LOG2.exp2();
        crate::util::proptest_lite::check("hist_bucket_error", 200, |rng| {
            // Log-uniform over ~6 decades, well inside the bucket range
            // (0.02 ms … ~21 s; the top edge is ≈ 141 s).
            let v = 0.02 * (rng.f64() * 20.0).exp2();
            let h = Histogram::new();
            h.record(v);
            let p = h.percentile(0.5);
            // 1e-9 relative slack absorbs log2/exp2 rounding when v sits
            // exactly on a bucket edge.
            crate::prop_assert!(p >= v * (1.0 - 1e-9), "p {p} understates v {v}");
            crate::prop_assert!(p <= v * err * (1.0 + 1e-9), "p {p} overstates v {v}");
            Ok(())
        });
        // Edge behavior: sub-floor values land in bucket 0, huge values
        // saturate the top bucket instead of indexing out of range.
        let h = Histogram::new();
        h.record(0.0);
        assert!((h.percentile(0.5) - HIST_LO_MS).abs() < 1e-12);
        let h = Histogram::new();
        h.record(1e12);
        assert!(h.percentile(1.0) > 1e5);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn histogram_percentile_rank_convention() {
        // Matches the exact-sample rule it replaced: element at
        // round((n−1)·q) of the sorted samples, up to bucket error.
        let h = Histogram::new();
        for v in [5.0, 50.0, 100.0] {
            h.record(v);
        }
        // p50 → rank 1 → the 50.0 sample's bucket edge.
        let p50 = h.percentile(0.5);
        assert!((50.0..60.0).contains(&p50));
        // p99 → rank 2 → the 100.0 sample's bucket edge.
        let p99 = h.percentile(0.99);
        assert!((100.0..119.0).contains(&p99));
        // p0 → rank 0 → the 5.0 sample's bucket edge.
        let p0 = h.percentile(0.0);
        assert!((5.0..6.0).contains(&p0));
    }

    #[test]
    fn timed_requests_split_queue_ttft_decode() {
        let m = Metrics::new();
        m.record_request_timed(10, 100.0, 30.0, Some(40.0), Some(60.0));
        // A zero-token request has no first token: ttft/decode skipped.
        m.record_request_timed(0, 10.0, 10.0, None, None);
        let s = m.snapshot();
        assert_eq!(s.get("requests").as_f64(), Some(2.0));
        assert!(s.get("queue_p99_ms").as_f64().unwrap() >= 30.0);
        assert!(s.get("ttft_p50_ms").as_f64().unwrap() >= 40.0);
        assert!(s.get("ttft_p50_ms").as_f64().unwrap() < 48.0);
        assert!(s.get("decode_p50_ms").as_f64().unwrap() >= 60.0);
    }

    #[test]
    fn phases_block_shares_bounded() {
        let m = Metrics::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        // Simulate a scheduler that spent 2 ms in matmul scopes.
        m.phases().add(crate::util::phase::Phase::QuantMatmul, 2_000_000);
        let s = m.snapshot();
        let ph = s.get("phases");
        let obj = ph.as_obj().expect("phases is an object");
        // One `_ms` and one `_share` key per phase.
        assert_eq!(obj.len(), 2 * PHASE_COUNT);
        let matmul_ms = ph.get("matmul_ms").as_f64().unwrap();
        assert!((matmul_ms - 2.0).abs() < 1e-9);
        let share_sum: f64 = obj
            .iter()
            .filter(|(k, _)| k.ends_with("_share"))
            .map(|(_, v)| v.as_f64().unwrap())
            .sum();
        assert!(share_sum > 0.0);
        assert!(share_sum <= 1.0, "phase shares must sum to ≤ 1");
    }

    #[test]
    fn merged_histograms_and_phases() {
        let a = Arc::new(Metrics::new());
        let b = Arc::new(Metrics::new());
        for _ in 0..9 {
            a.record_request_timed(1, 10.0, 1.0, Some(2.0), Some(8.0));
        }
        b.record_request_timed(1, 1000.0, 1.0, Some(2.0), Some(998.0));
        // Only `a` recorded phase time, so the share denominator is its
        // uptime alone — the idle part must not dilute shares.
        std::thread::sleep(std::time::Duration::from_millis(2));
        a.phases().add(crate::util::phase::Phase::Attention, 1_000_000);
        let s = Metrics::merged(&[a, b]);
        // 10 samples; p50 → rank 4 (a 10 ms sample), p99 → rank 9 (the
        // 1000 ms outlier) — a per-part average could never report both.
        let p50 = s.get("p50_ms").as_f64().unwrap();
        let p99 = s.get("p99_ms").as_f64().unwrap();
        assert!((10.0..12.0).contains(&p50), "fleet p50 {p50}");
        assert!((1000.0..1190.0).contains(&p99), "fleet p99 {p99}");
        assert!(s.get("decode_p99_ms").as_f64().unwrap() >= 998.0);
        let ph = s.get("phases");
        assert!((ph.get("attention_ms").as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!(ph.get("attention_share").as_f64().unwrap() > 0.0);
    }
}
