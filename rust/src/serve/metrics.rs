//! Serving metrics: throughput and latency counters, exported as JSON
//! through the `stats` API command.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub decode_steps: AtomicU64,
    pub batched_sequences: AtomicU64,
    latencies_ms: Mutex<Vec<f64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            start: Instant::now(),
            requests_completed: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            decode_steps: AtomicU64::new(0),
            batched_sequences: AtomicU64::new(0),
            latencies_ms: Mutex::new(Vec::new()),
        }
    }

    pub fn record_request(&self, tokens: usize, latency_ms: f64) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated
            .fetch_add(tokens as u64, Ordering::Relaxed);
        self.latencies_ms.lock().unwrap().push(latency_ms);
    }

    pub fn record_step(&self, batch: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.batched_sequences
            .fetch_add(batch as u64, Ordering::Relaxed);
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let toks = self.tokens_generated.load(Ordering::Relaxed) as f64;
        toks / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    /// Mean batch occupancy per decode step.
    pub fn mean_batch(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed).max(1) as f64;
        self.batched_sequences.load(Ordering::Relaxed) as f64 / steps
    }

    pub fn snapshot(&self) -> Json {
        let lats = self.latencies_ms.lock().unwrap();
        let mut sorted = lats.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            sorted[((sorted.len() - 1) as f64 * q).round() as usize]
        };
        Json::obj(vec![
            (
                "requests",
                Json::num(self.requests_completed.load(Ordering::Relaxed) as f64),
            ),
            (
                "tokens",
                Json::num(self.tokens_generated.load(Ordering::Relaxed) as f64),
            ),
            ("tok_per_sec", Json::num(self.tokens_per_sec())),
            ("mean_batch", Json::num(self.mean_batch())),
            ("p50_ms", Json::num(pct(0.5))),
            ("p99_ms", Json::num(pct(0.99))),
            ("uptime_sec", Json::num(self.start.elapsed().as_secs_f64())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(10, 5.0);
        m.record_request(20, 15.0);
        m.record_step(2);
        m.record_step(4);
        let s = m.snapshot();
        assert_eq!(s.get("requests").as_f64(), Some(2.0));
        assert_eq!(s.get("tokens").as_f64(), Some(30.0));
        assert_eq!(s.get("mean_batch").as_f64(), Some(3.0));
        assert!(s.get("p50_ms").as_f64().unwrap() >= 5.0);
    }
}
