//! Request-lifecycle tracing: every request's path through the serving
//! fleet recorded as typed span events in lock-cheap bounded ring
//! buffers, with three read paths — the `trace` TCP command (one
//! request's merged timeline as JSON), an optional JSONL export of
//! completed-request traces (`serve --trace-out`), and the per-phase
//! timing block ([`crate::util::phase`]) next to the `stats` snapshot.
//!
//! # Event taxonomy
//!
//! A request's legal lifecycle is the grammar
//!
//! ```text
//! submit → queued(class) → admit(replica) → prefill* → decode_round*
//!        → { preempt [→ spill] [→ restore | → queued] , reroute }*
//!        → finish | fail
//! ```
//!
//! * `submit` — accepted by the front (router, or the engine itself on a
//!   single-replica deployment). Always the first event.
//! * `queued` — entered an engine's class-ordered submit queue; recurs
//!   after a restart-preemption (fp32 pool: tokens are discarded and
//!   re-derived) and after a re-route.
//! * `admit` — the scheduler activated the request on a replica. A
//!   freshly (re-)admitted request has generated no surviving tokens.
//! * `prefill` / `decode_round` — one scheduler round's prompt
//!   consumption / token emission for this sequence. `decode_round`
//!   carries the tokens emitted this round and the running total, so a
//!   trace double-checks its own token accounting; `spec` marks
//!   draft/verify rounds.
//! * `preempt` — evicted under pool pressure. `spilled: true` means the
//!   KV moved to the host arena (`spill` follows, `restore` re-admits
//!   with tokens intact); `spilled: false` means restart semantics
//!   (`queued` follows, the token count resets and the deterministic
//!   decode re-derives the identical stream).
//! * `reroute` — the router re-dispatched the request after its replica
//!   died; the new replica starts from scratch (`queued` follows). The
//!   dead replica's events stay in the trace — a faithful causal
//!   history — and the token stream restarts, bitwise identical.
//! * `finish` / `fail` — terminal; at most one per request.
//!
//! # Ring-buffer design
//!
//! One bounded ring per shard — shard 0 for the front (router/server),
//! shard `r + 1` for replica `r` — each behind its own mutex, so a
//! replica's scheduler thread only ever touches its own shard:
//! recording is one short uncontended lock, one `VecDeque` push, and an
//! overwrite of the oldest event when full (bounded memory, newest
//! history wins). A process-wide atomic sequence number stamps every
//! event, giving the fleet-merged reader ([`Tracer::trace_json`]) a
//! total order to sort shards into without any cross-shard
//! coordination on the write path. Per-request sampling
//! (`sample_every`, default 1 = everything) filters whole requests by
//! id so a sampled trace is always complete, never partial.
//!
//! # Overhead
//!
//! Off the serving path (no [`TraceWriter`] configured) nothing is
//! recorded and the engine pays a single `Option` check per event site.
//! With tracing on, an event is ~100ns of uncontended mutex + ring
//! push, a few times per scheduler round per sequence — noise against
//! a decode step's matmuls. The phase timers are separate
//! ([`crate::util::phase`]): threads without an installed sink skip
//! even the clock read.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Default per-shard ring capacity (events, not requests).
pub const DEFAULT_RING_CAPACITY: usize = 8192;

/// Every event kind's wire name, in declaration order. Pinned by the
/// docs-drift test against the `#### Trace events` table in
/// `rust/src/serve/README.md`; [`TraceEvent::kind`] is an exhaustive
/// match, so adding a variant without updating both breaks the build
/// (`clippy -D warnings` and the drift test both gate it).
pub const EVENT_KINDS: [&str; 11] = [
    "submit",
    "queued",
    "admit",
    "prefill",
    "decode_round",
    "preempt",
    "spill",
    "restore",
    "reroute",
    "finish",
    "fail",
];

/// One typed lifecycle event (see the module docs for the grammar).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// Accepted by the front; `class` is the request's SLO priority.
    Submit { class: u8 },
    /// Entered an engine's class-ordered submit queue (or re-entered it
    /// after a restart-preemption or re-route).
    Queued { class: u8 },
    /// Activated by a replica's scheduler.
    Admit { replica: usize },
    /// Prompt tokens consumed by chunked prefill this round.
    Prefill { tokens: usize },
    /// Tokens emitted this round (`spec` = a draft/verify round) and
    /// the surviving-stream total after them.
    DecodeRound {
        tokens: usize,
        total: usize,
        spec: bool,
    },
    /// Evicted under pool pressure; `spilled` says whether the KV was
    /// exported to the host arena (else restart semantics).
    Preempt { spilled: bool },
    /// KV pages exported to the spill arena.
    Spill { pages: usize },
    /// Spilled pages imported back; the sequence resumes with its
    /// token stream intact.
    Restore { pages: usize },
    /// Re-dispatched away from dead replica `from`.
    Reroute { from: usize },
    /// Completed with `tokens` generated tokens.
    Finish { tokens: usize },
    /// Rejected or failed; terminal.
    Fail { reason: String },
}

impl TraceEvent {
    /// The wire name (an entry of [`EVENT_KINDS`]). Exhaustive on
    /// purpose — see [`EVENT_KINDS`].
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Submit { .. } => "submit",
            TraceEvent::Queued { .. } => "queued",
            TraceEvent::Admit { .. } => "admit",
            TraceEvent::Prefill { .. } => "prefill",
            TraceEvent::DecodeRound { .. } => "decode_round",
            TraceEvent::Preempt { .. } => "preempt",
            TraceEvent::Spill { .. } => "spill",
            TraceEvent::Restore { .. } => "restore",
            TraceEvent::Reroute { .. } => "reroute",
            TraceEvent::Finish { .. } => "finish",
            TraceEvent::Fail { .. } => "fail",
        }
    }

    /// Whether this event terminates a request's lifecycle.
    pub fn is_terminal(&self) -> bool {
        matches!(self, TraceEvent::Finish { .. } | TraceEvent::Fail { .. })
    }

    fn payload(&self, fields: &mut Vec<(&'static str, Json)>) {
        match self {
            TraceEvent::Submit { class } | TraceEvent::Queued { class } => {
                fields.push(("class", Json::num(*class as f64)));
            }
            TraceEvent::Admit { replica } => {
                fields.push(("replica_to", Json::num(*replica as f64)));
            }
            TraceEvent::Prefill { tokens } => {
                fields.push(("tokens", Json::num(*tokens as f64)));
            }
            TraceEvent::DecodeRound {
                tokens,
                total,
                spec,
            } => {
                fields.push(("tokens", Json::num(*tokens as f64)));
                fields.push(("total", Json::num(*total as f64)));
                fields.push(("spec", Json::Bool(*spec)));
            }
            TraceEvent::Preempt { spilled } => {
                fields.push(("spilled", Json::Bool(*spilled)));
            }
            TraceEvent::Spill { pages } | TraceEvent::Restore { pages } => {
                fields.push(("pages", Json::num(*pages as f64)));
            }
            TraceEvent::Reroute { from } => {
                fields.push(("from", Json::num(*from as f64)));
            }
            TraceEvent::Finish { tokens } => {
                fields.push(("tokens", Json::num(*tokens as f64)));
            }
            TraceEvent::Fail { reason } => {
                fields.push(("reason", Json::str(reason.clone())));
            }
        }
    }
}

/// One recorded event: the typed payload plus its total-order stamp,
/// microsecond offset from tracer start, request id, and recording
/// shard's replica (`None` = the front shard).
#[derive(Clone, Debug)]
struct Recorded {
    seq: u64,
    t_us: u64,
    id: u64,
    replica: Option<usize>,
    event: TraceEvent,
}

impl Recorded {
    fn to_json(&self) -> Json {
        let mut fields: Vec<(&'static str, Json)> = vec![
            ("seq", Json::num(self.seq as f64)),
            ("t_us", Json::num(self.t_us as f64)),
            (
                "replica",
                match self.replica {
                    Some(r) => Json::num(r as f64),
                    None => Json::Null,
                },
            ),
            ("kind", Json::str(self.event.kind())),
        ];
        self.event.payload(&mut fields);
        Json::obj(fields)
    }
}

/// Bounded overwrite-oldest event buffer (one per shard).
#[derive(Debug)]
struct Ring {
    buf: VecDeque<Recorded>,
    cap: usize,
}

impl Ring {
    fn push(&mut self, ev: Recorded) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(ev);
    }
}

/// Tracer configuration (see [`Tracer::new`]).
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Events retained per shard before the oldest is overwritten.
    pub capacity: usize,
    /// Trace requests whose `id % sample_every == 0`; `1` traces
    /// everything, `0` disables recording entirely.
    pub sample_every: u64,
    /// When set, each completed (or failed) traced request's full
    /// merged timeline is appended to this file as one JSON line.
    pub jsonl: Option<PathBuf>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            capacity: DEFAULT_RING_CAPACITY,
            sample_every: 1,
            jsonl: None,
        }
    }
}

/// The fleet-wide trace store: per-shard rings, the global event
/// sequence, and the optional JSONL sink. Shared (`Arc`) between the
/// front and every replica's [`TraceWriter`].
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    seq: AtomicU64,
    sample_every: u64,
    shards: Vec<Mutex<Ring>>,
    sink: Option<Mutex<BufWriter<File>>>,
}

impl Tracer {
    /// Build a tracer for `replicas` engine shards plus the front
    /// shard. Fails only if the JSONL sink file cannot be created.
    pub fn new(replicas: usize, cfg: TraceConfig) -> std::io::Result<Arc<Tracer>> {
        let shards = (0..replicas.max(1) + 1)
            .map(|_| {
                Mutex::new(Ring {
                    buf: VecDeque::new(),
                    cap: cfg.capacity.max(1),
                })
            })
            .collect();
        let sink = match &cfg.jsonl {
            Some(path) => Some(Mutex::new(BufWriter::new(File::create(path)?))),
            None => None,
        };
        Ok(Arc::new(Tracer {
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            sample_every: cfg.sample_every,
            shards,
            sink,
        }))
    }

    /// Whether request `id` is traced under the sampling setting.
    pub fn sampled(&self, id: u64) -> bool {
        self.sample_every != 0 && id % self.sample_every == 0
    }

    /// Writer for the front shard (router / single-engine server); it
    /// owns the `submit` event.
    pub fn front_writer(self: &Arc<Self>) -> TraceWriter {
        TraceWriter {
            tracer: self.clone(),
            replica: None,
            owns_submit: true,
        }
    }

    /// Writer for replica `replica`'s shard.
    pub fn writer(self: &Arc<Self>, replica: usize) -> TraceWriter {
        TraceWriter {
            tracer: self.clone(),
            replica: Some(replica),
            owns_submit: false,
        }
    }

    fn record(&self, shard: usize, replica: Option<usize>, id: u64, event: TraceEvent) {
        if !self.sampled(id) {
            return;
        }
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let rec = Recorded {
            seq,
            t_us,
            id,
            replica,
            event,
        };
        self.shards[shard.min(self.shards.len() - 1)]
            .lock()
            .unwrap()
            .push(rec);
    }

    /// The fleet-merged timeline of request `id`: every shard's events
    /// for it, sorted by the global sequence stamp. `truncated` is true
    /// when the ring has already overwritten the head of the history
    /// (the first surviving event is not `submit`).
    pub fn trace_json(&self, id: u64) -> Json {
        let mut events: Vec<Recorded> = Vec::new();
        for shard in &self.shards {
            let ring = shard.lock().unwrap();
            events.extend(ring.buf.iter().filter(|r| r.id == id).cloned());
        }
        events.sort_by_key(|r| r.seq);
        let truncated = events
            .first()
            .map(|r| r.event.kind() != "submit")
            .unwrap_or(false);
        Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("truncated", Json::Bool(truncated)),
            (
                "events",
                Json::Arr(events.iter().map(Recorded::to_json).collect()),
            ),
        ])
    }

    /// Append `id`'s merged timeline to the JSONL sink, if configured.
    /// Called by [`TraceWriter::finish`] right after the terminal event
    /// lands, so an exported line is always a complete trace.
    fn export(&self, id: u64) {
        let Some(sink) = &self.sink else {
            return;
        };
        let line = self.trace_json(id).emit();
        let mut w = sink.lock().unwrap();
        // Serving must not die on a full disk; drop the line instead.
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
}

/// A shard-bound handle for recording events — cheap to clone, one per
/// replica (plus the front). `owns_submit` marks the single writer
/// responsible for the `submit` event so a fleet engine behind a router
/// does not duplicate what the router already recorded.
#[derive(Clone, Debug)]
pub struct TraceWriter {
    tracer: Arc<Tracer>,
    replica: Option<usize>,
    owns_submit: bool,
}

impl TraceWriter {
    /// Rebind to replica `replica`'s shard, preserving `owns_submit`
    /// (used by `NativeEngine::start_replicas` to give each replica its
    /// own shard from one template writer).
    pub fn with_replica(&self, replica: usize) -> TraceWriter {
        TraceWriter {
            tracer: self.tracer.clone(),
            replica: Some(replica),
            owns_submit: self.owns_submit,
        }
    }

    /// Mark this writer as the `submit`-event owner (single-engine
    /// deployments, where the engine is the front).
    pub fn owning_submit(mut self) -> Self {
        self.owns_submit = true;
        self
    }

    /// Whether this writer records the `submit` event.
    pub fn owns_submit(&self) -> bool {
        self.owns_submit
    }

    /// The replica index events from this writer carry (`0` for the
    /// front shard, which also serves single-engine deployments).
    pub fn replica(&self) -> usize {
        self.replica.unwrap_or(0)
    }

    /// The shared tracer (for `trace_json` / merged reads).
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Record one event for request `id` on this writer's shard.
    pub fn record(&self, id: u64, event: TraceEvent) {
        let shard = self.replica.map(|r| r + 1).unwrap_or(0);
        self.tracer.record(shard, self.replica, id, event);
    }

    /// Record a terminal event and, when a JSONL sink is configured,
    /// export the request's completed timeline.
    pub fn finish(&self, id: u64, event: TraceEvent) {
        debug_assert!(event.is_terminal(), "finish() takes terminal events");
        self.record(id, event);
        if self.tracer.sampled(id) {
            self.tracer.export(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_cover_event_kinds_exactly() {
        let samples = [
            TraceEvent::Submit { class: 0 },
            TraceEvent::Queued { class: 1 },
            TraceEvent::Admit { replica: 0 },
            TraceEvent::Prefill { tokens: 3 },
            TraceEvent::DecodeRound {
                tokens: 1,
                total: 1,
                spec: false,
            },
            TraceEvent::Preempt { spilled: true },
            TraceEvent::Spill { pages: 2 },
            TraceEvent::Restore { pages: 2 },
            TraceEvent::Reroute { from: 0 },
            TraceEvent::Finish { tokens: 4 },
            TraceEvent::Fail {
                reason: "x".to_string(),
            },
        ];
        assert_eq!(samples.len(), EVENT_KINDS.len());
        for (ev, &kind) in samples.iter().zip(EVENT_KINDS.iter()) {
            assert_eq!(ev.kind(), kind);
        }
        assert!(samples.iter().filter(|e| e.is_terminal()).count() == 2);
    }

    #[test]
    fn ring_overwrites_oldest_and_flags_truncation() {
        let tracer = Tracer::new(
            1,
            TraceConfig {
                capacity: 4,
                ..TraceConfig::default()
            },
        )
        .unwrap();
        let w = tracer.writer(0).owning_submit();
        w.record(7, TraceEvent::Submit { class: 0 });
        for i in 0..6usize {
            w.record(
                7,
                TraceEvent::DecodeRound {
                    tokens: 1,
                    total: i + 1,
                    spec: false,
                },
            );
        }
        let t = tracer.trace_json(7);
        let events = t.get("events").as_arr().unwrap();
        assert_eq!(events.len(), 4, "ring bounds history");
        assert_eq!(t.get("truncated").as_bool(), Some(true));
        // The newest events survive.
        assert_eq!(events.last().unwrap().get("total").as_f64(), Some(6.0));
    }

    #[test]
    fn fleet_merge_sorts_by_global_sequence() {
        let tracer = Tracer::new(2, TraceConfig::default()).unwrap();
        let front = tracer.front_writer();
        let r0 = tracer.writer(0);
        let r1 = tracer.writer(1);
        front.record(3, TraceEvent::Submit { class: 2 });
        r0.record(3, TraceEvent::Queued { class: 2 });
        r0.record(3, TraceEvent::Admit { replica: 0 });
        front.record(3, TraceEvent::Reroute { from: 0 });
        r1.record(3, TraceEvent::Queued { class: 2 });
        let t = tracer.trace_json(3);
        assert_eq!(t.get("truncated").as_bool(), Some(false));
        let kinds: Vec<&str> = t
            .get("events")
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("kind").as_str().unwrap())
            .collect();
        assert_eq!(kinds, ["submit", "queued", "admit", "reroute", "queued"]);
        let replicas: Vec<Option<f64>> = t
            .get("events")
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("replica").as_f64())
            .collect();
        assert_eq!(replicas, [None, Some(0.0), Some(0.0), None, Some(1.0)]);
    }

    #[test]
    fn sampling_filters_whole_requests() {
        let tracer = Tracer::new(
            1,
            TraceConfig {
                sample_every: 2,
                ..TraceConfig::default()
            },
        )
        .unwrap();
        let w = tracer.writer(0).owning_submit();
        for id in 0..4u64 {
            w.record(id, TraceEvent::Submit { class: 0 });
            w.finish(id, TraceEvent::Finish { tokens: 0 });
        }
        for id in 0..4u64 {
            let n = tracer.trace_json(id).get("events").as_arr().unwrap().len();
            assert_eq!(n, if id % 2 == 0 { 2 } else { 0 }, "id {id}");
        }
    }

    #[test]
    fn jsonl_sink_gets_one_complete_line_per_terminal() {
        let path = std::env::temp_dir().join(format!(
            "quipsharp-trace-unit-{}.jsonl",
            std::process::id()
        ));
        let tracer = Tracer::new(
            1,
            TraceConfig {
                jsonl: Some(path.clone()),
                ..TraceConfig::default()
            },
        )
        .unwrap();
        let w = tracer.writer(0).owning_submit();
        w.record(5, TraceEvent::Submit { class: 0 });
        w.record(5, TraceEvent::Queued { class: 0 });
        w.record(5, TraceEvent::Admit { replica: 0 });
        w.finish(5, TraceEvent::Finish { tokens: 0 });
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1);
        let t = Json::parse(lines[0]).unwrap();
        assert_eq!(t.get("id").as_f64(), Some(5.0));
        assert_eq!(t.get("truncated").as_bool(), Some(false));
        assert_eq!(t.get("events").as_arr().unwrap().len(), 4);
    }
}
