//! L3 serving stack: request router, continuous batcher, KV-slot manager,
//! metrics, and a line-delimited JSON TCP API.
//!
//! The paper's thesis (§6.3) is that QuIP# makes *memory-bound decoding*
//! faster; this engine is where that shows up end-to-end. Two backends:
//!
//! * `native` — the Rust hot path (fused E8P decode / dense f32), lazily
//!   grown per-sequence KV caches, continuous batching at step granularity
//!   with *batch-native* decode: one `decode_batch` call per step decodes
//!   each packed codeword once and multiplies it against every active
//!   sequence, and freshly admitted prompts prefill in chunked slices.
//! * `pjrt` — the AOT JAX/Pallas artifacts executed through the PJRT
//!   runtime (lockstep batch; demonstrates the three-layer path).

pub mod engine;
pub mod metrics;
pub mod pjrt_engine;
pub mod server;

pub use engine::{Engine, EngineRequest, EngineResponse, NativeEngine};
pub use metrics::Metrics;
pub use server::{serve_blocking, Client, ServerConfig, ServerHandle};
