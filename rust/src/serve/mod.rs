//! L3 serving stack: request router, continuous batcher, paged KV pool,
//! metrics, and a line-delimited JSON TCP API.
//!
//! The paper's thesis (§6.3) is that QuIP# makes *memory-bound decoding*
//! faster; this engine is where that shows up end-to-end. Two backends:
//!
//! * `native` — the Rust hot path (fused E8P decode / dense f32) over a
//!   shared **paged KV pool** ([`crate::generation::paged`]): fixed-size
//!   pages, per-sequence page tables, allocation on demand, preemption
//!   under pressure. Continuous batching at step granularity with
//!   *batch-native* decode: one `decode_batch_paged` call per step
//!   decodes each packed codeword once, runs one fused blocked attention
//!   pass over every active sequence's page list, and freshly admitted
//!   prompts prefill in chunked slices.
//! * `pjrt` — the AOT JAX/Pallas artifacts executed through the PJRT
//!   runtime (lockstep batch; demonstrates the three-layer path).
//!
//! # Pool sizing knobs
//!
//! The native engine's KV capacity is set in *pages* of
//! [`crate::generation::paged::PAGE_ROWS`] token rows (one page holds K
//! and V for every layer over those rows, i.e.
//! `n_layers × 2 × PAGE_ROWS × d_model` f32 slots):
//!
//! * [`engine::NativeEngine::start`] sizes the pool for the worst case —
//!   `max_batch × paged::pages_per_seq(&cfg)` pages — so admission never
//!   has to preempt (the pre-paging behavior, at the pre-paging
//!   footprint).
//! * [`engine::NativeEngine::start_with_pool`] takes an explicit page
//!   count. Sizing below worst case **oversubscribes** KV: requests are
//!   admitted while any page is free (actual usage, not reserved ctx),
//!   and if an allocation fails mid-step the youngest active sequence is
//!   preempted — its pages return to the pool and its request requeues
//!   at the queue front. Decode is deterministic per request — greedy by
//!   construction, sampled via the position-keyed per-request RNG
//!   ([`crate::generation::sampling`]) — so the retry reproduces the
//!   same tokens and responses are unchanged; only latency shifts.
//! * Metrics expose `pool_pages`, `pages_in_use`, `peak_pages_in_use`,
//!   `preemptions`, and `requests_rejected` for tuning. The
//!   `bench_generation` pool-pressure sweep (`make bench-serve`) reports
//!   how far a half-sized pool over-admits versus worst-case
//!   reservation.
//!
//! # Prompt-prefix sharing
//!
//! Many-users-one-system-prompt workloads hit the pool hardest through
//! duplicated prefix KV. [`engine::Engine::register_prefix`] (TCP:
//! `{"cmd":"register_prefix","id":…,"tokens":[…]}`) registers a
//! reusable prefix; a request whose prompt starts with it — matched by
//! longest common token prefix, or pinned via the request's `prefix_id`
//! field — is admitted by *forking* the cached prefix: its page-table
//! entries alias the cached pages (refcounted, copy-on-write on first
//! divergent write) and only the unshared prompt remainder is
//! prefilled. Decode over aliased pages is bit-exact with unshared
//! decode, so responses never change — only pages and prefill compute
//! are saved. Metrics: `shared_pages` (gauge), `prefix_hits`,
//! `pages_saved`; the `bench_generation` shared-prefix sweep measures
//! the peak-page and throughput effect at N sequences over one prompt.
//! Under pool pressure, *cold* cached prefixes (pages referenced by no
//! live sequence) are unpinned LRU-first before any live sequence is
//! preempted (`prefix_evictions`); a later hit rebuilds the cache.
//!
//! # Self-speculative decoding
//!
//! A request carrying `speculate: k` (or an engine started with
//! [`engine::EngineOptions::speculate_k`] > 0) decodes through
//! draft/verify rounds ([`crate::generation::speculative`]): the RVQ
//! base-stage model embedded in every multi-stage quantization drafts
//! k tokens against its own KV (pages from the same pool), the full
//! model verifies all k + 1 positions in one chunked batched step, and
//! both KVs roll back to the last accepted token. The coupled accept
//! rule ([`crate::generation::speculative`]) keeps the response
//! **bit-identical** to plain decode in both greedy and sampled mode —
//! only throughput moves, reported via `tokens_drafted` /
//! `tokens_accepted` / `acceptance_rate` / `tokens_resampled`.
//! `benches/bench_speculative.rs` (`make bench-spec`) sweeps k × batch
//! on the shared-prefix workload, greedy and sampled.
//!
//! # Serving fleet
//!
//! `serve --replicas N` puts a [`router::Router`] in front of N
//! in-process engine replicas ([`engine::NativeEngine::start_replicas`])
//! that share one `Arc<QuantizedModel>` — packed codes and codebook
//! tables are never duplicated, so each extra replica costs only its KV
//! pool and scheduler thread. Routing (`--route prefix|rr|least-loaded`)
//! defaults to prefix-cache affinity with a load-based spill valve;
//! requests carry an SLO class (`priority`) that orders every replica's
//! queue and preemption; a dead or stalled replica is drained and its
//! requests re-routed (`requests_rerouted`), bitwise-identically —
//! decode is deterministic per request in both greedy and sampled mode,
//! so no routing, spill, preemption, or re-route decision can ever
//! change tokens (`rust/tests/router_e2e.rs` pins fleet output against
//! a single engine). `{"cmd":"stats"}` returns the fleet-merged
//! [`Metrics::merged`] view plus per-replica rows; see [`router`] and
//! `rust/src/serve/README.md`.
//!
//! # Observability
//!
//! Every request's lifecycle is recorded as typed span events
//! (`submit → queued → admit → prefill/decode rounds → preempt / spill
//! / restore / reroute → finish/fail`) in per-replica bounded ring
//! buffers ([`trace`]), read back fleet-merged through the `trace` TCP
//! command, exported as JSONL via `serve --trace-out`, and paired with
//! per-phase decode timings ([`crate::util::phase`]) in the `stats`
//! snapshot's `phases` block. See `ARCHITECTURE.md` ("Observability").

pub mod engine;
pub mod metrics;
pub mod pjrt_engine;
pub mod router;
pub mod server;
pub mod trace;

pub use crate::generation::sampling::SamplingParams;
pub use engine::{Engine, EngineOptions, EngineRequest, EngineResponse, NativeEngine};
pub use metrics::Metrics;
pub use router::{RoutePolicy, Router, RouterOptions};
pub use server::{serve_blocking, Client, ClientOptions, ServerConfig, ServerHandle};
pub use trace::{TraceConfig, TraceEvent, TraceWriter, Tracer, EVENT_KINDS};
