//! PJRT-backed batched decode: drives the AOT-lowered `{size}_decode_fp` /
//! `{size}_decode_e8p` artifacts (L2 JAX + L1 Pallas, compiled once) in a
//! lockstep batch of B sequences. Demonstrates the full three-layer path;
//! the native engine remains the latency-optimized default.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::generation::argmax;
use crate::model::Model;
use crate::qmodel::QuantizedModel;
use crate::runtime::{ArtDtype, HostTensor, Runtime};

/// Lockstep batched generator over a decode artifact.
pub struct PjrtBatchEngine<'a> {
    rt: &'a Runtime,
    artifact: String,
    /// Fixed leading inputs (weights / packed codes), in manifest order.
    fixed: Vec<HostTensor>,
    batch: usize,
    n_layers: usize,
    ctx: usize,
    heads: usize,
    head_dim: usize,
    vocab: usize,
}

impl<'a> PjrtBatchEngine<'a> {
    /// fp backend: weights are streamed from the native model's params in
    /// the manifest's input order.
    pub fn new_fp(rt: &'a Runtime, model: &Model, artifact: &str) -> Result<Self> {
        let spec = rt
            .manifest
            .artifacts
            .get(artifact)
            .with_context(|| format!("artifact {artifact}"))?;
        let mut fixed = Vec::new();
        for inp in &spec.inputs {
            match inp.name.as_str() {
                "token" | "pos" | "kv_k" | "kv_v" => break,
                name => {
                    let t = model.p(name);
                    fixed.push(HostTensor::F32(t.shape.clone(), t.data.clone()));
                }
            }
        }
        Self::finish(rt, model, artifact, fixed)
    }

    /// e8p backend: packed codes / scales / sign vectors from the
    /// quantized model plug into the artifact's runtime inputs.
    pub fn new_e8p(rt: &'a Runtime, qm: &QuantizedModel, artifact: &str) -> Result<Self> {
        let model = &qm.model;
        let spec = rt
            .manifest
            .artifacts
            .get(artifact)
            .with_context(|| format!("artifact {artifact}"))?;
        let mut per_layer: BTreeMap<&str, &crate::quant::pipeline::QuantizedLinear> =
            BTreeMap::new();
        for (k, v) in &qm.layers {
            per_layer.insert(k.as_str(), v);
        }
        let mut fixed = Vec::new();
        for inp in &spec.inputs {
            let name = inp.name.as_str();
            if matches!(name, "token" | "pos" | "kv_k" | "kv_v") {
                break;
            }
            if let Some((layer, field)) = name.rsplit_once('.') {
                if let Some(ql) = per_layer.get(layer) {
                    let p = ql.packed.as_ref().context("layer not packed (not an E8P method?)")?;
                    let t = match field {
                        "scales" => HostTensor::F32(
                            vec![p.stage_scales.len()],
                            p.stage_scales.clone(),
                        ),
                        "su" => HostTensor::F32(vec![p.su.len()], p.su.clone()),
                        "sv" => HostTensor::F32(vec![p.sv.len()], p.sv.clone()),
                        f if f.starts_with("codes") => {
                            let stage: usize = f["codes".len()..].parse()?;
                            let codes: Vec<i32> = p.stage_codes[stage]
                                .iter()
                                .map(|&c| c as i32)
                                .collect();
                            HostTensor::I32(inp.shape.clone(), codes)
                        }
                        other => bail!("unknown e8p input field {other}"),
                    };
                    fixed.push(t);
                    continue;
                }
            }
            // Plain fp parameter (embed, norms, head).
            let t = model.p(name);
            fixed.push(HostTensor::F32(t.shape.clone(), t.data.clone()));
        }
        Self::finish(rt, model, artifact, fixed)
    }

    fn finish(
        rt: &'a Runtime,
        model: &Model,
        artifact: &str,
        fixed: Vec<HostTensor>,
    ) -> Result<Self> {
        let spec = &rt.manifest.artifacts[artifact];
        // kv_k spec: (L, B, ctx, H, hd)
        let kv_spec = spec
            .inputs
            .iter()
            .find(|i| i.name == "kv_k")
            .context("artifact lacks kv_k input")?;
        let token_spec = spec
            .inputs
            .iter()
            .find(|i| i.name == "token")
            .context("artifact lacks token input")?;
        anyhow::ensure!(token_spec.dtype == ArtDtype::I32);
        Ok(PjrtBatchEngine {
            rt,
            artifact: artifact.to_string(),
            fixed,
            batch: kv_spec.shape[1],
            n_layers: kv_spec.shape[0],
            ctx: kv_spec.shape[2],
            heads: kv_spec.shape[3],
            head_dim: kv_spec.shape[4],
            vocab: model.cfg.vocab,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Lockstep batched generation: all prompts must share one length.
    /// Returns `max_new` generated tokens per sequence.
    pub fn generate_batch(&self, prompts: &[Vec<u8>], max_new: usize) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(!prompts.is_empty() && prompts.len() <= self.batch);
        let plen = prompts[0].len();
        anyhow::ensure!(
            prompts.iter().all(|p| p.len() == plen),
            "lockstep batch needs equal prompt lengths"
        );
        anyhow::ensure!(plen + max_new <= self.ctx, "exceeds artifact ctx");
        let b = self.batch;
        let kv_numel = self.n_layers * b * self.ctx * self.heads * self.head_dim;
        let kv_shape = vec![self.n_layers, b, self.ctx, self.heads, self.head_dim];
        let mut kv_k = vec![0.0f32; kv_numel];
        let mut kv_v = vec![0.0f32; kv_numel];
        let mut outs: Vec<Vec<u8>> = vec![Vec::new(); prompts.len()];
        let mut tokens: Vec<i32> = (0..b)
            .map(|i| prompts.get(i).map(|p| p[0] as i32).unwrap_or(0))
            .collect();
        let mut last_logits: Vec<f32> = Vec::new();
        for step in 0..plen + max_new - 1 {
            // Fixed inputs (weights / packed codes) are passed by
            // reference — the decode loop never clones them per step.
            let token_t = HostTensor::I32(vec![b], tokens.clone());
            let pos_t = HostTensor::I32(vec![], vec![step as i32]);
            let kv_k_t = HostTensor::F32(kv_shape.clone(), kv_k);
            let kv_v_t = HostTensor::F32(kv_shape.clone(), kv_v);
            let mut inputs: Vec<&HostTensor> = self.fixed.iter().collect();
            inputs.push(&token_t);
            inputs.push(&pos_t);
            inputs.push(&kv_k_t);
            inputs.push(&kv_v_t);
            let mut result = self.rt.execute_ref(&self.artifact, &inputs)?;
            // outputs: logits (B,V), kv_k', kv_v'
            let kv_v_out = result.pop().context("kv_v")?;
            let kv_k_out = result.pop().context("kv_k")?;
            let logits = result.pop().context("logits")?;
            kv_k = match kv_k_out {
                HostTensor::F32(_, d) => d,
                _ => bail!("kv dtype"),
            };
            kv_v = match kv_v_out {
                HostTensor::F32(_, d) => d,
                _ => bail!("kv dtype"),
            };
            last_logits = logits.as_f32()?.to_vec();
            // Next input token per lane.
            for lane in 0..b {
                let next = if step + 1 < plen {
                    prompts.get(lane).map(|p| p[step + 1] as i32).unwrap_or(0)
                } else {
                    let row = &last_logits[lane * self.vocab..(lane + 1) * self.vocab];
                    let t = argmax(row) as i32;
                    if lane < outs.len() {
                        outs[lane].push(t as u8);
                    }
                    t
                };
                tokens[lane] = next;
            }
        }
        let _ = last_logits;
        Ok(outs)
    }
}
